#!/usr/bin/env bash
# Tier-1 gate + bench smoke run.
#
#   scripts/ci.sh            full gate: build, tests, bench smoke
#   scripts/ci.sh --no-bench tier-1 only
#
# The bench smoke run fails loudly if the indexed placement path loses
# its edge over the linear-scan reference (< 5x at 1024 servers) and
# refreshes BENCH_scheduler.json / BENCH_hotpath.json in the repo root
# so the perf trajectory stays tracked.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

echo "== docs: cargo doc --no-deps (rustdoc warnings denied, incl. missing_docs in swept modules)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# Static determinism & accounting pass (docs/ANALYSIS.md): D1-D6 + C1
# over rust/src/ against the committed allowlist. Nonzero exit on any
# violation or stale allowlist entry — same tier as cargo test.
echo "== tier-1: zenix_lint (static determinism & accounting pass)"
cargo run --release --bin zenix_lint

# Clippy rides along where the component is installed (the offline
# image ships rustc/cargo only). Lint policy is committed: [lints]
# in Cargo.toml + clippy.toml thresholds.
if command -v cargo-clippy >/dev/null 2>&1; then
    echo "== tier-1: cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "== tier-1: clippy not installed; skipping (zenix_lint gate above still ran)"
fi

if [[ "${1:-}" == "--no-bench" ]]; then
    echo "CI gate passed (benches skipped)."
    exit 0
fi

echo "== driver smoke: 1k invocations / 20 apps, deterministic per seed"
drv1=$(cargo run --release --example multi_tenant -- --apps 20 --invocations 1000 --seed 7)
drv2=$(cargo run --release --example multi_tenant -- --apps 20 --invocations 1000 --seed 7)
dig1=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$drv1" | head -1)
dig2=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$drv2" | head -1)
if [[ -z "$dig1" || "$dig1" != "$dig2" ]]; then
    echo "FAIL: multi-tenant driver not deterministic per seed ('$dig1' vs '$dig2')" >&2
    exit 1
fi
savings=$(grep -oE 'alloc-savings vs faas-static: -?[0-9]+(\.[0-9]+)?' <<<"$drv1" | grep -oE '\-?[0-9]+(\.[0-9]+)?$' | head -1)
if [[ -z "$savings" ]]; then
    echo "FAIL: could not find the alloc-savings line in the driver output" >&2
    exit 1
fi
awk -v s="$savings" 'BEGIN { exit (s + 0 >= 50.0) ? 0 : 1 }' || {
    echo "FAIL: multi-tenant savings ${savings}% < 50% vs faas-static (paper: up to 90%)" >&2
    exit 1
}
echo "driver smoke passed: ${dig1}, ${savings}% allocated-memory savings vs faas-static"

# Pin the seeded digest across builds: the first toolchain-bearing run
# records it; every later run must reproduce it byte-identically (the
# allocation-free refactor contract — event order and accounting are
# load-bearing). Delete DRIVER_DIGEST.lock only with a PR that
# intentionally changes simulation semantics.
lock="DRIVER_DIGEST.lock"
if [[ -f "$lock" ]]; then
    if ! grep -qx "1k_seed7=${dig1}" "$lock"; then
        echo "FAIL: driver digest drifted: got ${dig1}, pinned $(cat "$lock")" >&2
        exit 1
    fi
    echo "driver digest matches pinned ${dig1}"
else
    echo "1k_seed7=${dig1}" > "$lock"
    echo "NOTE: pinned driver digest written to $lock — commit it."
fi

echo "== driver smoke: admission control (fifo must strictly beat reject under saturation)"
adm_args="--apps 20 --invocations 2000 --seed 7 --mean-iat 60 --burst 6"
rej_out=$(cargo run --release --example multi_tenant -- $adm_args --admission reject)
fifo_out=$(cargo run --release --example multi_tenant -- $adm_args --admission fifo \
    --max-wait-ms 120000 --max-depth 128)
# `|| true` keeps the -z diagnostics reachable under set -e -o pipefail
rej=$(grep -oE 'rejected=[0-9]+' <<<"$rej_out" | head -1 | tr -dc '0-9' || true)
frej=$(grep -oE 'rejected=[0-9]+' <<<"$fifo_out" | head -1 | tr -dc '0-9' || true)
fto=$(grep -oE 'timed_out=[0-9]+' <<<"$fifo_out" | head -1 | tr -dc '0-9' || true)
if [[ -z "$rej" || -z "$frej" || -z "$fto" ]]; then
    echo "FAIL: could not parse the admission: line from the driver output" >&2
    exit 1
fi
if (( rej == 0 )); then
    echo "FAIL: reject-policy smoke produced 0 rejections — the load no longer saturates; retune adm_args" >&2
    exit 1
fi
if (( frej + fto >= rej )); then
    echo "FAIL: fifo queueing must strictly reduce failed admissions: ${frej}+${fto} vs reject ${rej}" >&2
    exit 1
fi
echo "admission smoke passed: reject=${rej} vs fifo rejected=${frej}+timed_out=${fto}"

echo "== driver smoke: fairness (fair-share Jain index must strictly beat FIFO under skewed overload)"
# Weighted asymmetric overload: tenant 0 carries 8x the arrival weight
# of everyone else on a saturating bursty schedule. Global-oldest-first
# FIFO mirrors the arrival monopoly in its completions; the fair-share
# round-robin drain must report a strictly higher Jain index over
# per-tenant completions (ISSUE 5 acceptance).
fair_args="--apps 4 --invocations 2000 --seed 7 --mean-iat 30 --burst 8 --skew 8 --max-wait-ms 8000 --max-depth 256"
fifo_fair_out=$(cargo run --release --example multi_tenant -- $fair_args --admission fifo)
fair_out=$(cargo run --release --example multi_tenant -- $fair_args --admission fair)
fifo_q=$(grep -oE 'queued=[0-9]+' <<<"$fifo_fair_out" | head -1 | tr -dc '0-9' || true)
fifo_jain=$(grep -oE 'completion=[0-9.]+' <<<"$fifo_fair_out" | head -1 | cut -d= -f2 || true)
fair_jain=$(grep -oE 'completion=[0-9.]+' <<<"$fair_out" | head -1 | cut -d= -f2 || true)
if [[ -z "$fifo_jain" || -z "$fair_jain" || -z "$fifo_q" ]]; then
    echo "FAIL: could not parse the jain:/admission: lines from the driver output" >&2
    exit 1
fi
if (( fifo_q == 0 )); then
    echo "FAIL: fairness smoke never engaged the queue — the load no longer saturates; retune fair_args" >&2
    exit 1
fi
awk -v f="$fair_jain" -v q="$fifo_jain" 'BEGIN { exit (f + 0 > q + 0) ? 0 : 1 }' || {
    echo "FAIL: fair-share Jain index ${fair_jain} must strictly beat FIFO ${fifo_jain} under skewed overload" >&2
    exit 1
}
echo "fairness smoke passed: jain(fair)=${fair_jain} > jain(fifo)=${fifo_jain} under 8x skew"

echo "== driver smoke: chaos (seeded fault injection, digest-stable, >=90% recovery)"
# ISSUE 6: a faulted replay must be deterministic per seed, the
# zero-fault replay must be byte-identical to the chaos-free pinned
# digest (the fault RNG stream draws nothing at rate 0), and graph-cut
# recovery must complete >= 90% of the invocations faults strike.
chaos_args="--apps 20 --invocations 1000 --seed 7 --fault-rate 6 --repair-ms 5000"
chaos1=$(cargo run --release --example multi_tenant -- $chaos_args)
chaos2=$(cargo run --release --example multi_tenant -- $chaos_args)
cdig1=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$chaos1" | head -1)
cdig2=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$chaos2" | head -1)
if [[ -z "$cdig1" || "$cdig1" != "$cdig2" ]]; then
    echo "FAIL: faulted driver not deterministic per seed ('$cdig1' vs '$cdig2')" >&2
    exit 1
fi
nochaos=$(cargo run --release --example multi_tenant -- \
    --apps 20 --invocations 1000 --seed 7 --fault-rate 0 --repair-ms 5000)
ndig=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$nochaos" | head -1)
if [[ -z "$ndig" || "$ndig" != "$dig1" ]]; then
    echo "FAIL: zero-fault digest ${ndig} must be byte-identical to the chaos-free ${dig1}" >&2
    exit 1
fi
faulted=$(grep -oE 'faulted=[0-9]+' <<<"$chaos1" | head -1 | tr -dc '0-9' || true)
recovered=$(grep -oE ' recovered=[0-9]+' <<<"$chaos1" | head -1 | tr -dc '0-9' || true)
if [[ -z "$faulted" || -z "$recovered" ]]; then
    echo "FAIL: could not parse the chaos: line from the driver output" >&2
    exit 1
fi
if (( faulted == 0 )); then
    echo "FAIL: chaos smoke struck 0 in-flight invocations — the fault rate no longer bites; retune chaos_args" >&2
    exit 1
fi
awk -v f="$faulted" -v r="$recovered" 'BEGIN { exit (r + 0 >= 0.9 * (f + 0)) ? 0 : 1 }' || {
    echo "FAIL: graph-cut recovery completed only ${recovered}/${faulted} faulted invocations (< 90%)" >&2
    exit 1
}
echo "chaos smoke passed: ${cdig1} stable, zero-fault == pinned, recovered ${recovered}/${faulted}"

echo "== driver smoke: 100k invocations, streaming stats, wall-clock budget"
t0=$SECONDS
drv100k=$(cargo run --release --example multi_tenant -- \
    --apps 24 --invocations 100000 --seed 7 --streaming)
elapsed=$((SECONDS - t0))
dig100k=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$drv100k" | head -1)
if [[ -z "$dig100k" ]]; then
    echo "FAIL: 100k driver run produced no digest" >&2
    exit 1
fi
# Budget: the allocation-free loop targets ~55 µs/invocation; 100k
# invocations x 3 replayed systems plus build overhead must land well
# under 120 s of wall clock.
if (( elapsed > 120 )); then
    echo "FAIL: 100k-invocation driver took ${elapsed}s (> 120 s budget)" >&2
    exit 1
fi
sav100k=$(grep -oE 'alloc-savings vs faas-static: -?[0-9]+(\.[0-9]+)?' <<<"$drv100k" | grep -oE '\-?[0-9]+(\.[0-9]+)?$' | head -1)
awk -v s="${sav100k:-0}" 'BEGIN { exit (s + 0 >= 50.0) ? 0 : 1 }' || {
    echo "FAIL: 100k-invocation savings ${sav100k}% < 50% vs faas-static" >&2
    exit 1
}
echo "100k driver smoke passed in ${elapsed}s: ${dig100k}, ${sav100k}% savings"
echo "(zero-steady-state-alloc gate runs under tier-1: rust/tests/alloc_free.rs)"

echo "== driver smoke: parallel replay (sharded epoch loop, digest-identical at any worker count)"
# ISSUE 8: the epoch-barrier engine must reproduce the sequential
# digests bit-for-bit. Single-shard check: the 1k trace with --workers 4
# on the default single-rack cluster routes through the sharded engine
# (workers clamp to the rack count) and must still match the pinned
# sequential digest. Multi-shard check: on the 8-rack 100k trace,
# --workers 4 must be byte-identical to --workers 1, with the pair
# inside the same 120 s wall-clock budget as the sequential smoke.
par1k=$(cargo run --release --example multi_tenant -- \
    --apps 20 --invocations 1000 --seed 7 --workers 4)
pdig1k=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$par1k" | head -1)
if [[ -z "$pdig1k" || "$pdig1k" != "$dig1" ]]; then
    echo "FAIL: sharded-engine 1k digest ${pdig1k} must match the pinned sequential ${dig1}" >&2
    exit 1
fi
t0=$SECONDS
par_args="--apps 24 --invocations 100000 --seed 7 --streaming --racks 8"
seq100k=$(cargo run --release --example multi_tenant -- $par_args --workers 1)
par100k=$(cargo run --release --example multi_tenant -- $par_args --workers 4)
elapsed=$((SECONDS - t0))
sdig=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$seq100k" | head -1)
pdig=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$par100k" | head -1)
if [[ -z "$sdig" || "$sdig" != "$pdig" ]]; then
    echo "FAIL: parallel 100k digest ${pdig} != sequential ${sdig} (workers must never affect the digest)" >&2
    exit 1
fi
epochs=$(grep -oE 'epochs=[0-9]+' <<<"$par100k" | head -1 | tr -dc '0-9' || true)
pworkers=$(grep -oE 'workers=[0-9]+' <<<"$par100k" | head -1 | tr -dc '0-9' || true)
if [[ -z "$epochs" || -z "$pworkers" ]]; then
    echo "FAIL: could not parse the parallel: line from the driver output" >&2
    exit 1
fi
if (( pworkers != 4 || epochs == 0 )); then
    echo "FAIL: parallel smoke did not engage the sharded loop (workers=${pworkers}, epochs=${epochs})" >&2
    exit 1
fi
if (( elapsed > 120 )); then
    echo "FAIL: parallel 100k smoke pair took ${elapsed}s (> 120 s budget)" >&2
    exit 1
fi
echo "parallel smoke passed in ${elapsed}s: ${pdig} == sequential, workers=${pworkers}, epochs=${epochs}"

echo "== driver smoke: tiered cold starts (budget-0 pinned, pre-warm >=10x p99 vs always-cold)"
# ISSUE 9: a zero snapshot budget leaves the tiered-start layer off —
# the 1k digest must stay byte-identical to the pinned sequential
# digest — and at a fixed budget the predictive pre-warm policy must
# beat an always-cold fleet by >=10x on p99 start latency over the
# byte-identical arrival schedule (the coldstart: line).
cold_args="--apps 20 --invocations 1000 --seed 7"
off1k=$(cargo run --release --example multi_tenant -- $cold_args --snapshot-budget 0)
odig=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$off1k" | head -1)
if [[ -z "$odig" || "$odig" != "$dig1" ]]; then
    echo "FAIL: budget-0 tiered digest ${odig} must be byte-identical to the pinned ${dig1}" >&2
    exit 1
fi
coldref=$(cargo run --release --example multi_tenant -- $cold_args --always-cold)
warmed=$(cargo run --release --example multi_tenant -- $cold_args --snapshot-budget 8192 --prewarm)
cold_p99=$(grep -oE 'p99-start-ms=[0-9.]+' <<<"$coldref" | head -1 | cut -d= -f2 || true)
warm_p99=$(grep -oE 'p99-start-ms=[0-9.]+' <<<"$warmed" | head -1 | cut -d= -f2 || true)
prewarms=$(grep -oE 'prewarms=[0-9]+' <<<"$warmed" | head -1 | tr -dc '0-9' || true)
if [[ -z "$cold_p99" || -z "$warm_p99" || -z "$prewarms" ]]; then
    echo "FAIL: could not parse the coldstart: line from the driver output" >&2
    exit 1
fi
if (( prewarms == 0 )); then
    echo "FAIL: coldstart smoke never pre-warmed an image — the policy no longer engages; retune cold_args" >&2
    exit 1
fi
awk -v c="$cold_p99" -v w="$warm_p99" 'BEGIN { exit (w + 0 > 0 && (w + 0) * 10.0 <= c + 0) ? 0 : 1 }' || {
    echo "FAIL: pre-warmed p99 start ${warm_p99} ms must sit >=10x below always-cold ${cold_p99} ms" >&2
    exit 1
}
echo "coldstart smoke passed: budget-0 digest == pinned; p99 start ${warm_p99} ms vs always-cold ${cold_p99} ms"

echo "== driver smoke: workflow tenants (DAG-of-1 pinned, affinity beats blind on cross-rack bytes)"
# ISSUE 10: a DAG-of-1 workflow wraps every arrival in a single-stage
# DAG — nothing spawned, nothing handed off — so the 1k digest must
# stay byte-identical to the pinned sequential digest. With 3-stage
# pipelines on a 4-rack fleet, rack-affinity placement must strictly
# shrink cross-rack handoff traffic vs affinity-blind routing on the
# identical schedule (the workflow: line).
wf_args="--apps 20 --invocations 1000 --seed 7"
single1k=$(cargo run --release --example multi_tenant -- $wf_args --workflow single)
wfdig=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$single1k" | head -1)
if [[ -z "$wfdig" || "$wfdig" != "$dig1" ]]; then
    echo "FAIL: DAG-of-1 workflow digest ${wfdig} must be byte-identical to the pinned ${dig1}" >&2
    exit 1
fi
pipe_args="$wf_args --racks 4 --workflow pipeline --workflow-stages 3 --workflow-handoff 400"
aff_out=$(cargo run --release --example multi_tenant -- $pipe_args)
blind_out=$(cargo run --release --example multi_tenant -- $pipe_args --workflow-affinity off)
aff_xr=$(grep -oE 'cross-rack-mb=[0-9.]+' <<<"$aff_out" | head -1 | cut -d= -f2 || true)
blind_xr=$(grep -oE 'cross-rack-mb=[0-9.]+' <<<"$blind_out" | head -1 | cut -d= -f2 || true)
wf_done=$(grep -oE 'runs-completed=[0-9]+' <<<"$aff_out" | head -1 | tr -dc '0-9' || true)
if [[ -z "$aff_xr" || -z "$blind_xr" || -z "$wf_done" ]]; then
    echo "FAIL: could not parse the workflow: line from the driver output" >&2
    exit 1
fi
if (( wf_done == 0 )); then
    echo "FAIL: workflow smoke completed 0 workflow runs — the pipeline no longer engages; retune pipe_args" >&2
    exit 1
fi
awk -v a="$aff_xr" -v b="$blind_xr" 'BEGIN { exit (b + 0 > 0 && a + 0 < b + 0) ? 0 : 1 }' || {
    echo "FAIL: affinity cross-rack ${aff_xr} MB must sit strictly below blind ${blind_xr} MB" >&2
    exit 1
}
echo "workflow smoke passed: DAG-of-1 digest == pinned; cross-rack ${aff_xr} MB (affinity) < ${blind_xr} MB (blind), ${wf_done} runs completed"

echo "== bench smoke: scheduler (quick budget, json to repo root)"
out=$(mktemp)
ZENIX_BENCH_JSON=. cargo bench --bench scheduler -- --quick | tee "$out"

# Parse the "-> 1024 servers: indexed ... = N.Nx speedup" line.
speedup=$(grep -E '1024 servers' "$out" | grep -oE '[0-9]+(\.[0-9]+)?x speedup' | head -1 | tr -dc '0-9.')
if [[ -z "$speedup" ]]; then
    echo "FAIL: could not find the 1024-server indexed-vs-linear speedup line" >&2
    exit 1
fi
awk -v x="$speedup" 'BEGIN { exit (x + 0 >= 5.0) ? 0 : 1 }' || {
    echo "FAIL: indexed placement speedup ${speedup}x < 5x at 1024 servers (perf regression)" >&2
    exit 1
}
echo "indexed placement speedup at 1024 servers: ${speedup}x (>= 5x required)"

# ISSUE 3 acceptance: the 100k-invocation driver row must hold a ≥5x
# per-invocation improvement over the PR 2 projection (~300 µs/inv),
# i.e. ≤ 60 µs/invocation.
us_per_inv=$(grep -E '100k-invocation driver' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.')
if [[ -z "$us_per_inv" ]]; then
    echo "FAIL: could not find the 100k-invocation driver rate line" >&2
    exit 1
fi
awk -v x="$us_per_inv" 'BEGIN { exit (x + 0 <= 60.0) ? 0 : 1 }' || {
    echo "FAIL: driver at ${us_per_inv} µs/invocation > 60 µs (need ≥5x over the PR 2 ~300 µs/inv rate)" >&2
    exit 1
}
echo "driver per-invocation rate: ${us_per_inv} µs (<= 60 µs required)"

# ISSUE 4: the queued-100k row (FIFO deferred queue + MMPP bursts) must
# run and report a rate; its budget is advisory until measured once.
queued_rate=$(grep -E '100k-invocation queued driver' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.' || true)
if [[ -z "$queued_rate" ]]; then
    echo "FAIL: could not find the 100k-invocation queued driver row" >&2
    exit 1
fi
echo "queued driver per-invocation rate: ${queued_rate} µs (admission retries included)"

# ISSUE 5: the multi-rack 100k row (8 racks × 1 server, fixed total
# capacity) must be present, and sharding must stay within 1.5x of the
# single-rack per-invocation cost — the two-level scheduler's
# incremental feeds, not O(racks) rescans, carry the fan-out.
multirack_rate=$(grep -E '100k-invocation 8-rack driver' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.' || true)
if [[ -z "$multirack_rate" ]]; then
    echo "FAIL: could not find the 100k-invocation 8-rack (driver_100k_multirack) row" >&2
    exit 1
fi
awk -v m="$multirack_rate" -v s="$us_per_inv" 'BEGIN { exit (m + 0 <= 1.5 * (s + 0)) ? 0 : 1 }' || {
    echo "FAIL: 8-rack driver at ${multirack_rate} µs/invocation > 1.5x the single-rack ${us_per_inv} µs (sharding regression)" >&2
    exit 1
}
echo "multirack driver per-invocation rate: ${multirack_rate} µs (<= 1.5x single-rack ${us_per_inv} µs)"

# ISSUE 6: the faulted 100k row (6 faults/min, 5 s repairs) must be
# present and stay within 2x of the fault-free per-invocation cost —
# crash scans, recovery re-execution, and churn-driven index rebuilds
# ride the same allocation-free loop.
faulted_rate=$(grep -E '100k-invocation faulted driver' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.' || true)
if [[ -z "$faulted_rate" ]]; then
    echo "FAIL: could not find the 100k-invocation faulted (driver_100k_faulted) row" >&2
    exit 1
fi
awk -v m="$faulted_rate" -v s="$us_per_inv" 'BEGIN { exit (m + 0 <= 2.0 * (s + 0)) ? 0 : 1 }' || {
    echo "FAIL: faulted driver at ${faulted_rate} µs/invocation > 2x the fault-free ${us_per_inv} µs (recovery overhead regression)" >&2
    exit 1
}
echo "faulted driver per-invocation rate: ${faulted_rate} µs (<= 2x fault-free ${us_per_inv} µs)"

# ISSUE 9: the tiered 100k row (8 GiB/rack snapshot budget + pre-warm)
# must be present and stay within 1.2x of the untiered per-invocation
# cost — cache touches, snapshot restores and pre-warm passes ride the
# same allocation-free loop.
tiered_rate=$(grep -E '100k-invocation tiered driver' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.' || true)
if [[ -z "$tiered_rate" ]]; then
    echo "FAIL: could not find the 100k-invocation tiered (driver_100k_tiered) row" >&2
    exit 1
fi
awk -v m="$tiered_rate" -v s="$us_per_inv" 'BEGIN { exit (m + 0 <= 1.2 * (s + 0)) ? 0 : 1 }' || {
    echo "FAIL: tiered driver at ${tiered_rate} µs/invocation > 1.2x the untiered ${us_per_inv} µs (snapshot-layer overhead regression)" >&2
    exit 1
}
echo "tiered driver per-invocation rate: ${tiered_rate} µs (<= 1.2x untiered ${us_per_inv} µs)"

# ISSUE 10: the workflow 100k row (three-stage pipelines on four racks,
# rack-affinity placement) must be present and its per-*stage* cost
# must stay within 1.5x of the independent-arrival per-invocation rate
# — the row reports mean_ns over ~300k stage invocations, so the gate
# measures the DAG layer's bookkeeping (handoff ledgers, ready-stage
# scans, affinity preference checks), not the 3x stage fan-out.
workflow_rate=$(grep -E '100k-invocation workflow driver' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.' || true)
if [[ -z "$workflow_rate" ]]; then
    echo "FAIL: could not find the 100k-invocation workflow (driver_100k_workflow) row" >&2
    exit 1
fi
awk -v m="$workflow_rate" -v s="$us_per_inv" 'BEGIN { exit (m + 0 <= 1.5 * (s + 0)) ? 0 : 1 }' || {
    echo "FAIL: workflow driver at ${workflow_rate} µs/stage > 1.5x the independent-arrival ${us_per_inv} µs (DAG-layer overhead regression)" >&2
    exit 1
}
echo "workflow driver per-stage rate: ${workflow_rate} µs (<= 1.5x independent ${us_per_inv} µs)"

# ISSUE 8: the 1M-invocation parallel rows must be present for every
# worker count, and the 1-worker sharded run must hold the 60 µs/inv
# driver rate (epoch bookkeeping amortized). The 8-worker >=3x speedup
# target is advisory until first measured — scaling is hardware-bound;
# digest equality is the hard gate (parallel smoke above + tier-1).
for w in 1 2 4 8; do
    if ! grep -qE "1M-invocation parallel driver \(workers=${w}\)" "$out"; then
        echo "FAIL: could not find the driver_1m_parallel_w${w} row" >&2
        exit 1
    fi
done
par1m_w1=$(grep -E '1M-invocation parallel driver \(workers=1\)' "$out" | grep -oE '[0-9]+(\.[0-9]+)? µs/invocation' | head -1 | tr -dc '0-9.' || true)
if [[ -z "$par1m_w1" ]]; then
    echo "FAIL: could not parse the driver_1m_parallel_w1 rate" >&2
    exit 1
fi
awk -v x="$par1m_w1" 'BEGIN { exit (x + 0 <= 60.0) ? 0 : 1 }' || {
    echo "FAIL: 1M-invocation 1-worker driver at ${par1m_w1} µs/invocation > 60 µs (epoch-loop overhead regression)" >&2
    exit 1
}
speedup8=$(grep -E '1M-invocation parallel driver \(workers=8\)' "$out" | grep -oE '[0-9]+(\.[0-9]+)?x vs' | head -1 | tr -dc '0-9.' || true)
echo "1M parallel driver: ${par1m_w1} µs/inv at 1 worker; 8-worker speedup ${speedup8:-?}x (>= 3x target, advisory)"

echo "== bench smoke: hotpath (quick budget, json to repo root)"
ZENIX_BENCH_JSON=. cargo bench --bench hotpath -- --quick

echo "CI gate passed."
