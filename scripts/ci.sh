#!/usr/bin/env bash
# Tier-1 gate + bench smoke run.
#
#   scripts/ci.sh            full gate: build, tests, bench smoke
#   scripts/ci.sh --no-bench tier-1 only
#
# The bench smoke run fails loudly if the indexed placement path loses
# its edge over the linear-scan reference (< 5x at 1024 servers) and
# refreshes BENCH_scheduler.json / BENCH_hotpath.json in the repo root
# so the perf trajectory stays tracked.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

if [[ "${1:-}" == "--no-bench" ]]; then
    echo "CI gate passed (benches skipped)."
    exit 0
fi

echo "== driver smoke: 1k invocations / 20 apps, deterministic per seed"
drv1=$(cargo run --release --example multi_tenant -- --apps 20 --invocations 1000 --seed 7)
drv2=$(cargo run --release --example multi_tenant -- --apps 20 --invocations 1000 --seed 7)
dig1=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$drv1" | head -1)
dig2=$(grep -oE 'digest=0x[0-9a-f]+' <<<"$drv2" | head -1)
if [[ -z "$dig1" || "$dig1" != "$dig2" ]]; then
    echo "FAIL: multi-tenant driver not deterministic per seed ('$dig1' vs '$dig2')" >&2
    exit 1
fi
savings=$(grep -oE 'alloc-savings vs faas-static: -?[0-9]+(\.[0-9]+)?' <<<"$drv1" | grep -oE '\-?[0-9]+(\.[0-9]+)?$' | head -1)
if [[ -z "$savings" ]]; then
    echo "FAIL: could not find the alloc-savings line in the driver output" >&2
    exit 1
fi
awk -v s="$savings" 'BEGIN { exit (s + 0 >= 50.0) ? 0 : 1 }' || {
    echo "FAIL: multi-tenant savings ${savings}% < 50% vs faas-static (paper: up to 90%)" >&2
    exit 1
}
echo "driver smoke passed: ${dig1}, ${savings}% allocated-memory savings vs faas-static"

echo "== bench smoke: scheduler (quick budget, json to repo root)"
out=$(mktemp)
ZENIX_BENCH_JSON=. cargo bench --bench scheduler -- --quick | tee "$out"

# Parse the "-> 1024 servers: indexed ... = N.Nx speedup" line.
speedup=$(grep -E '1024 servers' "$out" | grep -oE '[0-9]+(\.[0-9]+)?x speedup' | head -1 | tr -dc '0-9.')
if [[ -z "$speedup" ]]; then
    echo "FAIL: could not find the 1024-server indexed-vs-linear speedup line" >&2
    exit 1
fi
awk -v x="$speedup" 'BEGIN { exit (x + 0 >= 5.0) ? 0 : 1 }' || {
    echo "FAIL: indexed placement speedup ${speedup}x < 5x at 1024 servers (perf regression)" >&2
    exit 1
}
echo "indexed placement speedup at 1024 servers: ${speedup}x (>= 5x required)"

echo "== bench smoke: hotpath (quick budget, json to repo root)"
ZENIX_BENCH_JSON=. cargo bench --bench hotpath -- --quick

echo "CI gate passed."
