//! Data-analytics scenario: TPC-DS queries on Zenix vs PyWren+Orion
//! (the paper's §6.1.1 headline comparison), plus a real PJRT-executed
//! groupby-aggregate stage.
//!
//!     cargo run --release --example analytics [dataset-GB]

use zenix::apps::tpcds;
use zenix::figures::{render, tpcds_figs};
use zenix::runtime::{manifest::find_artifact_dir, spawn_compute_service, Tensor};
use zenix::util::rng::Rng;

fn main() -> zenix::Result<()> {
    let gb: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);
    println!("TPC-DS at {gb} GB — Zenix vs PyWren+Orion\n");

    for (q, zenix, pywren) in tpcds_figs::fig08_09_tpcds(gb) {
        let title = format!("query {q}");
        println!("{}", render(&title, &[zenix.clone(), pywren.clone()]));
        println!(
            "  -> zenix saves {:.1}% memory GB·s, {:.2}x faster\n",
            zenix.mem_savings_vs(&pywren) * 100.0,
            zenix.speedup_vs(&pywren)
        );
    }

    // One real stage through PJRT: the analytics_stage artifact is the
    // segment-sum (groupby) kernel the TPC-DS stages run.
    let dir = find_artifact_dir()?;
    let (compute, _join) = spawn_compute_service(&dir)?;
    let (n, k, d) = (2048, 64, 32);
    let mut rng = Rng::new(5);
    let mut seg = vec![0f32; n * k];
    for i in 0..n {
        seg[i * k + rng.range(0, k)] = 1.0;
    }
    let x = Tensor::new((0..n * d).map(|_| rng.normal() as f32).collect(), vec![n, d]);
    let t0 = std::time::Instant::now();
    let (sums, counts, _means) =
        compute.analytics_stage(Tensor::new(seg, vec![n, k]), x)?;
    println!(
        "real PJRT analytics_stage: {n} rows -> {k} groups in {:.2} ms (checksum sums={:.1}, rows={})",
        t0.elapsed().as_secs_f64() * 1000.0,
        sums.data.iter().map(|v| v.abs()).sum::<f32>(),
        counts.data.iter().sum::<f32>() as usize,
    );
    compute.shutdown();

    let _ = tpcds::QUERIES; // the supported query list
    Ok(())
}
