//! END-TO-END VALIDATION (DESIGN.md): train logistic regression through
//! the full three-layer stack on a real synthetic workload.
//!
//!     cargo run --release --example train_e2e
//!
//! - L3 (rust coordinator): the Zenix platform schedules the annotated
//!   LR program — sizing, placement, materialization, history.
//! - L2/L1 (JAX + Pallas, AOT): the `train` component's hot loop is the
//!   real `lr_train_step` HLO artifact (blocked Pallas gradient kernel)
//!   executed via PJRT for a few hundred steps; `lr_eval` validates.
//!
//! The loss curve is logged (recorded in EXPERIMENTS.md) and the run
//! asserts loss decreases and accuracy crosses 90% — proving all layers
//! compose.

use zenix::apps::{lr, Invocation};
use zenix::coordinator::graph::ResourceGraph;
use zenix::coordinator::Platform;
use zenix::runtime::{manifest::find_artifact_dir, spawn_compute_service, Tensor};
use zenix::util::rng::Rng;

const N: usize = 1024;
const D: usize = 256;
const STEPS: usize = 300;
const LOG_EVERY: usize = 25;

fn main() -> zenix::Result<()> {
    // ---- platform run (L3): schedule the annotated program ------------
    let program = lr::program();
    let graph = ResourceGraph::from_program(&program)?;
    let mut platform = Platform::testbed();
    for _ in 0..3 {
        platform.invoke(&graph, Invocation::new(1.0))?;
    }
    let report = platform.invoke(&graph, Invocation::new(1.0))?;
    println!(
        "[L3] zenix scheduled {}: exec {:.2}s, {:.1} GB·s allocated ({:.0}% utilized), {:.0}% co-located",
        program.name,
        report.exec_ms / 1000.0,
        report.consumption.alloc_gb_s(),
        report.consumption.mem_utilization() * 100.0,
        report.local_fraction * 100.0,
    );

    // ---- real compute (L2/L1 via PJRT): the train component -----------
    let dir = find_artifact_dir()?;
    let (compute, _join) = spawn_compute_service(&dir)?;
    compute.warm("lr_train_step")?; // pre-launch (§5.2.1, runtime analogue)
    compute.warm("lr_eval")?;

    // synthetic separable-ish dataset (the paper's Cirrus port loads a
    // real CSV; the geometry is identical)
    let mut rng = Rng::new(2024);
    let w_true: Vec<f32> = (0..D).map(|_| rng.normal() as f32).collect();
    let mut xdata = vec![0f32; N * D];
    let mut ydata = vec![0f32; N];
    for i in 0..N {
        let mut dot = 0f32;
        for j in 0..D {
            let v = rng.normal() as f32;
            xdata[i * D + j] = v;
            dot += v * w_true[j];
        }
        ydata[i] = (dot + 0.1 * rng.normal() as f32 > 0.0) as u8 as f32;
    }
    let x = Tensor::new(xdata, vec![N, D]);
    let y = Tensor::new(ydata, vec![N, 1]);

    let mut w = Tensor::zeros(&[D, 1]);
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    let t0 = std::time::Instant::now();
    println!("[L1/L2] training {STEPS} steps via PJRT (lr_train_step.hlo.txt):");
    for step in 0..STEPS {
        let (w2, loss) = compute.lr_train_step(x.clone(), y.clone(), w, 1.5)?;
        w = w2;
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if step % LOG_EVERY == 0 || step == STEPS - 1 {
            println!("  step {step:>4}  loss {loss:.5}");
        }
    }
    let elapsed = t0.elapsed();
    let (val_loss, acc) = compute.lr_eval(x, y, w)?;
    println!(
        "[L1/L2] {} steps in {:.2}s ({:.1} steps/s) — final loss {:.5}, val loss {:.5}, accuracy {:.1}%",
        STEPS,
        elapsed.as_secs_f64(),
        STEPS as f64 / elapsed.as_secs_f64(),
        last_loss,
        val_loss,
        acc * 100.0
    );
    compute.shutdown();

    assert!(last_loss < 0.5 * first_loss, "loss must fall: {first_loss} -> {last_loss}");
    assert!(acc > 0.9, "accuracy must exceed 90%: {acc}");
    println!("train_e2e OK: all three layers compose.");
    Ok(())
}
