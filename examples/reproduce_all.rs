//! Regenerate every figure/table in the paper's evaluation
//! (DESIGN.md §5 experiment index) and write the rows to results/.
//!
//!     cargo run --release --example reproduce_all

use std::fmt::Write as _;
use std::fs;

use zenix::apps::lr;
use zenix::figures::{
    coldstart_figs, lr_figs, platform_figs, render, scaling_figs, tpcds_figs, video_figs,
    workflow_figs,
};

fn main() -> zenix::Result<()> {
    fs::create_dir_all("results")?;
    let mut index = String::new();

    let mut emit = |name: &str, body: String| {
        println!("=== {name} ===\n{body}");
        fs::write(format!("results/{name}.txt"), &body).expect("write result");
        let _ = writeln!(index, "- results/{name}.txt");
    };

    // Fig 3
    let mut s = String::new();
    let _ = writeln!(s, "stage                  workers   total MB");
    for (name, w, mb) in tpcds_figs::fig03_stage_variation() {
        let _ = writeln!(s, "{name:<22} {w:>7} {mb:>10.0}");
    }
    emit("fig03_stage_variation", s);

    // Fig 4
    let mut s = String::new();
    let _ = writeln!(s, "stage                  min MB    avg MB    max MB   max/min");
    for (name, min, avg, max) in tpcds_figs::fig04_input_variation() {
        let _ = writeln!(s, "{name:<22} {min:>8.0} {avg:>9.0} {max:>9.0} {:>8.1}x", max / min);
    }
    emit("fig04_input_variation", s);

    // Fig 7
    for (label, pro) in [("baseline", false), ("proactive", true)] {
        let mut s = String::new();
        for (ev, a, b) in platform_figs::fig07_startup_flow(pro) {
            let _ = writeln!(s, "{ev:<34} {a:>8.0} -> {b:>8.0} ms");
        }
        emit(&format!("fig07_startup_flow_{label}"), s);
    }

    // Figs 8+9
    let mut s = String::new();
    for (q, z, w) in tpcds_figs::fig08_09_tpcds(20.0) {
        let _ = writeln!(s, "{}", render(&format!("TPC-DS Q{q} (20 GB)"), &[z, w]));
    }
    emit("fig08_09_tpcds", s);

    // Fig 10
    emit("fig10_ablation_tpcds", render("Q16 ablation", &tpcds_figs::fig10_ablation(20.0)));

    // Figs 11-13
    let mut s = String::new();
    for (res, rows) in video_figs::fig11_13_video() {
        let _ = writeln!(s, "{}", render(res, &rows));
    }
    emit("fig11_13_video", s);

    // Fig 14
    emit("fig14_ablation_video", render("720P ablation", &video_figs::fig14_ablation()));

    // Figs 15-17
    emit(
        "fig15_lr_small",
        render("LR 12 MB input", &lr_figs::fig15_16_lr(lr::SMALL_INPUT_MB)),
    );
    emit(
        "fig16_lr_large",
        render("LR 44 MB input", &lr_figs::fig15_16_lr(lr::LARGE_INPUT_MB)),
    );
    let rows = lr_figs::fig17_breakdown();
    let mut s = String::new();
    let _ = writeln!(s, "{:<18} {:>9} {:>9} {:>9} {:>9} {:>9}", "system", "compute", "startup", "io", "serde", "sched");
    for r in &rows {
        let b = &r.breakdown;
        let _ = writeln!(
            s,
            "{:<18} {:>8.2}s {:>8.2}s {:>8.2}s {:>8.2}s {:>8.2}s",
            r.system,
            b.compute_ms / 1000.0,
            b.startup_ms / 1000.0,
            b.io_ms / 1000.0,
            b.serialize_ms / 1000.0,
            b.sched_ms / 1000.0
        );
    }
    emit("fig17_lr_breakdown", s);

    // Fig 18
    let mut s = String::new();
    for (label, rows) in lr_figs::fig18_scaling_tech() {
        let _ = writeln!(s, "{}", render(label, &rows));
    }
    emit("fig18_scaling_tech", s);

    // Figs 19+20
    let mut s = String::new();
    for (gb, z, w) in tpcds_figs::fig19_20_q1_inputs() {
        let _ = writeln!(
            s,
            "{gb:>5} GB: zenix {:>8.1} GB·s / {:>7.2}s   pywren {:>8.1} GB·s / {:>7.2}s   (saves {:.0}%, {:.1}x)",
            z.consumption.alloc_gb_s(),
            z.exec_ms / 1000.0,
            w.consumption.alloc_gb_s(),
            w.exec_ms / 1000.0,
            z.mem_savings_vs(&w) * 100.0,
            z.speedup_vs(&w)
        );
    }
    emit("fig19_20_q1_inputs", s);

    // Fig 21
    let mut s = String::new();
    for (senders, gb, local, remote, disagg) in tpcds_figs::fig21_placement() {
        let _ = writeln!(s, "--- {senders} senders, {gb:.1} GB total");
        let mut rows = vec![local, remote, disagg];
        rows[0].system = "local".into();
        rows[1].system = "remote-scale".into();
        rows[2].system = "disagg".into();
        let _ = writeln!(s, "{}", render("placement", &rows));
    }
    emit("fig21_placement", s);

    // Fig 22
    let mut s = String::new();
    let _ = writeln!(s, "{:<10} {:<16} {:>12} {:>12}", "trace", "strategy", "mem-util", "slowdown");
    for (arch, strat, util, slow) in platform_figs::fig22_sizing() {
        let _ = writeln!(s, "{arch:<10} {strat:<16} {:>11.0}% {slow:>12.3}", util * 100.0);
    }
    emit("fig22_sizing", s);

    // Fig 23
    let mut s = String::new();
    for (name, ms) in platform_figs::fig23_comm_startup() {
        let _ = writeln!(s, "{name:<26} {ms:>8.0} ms");
    }
    emit("fig23_comm_startup", s);

    // Fig 25
    let mut s = String::new();
    let _ = writeln!(s, "{:>8} {:>6} {:>9} {:>12} {:>10}", "array MB", "pat", "cache MB", "time ms", "overhead");
    for (mb, pat, cache, ms, ovh) in platform_figs::fig25_swap() {
        let _ = writeln!(s, "{mb:>8.0} {pat:>6} {cache:>9.0} {ms:>12.1} {:>9.1}%", ovh * 100.0);
    }
    emit("fig25_swap", s);

    // Fig 26
    let mut s = String::new();
    let _ = writeln!(s, "{:<10} {:>9} {:>9} {:>9}", "archetype", "p10 MB", "p50 MB", "p90 MB");
    for (a, p10, p50, p90) in platform_figs::fig26_trace_dists() {
        let _ = writeln!(s, "{a:<10} {p10:>9.0} {p50:>9.0} {p90:>9.0}");
    }
    emit("fig26_trace_dists", s);

    // Figs 27+28
    let mut s = String::new();
    for (app, z, ow) in platform_figs::fig27_28_small_apps() {
        let _ = writeln!(s, "{}", render(app, &[z, ow]));
    }
    emit("fig27_28_small_apps", s);

    // startup table
    let mut s = String::new();
    for (name, ms) in platform_figs::tab_startup_latency() {
        let _ = writeln!(s, "{name:<26} {ms:>8.0} ms");
    }
    emit("tab_startup_latency", s);

    // Fig 30
    let mut s = String::new();
    let _ = writeln!(s, "{:<12} {:>12} {:>12}", "system", "makespan s", "mem-util");
    for (name, makespan, util) in platform_figs::fig30_cluster_util(30) {
        let _ = writeln!(s, "{name:<12} {makespan:>12.1} {:>11.0}%", util * 100.0);
    }
    emit("fig30_cluster_util", s);

    // worker-scaling sweep (epoch-barrier parallel replay; the digest
    // column is identical down the whole table by construction)
    emit(
        "fig_worker_scaling",
        scaling_figs::render_scaling(
            "parallel replay, 4 racks",
            &scaling_figs::fig_worker_scaling(6, 240, 9, 4, &[1, 2, 4, 8]),
        ),
    );

    // cold-start-vs-cache-size sweep (tiered start model; row 0 is the
    // always-cold reference, the p99 start tail collapses with budget)
    emit(
        "fig_coldstart_cache",
        coldstart_figs::render_coldstart(
            "cold-start tail vs snapshot-cache budget",
            &coldstart_figs::fig_coldstart_cache(6, 240, 9, &[256, 1024, 8192]),
        ),
    );

    // workflow-tenant sweep (rack-affinity vs blind stage placement on
    // the identical schedule, per handoff size)
    emit(
        "fig_workflow_affinity",
        workflow_figs::render_workflow(
            "workflow stage placement, 4 racks",
            &workflow_figs::fig_workflow_affinity(6, 240, 17, &[100.0, 400.0, 900.0]),
        ),
    );

    // workflow apps vs the function-DAG baseline (PyWren parameters)
    emit(
        "fig_workflow_vs_dag",
        workflow_figs::render_workflow_baseline(
            "workflow apps vs function-DAG baseline",
            &workflow_figs::fig_workflow_vs_function_dag(180, 11, 300.0),
        ),
    );

    fs::write("results/INDEX.md", index)?;
    println!("all figures regenerated under results/");
    Ok(())
}
