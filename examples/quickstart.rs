//! Quickstart: deploy an annotated bulky application and watch Zenix
//! adapt its resources per invocation.
//!
//!     cargo run --release --example quickstart
//!
//! Deploys the Cirrus-ported logistic-regression program (4 `@compute`
//! + 3 `@data` annotations), invokes it with the paper's two input
//! sizes, and shows how the platform sizes/places components per
//! invocation — plus one real PJRT-executed training step to prove the
//! compute path is live.

use zenix::apps::{lr, Invocation};
use zenix::coordinator::graph::ResourceGraph;
use zenix::coordinator::Platform;
use zenix::metrics::print_table;
use zenix::runtime::{manifest::find_artifact_dir, spawn_compute_service, Tensor};
use zenix::util::rng::Rng;

fn main() -> zenix::Result<()> {
    // 1. Deploy: annotated program -> resource graph (offline part).
    let program = lr::program();
    let graph = ResourceGraph::from_program(&program)?;
    println!(
        "deployed {:?}: {} compute + {} data components, {} waves",
        program.name,
        graph.n_compute(),
        graph.n_data(),
        graph.waves().len()
    );

    // 2. Invoke with the paper's two inputs; the platform adapts sizing
    //    and placement per invocation (warm the history first, as the
    //    paper's sampling-based profiler does).
    let mut platform = Platform::testbed();
    let mut rows = Vec::new();
    for (label, mb) in [("12 MB input", lr::SMALL_INPUT_MB), ("44 MB input", lr::LARGE_INPUT_MB)] {
        let scale = lr::scale_for_mb(mb);
        for _ in 0..3 {
            platform.invoke(&graph, Invocation::new(scale))?;
        }
        let mut r = platform.invoke(&graph, Invocation::new(scale))?;
        r.system = format!("zenix ({label})").into();
        println!(
            "{label}: exec {:.2}s, peak {:.0} MB / {:.0} vCPU, {:.0}% co-located",
            r.exec_ms / 1000.0,
            r.peak_mem_mb,
            r.peak_cpu,
            r.local_fraction * 100.0
        );
        rows.push(r);
    }
    print_table("quickstart: per-invocation adaptation", &rows);

    // 3. One real PJRT training step through the AOT artifact (the same
    //    compute the `train` component's hot loop runs).
    let dir = find_artifact_dir()?;
    let (compute, _join) = spawn_compute_service(&dir)?;
    let mut rng = Rng::new(1);
    let (n, d) = (1024, 256);
    let x = Tensor::new((0..n * d).map(|_| rng.normal() as f32).collect(), vec![n, d]);
    let y = Tensor::new((0..n).map(|_| (rng.f32() > 0.5) as u8 as f32).collect(), vec![n, 1]);
    let w = Tensor::zeros(&[d, 1]);
    let (_, loss) = compute.lr_train_step(x, y, w, 1.0)?;
    println!("\nreal PJRT lr_train_step executed: initial loss = {loss:.4} (ln 2 ≈ 0.6931)");
    compute.shutdown();
    Ok(())
}
