//! Video-transcode scenario: Zenix vs ExCamera vs gg vs native vpxenc
//! (the paper's §6.1.2), plus a real PJRT-executed encode of a frame's
//! 8x8 blocks.
//!
//!     cargo run --release --example video_pipeline

use zenix::figures::{render, video_figs};
use zenix::runtime::{manifest::find_artifact_dir, spawn_compute_service, Tensor};
use zenix::util::rng::Rng;

fn main() -> zenix::Result<()> {
    println!("1-minute transcode (Sintel-like), three resolutions\n");
    for (res, rows) in video_figs::fig11_13_video() {
        println!("{}", render(res, &rows));
        let zenix = &rows[0];
        let gg = &rows[2];
        println!(
            "  -> zenix vs gg: {:.1}% less memory, {:.2}x faster\n",
            zenix.mem_savings_vs(gg) * 100.0,
            zenix.speedup_vs(gg)
        );
    }

    // Real encode of one frame's blocks through the AOT video_block
    // artifact (blocked Pallas DCT+quantize kernel).
    let dir = find_artifact_dir()?;
    let (compute, _join) = spawn_compute_service(&dir)?;
    let b = 256; // one 128x128 tile = 256 8x8 blocks
    let mut rng = Rng::new(8);
    let blocks = Tensor::new(
        (0..b * 64).map(|_| rng.uniform(0.0, 255.0) as f32).collect(),
        vec![b, 8, 8],
    );
    // JPEG-ish luma quant table scaled flat for simplicity
    let q = Tensor::new(vec![16.0; 64], vec![8, 8]);
    let t0 = std::time::Instant::now();
    let (coefs, mse) = compute.video_block(blocks, q)?;
    let nonzero = coefs.data.iter().filter(|&&v| v != 0.0).count();
    println!(
        "real PJRT video_block: {b} blocks encoded in {:.2} ms — {:.1}% coefficients retained, reconstruction MSE {:.2}",
        t0.elapsed().as_secs_f64() * 1000.0,
        nonzero as f64 / coefs.data.len() as f64 * 100.0,
        mse
    );
    compute.shutdown();
    Ok(())
}
