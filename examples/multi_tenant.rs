//! Multi-tenant trace-driven load on one shared Zenix cluster.
//!
//!     cargo run --release --example multi_tenant -- \
//!         --apps 20 --invocations 1000 --seed 7 --archetype average
//!
//! `--streaming` switches the report path to O(apps)-memory streaming
//! statistics (moments + P² quantiles) — use it for 100k+ invocation
//! traces; the digest is identical to the exact-storage default.
//!
//! Registers N applications (the bulky evaluation programs plus
//! synthetic apps shaped by an Azure usage archetype), draws a
//! deterministic Poisson arrival schedule, and dispatches the
//! overlapping invocations against one platform — then replays the
//! *identical* schedule through the peak-provision ablation and a
//! statically-sized FaaS baseline to reproduce the paper's Fig 22/26-
//! style allocated-memory savings. The final `digest=` line is stable
//! per seed (checked by `scripts/ci.sh`).

use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
use zenix::trace::Archetype;

fn arg_value(args: &[String], i: usize, flag: &str) -> String {
    args.get(i + 1)
        .unwrap_or_else(|| {
            eprintln!("{flag} needs a value");
            std::process::exit(2)
        })
        .clone()
}

fn main() {
    let mut apps = 20usize;
    let mut invocations = 1000usize;
    let mut seed = 7u64;
    let mut arch = Archetype::Average;
    let mut exact_stats = true;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--streaming" => {
                exact_stats = false;
                i += 1;
            }
            "--apps" => {
                apps = arg_value(&args, i, "--apps").parse().expect("--apps N");
                i += 2;
            }
            "--invocations" => {
                invocations = arg_value(&args, i, "--invocations")
                    .parse()
                    .expect("--invocations N");
                i += 2;
            }
            "--seed" => {
                seed = arg_value(&args, i, "--seed").parse().expect("--seed N");
                i += 2;
            }
            "--archetype" => {
                let name = arg_value(&args, i, "--archetype");
                arch = *Archetype::ALL
                    .iter()
                    .find(|a| a.name() == name)
                    .unwrap_or_else(|| {
                        eprintln!("unknown archetype {name}");
                        std::process::exit(2)
                    });
                i += 2;
            }
            other => {
                eprintln!("unknown argument {other}");
                std::process::exit(2);
            }
        }
    }

    println!(
        "multi-tenant driver: {apps} apps, {invocations} invocations, \
         archetype={}, seed={seed}, stats={}",
        arch.name(),
        if exact_stats { "exact" } else { "streaming (O(apps) memory)" }
    );
    let mix = standard_mix(apps, arch);
    let cfg = DriverConfig { seed, invocations, exact_stats, ..DriverConfig::default() };
    let driver = MultiTenantDriver::new(&mix, cfg);
    let out = driver.run_comparison();

    println!("\n### zenix per-app (overlapping on one cluster)");
    println!(
        "{:<22} {:>5} {:>5} {:>10} {:>10} {:>12} {:>6} {:>12}",
        "app", "done", "fail", "mean (s)", "p95 (s)", "mem GB·s", "warm%", "growths e→l"
    );
    for a in &out.zenix.apps {
        let total = (a.warm_hits + a.cold_starts).max(1);
        println!(
            "{:<22} {:>5} {:>5} {:>10.2} {:>10.2} {:>12.1} {:>5.0}% {:>6.2}→{:<5.2}",
            a.name,
            a.completed,
            a.failed,
            a.mean_exec_ms / 1000.0,
            a.p95_exec_ms / 1000.0,
            a.consumption.alloc_gb_s(),
            a.warm_hits as f64 / total as f64 * 100.0,
            a.early_growths_per_inv,
            a.late_growths_per_inv,
        );
    }

    println!("\n### fleet (identical arrival schedule per system)");
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "system", "mem GB·s", "used GB·s", "makespan s", "completed", "in-flight"
    );
    for r in [&out.zenix, &out.peak, &out.faas] {
        println!(
            "{:<16} {:>12.1} {:>12.1} {:>12.1} {:>10} {:>10}",
            r.system,
            r.fleet.alloc_gb_s(),
            r.fleet.used_gb_s(),
            r.makespan_ms / 1000.0,
            r.completed,
            r.max_in_flight,
        );
    }

    println!(
        "\nwarm-pool: {} hits / {} cold starts; peak overlap {} invocations",
        out.zenix.warm_hits, out.zenix.cold_starts, out.zenix.max_in_flight
    );
    println!(
        "alloc-savings vs faas-static: {:.1}% (same completed work; paper reports up to 90%)",
        out.gated_savings() * 100.0
    );
    println!(
        "alloc-savings vs peak-provision: {:.0}%",
        out.zenix.savings_vs(&out.peak) * 100.0
    );
    println!("zenix digest=0x{:016x}", out.zenix.digest);
}
