//! Multi-tenant trace-driven load on one shared Zenix cluster.
//!
//!     cargo run --release --example multi_tenant -- \
//!         --apps 20 --invocations 1000 --seed 7 --archetype average
//!
//! `--streaming` switches the report path to O(apps)-memory streaming
//! statistics (moments + P² quantiles) — use it for 100k+ invocation
//! traces; the digest is identical to the exact-storage default.
//!
//! Admission control & bursts:
//!
//! - `--admission reject|fifo|fair|wfair|deadline` picks the policy for
//!   arrivals the saturated cluster cannot admit (default `reject`,
//!   the digest-pinned behavior). The queueing policies park them in
//!   bounded per-tenant deferred queues (`--max-wait-ms`, `--max-depth`;
//!   for `deadline` the wait bound is the per-tenant SLO and eviction
//!   is earliest-deadline-first; `wfair` drains deficit-round-robin by
//!   `TenantApp::weight`) and drain on capacity-freeing events.
//! - `--burst MULT` switches the Poisson arrivals to a two-state MMPP
//!   whose ON-state rate is MULT× the OFF rate (same offered load,
//!   bursty), `--mean-iat MS` scales the offered load itself.
//!
//! Fairness & sharding:
//!
//! - `--skew MULT` multiplies tenant 0's arrival weight — the
//!   asymmetric-overload knob behind the `jain:` line `scripts/ci.sh`
//!   greps (Jain's index over per-tenant completions and
//!   goodput/demand ratios).
//! - `--racks R` reshards the cluster into R racks at fixed total
//!   capacity (the multi-rack sharding axis; the `routing:` line shows
//!   how the global scheduler's best-rack cache held up).
//!
//! Fault injection & churn:
//!
//! - `--fault-rate R` injects R seeded capacity faults per simulated
//!   minute (server crashes / transient compute crashes; add
//!   `--rack-outage` to make the capacity faults whole-rack outages).
//!   Struck invocations reroute through graph-cut recovery off the
//!   reliable message log; `--repair-ms MS` sets the churn repair
//!   delay. The `chaos:` line `scripts/ci.sh` greps reports the
//!   faulted/recovered split and recovery latency. `--fault-rate 0`
//!   (the default) is digest-identical to a build without the flags.
//!
//! Parallel replay:
//!
//! - `--workers N` replays the trace on the sharded epoch-barrier
//!   event loop with N worker threads (shards = racks, so pair it with
//!   `--racks`; N clamps to the rack count). The digest is identical
//!   to `--workers 1` by construction — the `parallel:` line
//!   `scripts/ci.sh` greps reports workers, epoch width, wall-clock
//!   and digest so CI can pin that equality. `--epoch-ms MS` bounds
//!   the epoch window (batching knob only; never affects the digest).
//!   With N > 1 the three system replays (zenix / peak-provision /
//!   faas) also run concurrently.
//!
//! Tiered cold starts:
//!
//! - `--snapshot-budget MB` gives every rack a byte-budgeted snapshot
//!   cache (LRU over per-app images, charged against rack memory):
//!   first environments tier into warm-pool hits, snapshot restores
//!   and residual cold boots. `--prewarm` turns on the predictive
//!   pre-warm policy (top-k images per rack by expected arrivals);
//!   `--always-cold` disables proactive start-up so every first
//!   environment pays the full reactive cold boot (the reference
//!   policy for the ≥10x p99 smoke in `scripts/ci.sh`, which greps
//!   the `coldstart:` line). Budget 0 (the default) leaves the layer
//!   off and the digest byte-identical to a build without the flags.
//!
//! Workflow tenants:
//!
//! - `--workflow single|pipeline|fanout` gives every tenant an
//!   inter-invocation DAG: each scheduled arrival runs the DAG's root
//!   and stage completions spawn the declared downstream invocations
//!   with data handoff (`--workflow-stages K` stages or fan-out width,
//!   `--workflow-handoff MB` per edge). `--workflow-affinity off`
//!   routes ready stages blind (smallest fit) instead of preferring
//!   the rack holding their resident inputs — the `workflow:` line
//!   `scripts/ci.sh` greps reports the cross-rack traffic and
//!   end-to-end latency both ways. `--workflow single` (a DAG of one
//!   stage) is digest-identical to no workflow at all, which CI pins
//!   against `DRIVER_DIGEST.lock`.
//!
//! Registers N applications (the bulky evaluation programs plus
//! synthetic apps shaped by an Azure usage archetype), draws a
//! deterministic arrival schedule, and dispatches the overlapping
//! invocations against one platform — then replays the *identical*
//! schedule through the peak-provision ablation and a statically-sized
//! FaaS baseline to reproduce the paper's Fig 22/26-style
//! allocated-memory savings. The final `digest=` line is stable per
//! seed and the `admission:` line is parsed by `scripts/ci.sh`.

use zenix::coordinator::admission::{AdmissionPolicy, ArrivalModel};
use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
use zenix::coordinator::faults::FaultConfig;
use zenix::coordinator::{Workflow, ZenixConfig};
use zenix::trace::Archetype;

fn arg_value(args: &[String], i: usize, flag: &str) -> String {
    args.get(i + 1)
        .unwrap_or_else(|| {
            eprintln!("{flag} needs a value");
            std::process::exit(2)
        })
        .clone()
}

fn main() {
    let mut apps = 20usize;
    let mut invocations = 1000usize;
    let mut seed = 7u64;
    let mut arch = Archetype::Average;
    let mut exact_stats = true;
    let mut mean_iat_ms = 400.0f64;
    let mut admission_name = "reject".to_string();
    let mut max_wait_ms = 60_000.0f64;
    let mut max_depth = 64usize;
    let mut burst: Option<f64> = None;
    let mut skew = 1.0f64;
    let mut racks = 1usize;
    let mut fault_rate = 0.0f64;
    let mut repair_ms = 30_000.0f64;
    let mut rack_outage = false;
    let mut workers = 1usize;
    let mut epoch_ms = 250.0f64;
    let mut snapshot_budget_mb = 0u64;
    let mut prewarm = false;
    let mut always_cold = false;
    let mut workflow_shape: Option<String> = None;
    let mut wf_stages = 3usize;
    let mut wf_handoff_mb = 300.0f64;
    let mut wf_affinity = true;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--streaming" => {
                exact_stats = false;
                i += 1;
            }
            "--apps" => {
                apps = arg_value(&args, i, "--apps").parse().expect("--apps N");
                i += 2;
            }
            "--invocations" => {
                invocations = arg_value(&args, i, "--invocations")
                    .parse()
                    .expect("--invocations N");
                i += 2;
            }
            "--seed" => {
                seed = arg_value(&args, i, "--seed").parse().expect("--seed N");
                i += 2;
            }
            "--mean-iat" => {
                mean_iat_ms = arg_value(&args, i, "--mean-iat").parse().expect("--mean-iat MS");
                i += 2;
            }
            "--admission" => {
                admission_name = arg_value(&args, i, "--admission");
                i += 2;
            }
            "--max-wait-ms" => {
                max_wait_ms =
                    arg_value(&args, i, "--max-wait-ms").parse().expect("--max-wait-ms MS");
                i += 2;
            }
            "--max-depth" => {
                max_depth = arg_value(&args, i, "--max-depth").parse().expect("--max-depth N");
                i += 2;
            }
            "--burst" => {
                burst = Some(arg_value(&args, i, "--burst").parse().expect("--burst MULT"));
                i += 2;
            }
            "--skew" => {
                skew = arg_value(&args, i, "--skew").parse().expect("--skew MULT");
                i += 2;
            }
            "--racks" => {
                racks = arg_value(&args, i, "--racks").parse().expect("--racks R");
                i += 2;
            }
            "--fault-rate" => {
                fault_rate =
                    arg_value(&args, i, "--fault-rate").parse().expect("--fault-rate R");
                i += 2;
            }
            "--repair-ms" => {
                repair_ms = arg_value(&args, i, "--repair-ms").parse().expect("--repair-ms MS");
                i += 2;
            }
            "--rack-outage" => {
                rack_outage = true;
                i += 1;
            }
            "--workers" => {
                workers = arg_value(&args, i, "--workers").parse().expect("--workers N");
                i += 2;
            }
            "--epoch-ms" => {
                epoch_ms = arg_value(&args, i, "--epoch-ms").parse().expect("--epoch-ms MS");
                i += 2;
            }
            "--snapshot-budget" => {
                snapshot_budget_mb = arg_value(&args, i, "--snapshot-budget")
                    .parse()
                    .expect("--snapshot-budget MB");
                i += 2;
            }
            "--prewarm" => {
                prewarm = true;
                i += 1;
            }
            "--always-cold" => {
                always_cold = true;
                i += 1;
            }
            "--workflow" => {
                workflow_shape = Some(arg_value(&args, i, "--workflow"));
                i += 2;
            }
            "--workflow-stages" => {
                wf_stages = arg_value(&args, i, "--workflow-stages")
                    .parse()
                    .expect("--workflow-stages K");
                i += 2;
            }
            "--workflow-handoff" => {
                wf_handoff_mb = arg_value(&args, i, "--workflow-handoff")
                    .parse()
                    .expect("--workflow-handoff MB");
                i += 2;
            }
            "--workflow-affinity" => {
                wf_affinity = match arg_value(&args, i, "--workflow-affinity").as_str() {
                    "on" => true,
                    "off" => false,
                    other => {
                        eprintln!("--workflow-affinity on|off, got {other}");
                        std::process::exit(2);
                    }
                };
                i += 2;
            }
            "--archetype" => {
                let name = arg_value(&args, i, "--archetype");
                arch = *Archetype::ALL
                    .iter()
                    .find(|a| a.name() == name)
                    .unwrap_or_else(|| {
                        eprintln!("unknown archetype {name}");
                        std::process::exit(2)
                    });
                i += 2;
            }
            other => {
                eprintln!("unknown argument {other}");
                std::process::exit(2);
            }
        }
    }

    let admission = match admission_name.as_str() {
        "reject" => AdmissionPolicy::RejectImmediately,
        "fifo" => AdmissionPolicy::FifoQueue { max_wait_ms, max_depth },
        "fair" => AdmissionPolicy::FairShare { max_wait_ms, max_depth },
        "wfair" => AdmissionPolicy::WeightedFairShare { max_wait_ms, max_depth },
        "deadline" => AdmissionPolicy::Deadline { deadline_ms: max_wait_ms, max_depth },
        other => {
            eprintln!("unknown admission policy {other} (reject|fifo|fair|wfair|deadline)");
            std::process::exit(2);
        }
    };
    let arrivals = match burst {
        None => ArrivalModel::Poisson,
        Some(on_mult) => ArrivalModel::Mmpp {
            on_mult,
            mean_on_ms: 5_000.0,
            mean_off_ms: 15_000.0,
        },
    };

    println!(
        "multi-tenant driver: {apps} apps, {invocations} invocations, \
         archetype={}, seed={seed}, mean-iat={mean_iat_ms}ms, stats={}, \
         admission={admission_name}, arrivals={}, skew={skew}, racks={racks}",
        arch.name(),
        if exact_stats { "exact" } else { "streaming (O(apps) memory)" },
        if burst.is_some() { "mmpp" } else { "poisson" },
    );
    let mut mix = standard_mix(apps, arch);
    if skew != 1.0 && !mix.is_empty() {
        mix[0].weight *= skew;
    }
    if let Some(shape) = workflow_shape.as_deref() {
        let dag = match shape {
            "single" => Workflow::single(),
            "pipeline" => Workflow::pipeline(wf_stages, wf_handoff_mb),
            "fanout" => Workflow::fan_out_in(wf_stages, 0.6, wf_handoff_mb),
            other => {
                eprintln!("unknown workflow shape {other} (single|pipeline|fanout)");
                std::process::exit(2);
            }
        };
        for app in mix.iter_mut() {
            app.workflow = Some(dag.clone());
        }
    }
    let cfg = DriverConfig {
        seed,
        invocations,
        mean_iat_ms,
        exact_stats,
        admission,
        arrivals,
        faults: FaultConfig { rate_per_min: fault_rate, repair_ms, rack_outage },
        workers,
        epoch_ms,
        snapshot_budget_bytes: snapshot_budget_mb * 1024 * 1024,
        prewarm,
        workflow_affinity: wf_affinity,
        config: ZenixConfig { proactive: !always_cold, ..ZenixConfig::default() },
        ..DriverConfig::default()
    }
    .with_racks(racks);
    let driver = MultiTenantDriver::new(&mix, cfg);
    let wall = std::time::Instant::now();
    let out = if workers > 1 {
        // parallel mode also fans the three system replays out across
        // threads — digest-identical to the sequential comparison
        driver.run_comparison_with_workers(3)
    } else {
        driver.run_comparison()
    };
    let wall_ms = wall.elapsed().as_secs_f64() * 1e3;

    println!("\n### zenix per-app (overlapping on one cluster)");
    println!(
        "{:<22} {:>5} {:>5} {:>5} {:>5} {:>10} {:>10} {:>12} {:>6} {:>12}",
        "app", "done", "rej", "abrt", "t/o", "mean (s)", "p95 (s)", "mem GB·s", "warm%", "growths e→l"
    );
    for a in &out.zenix.apps {
        let total = (a.warm_hits + a.cold_starts).max(1);
        println!(
            "{:<22} {:>5} {:>5} {:>5} {:>5} {:>10.2} {:>10.2} {:>12.1} {:>5.0}% {:>6.2}→{:<5.2}",
            a.name,
            a.completed,
            a.rejected,
            a.aborted,
            a.timed_out,
            a.mean_exec_ms / 1000.0,
            a.p95_exec_ms / 1000.0,
            a.consumption.alloc_gb_s(),
            a.warm_hits as f64 / total as f64 * 100.0,
            a.early_growths_per_inv,
            a.late_growths_per_inv,
        );
    }

    println!("\n### fleet (identical arrival schedule per system)");
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "system", "mem GB·s", "used GB·s", "makespan s", "completed", "in-flight"
    );
    for r in [&out.zenix, &out.peak, &out.faas] {
        println!(
            "{:<16} {:>12.1} {:>12.1} {:>12.1} {:>10} {:>10}",
            r.system,
            r.fleet.alloc_gb_s(),
            r.fleet.used_gb_s(),
            r.makespan_ms / 1000.0,
            r.completed,
            r.max_in_flight,
        );
    }

    println!(
        "\nwarm-pool: {} hits / {} cold starts; peak overlap {} invocations",
        out.zenix.warm_hits, out.zenix.cold_starts, out.zenix.max_in_flight
    );
    // parsed by scripts/ci.sh: rejected= timed_out= must stay greppable
    println!(
        "admission: policy={admission_name} queued={} rejected={} aborted={} timed_out={} \
         depth-hwm={} mean-delay-ms={:.1} p95-delay-ms={:.1}",
        out.zenix.queued,
        out.zenix.rejected,
        out.zenix.aborted,
        out.zenix.timed_out,
        out.zenix.apps.iter().map(|a| a.queue_depth_hwm).max().unwrap_or(0),
        out.zenix.mean_queue_delay_ms,
        out.zenix.p95_queue_delay_ms,
    );
    // parsed by scripts/ci.sh: the fairness smoke compares completion=
    // across admission policies under a skewed overload
    println!(
        "jain: completion={:.4} goodput={:.4} (1.0 = perfectly fair, {:.3} = one tenant monopolizes)",
        out.zenix.jain_completion,
        out.zenix.jain_goodput,
        1.0 / apps.max(1) as f64,
    );
    println!(
        "routing: racks={racks} fast-hits={} scans={} (global-scheduler best-rack cache)",
        out.zenix.route_fast_hits, out.zenix.route_scans,
    );
    // parsed by scripts/ci.sh: the chaos smoke greps faulted= recovered=
    println!(
        "chaos: fault-rate={fault_rate} faulted={} recovered={} unrecovered={} \
         mean-recovery-ms={:.1} p95-recovery-ms={:.1}",
        out.zenix.faulted,
        out.zenix.recovered,
        out.zenix.faulted_unrecovered,
        out.zenix.mean_recovery_ms,
        out.zenix.p95_recovery_ms,
    );
    // parsed by scripts/ci.sh: the coldstart smoke greps p99-start-ms=
    // (and digest= at budget 0) across the tiered-start policies
    println!(
        "coldstart: budget-mb={snapshot_budget_mb} prewarm={prewarm} always-cold={always_cold} \
         started={} cold={} restored={} warm={} mean-start-ms={:.1} p95-start-ms={:.1} \
         p99-start-ms={:.1} hits={} misses={} evictions={} prewarms={}",
        out.zenix.started,
        out.zenix.tier_cold,
        out.zenix.tier_restored,
        out.zenix.tier_warm,
        out.zenix.mean_start_ms,
        out.zenix.p95_start_ms,
        out.zenix.p99_start_ms,
        out.zenix.snap_hits,
        out.zenix.snap_misses,
        out.zenix.snap_evictions,
        out.zenix.snap_prewarms,
    );
    // parsed by scripts/ci.sh: the workflow smoke compares
    // cross-rack-mb= across --workflow-affinity settings and pins the
    // --workflow single digest against DRIVER_DIGEST.lock
    println!(
        "workflow: shape={} affinity={} runs={} runs-completed={} stages-started={} \
         stages-completed={} spawned={} cross-rack-mb={:.1} e2e-mean-ms={:.1} \
         e2e-p95-ms={:.1} e2e-p99-ms={:.1} hits={} spills={}",
        workflow_shape.as_deref().unwrap_or("none"),
        if wf_affinity { "on" } else { "off" },
        out.zenix.wf_runs,
        out.zenix.wf_runs_completed,
        out.zenix.wf_stages_started,
        out.zenix.wf_stages_completed,
        out.zenix.wf_spawned,
        out.zenix.wf_cross_rack_mb,
        out.zenix.wf_e2e_mean_ms,
        out.zenix.wf_e2e_p95_ms,
        out.zenix.wf_e2e_p99_ms,
        out.zenix.wf_affinity_hits,
        out.zenix.wf_affinity_spills,
    );
    // parsed by scripts/ci.sh: the parallel smoke pins digest= equality
    // across --workers values (and against DRIVER_DIGEST.lock)
    println!(
        "parallel: workers={} epoch-ms={epoch_ms} epochs={} batches={} wall-ms={wall_ms:.1} \
         digest=0x{:016x}",
        out.zenix.workers, out.zenix.epochs, out.zenix.parallel_batches, out.zenix.digest,
    );
    println!(
        "alloc-savings vs faas-static: {:.1}% (same completed work; paper reports up to 90%)",
        out.gated_savings() * 100.0
    );
    println!(
        "alloc-savings vs peak-provision: {:.0}%",
        out.zenix.savings_vs(&out.peak) * 100.0
    );
    println!("zenix digest=0x{:016x}", out.zenix.digest);
}
