"""Zenix L2: JAX compute graphs for the bulky applications (build-time).

Each entry point here is a pure function lowered ONCE by `aot.py` to HLO
text and executed from the rust runtime (rust/src/runtime/) via PJRT.
Python never runs on the request path.

Entry points (shapes fixed at AOT time, see SPECS):

  lr_train_step  — one SGD step of binary logistic regression
                   (the Cirrus-ported ML app, paper §6.1.3)
  lr_eval        — loss + accuracy of a weight vector
  analytics_stage— groupby-aggregate stage (sum/count/mean), the
                   TPC-DS stage compute proxy (§6.1.1)
  video_block    — DCT+quantize encode of a batch of 8x8 blocks plus
                   reconstruction error (ExCamera proxy, §6.1.2)

All heavy inner loops call the L1 Pallas kernels (kernels/*) so the
paper's hot spots lower into the same HLO module.
"""

import jax
import jax.numpy as jnp

from .kernels import dct, lr, ref, segreduce


# ---------------------------------------------------------------------------
# Logistic regression (Cirrus port, §6.1.3)
# ---------------------------------------------------------------------------

def lr_train_step(x, y, w, step_size):
    """One SGD step. Returns (w_new, loss-before-step).

    x: (N, D) float32, y: (N, 1) float32 {0,1}, w: (D, 1) float32,
    step_size: () float32.

    Gradient and loss come from one fused Pallas pass over X (the loss
    reuses the forward logits — no second X@w matmul; §Perf).
    """
    grad, loss = lr.lr_grad_loss(x, w, y)
    w_new = w - step_size * grad
    return w_new, loss


def lr_eval(x, y, w):
    """Validation metrics. Returns (loss, accuracy)."""
    z = x @ w
    loss = jnp.mean(jnp.logaddexp(0.0, z) - y * z)
    pred = (z > 0.0).astype(jnp.float32)
    acc = jnp.mean((pred == y).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# Data analytics stage (TPC-DS proxy, §6.1.1)
# ---------------------------------------------------------------------------

def analytics_stage(seg_onehot, x):
    """Groupby-aggregate over K segments: (sums, counts, means).

    seg_onehot: (N, K) one-hot membership, x: (N, D) values.
    sums: (K, D), counts: (K, 1), means: (K, D).
    """
    sums = segreduce.segsum(seg_onehot, x)
    counts = jnp.sum(seg_onehot, axis=0, keepdims=True).T  # (K, 1)
    means = sums / jnp.maximum(counts, 1.0)
    return sums, counts, means


# ---------------------------------------------------------------------------
# Video block encode (ExCamera proxy, §6.1.2)
# ---------------------------------------------------------------------------

def video_block(blocks, q):
    """Encode a batch of 8x8 pixel blocks. Returns (coefs, mse).

    blocks: (B, 8, 8) float32 pixels, q: (8, 8) float32 quant table.
    coefs: quantized DCT coefficients; mse: () reconstruction error —
    the quality metric the transcode pipeline reports.
    """
    coefs = dct.dct_quant(blocks, q)
    recon = ref.idct_dequant_ref(coefs, q)
    mse = jnp.mean((recon - blocks) ** 2)
    return coefs, mse


# ---------------------------------------------------------------------------
# AOT specs: entry name -> (fn, example-arg shapes/dtypes)
# ---------------------------------------------------------------------------

# Batch geometry for the AOT artifacts. The rust runtime pads inputs to
# these shapes (zero rows are gradient-neutral for LR; empty segments and
# zero blocks are harmless for the other two).
LR_N, LR_D = 1024, 256
AN_N, AN_K, AN_D = 2048, 64, 32
VID_B = 256

_f32 = jnp.float32


def _s(shape):
    return jax.ShapeDtypeStruct(shape, _f32)


SPECS = {
    "lr_train_step": (
        lr_train_step,
        (_s((LR_N, LR_D)), _s((LR_N, 1)), _s((LR_D, 1)), _s(())),
    ),
    "lr_eval": (
        lr_eval,
        (_s((LR_N, LR_D)), _s((LR_N, 1)), _s((LR_D, 1))),
    ),
    "analytics_stage": (
        analytics_stage,
        (_s((AN_N, AN_K)), _s((AN_N, AN_D))),
    ),
    "video_block": (
        video_block,
        (_s((VID_B, 8, 8)), _s((8, 8))),
    ),
}
