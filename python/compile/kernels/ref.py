"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes and dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these references.

Zenix's bulky-application workloads map to three compute hot spots
(DESIGN.md §2 Hardware-Adaptation):

- logistic-regression gradient (the Cirrus-ported ML app, paper §6.1.3)
- segment-sum aggregation (the TPC-DS groupby/ReduceBy proxy, §6.1.1/§6.2)
- 8x8 blockwise DCT + quantization (the ExCamera transcode proxy, §6.1.2)
"""

import jax
import jax.numpy as jnp
import numpy as np


def lr_grad_ref(x, w, y):
    """Gradient of mean binary cross-entropy for logistic regression.

    x: (N, D) features, w: (D, 1) weights, y: (N, 1) labels in {0,1}.
    Returns (D, 1) gradient  X^T (sigmoid(Xw) - y) / N.
    """
    p = jax.nn.sigmoid(x @ w)
    return x.T @ (p - y) / x.shape[0]


def lr_loss_ref(x, w, y):
    """Mean binary cross-entropy, computed stably from logits."""
    z = x @ w
    # log(1 + e^z) - y*z, stable via logaddexp
    return jnp.mean(jnp.logaddexp(0.0, z) - y * z)


def segsum_ref(seg_onehot, x):
    """Segment-sum as a matmul: seg_onehot (N, K) one-hot rows, x (N, D).

    Returns (K, D) per-segment sums. This is the MXU formulation of a
    groupby-aggregate: S^T X instead of a hash/scatter aggregation.
    """
    return seg_onehot.T @ x


def dct_matrix(n=8, dtype=jnp.float32):
    """Orthonormal DCT-II basis matrix (n, n)."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * np.sqrt(2.0 / n)
    m[0, :] = 1.0 / np.sqrt(n)
    return jnp.asarray(m, dtype=dtype)


def dct_quant_ref(blocks, q):
    """Blockwise 2-D DCT-II followed by quantization.

    blocks: (B, 8, 8) pixel blocks; q: (8, 8) quantization table.
    Returns (B, 8, 8) quantized coefficients round(D b D^T / q).
    """
    d = dct_matrix(blocks.shape[-1], blocks.dtype)
    coef = jnp.einsum("ij,bjk,lk->bil", d, blocks, d)
    return jnp.round(coef / q)


def idct_dequant_ref(coefs, q):
    """Inverse of dct_quant_ref (up to quantization loss)."""
    d = dct_matrix(coefs.shape[-1], coefs.dtype)
    deq = coefs * q
    return jnp.einsum("ji,bjk,kl->bil", d, deq, d)
