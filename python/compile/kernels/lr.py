"""Pallas kernel: blocked logistic-regression gradient.

The paper's ML workload (§6.1.3, Cirrus-ported LR) spends its time in
X^T (sigmoid(X w) - y). On a GPU this would be a fused CUDA kernel; the
TPU re-think (DESIGN.md §2) tiles rows of X into VMEM and drives both
matmuls (forward X@w and backward X^T@residual) through the MXU, with the
(D, 1) accumulator resident in VMEM across the whole row-grid.

BlockSpec schedule:
  grid = (N // block_n,)
  x tile    : (block_n, D)   streamed HBM -> VMEM per grid step
  y tile    : (block_n, 1)   streamed
  w         : (D, 1)         resident (same block every step)
  out accum : (D, 1)         resident; revision i adds its partial sum

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
that the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-tile. 128 keeps the streamed tile MXU-aligned and the VMEM
# footprint small: for D=256 fp32, x-tile = 128*256*4 = 128 KiB.
DEFAULT_BLOCK_N = 128


def _lr_grad_kernel(x_ref, w_ref, y_ref, o_ref, loss_ref, *, n_total):
    """One row-block of gradient + loss; accumulates into both outputs.

    Computing the loss inside the kernel reuses the forward logits: one
    pass over X per step instead of two (EXPERIMENTS.md §Perf, L1/L2
    change — removes the duplicate X@w matmul from the train-step HLO).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]
    z = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    p = jax.nn.sigmoid(z)
    y = y_ref[...].astype(p.dtype)
    resid = (p - y) / n_total
    partial = jnp.dot(x.T.astype(p.dtype), resid,
                      preferred_element_type=jnp.float32)
    o_ref[...] += partial.astype(o_ref.dtype)
    # stable BCE on the already-computed logits: logaddexp(0, z) - y*z
    block_loss = jnp.sum(jnp.logaddexp(0.0, z) - y * z) / n_total
    loss_ref[...] += block_loss.reshape(1, 1).astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def lr_grad_loss(x, w, y, *, block_n=DEFAULT_BLOCK_N):
    """Blocked BCE gradient + mean loss in one pass over X.

    x: (N, D), w: (D, 1), y: (N, 1) -> ((D, 1) grad, () loss).

    N must be a multiple of block_n (the AOT entry points use padded
    batches; the runtime pads with zero rows whose labels are the
    sigmoid(0) fixpoint contribution — zero rows contribute zero gradient
    because x rows are zero).
    """
    n, d = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    grad, loss = pl.pallas_call(
        functools.partial(_lr_grad_kernel, n_total=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(x, w, y)
    return grad, loss.reshape(())


@functools.partial(jax.jit, static_argnames=("block_n",))
def lr_grad(x, w, y, *, block_n=DEFAULT_BLOCK_N):
    """Gradient only (see [`lr_grad_loss`])."""
    return lr_grad_loss(x, w, y, block_n=block_n)[0]
