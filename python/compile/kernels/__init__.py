"""Zenix L1: Pallas kernels for the bulky-application compute hot spots.

All kernels lower with interpret=True (CPU-PJRT-executable HLO). The
pure-jnp oracles live in `ref` and back the hypothesis sweeps in
python/tests/test_kernels.py.
"""

from . import dct, lr, ref, segreduce  # noqa: F401
