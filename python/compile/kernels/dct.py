"""Pallas kernel: 8x8 blockwise DCT-II + quantization.

The video-transcode workload (§6.1.2, ExCamera operators) reduces to a
per-block transform + quantize. On the TPU (DESIGN.md §2) each 8x8 block
transform D b D^T is two tiny matmuls; we batch `block_b` pixel blocks per
grid step so the MXU sees (block_b*8, 8) x (8, 8) shaped work and the DCT
basis + quant table stay resident in VMEM.

BlockSpec schedule:
  grid = (B // block_b,)
  blocks tile : (block_b, 8, 8) streamed
  d basis     : (8, 8)          resident
  q table     : (8, 8)          resident
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_B = 64


def _dct_quant_kernel(b_ref, d_ref, q_ref, o_ref):
    blocks = b_ref[...]          # (bb, 8, 8)
    d = d_ref[...]               # (8, 8)
    bb, n, _ = blocks.shape
    # D @ b @ D^T for the whole tile: fold batch into rows so both
    # contractions are plain 2-D matmuls (MXU-shaped).
    left = jnp.dot(blocks.reshape(bb * n, n), d.T,
                   preferred_element_type=jnp.float32)   # (bb*8, 8) = b D^T
    left = left.reshape(bb, n, n).transpose(0, 2, 1)     # (bb, 8, 8) = (b D^T)^T
    coef = jnp.dot(left.reshape(bb * n, n), d.T,
                   preferred_element_type=jnp.float32)   # rows = D b D^T cols
    coef = coef.reshape(bb, n, n).transpose(0, 2, 1)
    o_ref[...] = jnp.round(coef / q_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def dct_quant(blocks, q, *, block_b=DEFAULT_BLOCK_B):
    """Quantized DCT coefficients. blocks: (B, 8, 8), q: (8, 8)."""
    b, n, n2 = blocks.shape
    assert n == n2 == 8, f"expected 8x8 blocks, got {n}x{n2}"
    block_b = min(block_b, b)
    assert b % block_b == 0, f"B={b} not a multiple of block_b={block_b}"
    d = ref.dct_matrix(n, jnp.float32)
    grid = (b // block_b,)
    return pl.pallas_call(
        _dct_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), jnp.float32),
        interpret=True,
    )(blocks, d, q)
