"""Pallas kernel: segment-sum aggregation as a one-hot matmul.

The TPC-DS stages Zenix schedules (§6.1.1) are dominated by
groupby-aggregate / ReduceBy operators. A CPU implementation hashes; the
TPU re-think (DESIGN.md §2) expresses the reduction as S^T X where S is
the (N, K) one-hot segment-membership matrix, so the whole aggregation is
a single MXU matmul streamed over row-tiles with the (K, D) accumulator
resident in VMEM.

BlockSpec schedule:
  grid = (N // block_n,)
  s tile : (block_n, K)  streamed
  x tile : (block_n, D)  streamed
  out    : (K, D)        resident accumulator
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _segsum_kernel(s_ref, x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    partial = jnp.dot(s_ref[...].T, x_ref[...],
                      preferred_element_type=jnp.float32)
    o_ref[...] += partial.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def segsum(seg_onehot, x, *, block_n=DEFAULT_BLOCK_N):
    """Segment sums. seg_onehot: (N, K), x: (N, D) -> (K, D)."""
    n, k = seg_onehot.shape
    n2, d = x.shape
    assert n == n2, f"row mismatch {n} vs {n2}"
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, d), jnp.float32),
        interpret=True,
    )(seg_onehot, x)
