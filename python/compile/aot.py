"""Zenix AOT bridge: lower every L2 entry point to HLO *text*.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's pinned
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`:
    cd python && python -m compile.aot --out ../artifacts

Outputs, per entry point in model.SPECS:
    artifacts/<name>.hlo.txt
plus artifacts/manifest.json describing each entry's input/output
signature so the rust runtime can type-check invocations.
"""

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    print_large_constants=True is load-bearing: the default printer elides
    arrays >10 elements as `constant({...})`, which the xla_extension
    0.5.1 text parser silently reads back as zeros (observed: the Pallas
    DCT basis matrix came back null, zeroing every video coefficient).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    po = xc._xla.HloPrintOptions()
    po.print_large_constants = True
    # New-jax metadata attrs (source_end_line, ...) are rejected by the
    # 0.5.1 parser; metadata is debug-only, drop it.
    po.print_metadata = False
    return comp.get_hlo_module().to_string(po)


def _sig(avals):
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in avals]


def lower_all(outdir: pathlib.Path) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    manifest = {}
    for name, (fn, args) in model.SPECS.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = outdir / f"{name}.hlo.txt"
        path.write_text(text)
        out_avals = lowered.out_info
        flat_out, _ = jax.tree.flatten(out_avals)
        manifest[name] = {
            "file": path.name,
            "inputs": _sig(args),
            "outputs": _sig(flat_out),
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    lower_all(pathlib.Path(args.out))
    print("AOT lowering complete.")


if __name__ == "__main__":
    main()
