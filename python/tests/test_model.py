"""L2 model tests: shapes, training dynamics, and AOT round-trip."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _lr_data(n, d, seed=0):
    """Linearly separable-ish synthetic LR data."""
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((d, 1)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = (x @ w_true + 0.1 * rng.standard_normal((n, 1)) > 0).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


class TestLrTraining:
    def test_loss_decreases(self):
        x, y = _lr_data(model.LR_N, model.LR_D)
        w = jnp.zeros((model.LR_D, 1), jnp.float32)
        losses = []
        for _ in range(20):
            w, loss = model.lr_train_step(x, y, w, jnp.float32(1.0))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses
        assert losses == sorted(losses, reverse=True) or losses[-1] < losses[0]

    def test_accuracy_improves(self):
        x, y = _lr_data(model.LR_N, model.LR_D, seed=1)
        w = jnp.zeros((model.LR_D, 1), jnp.float32)
        _, acc0 = model.lr_eval(x, y, w)
        for _ in range(60):
            w, _ = model.lr_train_step(x, y, w, jnp.float32(2.0))
        _, acc = model.lr_eval(x, y, w)
        assert float(acc) > 0.9, (float(acc0), float(acc))

    def test_train_step_matches_manual_sgd(self):
        x, y = _lr_data(256, 16, seed=2)
        w = jnp.asarray(np.random.default_rng(3).standard_normal((16, 1)),
                        jnp.float32)
        w2, loss = model.lr_train_step(x, y, w, jnp.float32(0.5))
        want = w - 0.5 * ref.lr_grad_ref(x, w, y)
        np.testing.assert_allclose(w2, want, rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(loss, ref.lr_loss_ref(x, w, y), rtol=1e-5)


class TestAnalyticsStage:
    def test_sums_counts_means_consistent(self):
        rng = np.random.default_rng(7)
        n, k, d = 512, 16, 8
        ids = rng.integers(0, k, n)
        seg = jnp.asarray(np.eye(k, dtype=np.float32)[ids])
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        sums, counts, means = model.analytics_stage(seg, x)
        assert sums.shape == (k, d) and counts.shape == (k, 1)
        np.testing.assert_allclose(counts[:, 0],
                                   np.bincount(ids, minlength=k), atol=1e-5)
        nz = np.asarray(counts[:, 0]) > 0
        np.testing.assert_allclose(np.asarray(means)[nz],
                                   np.asarray(sums)[nz]
                                   / np.asarray(counts)[nz],
                                   rtol=1e-5)

    def test_empty_segment_mean_is_zero_not_nan(self):
        seg = jnp.zeros((64, 4)).at[:, 0].set(1.0)
        x = jnp.ones((64, 2))
        _, _, means = model.analytics_stage(seg, x)
        assert not np.any(np.isnan(np.asarray(means)))


class TestVideoBlock:
    def test_mse_increases_with_quantization(self):
        rng = np.random.default_rng(9)
        blocks = jnp.asarray(rng.uniform(0, 255, (model.VID_B, 8, 8)),
                             jnp.float32)
        mses = []
        for qscale in [1.0, 8.0, 64.0]:
            _, mse = model.video_block(blocks, qscale * jnp.ones((8, 8)))
            mses.append(float(mse))
        assert mses[0] < mses[1] < mses[2], mses

    def test_output_shapes(self):
        blocks = jnp.zeros((model.VID_B, 8, 8), jnp.float32)
        coefs, mse = model.video_block(blocks, jnp.ones((8, 8)))
        assert coefs.shape == (model.VID_B, 8, 8)
        assert mse.shape == ()


class TestAotArtifacts:
    @pytest.fixture(scope="class")
    def outdir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        aot.lower_all(out)
        return out

    def test_all_entries_emitted(self, outdir):
        manifest = json.loads((outdir / "manifest.json").read_text())
        assert set(manifest) == set(model.SPECS)
        for name, entry in manifest.items():
            text = (outdir / entry["file"]).read_text()
            assert "ENTRY" in text and "HloModule" in text, name

    def test_manifest_signatures(self, outdir):
        manifest = json.loads((outdir / "manifest.json").read_text())
        lr_sig = manifest["lr_train_step"]
        assert lr_sig["inputs"][0]["shape"] == [model.LR_N, model.LR_D]
        assert lr_sig["inputs"][0]["dtype"] == "float32"
        # train step returns (w_new, loss)
        assert len(lr_sig["outputs"]) == 2
        assert manifest["analytics_stage"]["outputs"][0]["shape"] == \
            [model.AN_K, model.AN_D]

    def test_hlo_text_has_no_custom_calls(self, outdir):
        """interpret=True must have erased all Mosaic custom-calls; the
        CPU PJRT client cannot execute them."""
        for name in model.SPECS:
            text = (outdir / f"{name}.hlo.txt").read_text()
            assert "custom-call" not in text.lower() or \
                "mosaic" not in text.lower(), name
