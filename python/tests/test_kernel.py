"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes and dtypes of every Pallas kernel
(interpret=True) against the pure-jnp references in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dct, lr, ref, segreduce

jax.config.update("jax_enable_x64", False)

# Hypothesis defaults: interpret-mode Pallas is slow, keep example counts
# modest but meaningful.
SWEEP = settings(max_examples=12, deadline=None)


def _rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale,
                       dtype=dtype)


# ---------------------------------------------------------------------------
# lr_grad
# ---------------------------------------------------------------------------

class TestLrGrad:
    @SWEEP
    @given(
        nb=st.integers(1, 6),
        block=st.sampled_from([8, 32, 128]),
        d=st.sampled_from([4, 16, 64, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shapes(self, nb, block, d, seed):
        rng = np.random.default_rng(seed)
        n = nb * block
        x = _rand(rng, (n, d))
        w = _rand(rng, (d, 1), scale=0.5)
        y = jnp.asarray(rng.integers(0, 2, (n, 1)), jnp.float32)
        got = lr.lr_grad(x, w, y, block_n=block)
        want = ref.lr_grad_ref(x, w, y)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)

    def test_bfloat16_inputs(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (256, 32), jnp.bfloat16)
        w = _rand(rng, (32, 1), jnp.bfloat16, scale=0.5)
        y = jnp.asarray(rng.integers(0, 2, (256, 1)), jnp.bfloat16)
        got = lr.lr_grad(x, w, y, block_n=128)
        want = ref.lr_grad_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                               y.astype(jnp.float32))
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-3)

    def test_zero_rows_are_neutral(self):
        """Padding rows (all-zero features+labels) must not perturb the
        gradient direction — the rust runtime relies on this to pad
        batches to the AOT shape."""
        rng = np.random.default_rng(1)
        x = _rand(rng, (128, 16))
        w = _rand(rng, (16, 1))
        y = jnp.asarray(rng.integers(0, 2, (128, 1)), jnp.float32)
        gpad = lr.lr_grad(
            jnp.concatenate([x, jnp.zeros((128, 16))]),
            w,
            jnp.concatenate([y, 0.5 * jnp.ones((128, 1))]),
            block_n=128,
        )
        g = ref.lr_grad_ref(x, w, y)
        # padded mean divides by 2N; zero rows with y=0.5 add exactly 0.
        np.testing.assert_allclose(gpad, g / 2.0, rtol=2e-5, atol=1e-6)

    def test_single_block(self):
        rng = np.random.default_rng(2)
        x, w = _rand(rng, (32, 8)), _rand(rng, (8, 1))
        y = jnp.asarray(rng.integers(0, 2, (32, 1)), jnp.float32)
        got = lr.lr_grad(x, w, y, block_n=32)
        np.testing.assert_allclose(got, ref.lr_grad_ref(x, w, y),
                                   rtol=2e-5, atol=1e-6)

    def test_rejects_ragged_batch(self):
        with pytest.raises(AssertionError):
            lr.lr_grad(jnp.zeros((100, 8)), jnp.zeros((8, 1)),
                       jnp.zeros((100, 1)), block_n=64)


# ---------------------------------------------------------------------------
# segsum
# ---------------------------------------------------------------------------

class TestSegSum:
    @SWEEP
    @given(
        nb=st.integers(1, 4),
        block=st.sampled_from([16, 64, 128]),
        k=st.sampled_from([2, 8, 64]),
        d=st.sampled_from([1, 8, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, nb, block, k, d, seed):
        rng = np.random.default_rng(seed)
        n = nb * block
        seg = jnp.asarray(np.eye(k, dtype=np.float32)[rng.integers(0, k, n)])
        x = _rand(rng, (n, d))
        got = segreduce.segsum(seg, x, block_n=block)
        want = ref.segsum_ref(seg, x)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_empty_segments_stay_zero(self):
        rng = np.random.default_rng(3)
        n, k, d = 128, 8, 4
        # all rows in segment 0 — segments 1..7 must be exactly zero
        seg = jnp.zeros((n, k)).at[:, 0].set(1.0)
        x = _rand(rng, (n, d))
        got = segreduce.segsum(seg, x)
        assert np.all(np.asarray(got[1:]) == 0.0)
        np.testing.assert_allclose(got[0], x.sum(0), rtol=2e-5, atol=2e-5)

    def test_counts_via_ones(self):
        """Counts = segsum against a ones column — the analytics_stage
        contract."""
        rng = np.random.default_rng(4)
        n, k = 256, 16
        ids = rng.integers(0, k, n)
        seg = jnp.asarray(np.eye(k, dtype=np.float32)[ids])
        got = segreduce.segsum(seg, jnp.ones((n, 1)))
        want = np.bincount(ids, minlength=k).astype(np.float32)[:, None]
        np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# dct_quant
# ---------------------------------------------------------------------------

class TestDctQuant:
    @SWEEP
    @given(
        bb=st.integers(1, 4),
        block=st.sampled_from([8, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, bb, block, seed):
        rng = np.random.default_rng(seed)
        b = bb * block
        blocks = jnp.asarray(rng.uniform(-128, 128, (b, 8, 8)), jnp.float32)
        q = jnp.asarray(rng.uniform(1, 32, (8, 8)), jnp.float32)
        got = dct.dct_quant(blocks, q, block_b=block)
        want = ref.dct_quant_ref(blocks, q)
        np.testing.assert_allclose(got, want, atol=1.0 + 1e-4)
        # round() boundaries can flip by 1 ulp of the quotient; require
        # near-exact agreement on >99% of coefficients.
        frac_exact = np.mean(np.asarray(got) == np.asarray(want))
        assert frac_exact > 0.99

    def test_dct_matrix_orthonormal(self):
        d = ref.dct_matrix(8)
        np.testing.assert_allclose(d @ d.T, np.eye(8), atol=1e-6)

    def test_roundtrip_error_small(self):
        """Quantize at q=1 (lossless up to rounding): reconstruction error
        bounded by quantization step."""
        rng = np.random.default_rng(5)
        blocks = jnp.asarray(rng.uniform(0, 255, (64, 8, 8)), jnp.float32)
        q = jnp.ones((8, 8), jnp.float32)
        coefs = dct.dct_quant(blocks, q)
        recon = ref.idct_dequant_ref(coefs, q)
        assert float(jnp.max(jnp.abs(recon - blocks))) < 4.0

    def test_rejects_non_8x8(self):
        with pytest.raises(AssertionError):
            dct.dct_quant(jnp.zeros((16, 4, 4)), jnp.ones((4, 4)))
