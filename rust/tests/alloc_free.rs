//! Zero-allocation gate for the driver hot path (ISSUE 3 tentpole).
//!
//! A counting global allocator wraps the system allocator; after a
//! warm-up long enough to saturate every pooled/cached structure
//! (invocation shells, history windows at their retention cap, the
//! §5.2.3 re-tune cache, dense tables, timeline buffers), a
//! steady-state arrival must perform **zero** heap allocations.
//!
//! This binary contains exactly one `#[test]` so no concurrent test
//! thread can pollute the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use zenix::apps::{lr, Invocation};
use zenix::cluster::ClusterSpec;
use zenix::coordinator::admission::AdmissionPolicy;
use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
use zenix::coordinator::faults::FaultConfig;
use zenix::coordinator::graph::ResourceGraph;
use zenix::coordinator::{Platform, ZenixConfig};
use zenix::trace::Archetype;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // frees are not counted: releasing pooled capacity is fine
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn counted<R>(f: impl FnOnce() -> R) -> (R, u64) {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let out = f();
    COUNTING.store(false, Ordering::SeqCst);
    (out, ALLOCS.load(Ordering::SeqCst))
}

/// Phase 1 — the re-entrant engine: after warm-up, whole invocations
/// run allocation-free (pooled shells, dense tables, retired message
/// log, incremental rack deltas, pooled solver scratch).
///
/// Phase 2 — the full multi-tenant event loop: marginal allocations per
/// additional scheduled invocation stay far below one (only
/// logarithmically many capacity doublings of the heap/slab/windows
/// remain), where the pre-refactor driver paid dozens per invocation
/// (four hash maps, a fresh wave table, per-report label strings, an
/// ever-growing slot vector ...).
#[test]
fn steady_state_arrivals_allocate_nothing() {
    // ---- phase 1: zero allocations per steady-state invocation ------
    let graph = ResourceGraph::from_program(&lr::program()).unwrap();
    let mut p = Platform::new(ClusterSpec::paper_testbed(), ZenixConfig::default());
    // Warm-up: saturate the per-(app,node,metric) history windows
    // (retention cap 256) plus several §5.2.3 re-tune cycles, so the
    // counting window sees the true steady state.
    for _ in 0..300 {
        p.invoke(&graph, Invocation::new(1.0)).unwrap();
    }
    let (_, allocs) = counted(|| {
        for _ in 0..64 {
            p.invoke(&graph, Invocation::new(1.0)).unwrap();
        }
    });
    assert_eq!(
        allocs, 0,
        "steady-state invocations must not allocate (got {allocs} allocations over 64 invocations)"
    );

    // ---- phase 2: driver loop marginal allocations ------------------
    let apps = standard_mix(6, Archetype::Average);
    let cfg_small = DriverConfig {
        seed: 5,
        invocations: 2000,
        mean_iat_ms: 300.0,
        exact_stats: false,
        ..DriverConfig::default()
    };
    let cfg_big = DriverConfig { invocations: 4000, ..cfg_small };
    let d_small = MultiTenantDriver::new(&apps, cfg_small);
    let d_big = MultiTenantDriver::new(&apps, cfg_big);
    let s_small = d_small.schedule();
    let s_big = d_big.schedule();
    let (_, a_small) = counted(|| {
        std::hint::black_box(d_small.run_zenix(&s_small));
    });
    let (_, a_big) = counted(|| {
        std::hint::black_box(d_big.run_zenix(&s_big));
    });
    let marginal = a_big.saturating_sub(a_small) as f64 / 2000.0;
    assert!(
        marginal < 1.0,
        "driver loop marginal allocations per invocation too high: \
         {marginal:.3} ({a_small} @2k vs {a_big} @4k)"
    );

    // ---- phase 3: queued-admission steady state ---------------------
    // ISSUE 5 satellite: with the deferred queues engaged under a
    // saturating schedule, a steady-state invocation still allocates
    // nothing once the slot pool is warm — parking, drains, timeout
    // expiry (head-scan FIFO and full-scan Deadline EDF alike) and the
    // DRR bookkeeping all recycle through the intrusive free lists, so
    // the marginal allocation count per extra scheduled invocation
    // stays below one.
    for (label, admission) in [
        (
            "fifo",
            AdmissionPolicy::FifoQueue { max_wait_ms: 30_000.0, max_depth: 64 },
        ),
        (
            "deadline",
            AdmissionPolicy::Deadline { deadline_ms: 20_000.0, max_depth: 64 },
        ),
    ] {
        let cfg_small = DriverConfig {
            seed: 5,
            invocations: 2000,
            mean_iat_ms: 120.0, // saturating: the queues must engage
            exact_stats: false,
            admission,
            ..DriverConfig::default()
        };
        let cfg_big = DriverConfig { invocations: 4000, ..cfg_small };
        let d_small = MultiTenantDriver::new(&apps, cfg_small);
        let d_big = MultiTenantDriver::new(&apps, cfg_big);
        let s_small = d_small.schedule();
        let s_big = d_big.schedule();
        let (rep_small, a_small) = counted(|| d_small.run_zenix(&s_small));
        let (rep_big, a_big) = counted(|| d_big.run_zenix(&s_big));
        assert!(
            rep_small.queued > 0 && rep_big.queued > 0,
            "{label}: the schedule must engage the deferred queue for this gate to bind"
        );
        std::hint::black_box(&rep_big);
        let marginal = a_big.saturating_sub(a_small) as f64 / 2000.0;
        assert!(
            marginal < 1.0,
            "{label}: queued-admission marginal allocations per invocation too high: \
             {marginal:.3} ({a_small} @2k vs {a_big} @4k)"
        );
    }

    // ---- phase 4: fault handling steady state -----------------------
    // ISSUE 6 satellite: with fault injection live, the marginal
    // allocation count per extra invocation stays below one — the
    // fault plan is generated once up front (its events ride the
    // pre-sized heap), crash scans walk the slab in place, recovery
    // rewinds reuse the shell's existing tables, and repairs only flip
    // server flags plus the dirty-rack feed. Only the plan vector
    // itself and the heap's capacity doublings remain, amortized.
    {
        let cfg_small = DriverConfig {
            seed: 5,
            invocations: 2000,
            mean_iat_ms: 300.0,
            exact_stats: false,
            faults: FaultConfig { rate_per_min: 4.0, repair_ms: 2_000.0, rack_outage: false },
            ..DriverConfig::default()
        };
        let cfg_big = DriverConfig { invocations: 4000, ..cfg_small };
        let d_small = MultiTenantDriver::new(&apps, cfg_small);
        let d_big = MultiTenantDriver::new(&apps, cfg_big);
        let s_small = d_small.schedule();
        let s_big = d_big.schedule();
        let (rep_small, a_small) = counted(|| d_small.run_zenix(&s_small));
        let (rep_big, a_big) = counted(|| d_big.run_zenix(&s_big));
        assert!(
            rep_big.faulted > 0,
            "the fault schedule must strike in-flight work for this gate to bind"
        );
        std::hint::black_box((&rep_small, &rep_big));
        let marginal = a_big.saturating_sub(a_small) as f64 / 2000.0;
        assert!(
            marginal < 1.0,
            "faulted driver loop marginal allocations per invocation too high: \
             {marginal:.3} ({a_small} @2k vs {a_big} @4k)"
        );
    }

    // ---- phase 5: parallel replay steady state ----------------------
    // ISSUE 8 tentpole: with the sharded epoch-barrier loop engaged
    // (4 workers over 4 racks), the marginal allocation count per
    // extra invocation *per worker* stays below one. Shard heaps,
    // slabs and note buffers keep their capacity across windows, the
    // barrier merge replays notes in place, and telemetry folds into
    // preallocated accumulators — what remains is the scoped worker
    // pool itself (thread spawns per engaged window), amortized over
    // the whole window's arrivals by the wide epoch.
    {
        let cfg_small = DriverConfig {
            seed: 5,
            invocations: 2000,
            mean_iat_ms: 60.0, // dense: every window clears PAR_THRESHOLD
            exact_stats: false,
            workers: 4,
            epoch_ms: 2_000.0,
            ..DriverConfig::default()
        }
        .with_racks(4);
        let cfg_big = DriverConfig { invocations: 4000, ..cfg_small };
        let d_small = MultiTenantDriver::new(&apps, cfg_small);
        let d_big = MultiTenantDriver::new(&apps, cfg_big);
        let s_small = d_small.schedule();
        let s_big = d_big.schedule();
        let (rep_small, a_small) = counted(|| d_small.run_zenix(&s_small));
        let (rep_big, a_big) = counted(|| d_big.run_zenix(&s_big));
        assert!(
            rep_big.parallel_batches > rep_small.parallel_batches,
            "the worker pool must engage on the marginal window for this gate to bind \
             ({} batches @2k vs {} @4k)",
            rep_small.parallel_batches,
            rep_big.parallel_batches
        );
        std::hint::black_box((&rep_small, &rep_big));
        let per_worker = a_big.saturating_sub(a_small) as f64 / 2000.0 / 4.0;
        assert!(
            per_worker < 1.0,
            "parallel driver loop marginal allocations per invocation per worker too high: \
             {per_worker:.3} ({a_small} @2k vs {a_big} @4k, 4 workers)"
        );
    }

    // ---- phase 6: snapshot-cache + pre-warm steady state ------------
    // ISSUE 9 tentpole: with the tiered start model live (per-rack
    // byte-budgeted snapshot caches, predictive pre-warm passes at
    // rack-dirty instants), the marginal allocation count per extra
    // invocation stays below one. The cache is a slot arena with
    // intrusive MRU/free lists — touches, inserts, evictions and
    // pre-warm placements all recycle slots in place; the tier
    // telemetry folds into preallocated streaming moments and P²
    // markers. Only the caches' one-time slot growth to their
    // high-water mark remains, amortized.
    {
        let cfg_small = DriverConfig {
            seed: 5,
            invocations: 2000,
            mean_iat_ms: 300.0,
            exact_stats: false,
            snapshot_budget_bytes: 512 * 1024 * 1024,
            prewarm: true,
            ..DriverConfig::default()
        };
        let cfg_big = DriverConfig { invocations: 4000, ..cfg_small };
        let d_small = MultiTenantDriver::new(&apps, cfg_small);
        let d_big = MultiTenantDriver::new(&apps, cfg_big);
        let s_small = d_small.schedule();
        let s_big = d_big.schedule();
        let (rep_small, a_small) = counted(|| d_small.run_zenix(&s_small));
        let (rep_big, a_big) = counted(|| d_big.run_zenix(&s_big));
        assert!(
            rep_big.snap_hits > 0,
            "the snapshot cache must serve hits for this gate to bind"
        );
        assert_eq!(
            rep_big.tier_cold + rep_big.tier_restored + rep_big.tier_warm,
            rep_big.started,
            "tier split must partition starts under the counting window"
        );
        std::hint::black_box((&rep_small, &rep_big));
        let marginal = a_big.saturating_sub(a_small) as f64 / 2000.0;
        assert!(
            marginal < 1.0,
            "tiered driver loop marginal allocations per invocation too high: \
             {marginal:.3} ({a_small} @2k vs {a_big} @4k)"
        );
    }
}
