//! Randomized property tests over coordinator invariants (std-only
//! quickcheck harness, `zenix::util::quickcheck`).

use zenix::apps::{lr, program, tpcds, video, Invocation, Program};
use zenix::cluster::{Cluster, ClusterSpec, Resources, ServerId, SnapshotCache};
use zenix::coordinator::adjust::{self, AdjustParams};
use zenix::coordinator::graph::ResourceGraph;
use zenix::coordinator::msglog::{LogEntry, MessageLog};
use zenix::coordinator::{failure, placement, Platform, ZenixConfig};
use zenix::metrics::fairness::{jains_index, JainAccumulator};
use zenix::metrics::streaming::P2Quantile;
use zenix::util::quickcheck::forall;
use zenix::util::rng::Rng;
use zenix::util::stats;

/// Random alloc/free sequences never overcommit a server, and
/// allocation bookkeeping stays conserved.
#[test]
fn server_never_overcommitted() {
    forall(
        60,
        |rng: &mut Rng| {
            let ops: Vec<(f64, f64, bool)> = (0..rng.range(5, 60))
                .map(|_| (rng.uniform(0.0, 40.0), rng.uniform(0.0, 80000.0), rng.chance(0.4)))
                .collect();
            ops
        },
        |ops| {
            let mut c = Cluster::new(ClusterSpec::paper_testbed());
            let cap = c.server(ServerId(0)).capacity;
            let mut live: Vec<Resources> = Vec::new();
            let mut t = 0.0;
            for &(cpu, mem, free) in ops {
                t += 1.0;
                let r = Resources::new(cpu, mem);
                if free && !live.is_empty() {
                    let r = live.pop().unwrap();
                    c.server_mut(ServerId(0)).free(r, t);
                } else if c.server_mut(ServerId(0)).try_alloc(r, t) {
                    live.push(r);
                }
                let a = c.server(ServerId(0)).allocated();
                if a.cpu > cap.cpu + 1e-6 || a.mem_mb > cap.mem_mb + 1e-6 {
                    return false;
                }
            }
            // free everything: must return to empty (float tolerance)
            for r in live.drain(..) {
                t += 1.0;
                c.server_mut(ServerId(0)).free(r, t);
            }
            let a = c.server(ServerId(0)).allocated();
            a.cpu.abs() < 1e-6 && a.mem_mb.abs() < 1e-6
        },
    );
}

/// Used consumption never exceeds allocated consumption.
#[test]
fn consumption_used_bounded_by_alloc() {
    forall(
        40,
        |rng: &mut Rng| {
            (0..rng.range(3, 30))
                .map(|_| {
                    (
                        rng.uniform(0.0, 16.0),
                        rng.uniform(0.0, 30000.0),
                        rng.uniform(0.0, 32.0),
                        rng.uniform(0.0, 70000.0),
                    )
                })
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut c = Cluster::new(ClusterSpec::paper_testbed());
            let mut t = 0.0;
            for &(acpu, amem, ucpu, umem) in ops {
                t += 10.0;
                let s = c.server_mut(ServerId(0));
                s.try_alloc(Resources::new(acpu, amem), t);
                s.set_used(Resources::new(ucpu, umem), t);
            }
            let total = c.total_consumption(t + 100.0);
            total.used_cpu_s <= total.alloc_cpu_s + 1e-6
                && total.used_mem_mb_s <= total.alloc_mem_mb_s + 1e-6
        },
    );
}

/// The adjust solver always covers every history point and never beats
/// the brute-force optimum on its own grid.
#[test]
fn solver_coverage_and_sanity() {
    forall(
        50,
        |rng: &mut Rng| {
            let n = rng.range(1, 40);
            (0..n).map(|_| rng.lognormal(5.5, 1.2).max(1.0)).collect::<Vec<f64>>()
        },
        |history| {
            let s = adjust::solve(history, None, AdjustParams::default());
            if !(s.init_mb.is_finite() && s.step_mb >= 16.0) {
                return false;
            }
            history.iter().all(|&h| {
                s.init_mb + adjust::growths(s.init_mb, s.step_mb, h) * s.step_mb >= h - 1e-6
            })
        },
    );
}

/// Placement never returns a server that cannot fit the demand.
#[test]
fn placement_respects_capacity() {
    forall(
        60,
        |rng: &mut Rng| {
            let allocs: Vec<(usize, f64, f64)> = (0..rng.range(0, 20))
                .map(|_| (rng.range(0, 8), rng.uniform(0.0, 32.0), rng.uniform(0.0, 65536.0)))
                .collect();
            let demand = (rng.uniform(0.0, 40.0), rng.uniform(0.0, 80000.0));
            (allocs, demand)
        },
        |(allocs, (dc, dm))| {
            let mut c = Cluster::new(ClusterSpec::paper_testbed());
            for &(s, cpu, mem) in allocs {
                c.server_mut(ServerId(s)).try_alloc(Resources::new(cpu, mem), 0.0);
            }
            let demand = Resources::new(*dc, *dm);
            match placement::smallest_fit(&c, demand) {
                Some(id) => c.server(id).available().fits(demand),
                None => c.servers().iter().all(|s| !s.available().fits(demand)),
            }
        },
    );
}

/// Every invocation leaves the cluster exactly as it found it (no
/// resource leaks), across random configs, workloads and scales.
#[test]
fn invocations_never_leak_resources() {
    let programs: Vec<Program> =
        vec![lr::program(), tpcds::query(1), tpcds::query(95), video::pipeline()];
    forall(
        25,
        |rng: &mut Rng| {
            (
                rng.range(0, 4),                 // program
                rng.uniform(0.05, 2.0),          // scale
                rng.chance(0.5),                 // adaptive
                rng.chance(0.5),                 // proactive
                rng.chance(0.5),                 // history
                rng.chance(0.3),                 // force remote
            )
        },
        |&(pi, scale, adaptive, proactive, history_sizing, force_remote)| {
            let graph = ResourceGraph::from_program(&programs[pi]).unwrap();
            let config = ZenixConfig {
                adaptive,
                proactive,
                history_sizing,
                force_remote_data: force_remote,
                ..ZenixConfig::default()
            };
            let mut p = Platform::new(ClusterSpec::paper_testbed(), config);
            for _ in 0..2 {
                if p.invoke(&graph, Invocation::new(scale)).is_err() {
                    return false;
                }
            }
            p.cluster.servers().iter().all(|s| {
                let a = s.allocated();
                let m = s.marked();
                a.cpu.abs() < 1e-6
                    && a.mem_mb.abs() < 1e-6
                    && m.cpu.abs() < 1e-6
                    && m.mem_mb.abs() < 1e-6
            })
        },
    );
}

/// The availability index is decision-identical to the retained
/// linear-scan reference: random alloc/free/mark/unmark sequences
/// driven through the index-maintaining `Cluster` hooks — with raw
/// `server_mut` mutations interleaved to exercise dirty-epoch
/// rebuilds — must produce identical `smallest_fit` answers, cluster-
/// wide and per rack, and identical rack-availability aggregates.
#[test]
fn indexed_placement_matches_linear_reference() {
    forall(
        60,
        |rng: &mut Rng| {
            let ops: Vec<(u8, usize, f64, f64)> = (0..rng.range(5, 80))
                .map(|_| {
                    (
                        rng.range(0, 6) as u8,
                        rng.range(0, 16),
                        rng.uniform(0.0, 40.0),
                        rng.uniform(0.0, 80000.0),
                    )
                })
                .collect();
            let demands: Vec<(f64, f64)> = (0..rng.range(2, 12))
                .map(|_| (rng.uniform(0.0, 40.0), rng.uniform(0.0, 80000.0)))
                .collect();
            (ops, demands)
        },
        |(ops, demands)| {
            let mut c = Cluster::new(ClusterSpec::multi_rack(2, 8));
            let racks: Vec<Vec<ServerId>> = c
                .racks()
                .map(|r| c.rack_servers(r).collect())
                .collect();
            let agrees = |c: &Cluster, (dc, dm): (f64, f64)| -> bool {
                let d = Resources::new(dc, dm);
                if placement::smallest_fit(c, d) != placement::smallest_fit_linear(c, d) {
                    return false;
                }
                for (ri, servers) in racks.iter().enumerate() {
                    let rack = zenix::cluster::RackId(ri);
                    let linear =
                        placement::smallest_fit_among(c, d, servers.iter().copied());
                    if placement::smallest_fit_in_rack(c, rack, d) != linear {
                        return false;
                    }
                    // aggregate view matches a direct fold
                    let fold = servers
                        .iter()
                        .fold(Resources::ZERO, |acc, &s| acc.plus(c.server(s).available()));
                    let idx = c.rack_available(rack);
                    if (idx.cpu - fold.cpu).abs() > 1e-6
                        || (idx.mem_mb - fold.mem_mb).abs() > 1e-6
                    {
                        return false;
                    }
                }
                true
            };
            let mut t = 0.0;
            for (i, &(op, s, cpu, mem)) in ops.iter().enumerate() {
                t += 1.0;
                let id = ServerId(s);
                let r = Resources::new(cpu, mem);
                match op {
                    0 | 1 => {
                        c.try_alloc(id, r, t);
                    }
                    2 => c.free(id, Resources::new(cpu * 0.5, mem * 0.5), t),
                    3 => c.mark(id, r),
                    4 => c.unmark(id, Resources::new(cpu * 0.5, mem * 0.5)),
                    // raw access: invalidates the index (rebuild path)
                    _ => {
                        c.server_mut(id).try_alloc(Resources::new(cpu * 0.25, mem * 0.25), t);
                    }
                }
                if i % 7 == 0 && !agrees(&c, demands[0]) {
                    return false;
                }
            }
            demands.iter().all(|&d| agrees(&c, d))
        },
    );
}

/// Streaming P² quantile estimates stay within 5% (plus a small
/// absolute floor) of the exact nearest-rank quantile across random
/// sample distributions shaped like the driver's latency streams
/// (uniform, lognormal, and bimodal warm/cold mixtures).
#[test]
fn p2_quantiles_track_exact_within_five_percent() {
    forall(
        30,
        |rng: &mut Rng| {
            let kind = rng.range(0, 3);
            let n = rng.range(800, 4000);
            let q = if rng.chance(0.5) { 0.95 } else { 0.5 };
            let xs: Vec<f64> = (0..n)
                .map(|_| match kind {
                    0 => rng.uniform(10.0, 5000.0),
                    1 => rng.lognormal(6.0, 0.75),
                    // bimodal: warm fast path vs cold starts
                    _ => {
                        if rng.chance(0.8) {
                            rng.uniform(50.0, 200.0)
                        } else {
                            rng.uniform(1500.0, 2500.0)
                        }
                    }
                })
                .collect();
            (xs, q)
        },
        |(xs, q)| {
            let mut est = P2Quantile::new(*q);
            for &x in xs {
                est.push(x);
            }
            let exact = stats::percentile(xs, q * 100.0);
            let got = est.value();
            // 5% relative + small absolute slack for the discrete
            // nearest-rank reference on bimodal gaps
            let tol = 0.05 * exact.abs() + 0.02 * (exact.abs() + got.abs()) + 1.0;
            (got - exact).abs() <= tol
        },
    );
}

/// Recovery plans: re-executed computes form a downstream-closed set in
/// wave order, and durable unaffected computes are never re-run.
#[test]
fn recovery_plan_invariants() {
    let graph = ResourceGraph::from_program(&video::pipeline()).unwrap();
    forall(
        60,
        |rng: &mut Rng| {
            let durable: Vec<usize> =
                (0..graph.n_compute()).filter(|_| rng.chance(0.5)).collect();
            let crash = rng.range(0, graph.n_compute());
            (durable, crash)
        },
        |(durable, crash)| {
            let mut log = MessageLog::new();
            for &c in durable {
                log.append(LogEntry { invocation: 1, compute: c, result_mb: 1.0 });
            }
            log.flush();
            let plan = failure::plan(&graph, &log, 1, failure::Crash::Compute(*crash));
            // crashed compute always re-runs
            if !plan.reexecute.contains(crash) {
                return false;
            }
            // wave-ordered
            for w in plan.reexecute.windows(2) {
                if graph.wave[w[0]] > graph.wave[w[1]] {
                    return false;
                }
            }
            true
        },
    );
}

/// Resource-graph topological waves respect trigger edges for random
/// DAG programs.
#[test]
fn random_dag_waves_respect_triggers() {
    forall(
        40,
        |rng: &mut Rng| {
            // random layered DAG
            let n = rng.range(2, 20);
            let mut computes = Vec::new();
            for i in 0..n {
                let mut c = program::compute("n", rng.uniform(10.0, 1000.0), 1.0, 64.0);
                // edges only forward
                for j in (i + 1)..n {
                    if rng.chance(0.25) {
                        c.triggers.push(j);
                    }
                }
                computes.push(c);
            }
            Program {
                name: "random",
                app_limit: Resources::new(64.0, 131072.0),
                computes,
                data: vec![],
                entry: 0,
            }
        },
        |prog| {
            let graph = match ResourceGraph::from_program(prog) {
                Ok(g) => g,
                Err(_) => return false,
            };
            graph.triggers.iter().all(|&(a, b)| graph.wave[a] < graph.wave[b])
        },
    );
}

/// Jain's fairness index over random per-tenant allocation vectors:
/// always in [1/n, 1] (with the all-zero convention of 1), exactly 1
/// for identical rates, and permutation-invariant — the contract the
/// driver's `jain_completion`/`jain_goodput` report fields rely on
/// (ISSUE 5 satellite).
#[test]
fn jains_index_is_bounded_unit_at_equality_and_permutation_invariant() {
    forall(
        200,
        |rng: &mut Rng| {
            let n = rng.range(1, 24);
            let xs: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.chance(0.2) {
                        0.0 // starved tenants are common in overload
                    } else {
                        rng.uniform(0.0, 1e4)
                    }
                })
                .collect();
            let rot = rng.range(0, n);
            (xs, rot)
        },
        |(xs, rot)| {
            let n = xs.len();
            let j = jains_index(xs.iter().copied());
            if xs.iter().all(|&x| x == 0.0) {
                return j == 1.0;
            }
            // bounds
            if !(j >= 1.0 / n as f64 - 1e-9 && j <= 1.0 + 1e-9) {
                return false;
            }
            // identical positive rates → exactly fair
            let uniform = jains_index(std::iter::repeat(xs[0].max(1.0)).take(n));
            if (uniform - 1.0).abs() > 1e-12 {
                return false;
            }
            // permutation invariance: rotation and reversal
            let mut rotated: Vec<f64> = xs[*rot..].to_vec();
            rotated.extend_from_slice(&xs[..*rot]);
            let jr = jains_index(rotated.iter().copied());
            let mut acc = JainAccumulator::new();
            for &x in xs.iter().rev() {
                acc.push(x);
            }
            (jr - j).abs() <= 1e-9 * j.max(1.0) && (acc.value() - j).abs() <= 1e-9 * j.max(1.0)
        },
    );
}

/// Differential (ISSUE 5 satellite): `WeightedFairShare` with all
/// tenant weights equal — at any absolute scale — must be
/// *digest-identical* to plain `FairShare` over a full saturating
/// driver replay: uniform weights give every tenant quantum 1, which
/// reduces the deficit round-robin pick-for-pick to the unweighted
/// cursor round-robin (and the schedule itself is weight-normalized,
/// so scaling the weights does not reshape arrivals).
#[test]
fn equal_weight_weighted_fair_share_is_digest_identical_to_fair_share() {
    use zenix::coordinator::admission::AdmissionPolicy;
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::trace::Archetype;

    forall(
        5,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(4, 8),          // apps
                rng.range(100, 220),      // invocations
                rng.uniform(40.0, 140.0), // fleet mean IAT (saturating band)
                rng.uniform(0.5, 8.0),    // uniform weight scale
            )
        },
        |&(seed, apps, invocations, mean_iat_ms, scale)| {
            let mut fair_mix = standard_mix(apps, Archetype::Average);
            let mut weighted_mix = standard_mix(apps, Archetype::Average);
            for a in &mut fair_mix {
                a.weight = 1.0;
            }
            for a in &mut weighted_mix {
                a.weight = scale; // uniform at a different absolute scale
            }
            let base = DriverConfig { seed, invocations, mean_iat_ms, ..DriverConfig::default() };
            let fair_cfg = DriverConfig {
                admission: AdmissionPolicy::FairShare { max_wait_ms: 20_000.0, max_depth: 64 },
                ..base
            };
            let weighted_cfg = DriverConfig {
                admission: AdmissionPolicy::WeightedFairShare {
                    max_wait_ms: 20_000.0,
                    max_depth: 64,
                },
                ..base
            };
            let fair_driver = MultiTenantDriver::new(&fair_mix, fair_cfg);
            let schedule = fair_driver.schedule();
            let fair = fair_driver.run_zenix(&schedule);
            let weighted = MultiTenantDriver::new(&weighted_mix, weighted_cfg).run_zenix(&schedule);
            fair.digest == weighted.digest
                && fair.completed == weighted.completed
                && fair.timed_out == weighted.timed_out
        },
    );
}

/// Queueing is work-conserving: over random saturating mixes, the
/// queued replay (unbounded wait/depth) completes every invocation the
/// unqueued replay completes — the only tolerated shortfall is an
/// invocation the queued run *admitted* but aborted mid-run (shifted
/// admission times change mid-run contention). Queueing may only delay
/// work or (at trace end) time it out, never silently lose it.
#[test]
fn deferred_queueing_never_loses_completed_work() {
    use zenix::coordinator::admission::AdmissionPolicy;
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::trace::Archetype;

    forall(
        8,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(4, 8),          // apps
                rng.range(80, 200),       // invocations
                rng.uniform(40.0, 160.0), // fleet mean IAT (saturating band)
            )
        },
        |&(seed, apps, invocations, mean_iat_ms)| {
            let mix = standard_mix(apps, Archetype::Average);
            let reject_cfg = DriverConfig {
                seed,
                invocations,
                mean_iat_ms,
                ..DriverConfig::default()
            };
            let fifo_cfg = DriverConfig {
                admission: AdmissionPolicy::FifoQueue {
                    max_wait_ms: f64::INFINITY,
                    max_depth: usize::MAX,
                },
                ..reject_cfg
            };
            let driver = MultiTenantDriver::new(&mix, reject_cfg);
            let schedule = driver.schedule();
            let reject = driver.run_zenix(&schedule);
            let fifo = MultiTenantDriver::new(&mix, fifo_cfg).run_zenix(&schedule);

            // conservation: every arrival ends in exactly one bucket
            let n = invocations;
            if reject.completed + reject.rejected + reject.aborted + reject.timed_out
                + reject.expired
                != n
            {
                return false;
            }
            if fifo.completed + fifo.rejected + fifo.aborted + fifo.timed_out + fifo.expired != n {
                return false;
            }
            // unbounded queue: nothing is rejected for depth
            if fifo.rejected != 0 {
                return false;
            }
            // subset: reject-completed ⊆ fifo-completed ∪ fifo-aborted
            let violations = (0..n)
                .filter(|&i| reject.completed_mask.get(i) && !fifo.completed_mask.get(i))
                .count();
            violations <= fifo.aborted && fifo.completed + fifo.aborted >= reject.completed
        },
    );
}

/// Recovery plans are *closed* under the engine's actual durability
/// regime (ISSUE 6 satellite). The engine flushes the message log
/// synchronously as each wave's computes finish, so at any crash the
/// durable set is a wave prefix. Under such a cut, every trigger edge
/// into the redo set is satisfiable: its source is either durably
/// logged (replayable input) or itself at/past the first redo wave
/// (re-runs this pass). Arbitrary durable sets (the naive closure
/// statement) are *not* closed — disjoint branches below the cut can
/// dangle — which is exactly why the engine logs per wave. A crash
/// can only strike a compute the engine has reached, so the crashed
/// compute's wave is at most `cut + 1` (the wave executing when the
/// prefix `0..=cut` was durable).
#[test]
fn recovery_plan_is_closed_under_wave_prefix_durability() {
    let graph = ResourceGraph::from_program(&video::pipeline()).unwrap();
    let max_wave = *graph.wave.iter().max().unwrap();
    forall(
        60,
        |rng: &mut Rng| {
            let cut = rng.range(0, max_wave + 1); // durable waves: 0..=cut
            let crash_data = rng.chance(0.4);
            let pick = rng.range(0, graph.n_compute().max(graph.n_data()));
            (cut, crash_data, pick)
        },
        |&(cut, crash_data, pick)| {
            let durable: Vec<usize> =
                (0..graph.n_compute()).filter(|&c| graph.wave[c] <= cut).collect();
            let mut log = MessageLog::new();
            for &c in &durable {
                log.append(LogEntry { invocation: 1, compute: c, result_mb: 1.0 });
            }
            log.flush();
            let crash = if crash_data && graph.n_data() > 0 {
                failure::Crash::DataRegion(pick % graph.n_data())
            } else {
                // the engine only reaches waves <= cut + 1
                let reachable: Vec<usize> =
                    (0..graph.n_compute()).filter(|&c| graph.wave[c] <= cut + 1).collect();
                failure::Crash::Compute(reachable[pick % reachable.len()])
            };
            let plan = failure::plan(&graph, &log, 1, crash);
            if let failure::Crash::Compute(c) = crash {
                if !plan.reexecute.contains(&c) {
                    return false;
                }
            }
            if plan.reexecute.is_empty() {
                // a data crash no one accesses discards nothing to redo
                return plan.discard_data.is_empty() || crash_data;
            }
            let redo_wave = graph.wave[plan.reexecute[0]];
            // closure: every trigger edge into the redo set has a
            // durable source or a source that itself re-runs this pass
            graph.triggers.iter().all(|&(a, b)| {
                !plan.reexecute.contains(&b)
                    || durable.contains(&a)
                    || graph.wave[a] >= redo_wave
            })
        },
    );
}

/// Under *full* durability the recovery plan is exact, not just safe
/// (ISSUE 6 satellite): a compute crash re-runs only itself and
/// discards only its own accessed regions; a data-region crash re-runs
/// exactly the region's accessors; and every discarded region keeps at
/// least one accessor in the redo set (no orphaned discards).
#[test]
fn recovery_plan_is_exact_under_full_durability() {
    let programs = [lr::program(), video::pipeline()];
    forall(
        60,
        |rng: &mut Rng| {
            let pi = rng.range(0, 2);
            let crash_data = rng.chance(0.5);
            let pick = rng.range(0, 64);
            (pi, crash_data, pick)
        },
        |&(pi, crash_data, pick)| {
            let graph = ResourceGraph::from_program(&programs[pi]).unwrap();
            let mut log = MessageLog::new();
            for c in 0..graph.n_compute() {
                log.append(LogEntry { invocation: 1, compute: c, result_mb: 1.0 });
            }
            log.flush();
            if crash_data && graph.n_data() > 0 {
                let d = pick % graph.n_data();
                let plan = failure::plan(&graph, &log, 1, failure::Crash::DataRegion(d));
                let mut want = graph.accessors_of(d);
                want.sort_unstable_by_key(|&c| (graph.wave[c], c));
                plan.reexecute == want
                    && plan.discard_data.iter().all(|&dd| {
                        let acc = graph.accessors_of(dd);
                        acc.is_empty() || acc.iter().any(|c| plan.reexecute.contains(c))
                    })
            } else {
                let c = pick % graph.n_compute();
                let plan = failure::plan(&graph, &log, 1, failure::Crash::Compute(c));
                let want: std::collections::BTreeSet<usize> =
                    graph.accessed_data(c).into_iter().collect();
                plan.reexecute == vec![c] && plan.discard_data == want
            }
        },
    );
}

/// Fault injection partitions arrivals with nothing leaked (ISSUE 6
/// acceptance): over random seeds, loads, fault rates, repair delays,
/// outage modes, and admission policies, `completed + rejected +
/// aborted + timed_out + faulted_unrecovered == arrivals`, faults
/// split exactly into recovered vs unrecovered (fleet and per app),
/// and consumption stays bounded. The driver's own debug asserts
/// (active here) additionally pin that the cluster drains to empty —
/// no allocation or mark survives the churn.
#[test]
fn fault_injection_partitions_arrivals_and_leaks_nothing() {
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::coordinator::{AdmissionPolicy, FaultConfig};
    use zenix::trace::Archetype;

    forall(
        8,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(4, 8),             // apps
                rng.range(80, 200),          // invocations
                rng.uniform(40.0, 160.0),    // fleet mean IAT (saturating band)
                rng.uniform(0.0, 12.0),      // fault rate per minute
                rng.uniform(1000.0, 8000.0), // repair delay ms
                rng.chance(0.4),             // whole-rack outages
                rng.range(0, 3),             // admission policy
            )
        },
        |&(seed, apps, invocations, mean_iat_ms, rate, repair_ms, rack_outage, policy)| {
            let mix = standard_mix(apps, Archetype::Average);
            let admission = match policy {
                0 => AdmissionPolicy::RejectImmediately,
                1 => AdmissionPolicy::FifoQueue { max_wait_ms: 60_000.0, max_depth: 64 },
                _ => AdmissionPolicy::FairShare { max_wait_ms: 60_000.0, max_depth: 64 },
            };
            let cfg = DriverConfig {
                seed,
                invocations,
                mean_iat_ms,
                admission,
                faults: FaultConfig { rate_per_min: rate, repair_ms, rack_outage },
                ..DriverConfig::default()
            };
            let driver = MultiTenantDriver::new(&mix, cfg);
            let r = driver.run_zenix(&driver.schedule());
            if r.completed + r.rejected + r.aborted + r.timed_out + r.expired
                + r.faulted_unrecovered
                != invocations
            {
                return false;
            }
            if r.faulted != r.recovered + r.faulted_unrecovered {
                return false;
            }
            let sums = r.apps.iter().fold((0, 0, 0), |acc, a| {
                (acc.0 + a.faulted, acc.1 + a.recovered, acc.2 + a.faulted_unrecovered)
            });
            sums == (r.faulted, r.recovered, r.faulted_unrecovered)
                && r.apps.iter().all(|a| a.completed + a.failed() == a.scheduled + a.spawned)
                && r.fleet.used_mem_mb_s <= r.fleet.alloc_mem_mb_s + 1e-6
        },
    );
}

/// Tentpole invariant (ISSUE 8): the sharded epoch-barrier replay is
/// digest-identical to the sequential loop for *every* worker count —
/// over random seeds, rack counts, admission policies and fault rates.
/// Shards are racks (worker-count-independent), cross-shard effects
/// exchange at the `(time, seq)` barrier, and queueing replays
/// serialize exactly, so `workers = n` must reproduce `workers = 1`
/// bit-for-bit: same digest, same conservation split, same fault
/// accounting.
#[test]
fn parallel_replay_digest_matches_single_worker() {
    use zenix::coordinator::admission::AdmissionPolicy;
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::coordinator::faults::FaultConfig;
    use zenix::trace::Archetype;

    forall(
        5,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(4, 8),             // apps
                rng.range(80, 200),          // invocations
                rng.uniform(60.0, 300.0),    // fleet mean IAT
                [2usize, 4, 8][rng.range(0, 3)], // racks (shards; must divide the 8-server testbed)
                rng.uniform(0.0, 8.0),       // fault rate per minute
                rng.range(0, 3),             // admission policy
            )
        },
        |&(seed, apps, invocations, mean_iat_ms, racks, rate, policy)| {
            let mix = standard_mix(apps, Archetype::Average);
            let admission = match policy {
                0 => AdmissionPolicy::RejectImmediately,
                1 => AdmissionPolicy::FifoQueue { max_wait_ms: 60_000.0, max_depth: 64 },
                _ => AdmissionPolicy::FairShare { max_wait_ms: 60_000.0, max_depth: 64 },
            };
            let base = DriverConfig {
                seed,
                invocations,
                mean_iat_ms,
                admission,
                faults: FaultConfig {
                    rate_per_min: rate,
                    repair_ms: 5_000.0,
                    rack_outage: rate > 4.0,
                },
                ..DriverConfig::default()
            }
            .with_racks(racks);

            let driver = MultiTenantDriver::new(&mix, base);
            let schedule = driver.schedule();
            let seq = driver.run_zenix(&schedule);
            // the sequential replay satisfies conservation...
            if seq.completed + seq.rejected + seq.aborted + seq.timed_out + seq.expired
                + seq.faulted_unrecovered
                != invocations
            {
                return false;
            }
            for workers in [2usize, 4, 8] {
                let cfg = DriverConfig { workers, ..base };
                let par = MultiTenantDriver::new(&mix, cfg).run_zenix(&schedule);
                // ...and every parallel replay reproduces it exactly
                if par.digest != seq.digest
                    || par.completed != seq.completed
                    || par.rejected != seq.rejected
                    || par.aborted != seq.aborted
                    || par.timed_out != seq.timed_out
                    || par.expired != seq.expired
                    || par.faulted != seq.faulted
                    || par.recovered != seq.recovered
                    || par.faulted_unrecovered != seq.faulted_unrecovered
                    || par.warm_hits != seq.warm_hits
                    || par.max_in_flight != seq.max_in_flight
                {
                    return false;
                }
            }
            true
        },
    );
}

/// Tentpole invariant (ISSUE 9): the byte-budgeted snapshot cache never
/// exceeds its budget, agrees decision-for-decision with a naive
/// reference LRU over random op sequences, and evicts in the exact
/// reference recency order. The structure is a slot arena plus
/// intrusive lists — no hash map anywhere (`zenix_lint` D1) — so the
/// same op sequence replays identically on every run and machine: the
/// eviction order is a pure function of the operations, never of
/// iteration order.
#[test]
fn snapshot_cache_respects_budget_and_is_permutation_deterministic() {
    const NAMES: [&str; 6] = ["cache-a", "cache-b", "cache-c", "cache-d", "cache-e", "cache-f"];
    forall(
        80,
        |rng: &mut Rng| {
            let budget = rng.range(64, 4096) as u64;
            let ops: Vec<(u8, usize, u64, usize)> = (0..rng.range(10, 120))
                .map(|_| {
                    (
                        rng.range(0, 3) as u8,     // 0 touch, 1 insert, 2 evict_lru
                        rng.range(0, NAMES.len()), // app
                        rng.range(1, 1500) as u64, // image bytes
                        rng.range(0, 8),           // home server
                    )
                })
                .collect();
            (budget, ops)
        },
        |(budget, ops)| {
            let budget = *budget;
            let mut cache = SnapshotCache::new(budget);
            // reference model: MRU-at-front Vec, linear everything
            let mut model: Vec<(&'static str, u64, usize)> = Vec::new();
            let mut used = 0u64;
            let (mut hits, mut misses, mut evictions) = (0u64, 0u64, 0u64);
            for &(op, app, bytes, home) in ops {
                let name = NAMES[app];
                match op {
                    0 => {
                        let hit = cache.touch(name);
                        match model.iter().position(|e| e.0 == name) {
                            Some(i) => {
                                if !hit {
                                    return false;
                                }
                                hits += 1;
                                let e = model.remove(i);
                                model.insert(0, e);
                            }
                            None => {
                                if hit {
                                    return false;
                                }
                                misses += 1;
                            }
                        }
                    }
                    1 => {
                        let ok = cache.insert(name, bytes, ServerId(home));
                        let dup = model.iter().any(|e| e.0 == name);
                        let want = !dup && bytes <= budget.saturating_sub(used);
                        if ok != want {
                            return false;
                        }
                        if ok {
                            model.insert(0, (name, bytes, home));
                            used += bytes;
                        }
                    }
                    _ => match (cache.evict_lru(), model.pop()) {
                        (None, None) => {}
                        (Some((gn, gb, gs)), Some((wn, wb, ws))) => {
                            if gn != wn || gb != wb || gs != ServerId(ws) {
                                return false;
                            }
                            evictions += 1;
                            used -= wb;
                        }
                        _ => return false,
                    },
                }
                // the budget bound holds after *every* operation
                if cache.bytes() > budget
                    || cache.bytes() != used
                    || cache.len() != model.len()
                {
                    return false;
                }
            }
            // telemetry agrees with the reference count-for-count
            if cache.stats.hits != hits
                || cache.stats.misses != misses
                || cache.stats.evictions != evictions
            {
                return false;
            }
            // teardown drains in exact reference LRU order
            while let Some((gn, gb, gs)) = cache.evict_lru() {
                match model.pop() {
                    Some((wn, wb, ws)) if gn == wn && gb == wb && gs == ServerId(ws) => {}
                    _ => return false,
                }
            }
            model.is_empty() && cache.is_empty() && cache.bytes() == 0
        },
    );
}

/// Tentpole safety (ISSUE 9): a zero snapshot budget leaves the replay
/// byte-identical to the legacy engine — the `DRIVER_DIGEST.lock`
/// semantics cannot move. Random seeds, loads, rack counts and worker
/// counts, with the `prewarm` flag set both ways at budget 0 (pre-warm
/// is gated on the budget, so it must be inert): every variant
/// reproduces the plain default-config digest bit-for-bit, and the
/// snapshot layer reports zero activity.
#[test]
fn zero_budget_no_prewarm_is_digest_identical_to_seed_replay() {
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::trace::Archetype;

    forall(
        6,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(4, 8),              // apps
                rng.range(80, 200),           // invocations
                rng.uniform(60.0, 300.0),     // fleet mean IAT
                [1usize, 2, 4][rng.range(0, 3)], // racks
                [1usize, 4][rng.range(0, 2)], // workers
            )
        },
        |&(seed, apps, invocations, mean_iat_ms, racks, workers)| {
            let mix = standard_mix(apps, Archetype::Average);
            let base = DriverConfig { seed, invocations, mean_iat_ms, workers, ..DriverConfig::default() }
                .with_racks(racks);
            let driver = MultiTenantDriver::new(&mix, base);
            let schedule = driver.schedule();
            let legacy = driver.run_zenix(&schedule);
            for prewarm in [false, true] {
                let cfg = DriverConfig { snapshot_budget_bytes: 0, prewarm, ..base };
                let r = MultiTenantDriver::new(&mix, cfg).run_zenix(&schedule);
                if r.digest != legacy.digest
                    || r.completed != legacy.completed
                    || r.warm_hits != legacy.warm_hits
                    || r.snap_hits + r.snap_misses + r.snap_prewarms + r.snap_evictions != 0
                    || r.snap_bytes_hwm != 0
                {
                    return false;
                }
                // the tier split still partitions starts with the layer
                // off (the flat model maps to WarmHit/ColdBoot)
                if r.tier_cold + r.tier_restored + r.tier_warm != r.started
                    || r.tier_restored != 0
                {
                    return false;
                }
            }
            true
        },
    );
}

/// Tentpole invariant (ISSUE 9 × ISSUE 8): the tiered replay stays
/// worker-count invariant. Snapshot caches, pre-warm passes and tier
/// resolution all run coordinator-side at `(time, seq)`-identical
/// instants in both event loops, so random budgets and pre-warm flags
/// must reproduce the sequential digest — and the *entire*
/// digest-excluded tier/cache telemetry — at every worker count.
#[test]
fn parallel_tiered_replay_matches_single_worker() {
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::trace::Archetype;

    forall(
        5,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(4, 8),                        // apps
                rng.range(80, 200),                     // invocations
                rng.uniform(60.0, 300.0),               // fleet mean IAT
                [2usize, 4, 8][rng.range(0, 3)],        // racks (shards)
                [0u64, 64, 256, 2048][rng.range(0, 4)], // budget MiB per rack
                rng.chance(0.5),                        // prewarm
            )
        },
        |&(seed, apps, invocations, mean_iat_ms, racks, budget_mb, prewarm)| {
            let mix = standard_mix(apps, Archetype::Average);
            let base = DriverConfig {
                seed,
                invocations,
                mean_iat_ms,
                snapshot_budget_bytes: budget_mb * 1024 * 1024,
                prewarm,
                ..DriverConfig::default()
            }
            .with_racks(racks);
            let driver = MultiTenantDriver::new(&mix, base);
            let schedule = driver.schedule();
            let seq = driver.run_zenix(&schedule);
            // the sequential tier split partitions starts...
            if seq.tier_cold + seq.tier_restored + seq.tier_warm != seq.started {
                return false;
            }
            for workers in [2usize, 4, 8] {
                let cfg = DriverConfig { workers, ..base };
                let par = MultiTenantDriver::new(&mix, cfg).run_zenix(&schedule);
                // ...and every parallel replay reproduces digest AND
                // tier/cache telemetry exactly
                if par.digest != seq.digest
                    || par.completed != seq.completed
                    || par.started != seq.started
                    || par.tier_cold != seq.tier_cold
                    || par.tier_restored != seq.tier_restored
                    || par.tier_warm != seq.tier_warm
                    || par.snap_hits != seq.snap_hits
                    || par.snap_misses != seq.snap_misses
                    || par.snap_evictions != seq.snap_evictions
                    || par.snap_prewarms != seq.snap_prewarms
                    || par.snap_bytes_hwm != seq.snap_bytes_hwm
                {
                    return false;
                }
            }
            true
        },
    );
}

/// Tentpole property (ISSUE 10): workflow-structured replays conserve
/// every stage invocation and degenerate exactly. Random DAG shapes
/// (pipeline, fan-out/fan-in, trivial), seeds, loads and affinity
/// settings:
///   1. fleet and per-app, `completed + failed() == scheduled +
///      spawned` — every downstream stage launch lands in exactly one
///      conservation term;
///   2. the sharded loop reproduces the sequential digest AND the
///      workflow telemetry bit-for-bit at workers ∈ {2, 4};
///   3. a DAG-of-1 mix replays byte-identical to the same mix with no
///      workflow at all (trivial DAGs are digest-inert).
#[test]
fn workflow_replay_conserves_and_degenerates() {
    use zenix::coordinator::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use zenix::coordinator::Workflow;
    use zenix::trace::Archetype;

    forall(
        5,
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.range(3, 7),           // apps
                rng.range(60, 140),        // root invocations
                rng.uniform(150.0, 400.0), // fleet mean IAT
                rng.range(0, 3),           // DAG shape selector
                rng.range(2, 5),           // stages / fan-out width
                rng.uniform(1.0, 150.0),   // handoff MB
                rng.chance(0.5),           // affinity on/off
            )
        },
        |&(seed, apps, invocations, mean_iat_ms, shape, k, handoff_mb, affinity)| {
            let dag = match shape {
                0 => Workflow::pipeline(k, handoff_mb),
                1 => Workflow::fan_out_in(k, 0.6, handoff_mb),
                _ => Workflow::single(),
            };
            let mut mix = standard_mix(apps, Archetype::Average);
            for (i, app) in mix.iter_mut().enumerate() {
                // every other tenant carries the DAG: workflow and
                // independent tenants must coexist in one replay
                if i % 2 == 0 {
                    app.workflow = Some(dag.clone());
                }
            }
            let base = DriverConfig {
                seed,
                invocations,
                mean_iat_ms,
                workflow_affinity: affinity,
                ..DriverConfig::default()
            }
            .with_racks(4);
            let driver = MultiTenantDriver::new(&mix, base);
            let schedule = driver.schedule();
            let seq = driver.run_zenix(&schedule);

            // 1. conservation with the spawned term: fleet...
            let spawned = usize::try_from(seq.wf_spawned).expect("spawned fits usize");
            let lhs = seq.completed
                + seq.rejected
                + seq.aborted
                + seq.timed_out
                + seq.expired
                + seq.faulted_unrecovered;
            if lhs != schedule.arrivals.len() + spawned {
                return false;
            }
            // ...and per app, with per-app spawned summing to the fleet term
            let mut spawned_sum = 0usize;
            for a in &seq.apps {
                if a.completed + a.failed() != a.scheduled + a.spawned {
                    return false;
                }
                spawned_sum += a.spawned;
            }
            if spawned_sum != spawned
                || seq.wf_stages_completed > seq.wf_stages_started
                || seq.wf_runs_completed > seq.wf_runs
            {
                return false;
            }

            // 2. worker invariance: digest AND workflow telemetry
            for workers in [2usize, 4] {
                let par = MultiTenantDriver::new(&mix, DriverConfig { workers, ..base })
                    .run_zenix(&schedule);
                if par.digest != seq.digest
                    || par.wf_spawned != seq.wf_spawned
                    || par.wf_runs != seq.wf_runs
                    || par.wf_runs_completed != seq.wf_runs_completed
                    || par.wf_stages_started != seq.wf_stages_started
                    || par.wf_stages_completed != seq.wf_stages_completed
                    || par.wf_affinity_hits != seq.wf_affinity_hits
                    || par.wf_affinity_spills != seq.wf_affinity_spills
                    || par.wf_cross_rack_mb.to_bits() != seq.wf_cross_rack_mb.to_bits()
                    || par.expired != seq.expired
                {
                    return false;
                }
            }

            // 3. the trivial DAG degenerates to independent arrivals
            let mut trivial = standard_mix(apps, Archetype::Average);
            for app in trivial.iter_mut() {
                app.workflow = Some(Workflow::single());
            }
            let one = MultiTenantDriver::new(&trivial, base).run_zenix(&schedule);
            let plain_mix = standard_mix(apps, Archetype::Average);
            let plain = MultiTenantDriver::new(&plain_mix, base).run_zenix(&schedule);
            one.digest == plain.digest
                && one.completed == plain.completed
                && one.wf_spawned == 0
                && one.wf_cross_rack_mb == 0.0
        },
    );
}
