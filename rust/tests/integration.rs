//! Integration tests: end-to-end coordinator flows (always run) plus
//! real PJRT execution of the AOT artifacts (requires `make artifacts`
//! and the `pjrt` feature; those tests self-skip otherwise).

use zenix::cluster::ClusterSpec;
use zenix::coordinator::driver::{
    standard_mix, synthetic_program, DriverConfig, MultiTenantDriver, ScaleModel, TenantApp,
};
use zenix::coordinator::graph::ResourceGraph;
use zenix::coordinator::ZenixConfig;
use zenix::runtime::{manifest::find_artifact_dir, spawn_compute_service, Tensor};
use zenix::trace::Archetype;
use zenix::util::rng::Rng;

/// PR-2 acceptance gate: ≥1000 overlapping invocations across ≥20 apps
/// on the Average-archetype mix; Zenix's allocated memory over the run
/// must be ≤ 50% of a statically-sized FaaS deployment replaying the
/// *identical* arrival schedule (the paper reports savings up to 90%,
/// Figs 22/26/29); and the whole run is deterministic per seed.
#[test]
fn multi_tenant_driver_halves_faas_allocation_deterministically() {
    let mix = standard_mix(20, Archetype::Average);
    let cfg = DriverConfig {
        seed: 11,
        invocations: 1000,
        mean_iat_ms: 400.0,
        cluster: ClusterSpec::paper_testbed(),
        config: ZenixConfig::default(),
        exact_stats: true,
        ..DriverConfig::default()
    };
    let driver = MultiTenantDriver::new(&mix, cfg);
    let out = driver.run_comparison();

    assert_eq!(out.zenix.completed + out.zenix.failed, 1000);
    assert!(
        out.zenix.completed >= 900,
        "too many admission failures: {} of 1000",
        out.zenix.failed
    );
    assert!(
        out.zenix.max_in_flight > 1,
        "invocations must overlap on the cluster"
    );
    // Gate on the FaaS charge for the *same completed work* (the Zenix
    // integral additionally includes failed invocations' partial work,
    // so this comparison is conservative).
    let z = out.zenix.fleet.alloc_mem_mb_s;
    let f = out.faas_on_completed.fleet.alloc_mem_mb_s;
    assert!(
        z <= 0.5 * f,
        "zenix {:.0} MB·s vs faas-static {:.0} MB·s — need ≤ 50% (got {:.0}%)",
        z,
        f,
        z / f * 100.0
    );
    // peak-provision wastes at least as much as history sizing
    assert!(z <= out.peak.fleet.alloc_mem_mb_s * 1.02);

    // identical seed (fresh mix, fresh driver) → identical digests
    let mix2 = standard_mix(20, Archetype::Average);
    let out2 = MultiTenantDriver::new(&mix2, cfg).run_comparison();
    assert_eq!(out.zenix.digest, out2.zenix.digest, "zenix run must be deterministic");
    assert_eq!(out.peak.digest, out2.peak.digest);
    assert_eq!(out.faas.digest, out2.faas.digest);

    // a different seed reshapes the schedule
    let driver3 = MultiTenantDriver::new(&mix, DriverConfig { seed: 12, ..cfg });
    let schedule3 = driver3.schedule();
    let zenix3 = driver3.run_zenix(&schedule3);
    assert_ne!(out.zenix.digest, zenix3.digest, "seed must matter");
}

/// Digest-equivalence regression for the allocation-free refactor
/// (ISSUE 3): the standard seeded driver comparison must produce the
/// *identical* digest whether the report path stores every sample
/// (exact, the pre-refactor aggregation) or streams moments + P²
/// quantiles — proving the dense-table/pooling/slab/cursor rewrite
/// preserves event order and accounting bit-for-bit. The digest is
/// additionally pinned across builds by `scripts/ci.sh` (first
/// toolchain-bearing run writes `DRIVER_DIGEST.lock`; later runs must
/// reproduce it).
#[test]
fn driver_digest_identical_across_stats_modes() {
    let mix = standard_mix(12, Archetype::Average);
    let cfg = DriverConfig {
        seed: 7,
        invocations: 1600,
        mean_iat_ms: 400.0,
        cluster: ClusterSpec::paper_testbed(),
        config: ZenixConfig::default(),
        exact_stats: true,
        ..DriverConfig::default()
    };
    let exact = MultiTenantDriver::new(&mix, cfg).run_comparison();
    let streaming =
        MultiTenantDriver::new(&mix, DriverConfig { exact_stats: false, ..cfg }).run_comparison();

    assert_eq!(exact.zenix.digest, streaming.zenix.digest, "zenix digest must not depend on stats mode");
    assert_eq!(exact.peak.digest, streaming.peak.digest);
    assert_eq!(exact.faas.digest, streaming.faas.digest);
    assert_eq!(exact.zenix.completed, streaming.zenix.completed);
    assert_eq!(exact.zenix.failed, streaming.zenix.failed);
    assert!(
        (exact.gated_savings() - streaming.gated_savings()).abs() < 1e-12,
        "savings gate must be mode-independent"
    );

    // Satellite: streaming P² p95 stays within 5% of the exact
    // quantile for every app with a meaningful sample count on the
    // standard mix (plus a small absolute floor for ms-scale rows).
    for (a, b) in exact.zenix.apps.iter().zip(&streaming.zenix.apps) {
        assert_eq!(a.completed, b.completed, "{}", a.name);
        assert_eq!(
            a.mean_exec_ms.to_bits(),
            b.mean_exec_ms.to_bits(),
            "{}: ordered-sum streaming mean must be bit-identical",
            a.name
        );
        if a.completed >= 60 {
            let tol = 0.05 * a.p95_exec_ms.abs() + 2.0;
            assert!(
                (b.p95_exec_ms - a.p95_exec_ms).abs() <= tol,
                "{}: streaming p95 {:.2} vs exact {:.2} (n={})",
                a.name,
                b.p95_exec_ms,
                a.p95_exec_ms,
                a.completed
            );
        }
    }
}

/// ISSUE 4 acceptance gate: under a saturated MMPP burst schedule, the
/// FIFO deferred queue must strictly beat immediate rejection — total
/// rejections + timeouts drop below the reject policy's rejections —
/// while reporting per-tenant P² queueing-delay percentiles; and the
/// default policy stays digest-deterministic (the `DRIVER_DIGEST.lock`
/// contract is exercised end-to-end by `scripts/ci.sh`).
#[test]
fn fifo_queueing_beats_rejection_under_mmpp_burst() {
    use zenix::coordinator::admission::{AdmissionPolicy, ArrivalModel};

    let mix = standard_mix(16, Archetype::Average);
    let reject_cfg = DriverConfig {
        seed: 7,
        invocations: 800,
        mean_iat_ms: 60.0,
        arrivals: ArrivalModel::Mmpp {
            on_mult: 6.0,
            mean_on_ms: 4_000.0,
            mean_off_ms: 12_000.0,
        },
        ..DriverConfig::default()
    };
    let fifo_cfg = DriverConfig {
        admission: AdmissionPolicy::FifoQueue { max_wait_ms: 120_000.0, max_depth: 128 },
        ..reject_cfg
    };
    let driver = MultiTenantDriver::new(&mix, reject_cfg);
    let schedule = driver.schedule();
    let reject = driver.run_zenix(&schedule);
    let fifo = MultiTenantDriver::new(&mix, fifo_cfg).run_zenix(&schedule);

    assert!(
        reject.rejected > 0,
        "the burst schedule must saturate admission for this gate to mean anything"
    );
    assert!(
        fifo.rejected + fifo.timed_out < reject.rejected,
        "queueing must strictly reduce failed admissions: fifo {}+{} vs reject {}",
        fifo.rejected,
        fifo.timed_out,
        reject.rejected
    );
    // implied by the strict gate above (conservation): queueing turns
    // the saved rejections into completions, modulo mid-run aborts of
    // shifted admissions
    assert!(
        fifo.completed + fifo.aborted > reject.completed,
        "queueing must complete more work: {}+{} vs {}",
        fifo.completed,
        fifo.aborted,
        reject.completed
    );
    // per-tenant queueing-delay percentiles are reported
    assert!(fifo.queued > 0);
    let delayed_tenants = fifo
        .apps
        .iter()
        .filter(|a| a.queued > a.timed_out)
        .collect::<Vec<_>>();
    assert!(!delayed_tenants.is_empty(), "some tenant must drain from the queue");
    for a in &delayed_tenants {
        assert!(
            a.p95_queue_delay_ms > 0.0 && a.mean_queue_delay_ms > 0.0,
            "{}: queue delay must be reported (mean {}, p95 {})",
            a.name,
            a.mean_queue_delay_ms,
            a.p95_queue_delay_ms
        );
    }
    assert!(fifo.p95_queue_delay_ms > 0.0, "fleet P² p95 must be reported");
    // conservation both ways
    assert_eq!(
        reject.completed + reject.rejected + reject.aborted + reject.timed_out + reject.expired,
        800
    );
    assert_eq!(
        fifo.completed + fifo.rejected + fifo.aborted + fifo.timed_out + fifo.expired,
        800
    );
    // the queued replay is deterministic too
    let fifo2 = MultiTenantDriver::new(&mix, fifo_cfg).run_zenix(&schedule);
    assert_eq!(fifo.digest, fifo2.digest);
}

/// Differential test (ISSUE 5 satellite): `ClusterSpec::multi_rack(1, n)`
/// is definitionally the single-rack cluster — the driver replay must
/// be digest-identical (and therefore completion/rejection-identical)
/// to the plain single-rack spec; and the genuinely sharded replays
/// (r ∈ {2, 4, 8} at fixed total capacity) must be digest-stable per
/// seed across fresh mixes and drivers.
#[test]
fn multi_rack_one_matches_single_rack_and_sharded_replays_are_stable() {
    let cfg = |cluster: ClusterSpec| DriverConfig {
        seed: 11,
        invocations: 400,
        mean_iat_ms: 200.0,
        cluster,
        ..DriverConfig::default()
    };

    let mix = standard_mix(8, Archetype::Average);
    let single_driver = MultiTenantDriver::new(&mix, cfg(ClusterSpec::paper_testbed()));
    let schedule = single_driver.schedule();
    let single = single_driver.run_zenix(&schedule);
    let multi1 =
        MultiTenantDriver::new(&mix, cfg(ClusterSpec::multi_rack(1, 8))).run_zenix(&schedule);
    assert_eq!(
        single.digest, multi1.digest,
        "multi_rack(1, n) must replay identically to the single-rack spec"
    );
    assert_eq!(single.completed, multi1.completed);
    assert_eq!(single.rejected, multi1.rejected);
    assert_eq!(single.aborted, multi1.aborted);

    for racks in [2usize, 4, 8] {
        let sharded = cfg(ClusterSpec::paper_testbed()).with_racks(racks);
        let a = MultiTenantDriver::new(&mix, sharded).run_zenix(&schedule);
        // fresh mix + fresh driver: the digest is a property of
        // (seed, config), not of interned state left by earlier runs
        let mix2 = standard_mix(8, Archetype::Average);
        let b = MultiTenantDriver::new(&mix2, sharded).run_zenix(&schedule);
        assert_eq!(a.digest, b.digest, "{racks}-rack replay must be digest-stable");
        assert_eq!(a.completed + a.failed, 400, "{racks}-rack conservation");
        assert!(
            a.completed * 2 >= single.completed,
            "{racks}-rack sharding at fixed capacity must not collapse completions: \
             {} vs single-rack {}",
            a.completed,
            single.completed
        );
    }
}

/// ISSUE 5 acceptance gate: under a saturated *asymmetric* 2-tenant
/// overload (identical programs, 6:1 arrival weights, one server so
/// the fleet is far past capacity), the FIFO queue serves tenants in
/// proportion to their arrival monopoly — Jain's index over
/// per-tenant completions lands near the 6:1 closed form ≈ 0.66 —
/// while FairShare's round-robin drain restores near-equal service.
#[test]
fn fair_share_restores_fairness_under_asymmetric_overload() {
    use zenix::coordinator::admission::AdmissionPolicy;

    fn two_tenant_mix() -> Vec<TenantApp> {
        let mk = |name: &'static str, weight: f64| TenantApp {
            graph: ResourceGraph::from_program(&synthetic_program(name))
                .expect("synthetic program"),
            weight,
            scales: ScaleModel::Fixed(600.0),
            deadline_ms: None,
            workflow: None,
        };
        vec![mk("tenant-heavy", 6.0), mk("tenant-light", 1.0)]
    }

    let base = DriverConfig {
        seed: 7,
        invocations: 1200,
        mean_iat_ms: 10.0,
        cluster: ClusterSpec::multi_rack(1, 1),
        ..DriverConfig::default()
    };
    let fifo_cfg = DriverConfig {
        admission: AdmissionPolicy::FifoQueue { max_wait_ms: 4_000.0, max_depth: 256 },
        ..base
    };
    let fair_cfg = DriverConfig {
        admission: AdmissionPolicy::FairShare { max_wait_ms: 4_000.0, max_depth: 256 },
        ..base
    };

    let mix = two_tenant_mix();
    let driver = MultiTenantDriver::new(&mix, fifo_cfg);
    let schedule = driver.schedule();
    let fifo = driver.run_zenix(&schedule);
    let fair = MultiTenantDriver::new(&mix, fair_cfg).run_zenix(&schedule);

    // the schedule must genuinely overload the cluster and engage the
    // queues, or the gate is vacuous
    assert!(fifo.queued > 0 && fair.queued > 0, "overload must park arrivals");
    assert!(
        fifo.completed * 2 < 1200,
        "overload must exceed capacity: {} of 1200 completed",
        fifo.completed
    );
    assert_eq!(
        fifo.completed + fifo.rejected + fifo.aborted + fifo.timed_out + fifo.expired,
        1200
    );
    assert_eq!(
        fair.completed + fair.rejected + fair.aborted + fair.timed_out + fair.expired,
        1200
    );

    // the acceptance bars: FIFO mirrors the 6:1 arrival monopoly,
    // FairShare restores near-equal per-tenant service
    assert!(
        fifo.jain_completion < 0.8,
        "FIFO under 6:1 skew should mirror the monopoly: Jain {:.3} (completions {:?})",
        fifo.jain_completion,
        fifo.apps.iter().map(|a| a.completed).collect::<Vec<_>>()
    );
    assert!(
        fair.jain_completion >= 0.9,
        "FairShare must restore fairness: Jain {:.3} (completions {:?})",
        fair.jain_completion,
        fair.apps.iter().map(|a| a.completed).collect::<Vec<_>>()
    );
    // and fairness is not charity: fair-share serves no fewer
    // invocations overall than FIFO head-of-line blocking does
    assert!(
        fair.completed * 10 >= fifo.completed * 8,
        "fair-share throughput collapsed: {} vs {}",
        fair.completed,
        fifo.completed
    );
    // the light tenant is the beneficiary
    let light_fifo = fifo.apps[1].completed;
    let light_fair = fair.apps[1].completed;
    assert!(
        light_fair > light_fifo,
        "fair-share must serve the light tenant more: {light_fair} vs {light_fifo}"
    );
}

/// ISSUE 9 acceptance gate: at a fixed snapshot budget, predictive
/// pre-warming plus snapshot restore must beat an always-cold fleet by
/// ≥10x on p99 start latency over the *byte-identical* arrival
/// schedule. Always-cold disables proactive start-up and keeps the
/// snapshot layer off, so every environment pays the full reactive
/// cold boot; the tiered run pre-warms the top-k images per rack and
/// serves the rest from the warm pool, collapsing the start tail from
/// hundreds of milliseconds to tens.
#[test]
fn prewarmed_p99_start_beats_always_cold_by_10x_at_fixed_budget() {
    const MIB: u64 = 1024 * 1024;
    let mix = standard_mix(6, Archetype::Average);
    let base = DriverConfig { seed: 7, invocations: 600, ..DriverConfig::default() };
    let driver = MultiTenantDriver::new(&mix, base);
    // the schedule depends only on seed and mix, never on the start
    // tier policy — both runs replay identical arrivals
    let schedule = driver.schedule();

    let cold_cfg = DriverConfig {
        config: ZenixConfig { proactive: false, ..base.config },
        ..base
    };
    let cold = MultiTenantDriver::new(&mix, cold_cfg).run_zenix(&schedule);
    let tiered_cfg = DriverConfig {
        snapshot_budget_bytes: 8192 * MIB,
        prewarm: true,
        ..base
    };
    let tiered = MultiTenantDriver::new(&mix, tiered_cfg).run_zenix(&schedule);

    // engagement guards: the comparison must be between a genuinely
    // all-cold fleet and a genuinely tiered one
    assert!(cold.started > 0 && tiered.started > 0);
    assert_eq!(
        cold.tier_cold, cold.started,
        "always-cold must cold-boot every start ({} of {})",
        cold.tier_cold, cold.started
    );
    assert_eq!(cold.tier_restored + cold.tier_warm, 0);
    assert!(
        tiered.snap_prewarms > 0,
        "pre-warm must prime images before first use"
    );
    assert!(
        tiered.tier_restored + tiered.tier_warm > 0,
        "tiered run must serve starts below cold-boot cost"
    );

    // the acceptance bar: ≥10x on the p99 start-latency tail
    assert!(
        tiered.p99_start_ms * 10.0 <= cold.p99_start_ms,
        "need ≥10x p99 start improvement: tiered {:.1} ms vs always-cold {:.1} ms",
        tiered.p99_start_ms,
        cold.p99_start_ms
    );
    // and the mean moves the same direction
    assert!(tiered.mean_start_ms < cold.mean_start_ms);
}

/// Tier-split conservation regression (ISSUE 9): every started
/// invocation lands in exactly one start tier — `cold + restored +
/// warm == started` — fleet-wide *and* per app, in every
/// configuration: snapshot layer off, budget without pre-warm, budget
/// with pre-warm, and always-cold.
#[test]
fn tier_split_conserves_started_invocations_fleet_and_per_app() {
    const MIB: u64 = 1024 * 1024;
    let mix = standard_mix(8, Archetype::Average);
    let base = DriverConfig { seed: 13, invocations: 400, ..DriverConfig::default() };
    let schedule = MultiTenantDriver::new(&mix, base).schedule();

    let configs = [
        ("layer-off", base),
        ("budget", DriverConfig { snapshot_budget_bytes: 512 * MIB, ..base }),
        (
            "prewarm",
            DriverConfig { snapshot_budget_bytes: 512 * MIB, prewarm: true, ..base },
        ),
        (
            "always-cold",
            DriverConfig { config: ZenixConfig { proactive: false, ..base.config }, ..base },
        ),
    ];
    for (label, cfg) in configs {
        let r = MultiTenantDriver::new(&mix, cfg).run_zenix(&schedule);
        assert!(r.started > 0, "{label}: nothing started");
        assert_eq!(
            r.tier_cold + r.tier_restored + r.tier_warm,
            r.started,
            "{label}: fleet tier split must partition starts"
        );
        let mut per_app_started = 0;
        for (i, a) in r.apps.iter().enumerate() {
            assert_eq!(
                a.tier_cold + a.tier_restored + a.tier_warm,
                a.started,
                "{label}: app {i} tier split must partition its starts"
            );
            per_app_started += a.started;
        }
        assert_eq!(
            per_app_started, r.started,
            "{label}: per-app starts must sum to the fleet total"
        );
        // started bounds completed: nothing completes without starting
        assert!(r.completed <= r.started, "{label}: completed exceeds started");
    }
}

/// Satellite regression (ISSUE 10): end-of-trace queue expiry must
/// split genuine SLO violations (`timed_out`, deadline passed) from
/// entries drained only because the trace ended (`expired`, deadline
/// beyond the last event) — end-to-end through the driver, not just at
/// the queue layer. The tenant is a "whale" whose single wave accesses
/// eight server-sized data components: admission deterministically
/// fails even on an idle cluster, so every arrival parks and the final
/// drain makes no progress.
#[test]
fn end_of_trace_expiry_splits_slo_misses_from_drained_entries() {
    use zenix::apps::program::{compute, data};
    use zenix::apps::Program;
    use zenix::cluster::Resources;
    use zenix::coordinator::admission::AdmissionPolicy;

    // Eight data components each the size of a whole default server
    // (65536 MB): the degraded-allocation fallback shrinks free memory
    // by 10x per component and the launch path runs out well before the
    // last one, so the app can never be admitted — even idle.
    let mut c = compute("whale", 40.0, 1.0, 1.0);
    c.accesses = (0..8).collect();
    c.access_intensity = 0.2;
    let whale = Program {
        name: "whale",
        app_limit: Resources::new(32.0, 1_048_576.0),
        computes: vec![c],
        data: (0..8).map(|_| data("blob", 65_536.0)).collect(),
        entry: 0,
    };
    let mix = vec![TenantApp {
        graph: ResourceGraph::from_program(&whale).expect("whale compiles"),
        weight: 1.0,
        scales: ScaleModel::Fixed(1.0),
        deadline_ms: None,
        workflow: None,
    }];
    let base = DriverConfig {
        seed: 31,
        invocations: 40,
        mean_iat_ms: 200.0,
        cluster: ClusterSpec::multi_rack(1, 1),
        ..DriverConfig::default()
    };
    let schedule = MultiTenantDriver::new(&mix, base).schedule();

    // Long wait bound: every parked deadline lies beyond the last
    // event, so nothing is an SLO violation — all arrivals must drain
    // as `expired`, none as `timed_out`.
    let long_cfg = DriverConfig {
        admission: AdmissionPolicy::FifoQueue { max_wait_ms: 1e12, max_depth: 64 },
        ..base
    };
    let long = MultiTenantDriver::new(&mix, long_cfg).run_zenix(&schedule);
    assert_eq!(long.completed, 0, "the whale must never be admitted");
    assert_eq!(long.rejected, 0, "the queue is deep enough for every arrival");
    assert_eq!(long.timed_out, 0, "no deadline passed before the trace ended");
    assert_eq!(long.expired, 40, "every parked entry drains as expired");
    assert_eq!(long.failed, 40, "the digest-folded failure sum covers both splits");
    assert_eq!(long.apps[0].expired, 40, "the split must reach the per-app stats");
    assert_eq!(
        long.apps[0].completed + long.apps[0].failed(),
        long.apps[0].scheduled + long.apps[0].spawned,
        "per-app conservation with the expired term"
    );

    // Short wait bound (10 ms against a ~200 ms mean IAT): earlier
    // entries genuinely violate their SLO (timeouts), while an arrival
    // parked within 10 ms of the last event still holds an unviolated
    // deadline and must expire, not time out.
    let short_cfg = DriverConfig {
        admission: AdmissionPolicy::FifoQueue { max_wait_ms: 10.0, max_depth: 64 },
        ..base
    };
    let short = MultiTenantDriver::new(&mix, short_cfg).run_zenix(&schedule);
    assert_eq!(short.completed, 0);
    assert!(short.timed_out >= 1, "10 ms deadlines must produce real SLO misses");
    assert!(short.expired >= 1, "the trace-end parker must expire, not time out");
    assert_eq!(
        short.timed_out + short.expired + short.rejected,
        40,
        "the failure modes must partition the whale's arrivals"
    );

    // the split replay stays deterministic
    let again = MultiTenantDriver::new(&mix, short_cfg).run_zenix(&schedule);
    assert_eq!(short.digest, again.digest);
    assert_eq!(short.expired, again.expired);
}

/// ISSUE 10 tentpole acceptance: on the *identical* schedule, rack-
/// affinity stage placement must beat blind (smallest-fit) placement
/// on BOTH end-to-end workflow latency — mean AND p95 — and cross-rack
/// handoff traffic. Every tenant runs a three-stage pipeline with a
/// ~900 MB handoff, so a consumer placed off its producer's rack pays
/// a real transfer before it can launch.
#[test]
fn workflow_affinity_beats_blind_routing_on_latency_and_cross_rack_bytes() {
    use zenix::coordinator::Workflow;

    let mut mix = standard_mix(6, Archetype::Average);
    for app in mix.iter_mut() {
        app.workflow = Some(Workflow::pipeline(3, 900.0));
    }
    let base = DriverConfig {
        seed: 17,
        invocations: 300,
        mean_iat_ms: 500.0,
        cluster: ClusterSpec::multi_rack(4, 4),
        ..DriverConfig::default()
    };
    let driver = MultiTenantDriver::new(&mix, base);
    let schedule = driver.schedule();
    let aff = driver.run_zenix(&schedule);
    let blind =
        MultiTenantDriver::new(&mix, DriverConfig { workflow_affinity: false, ..base })
            .run_zenix(&schedule);

    // engagement guards: both runs must genuinely drive the DAGs
    assert!(aff.wf_runs > 0 && aff.wf_spawned > 0, "workflows must run");
    assert!(aff.wf_runs_completed > 0, "some workflow must complete end-to-end");
    assert!(aff.wf_affinity_hits > 0, "affinity must land stages on preferred racks");
    assert!(blind.wf_cross_rack_mb > 0.0, "blind routing must pay cross-rack handoffs");
    assert_eq!(aff.wf_runs, blind.wf_runs, "identical schedule, identical root count");

    assert!(
        aff.wf_cross_rack_mb < blind.wf_cross_rack_mb,
        "affinity must shrink cross-rack handoff bytes: {:.0} vs {:.0} MB",
        aff.wf_cross_rack_mb,
        blind.wf_cross_rack_mb
    );
    assert!(
        aff.wf_e2e_mean_ms < blind.wf_e2e_mean_ms,
        "affinity must shrink mean workflow latency: {:.1} vs {:.1} ms",
        aff.wf_e2e_mean_ms,
        blind.wf_e2e_mean_ms
    );
    assert!(
        aff.wf_e2e_p95_ms < blind.wf_e2e_p95_ms,
        "affinity must shrink p95 workflow latency: {:.1} vs {:.1} ms",
        aff.wf_e2e_p95_ms,
        blind.wf_e2e_p95_ms
    );

    // the workflow-coupled replay stays deterministic, telemetry included
    let again = MultiTenantDriver::new(&mix, base).run_zenix(&schedule);
    assert_eq!(aff.digest, again.digest);
    assert_eq!(aff.wf_cross_rack_mb.to_bits(), again.wf_cross_rack_mb.to_bits());
    assert_eq!(aff.wf_affinity_hits, again.wf_affinity_hits);
}

/// Satellite companion (ISSUE 10): with a zero snapshot budget nothing
/// is ever resident, so the post-repair tier re-resolution — and every
/// other snapshot-layer knob — must be digest-inert even under fault
/// injection (the coupling the bugfix touched).
#[test]
fn faulted_zero_budget_replay_ignores_snapshot_knobs() {
    use zenix::coordinator::FaultConfig;

    let mix = standard_mix(8, Archetype::Average);
    let base = DriverConfig {
        seed: 23,
        invocations: 400,
        faults: FaultConfig { rate_per_min: 2.0, ..FaultConfig::default() },
        ..DriverConfig::default()
    };
    let driver = MultiTenantDriver::new(&mix, base);
    let schedule = driver.schedule();
    let a = driver.run_zenix(&schedule);
    assert!(a.faulted > 0, "chaos must engage for this gate to mean anything");
    let b = MultiTenantDriver::new(&mix, DriverConfig { prewarm: true, ..base })
        .run_zenix(&schedule);
    assert_eq!(a.digest, b.digest, "budget-0 snapshot knobs must stay digest-inert");
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.faulted, b.faulted);
}

/// Locate the AOT artifacts or skip the test (they require `make
/// artifacts` plus a build with the `pjrt` feature; plain CI runs
/// without either — even with artifacts present — and must stay
/// green, since the stub Engine errors on every invoke).
macro_rules! artifacts_or_skip {
    () => {{
        if cfg!(not(feature = "pjrt")) {
            eprintln!("skipping PJRT integration test: built without the `pjrt` feature");
            return;
        }
        match find_artifact_dir() {
            Ok(dir) => dir,
            Err(e) => {
                eprintln!("skipping PJRT integration test: {e}");
                return;
            }
        }
    }};
}

const LR_N: usize = 1024;
const LR_D: usize = 256;

fn lr_data(rng: &mut Rng) -> (Tensor, Tensor, Vec<f32>) {
    // Linearly separable-ish data, mirrors python/tests/test_model.py.
    let w_true: Vec<f32> = (0..LR_D).map(|_| rng.normal() as f32).collect();
    let mut x = vec![0f32; LR_N * LR_D];
    let mut y = vec![0f32; LR_N];
    for i in 0..LR_N {
        let mut dot = 0f32;
        for j in 0..LR_D {
            let v = rng.normal() as f32;
            x[i * LR_D + j] = v;
            dot += v * w_true[j];
        }
        y[i] = if dot + 0.1 * rng.normal() as f32 > 0.0 { 1.0 } else { 0.0 };
    }
    (
        Tensor::new(x, vec![LR_N, LR_D]),
        Tensor::new(y, vec![LR_N, 1]),
        w_true,
    )
}

#[test]
fn lr_training_loss_decreases_via_pjrt() {
    let dir = artifacts_or_skip!();
    let (compute, _join) = spawn_compute_service(&dir).unwrap();
    let mut rng = Rng::new(42);
    let (x, y, _) = lr_data(&mut rng);
    let mut w = Tensor::zeros(&[LR_D, 1]);
    let mut losses = Vec::new();
    for _ in 0..30 {
        let (w2, loss) = compute.lr_train_step(x.clone(), y.clone(), w, 1.0).unwrap();
        w = w2;
        losses.push(loss);
    }
    assert!(
        losses[29] < 0.6 * losses[0],
        "loss did not decrease: first={} last={}",
        losses[0],
        losses[29]
    );
    let (_loss, acc) = compute.lr_eval(x, y, w).unwrap();
    assert!(acc > 0.85, "accuracy too low: {acc}");
    compute.shutdown();
}

#[test]
fn analytics_stage_matches_host_reference() {
    let dir = artifacts_or_skip!();
    let (compute, _join) = spawn_compute_service(&dir).unwrap();
    let (n, k, d) = (2048, 64, 32);
    let mut rng = Rng::new(7);
    let mut seg = vec![0f32; n * k];
    let mut x = vec![0f32; n * d];
    let mut want_sums = vec![0f64; k * d];
    let mut want_counts = vec![0f64; k];
    for i in 0..n {
        let s = rng.range(0, k);
        seg[i * k + s] = 1.0;
        want_counts[s] += 1.0;
        for j in 0..d {
            let v = rng.normal() as f32;
            x[i * d + j] = v;
            want_sums[s * d + j] += v as f64;
        }
    }
    let (sums, counts, means) = compute
        .analytics_stage(Tensor::new(seg, vec![n, k]), Tensor::new(x, vec![n, d]))
        .unwrap();
    for s in 0..k {
        assert!((counts.data[s] as f64 - want_counts[s]).abs() < 1e-3);
        for j in 0..d {
            let got = sums.data[s * d + j] as f64;
            assert!(
                (got - want_sums[s * d + j]).abs() < 1e-2,
                "segment {s} dim {j}: {got} vs {}",
                want_sums[s * d + j]
            );
            if want_counts[s] > 0.0 {
                let m = means.data[s * d + j] as f64;
                assert!((m - want_sums[s * d + j] / want_counts[s]).abs() < 1e-2);
            }
        }
    }
    compute.shutdown();
}

#[test]
fn video_block_mse_monotone_in_quantization() {
    let dir = artifacts_or_skip!();
    let (compute, _join) = spawn_compute_service(&dir).unwrap();
    let b = 256;
    let mut rng = Rng::new(9);
    let blocks = Tensor::new(
        (0..b * 64).map(|_| rng.uniform(0.0, 255.0) as f32).collect(),
        vec![b, 8, 8],
    );
    let mut mses = Vec::new();
    for qscale in [1.0f32, 8.0, 64.0] {
        let q = Tensor::new(vec![qscale; 64], vec![8, 8]);
        let (coefs, mse) = compute.video_block(blocks.clone(), q).unwrap();
        assert_eq!(coefs.shape, vec![b, 8, 8]);
        mses.push(mse);
    }
    assert!(mses[0] < mses[1] && mses[1] < mses[2], "{mses:?}");
    compute.shutdown();
}

#[test]
fn invoke_rejects_bad_shapes_and_entries() {
    let dir = artifacts_or_skip!();
    let (compute, _join) = spawn_compute_service(&dir).unwrap();
    let err = compute.invoke("no_such_entry", vec![]).unwrap_err().to_string();
    assert!(err.contains("unknown entry point"), "{err}");
    let err = compute
        .invoke("lr_eval", vec![Tensor::zeros(&[2, 2])])
        .unwrap_err()
        .to_string();
    assert!(err.contains("expected 3 inputs"), "{err}");
    let err = compute
        .invoke(
            "lr_eval",
            vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[2, 1]), Tensor::zeros(&[2, 1])],
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("shape"), "{err}");
    compute.shutdown();
}
