//! `zenix_lint` self-scan: the committed tree must pass its own static
//! determinism & accounting pass (the same gate `scripts/ci.sh` runs
//! via the bin target). A failure message prints the full text report,
//! so a regressing PR sees exactly the `file:line: [rule]` it added.

use std::path::Path;

#[test]
fn repo_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let r = zenix::analysis::scan_repo(root).expect("self-scan must run");
    assert!(r.clean(), "zenix_lint self-scan found violations:\n{}", r.render_text());
}

#[test]
fn self_scan_exercises_every_rule_and_the_allowlist() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let r = zenix::analysis::scan_repo(root).expect("self-scan must run");
    for rule in ["D1", "D2", "D3", "D4", "D5", "D6", "C1"] {
        assert!(r.rules_run.contains(&rule), "rule {rule} not active");
    }
    // the committed allowlist is live: every entry suppresses something
    // (stale entries would have failed `repo_is_lint_clean` above), and
    // the scan covered the real tree, not an empty directory.
    assert!(r.suppressed > 0, "allowlist suppressed nothing");
    assert!(r.files_scanned > 20, "only {} files scanned", r.files_scanned);
}
