//! Shape assertions for every reproduced figure: the paper's
//! qualitative claims — who wins, by roughly what factor, where
//! crossovers fall — asserted as tests (DESIGN.md §5).

use zenix::apps::lr;
use zenix::figures::{
    admission_figs, chaos_figs, coldstart_figs, lr_figs, platform_figs, scaling_figs,
    sharding_figs, tpcds_figs, video_figs,
};

// ---- §6.1.1 TPC-DS ------------------------------------------------------

#[test]
fn fig08_zenix_cuts_tpcds_memory_by_most_of_it() {
    // paper: 72.5% .. 84.8% memory reduction vs PyWren
    for (q, z, w) in tpcds_figs::fig08_09_tpcds(20.0) {
        let saving = z.mem_savings_vs(&w);
        assert!(
            saving > 0.5 && saving < 0.99,
            "Q{q}: saving {saving} outside the plausible band"
        );
    }
}

#[test]
fn fig09_zenix_faster_than_pywren() {
    // paper: 54.2% .. 63.5% faster (≈2.2-2.7×)
    for (q, z, w) in tpcds_figs::fig08_09_tpcds(20.0) {
        let speedup = z.speedup_vs(&w);
        assert!(speedup > 1.5, "Q{q}: speedup only {speedup}");
    }
}

#[test]
fn fig09_cpu_utilization_gap() {
    // paper: zenix 91.2% vs pywren 63.8% CPU utilization
    for (q, z, w) in tpcds_figs::fig08_09_tpcds(20.0) {
        assert!(
            z.consumption.cpu_utilization() > w.consumption.cpu_utilization(),
            "Q{q}"
        );
        assert!(z.consumption.cpu_utilization() > 0.8, "Q{q}");
    }
}

#[test]
fn fig10_each_ablation_step_helps() {
    let rows = tpcds_figs::fig10_ablation(20.0);
    assert_eq!(rows.len(), 4);
    // memory: every step no worse than the previous, full zenix ≪ DAG
    let mem: Vec<f64> = rows.iter().map(|r| r.consumption.alloc_gb_s()).collect();
    assert!(mem[1] < mem[0], "static RG already cuts memory: {mem:?}");
    assert!(mem[3] <= mem[1] * 1.1, "{mem:?}");
    // performance: adaptive step is the big one (co-location)
    let time: Vec<f64> = rows.iter().map(|r| r.exec_ms).collect();
    assert!(time[2] < time[1], "adaptive must speed up: {time:?}");
    assert!(time[3] <= time[2] * 1.05, "proactive must not regress: {time:?}");
    // co-location: paper reports ~78% of Q16 components co-located
    assert!(rows[3].local_fraction > 0.5, "{}", rows[3].local_fraction);
}

#[test]
fn fig19_pywren_waste_grows_as_inputs_shrink() {
    let rows = tpcds_figs::fig19_20_q1_inputs();
    // savings highest at the smallest input (fixed provisioning)
    let first_saving = rows[0].1.mem_savings_vs(&rows[0].2);
    let last_saving = rows.last().unwrap().1.mem_savings_vs(&rows.last().unwrap().2);
    assert!(first_saving > last_saving, "{first_saving} vs {last_saving}");
    // zenix always cheaper
    for (gb, z, w) in &rows {
        assert!(
            z.consumption.alloc_gb_s() < w.consumption.alloc_gb_s(),
            "{gb} GB"
        );
    }
}

#[test]
fn fig21_more_remote_components_cost_more_time() {
    for (senders, _, local, remote, disagg) in tpcds_figs::fig21_placement() {
        assert!(
            local.exec_ms <= remote.exec_ms * 1.05,
            "{senders}: local {} vs remote-scale {}",
            local.exec_ms,
            remote.exec_ms
        );
        assert!(
            remote.exec_ms <= disagg.exec_ms * 1.02,
            "{senders}: remote {} vs disagg {}",
            remote.exec_ms,
            disagg.exec_ms
        );
    }
}

// ---- §6.1.2 video -------------------------------------------------------

#[test]
fn fig11_zenix_fastest_at_all_resolutions() {
    for (res, rows) in video_figs::fig11_13_video() {
        let z = &rows[0];
        for other in &rows[1..] {
            assert!(
                z.exec_ms <= other.exec_ms * 1.02,
                "{res}: zenix {:.1}s vs {} {:.1}s",
                z.exec_ms / 1000.0,
                other.system,
                other.exec_ms / 1000.0
            );
        }
    }
}

#[test]
fn fig12_function_dags_waste_most_on_small_videos() {
    // ExCamera/gg provision for 4K: unused share largest at 240P
    let all = video_figs::fig11_13_video();
    let unused_frac = |rows: &Vec<zenix::metrics::RunReport>, i: usize| {
        let r = &rows[i];
        r.unused_gb_s() / r.consumption.alloc_gb_s().max(1e-9)
    };
    let at_240 = &all[0].1;
    let at_4k = &all[2].1;
    for sys in 1..3 {
        assert!(
            unused_frac(at_240, sys) > unused_frac(at_4k, sys),
            "system {sys}"
        );
    }
}

#[test]
fn fig13_vpxenc_underutilizes_cpu() {
    let rows = &video_figs::fig11_13_video()[1].1; // 720P
    let vpx = &rows[3];
    assert!(vpx.consumption.cpu_utilization() < 0.65);
    assert!(rows[0].consumption.cpu_utilization() > vpx.consumption.cpu_utilization());
}

#[test]
fn fig14_video_ablation_monotone_memory() {
    let rows = video_figs::fig14_ablation();
    let mem: Vec<f64> = rows.iter().map(|r| r.consumption.alloc_gb_s()).collect();
    assert!(mem[1] < mem[0], "{mem:?}");
    assert!(*mem.last().unwrap() < mem[0] * 0.8, "{mem:?}");
}

// ---- §6.1.3 LR ----------------------------------------------------------

#[test]
fn fig15_16_zenix_lowest_on_both_inputs() {
    for mb in [lr::SMALL_INPUT_MB, lr::LARGE_INPUT_MB] {
        let rows = lr_figs::fig15_16_lr(mb);
        let z = rows[0].consumption.alloc_gb_s();
        for other in &rows[2..] {
            assert!(
                z < other.consumption.alloc_gb_s(),
                "{mb} MB: {} ≥ {}",
                z,
                other.system
            );
        }
        // TCP variant close to RDMA (small overhead, §6.1.3)
        let tcp = rows[1].consumption.alloc_gb_s();
        assert!(tcp < 2.0 * z, "TCP {tcp} vs RDMA {z}");
    }
}

#[test]
fn fig15_improvement_higher_with_small_input() {
    let ow = |rows: &[zenix::metrics::RunReport]| {
        rows.iter().find(|r| r.system == "openwhisk").unwrap().clone()
    };
    let small = lr_figs::fig15_16_lr(lr::SMALL_INPUT_MB);
    let large = lr_figs::fig15_16_lr(lr::LARGE_INPUT_MB);
    let s_save = small[0].mem_savings_vs(&ow(&small));
    let l_save = large[0].mem_savings_vs(&ow(&large));
    // paper: 40% .. 84% savings vs OpenWhisk; more with the small input
    assert!(s_save > 0.3 && s_save < 0.95, "{s_save}");
    assert!(l_save > 0.2, "{l_save}");
    assert!(s_save >= l_save - 0.05, "small {s_save} vs large {l_save}");
}

#[test]
fn fig17_dag_baselines_pay_serde_zenix_does_not() {
    let rows = lr_figs::fig17_breakdown();
    let zenix = &rows[0];
    assert_eq!(zenix.breakdown.serialize_ms, 0.0);
    for name in ["sf-co(s3)", "sf-co(redis)", "sf-orion(s3)"] {
        let r = rows.iter().find(|r| r.system == name).unwrap();
        assert!(r.breakdown.serialize_ms > 0.0, "{name}");
        assert!(r.breakdown.io_ms > zenix.breakdown.io_ms, "{name}");
    }
}

#[test]
fn fig18_zenix_beats_all_scaling_techs() {
    for (label, rows) in lr_figs::fig18_scaling_tech() {
        let z = &rows[0];
        for other in &rows[1..] {
            assert!(
                z.exec_ms <= other.exec_ms * 1.05,
                "{label}: zenix {:.1}s vs {} {:.1}s",
                z.exec_ms / 1000.0,
                other.system,
                other.exec_ms / 1000.0
            );
        }
        // migration beats swap-disagg at large state? paper: both lose to
        // zenix; swap pays on every access, migration pays per move.
        let swap = &rows[1];
        let migros = &rows[3];
        assert!(swap.exec_ms > z.exec_ms && migros.exec_ms > z.exec_ms, "{label}");
    }
}

// ---- platform figures ---------------------------------------------------

#[test]
fn fig22_history_sizing_dominates() {
    let rows = platform_figs::fig22_sizing();
    for arch in ["small", "large", "varying", "stable", "average"] {
        let get = |strategy: &str| {
            rows.iter().find(|r| r.0 == arch && r.1 == strategy).unwrap()
        };
        let hist = get("zenix-history");
        let peak = get("peak-provision");
        let fixed = get("fixed-256/64");
        // peak: best performance, worst utilization on non-stable traces
        assert!(peak.3 <= hist.3 + 1e-9, "{arch}: peak slowdown");
        if arch != "stable" && arch != "small" {
            assert!(hist.2 >= peak.2 - 0.05, "{arch}: utilization {} vs peak {}", hist.2, peak.2);
        }
        // fixed config: poor somewhere — either utilization (small
        // traces) or performance (large traces)
        assert!(
            fixed.2 < 0.9 || fixed.3 > 1.01,
            "{arch}: fixed should be deficient somewhere"
        );
    }
}

#[test]
fn fig25_swap_overhead_in_paper_band() {
    // paper: +1%..+26% for moderate configs; overhead grows with array
    // size and shrinks with cache size
    let rows = platform_figs::fig25_swap();
    for (array, pat, cache, _, ovh) in &rows {
        if array <= cache {
            assert!(ovh.abs() < 0.01, "{array}/{pat}/{cache}: {ovh}");
        }
    }
    let get = |mb: f64, pat: &str, cache: f64| {
        rows.iter()
            .find(|r| r.0 == mb && r.1 == pat && r.2 == cache)
            .unwrap()
            .4
    };
    assert!(get(800.0, "seq", 200.0) > get(400.0, "seq", 200.0));
    assert!(get(800.0, "rand", 400.0) < get(800.0, "rand", 200.0));
}

#[test]
fn fig27_28_small_apps_zenix_matches_openwhisk() {
    for (app, z, ow) in platform_figs::fig27_28_small_apps() {
        // similar performance (within 2×: sub-second apps, warm paths)…
        assert!(z.exec_ms < ow.exec_ms * 2.0 + 1000.0, "{app}");
        // …but less allocated resource
        assert!(
            z.consumption.alloc_mem_mb_s <= ow.consumption.alloc_mem_mb_s * 1.2,
            "{app}: zenix {} vs ow {}",
            z.consumption.alloc_mem_mb_s,
            ow.consumption.alloc_mem_mb_s
        );
    }
}

#[test]
fn fig30_zenix_higher_utilization_and_throughput() {
    let rows = platform_figs::fig30_cluster_util(18);
    let zenix = rows.iter().find(|r| r.0 == "zenix").unwrap();
    let ow = rows.iter().find(|r| r.0 == "openwhisk").unwrap();
    assert!(zenix.2 > ow.2, "utilization {} vs {}", zenix.2, ow.2);
    assert!(zenix.1 < ow.1, "makespan {} vs {}", zenix.1, ow.1);
}

// ---- multi-rack sharding sweep ------------------------------------------

#[test]
fn sharding_sweep_fixed_capacity_deterministic_and_rendered() {
    let rack_counts = [1usize, 2, 4, 8];
    let rows = sharding_figs::fig_sharding_racks(6, 160, 7, &rack_counts);
    assert_eq!(rows.len(), 4);
    let single = &rows[0];
    assert_eq!(single.racks, 1);
    for (r, &racks) in rows.iter().zip(&rack_counts) {
        assert_eq!(r.racks, racks);
        // fixed total capacity: the paper testbed's 8 servers resharded
        assert_eq!(r.racks * r.servers_per_rack, 8, "racks={racks}");
        assert_eq!(r.completed + r.failed, 160, "racks={racks}: conservation");
        // Jain rides along and stays in range
        assert!(
            r.jain_completion >= 1.0 / 6.0 - 1e-9 && r.jain_completion <= 1.0 + 1e-9,
            "racks={racks}: jain {}",
            r.jain_completion
        );
        // every arrival routes through the global scheduler at least once
        assert!(
            r.route_fast_hits + r.route_scans >= 160,
            "racks={racks}: {} + {} routing decisions",
            r.route_fast_hits,
            r.route_scans
        );
        // sharding at fixed capacity must not collapse the fleet
        // (inter-rack spill keeps stranded capacity reachable)
        assert!(
            r.completed * 2 >= single.completed,
            "racks={racks}: completions collapsed ({} vs {})",
            r.completed,
            single.completed
        );
    }
    // per-seed digest stability of every sharded cell
    let again = sharding_figs::fig_sharding_racks(6, 160, 7, &rack_counts);
    for (a, b) in rows.iter().zip(&again) {
        assert_eq!(a.digest, b.digest, "racks={}: sweep must be digest-stable", a.racks);
    }
    // the renderer lists every cell (header + one line per row)
    let text = sharding_figs::render_sharding("sharding", &rows);
    assert_eq!(text.lines().count(), 2 + rows.len(), "render rows:\n{text}");
}

// ---- worker-count scaling sweep -----------------------------------------

#[test]
fn scaling_sweep_digest_constant_across_worker_counts() {
    // ISSUE 8 tentpole shape: the digest column is *flat* across the
    // whole sweep (parallelism is pure execution strategy), workers
    // clamp to the rack count, and the sharded cells actually report
    // parallel-loop telemetry — the sweep measures something real.
    let worker_counts = [1usize, 2, 4, 8];
    let rows = scaling_figs::fig_worker_scaling(6, 240, 9, 4, &worker_counts);
    assert_eq!(rows.len(), 4);
    let seq = &rows[0];
    assert_eq!(seq.workers, 1);
    assert_eq!(seq.epochs, 0, "workers=1 must take the sequential loop");
    for (r, &w) in rows.iter().zip(&worker_counts) {
        assert_eq!(r.workers_requested, w);
        assert_eq!(r.workers, w.min(4), "workers clamp to the rack count");
        assert_eq!(r.digest, seq.digest, "workers={w}: the digest moved");
        assert_eq!(r.completed, seq.completed, "workers={w}: completions moved");
        if r.workers > 1 {
            assert!(r.epochs > 0, "workers={w}: the epoch loop never engaged");
            assert!(
                r.parallel_local_events > 0,
                "workers={w}: no rack-local work ran in shard batches"
            );
            assert!(
                r.epoch_shard_jain > 0.0 && r.epoch_shard_jain <= 1.0 + 1e-9,
                "workers={w}: shard jain {} out of range",
                r.epoch_shard_jain
            );
        }
    }
    // per-seed digest stability of the sweep itself
    let again = scaling_figs::fig_worker_scaling(6, 240, 9, 4, &worker_counts);
    for (a, b) in rows.iter().zip(&again) {
        assert_eq!(a.digest, b.digest, "workers={}: sweep must be digest-stable", a.workers);
    }
    // the renderer lists every cell (header + one line per row)
    let text = scaling_figs::render_scaling("scaling", &rows);
    assert_eq!(text.lines().count(), 2 + rows.len(), "render rows:\n{text}");
}

// ---- admission control / offered-load sweep -----------------------------

#[test]
fn admission_sweep_fifo_dominates_reject_under_saturation() {
    // Two offered-load points (light and saturating) under MMPP bursts;
    // both policies replay the identical schedule per point.
    let rows = admission_figs::fig_admission_offered_load(10, 240, 7, &[240.0, 40.0]);
    assert_eq!(rows.len(), 4);
    let cell = |iat: f64, policy: &str| {
        rows.iter()
            .find(|r| r.mean_iat_ms == iat && r.policy == policy)
            .unwrap_or_else(|| panic!("missing cell {iat}/{policy}"))
            .clone()
    };
    for &iat in &[240.0, 40.0] {
        let rej = cell(iat, "reject");
        let fifo = cell(iat, "fifo");
        // reject never queues and reports no queueing delay
        assert_eq!(rej.queued, 0);
        assert_eq!(rej.timed_out, 0);
        assert_eq!(rej.mean_queue_delay_ms, 0.0);
        // queueing never fails more arrivals than rejecting does
        assert!(
            fifo.rejected + fifo.timed_out <= rej.rejected,
            "iat {iat}: fifo {}+{} vs reject {}",
            fifo.rejected,
            fifo.timed_out,
            rej.rejected
        );
        assert!(fifo.completed + fifo.aborted >= rej.completed, "iat {iat}");
    }
    // the saturated point must actually exercise admission…
    let rej_hot = cell(40.0, "reject");
    let fifo_hot = cell(40.0, "fifo");
    assert!(rej_hot.rejected > 0, "saturated sweep point must reject");
    assert!(fifo_hot.queued > 0, "saturated sweep point must park arrivals");
    // …and queueing pressure (delay experienced) grows with offered load
    let fifo_cold = cell(240.0, "fifo");
    assert!(
        fifo_hot.queued >= fifo_cold.queued,
        "parked entries should not shrink as load rises: {} vs {}",
        fifo_hot.queued,
        fifo_cold.queued
    );
    if fifo_hot.queued > fifo_hot.timed_out {
        assert!(fifo_hot.p95_queue_delay_ms >= fifo_hot.mean_queue_delay_ms * 0.5);
    }
    // the renderer lists every cell (rows start the line with the
    // policy name; the header's "rejected" column must not count)
    let text = admission_figs::render_admission("sweep", &rows);
    assert_eq!(text.matches("\nreject ").count(), 2, "render rows:\n{text}");
    assert_eq!(text.matches("\nfifo ").count(), 2, "render rows:\n{text}");
}

// ---- chaos sweep: availability vs fault pressure ------------------------

#[test]
fn chaos_sweep_goodput_and_recovery_vs_fault_rate() {
    let rates = [0.0, 10.0, 30.0];
    let rows = chaos_figs::fig_chaos_fault_rate(6, 160, 7, &rates);
    assert_eq!(rows.len(), 9, "3 policies x 3 rates");
    let mut total_faulted = 0usize;
    for r in &rows {
        if r.fault_rate_per_min == 0.0 {
            assert_eq!(r.faulted, 0, "{}: chaos-free row faulted", r.policy);
            assert_eq!(r.recovered, 0, "{}", r.policy);
        } else {
            total_faulted += r.faulted;
        }
        // faults split exactly into recovered vs lost in every cell
        assert_eq!(r.faulted, r.recovered + r.faulted_unrecovered, "{}", r.policy);
        assert!(
            r.goodput >= 0.0 && r.goodput <= 1.0,
            "{}: goodput {}",
            r.policy,
            r.goodput
        );
        // Jain's index over 6 tenants lives in [1/6, 1]
        assert!(
            r.jain_goodput >= 1.0 / 6.0 - 1e-9 && r.jain_goodput <= 1.0 + 1e-9,
            "{}: jain {}",
            r.policy,
            r.jain_goodput
        );
    }
    assert!(total_faulted > 0, "positive-rate rows must fault something");
    // per-seed determinism: the whole sweep replays digest-identically
    let again = chaos_figs::fig_chaos_fault_rate(6, 160, 7, &rates);
    for (a, b) in rows.iter().zip(&again) {
        assert_eq!(a.digest, b.digest, "{} @ {}", a.policy, a.fault_rate_per_min);
    }
    // the renderer lists header + one line per cell
    let text = chaos_figs::render_chaos("chaos", &rows);
    assert_eq!(text.lines().count(), 2 + rows.len(), "render rows:\n{text}");
}

// ---- cold-start-vs-cache-size sweep -------------------------------------

#[test]
fn coldstart_sweep_tail_collapses_with_budget() {
    // ISSUE 9 tentpole shape: an always-cold reference row, then the
    // tiered replay at growing per-rack snapshot budgets. Tier splits
    // conserve in every cell, the snapshot layer genuinely engages, and
    // the fully-budgeted cell beats the always-cold p99 start latency
    // by ≥10x (warm hits and snapshot restores displace cold boots).
    let budgets = [256u64, 1024, 8192];
    let rows = coldstart_figs::fig_coldstart_cache(6, 240, 9, &budgets);
    assert_eq!(rows.len(), 1 + budgets.len());
    let cold = &rows[0];
    assert_eq!(cold.policy, "always-cold");
    assert_eq!(cold.budget_mb, 0);
    // the reference row never restores and never warms: every start is
    // a full cold boot, and the snapshot layer is off entirely
    assert_eq!(cold.tier_restored, 0, "always-cold restored something");
    assert_eq!(cold.tier_warm, 0, "always-cold hit the warm pool");
    assert_eq!(cold.snap_hits + cold.snap_misses, 0, "layer must be off");
    assert!(cold.p99_start_ms > 0.0);
    for r in &rows {
        // tier-split conservation in every cell
        assert_eq!(
            r.tier_cold + r.tier_restored + r.tier_warm,
            r.started,
            "{} @ {} MB: tier split does not partition starts",
            r.policy,
            r.budget_mb
        );
        assert!(r.started >= r.completed, "{} @ {} MB", r.policy, r.budget_mb);
    }
    // budgeted cells must actually exercise the cache…
    let big = rows.last().unwrap();
    assert!(big.snap_hits > 0, "biggest budget never hit the cache");
    assert!(
        big.tier_restored + big.tier_warm > 0,
        "biggest budget never escaped a cold boot"
    );
    // …and the tail collapses: ≥10x p99 start-latency improvement
    assert!(
        big.p99_start_ms * 10.0 <= cold.p99_start_ms,
        "p99 start {} vs always-cold {}: less than 10x",
        big.p99_start_ms,
        cold.p99_start_ms
    );
    // per-seed digest stability of the whole sweep
    let again = coldstart_figs::fig_coldstart_cache(6, 240, 9, &budgets);
    for (a, b) in rows.iter().zip(&again) {
        assert_eq!(a.digest, b.digest, "{} @ {} MB: sweep must be digest-stable", a.policy, a.budget_mb);
    }
    // the renderer lists every cell (header + one line per row)
    let text = coldstart_figs::render_coldstart("coldstart", &rows);
    assert_eq!(text.lines().count(), 2 + rows.len(), "render rows:\n{text}");
}

// ---- workflow affinity sweep --------------------------------------------

#[test]
fn workflow_sweep_affinity_wins_both_axes_at_every_handoff() {
    use zenix::figures::workflow_figs;

    // ISSUE 10 tentpole shape: at every handoff size the affinity row
    // must beat its blind twin (identical schedule) on cross-rack
    // handoff bytes AND end-to-end workflow latency, mean and p95.
    let handoffs = [100.0, 400.0, 900.0];
    let rows = workflow_figs::fig_workflow_affinity(6, 240, 17, &handoffs);
    assert_eq!(rows.len(), 2 * handoffs.len());
    for pair in rows.chunks(2) {
        let (aff, blind) = (&pair[0], &pair[1]);
        assert_eq!(aff.placement, "affinity");
        assert_eq!(blind.placement, "blind");
        assert_eq!(aff.handoff_mb, blind.handoff_mb);
        // engagement: workflows must genuinely run in both cells
        assert!(aff.wf_runs_completed > 0, "@{} MB: no workflow completed", aff.handoff_mb);
        assert!(aff.affinity_hits > 0, "@{} MB: affinity never engaged", aff.handoff_mb);
        assert_eq!(blind.affinity_hits, 0, "blind routing has no preferred rack");
        assert!(
            blind.cross_rack_mb > 0.0,
            "@{} MB: blind routing must pay cross-rack handoffs",
            aff.handoff_mb
        );
        // the tentpole: both axes, every handoff size
        assert!(
            aff.cross_rack_mb < blind.cross_rack_mb,
            "@{} MB: cross-rack {} vs {}",
            aff.handoff_mb,
            aff.cross_rack_mb,
            blind.cross_rack_mb
        );
        assert!(
            aff.wf_e2e_mean_ms < blind.wf_e2e_mean_ms,
            "@{} MB: e2e mean {} vs {}",
            aff.handoff_mb,
            aff.wf_e2e_mean_ms,
            blind.wf_e2e_mean_ms
        );
        assert!(
            aff.wf_e2e_p95_ms < blind.wf_e2e_p95_ms,
            "@{} MB: e2e p95 {} vs {}",
            aff.handoff_mb,
            aff.wf_e2e_p95_ms,
            blind.wf_e2e_p95_ms
        );
    }
    // per-seed digest stability of the whole sweep
    let again = workflow_figs::fig_workflow_affinity(6, 240, 17, &handoffs);
    for (a, b) in rows.iter().zip(&again) {
        assert_eq!(
            a.digest, b.digest,
            "{} @ {} MB: sweep must be digest-stable",
            a.placement, a.handoff_mb
        );
    }
    // the renderer lists every cell (header + one line per row)
    let text = workflow_figs::render_workflow("workflow", &rows);
    assert_eq!(text.lines().count(), 2 + rows.len(), "render rows:\n{text}");
}

#[test]
fn workflow_vs_function_dag_reports_every_real_app() {
    use zenix::figures::workflow_figs;

    // The per-app baseline table: all three real evaluation apps, each
    // with a meaningful Zenix measurement and a function-DAG (PyWren)
    // reference on the same program and scale.
    let rows = workflow_figs::fig_workflow_vs_function_dag(180, 11, 300.0);
    assert_eq!(rows.len(), 3, "one row per real workflow app");
    let names: Vec<&str> = rows.iter().map(|r| r.app).collect();
    assert!(names.contains(&"logreg"), "{names:?}");
    assert!(names.contains(&"video-transcode"), "{names:?}");
    for r in &rows {
        assert!(r.zenix_mean_exec_ms > 0.0, "{}: zenix never completed a stage", r.app);
        assert!(r.dag_exec_ms > 0.0, "{}: baseline must execute", r.app);
        assert!(r.zenix_alloc_gb_s > 0.0 && r.dag_alloc_gb_s > 0.0, "{}", r.app);
        // the bulky-app argument: the per-function-box baseline pays
        // more wall-clock than a Zenix stage on the same program
        assert!(
            r.zenix_mean_exec_ms < r.dag_exec_ms,
            "{}: zenix stage {} ms vs pywren {} ms",
            r.app,
            r.zenix_mean_exec_ms,
            r.dag_exec_ms
        );
    }
    let text = workflow_figs::render_workflow_baseline("workflow-vs-dag", &rows);
    assert_eq!(text.lines().count(), 2 + rows.len(), "render rows:\n{text}");
}
