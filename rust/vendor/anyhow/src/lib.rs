//! Minimal, offline, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline, so the real crates.io
//! `anyhow` cannot be fetched. This shim implements the subset the
//! Zenix crate actually uses:
//!
//! - [`Error`]: boxed dynamic error with `Display`/`Debug`,
//!   `Send + Sync`, convertible from any `std::error::Error` via `?`;
//! - [`Result`]: `Result<T, Error>` alias with a defaulted error type;
//! - [`anyhow!`]: format-style error constructor;
//! - [`bail!`]: early-return with a formatted error.
//!
//! Swapping back to the real crate is a one-line `Cargo.toml` change —
//! no source edits — because every construct here matches the upstream
//! names and semantics (for this subset).

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error. Like upstream `anyhow::Error`, this type
/// deliberately does **not** implement `std::error::Error` itself so
/// the blanket `From<E: std::error::Error>` conversion below does not
/// overlap with the reflexive `From<Error> for Error`.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Construct from any error value.
    pub fn new<E>(error: E) -> Self
    where
        E: StdError + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// Construct from a displayable message (what [`anyhow!`] expands to).
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Borrow the underlying dynamic error.
    pub fn as_dyn(&self) -> &(dyn StdError + Send + Sync + 'static) {
        &*self.0
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Upstream prints the message (plus a cause chain); the message
        // alone is what our tests and panics rely on.
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Message-only error payload backing [`Error::msg`].
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Display> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

/// `Result` with a defaulted boxed error, mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn question_mark_passes_through_error() {
        fn leaf() -> Result<u32> {
            Err(anyhow!("leaf failed with code {}", 7))
        }
        fn outer() -> Result<u32> {
            let v = leaf()?;
            Ok(v)
        }
        let e = outer().unwrap_err();
        assert_eq!(e.to_string(), "leaf failed with code 7");
    }

    #[test]
    fn bail_returns_formatted() {
        fn f(x: i32) -> Result<()> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
    }

    #[test]
    fn debug_matches_display() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }
}
