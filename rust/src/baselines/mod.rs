//! Every system the paper compares against (§6), built on the same
//! cost models as the Zenix platform so comparisons are apples-to-apples:
//!
//! - [`kvstore`] — Redis/S3-style intermediate storage (serialization +
//!   transfer + provisioned instances).
//! - [`orion`] — Orion's per-function size tuning [40] (used by the
//!   PyWren and SF-Orion configurations).
//! - [`dag`] — generic function-DAG executor: PyWren [36], gg [29],
//!   ExCamera [30], AWS Step Functions configurations.
//! - [`faas`] — single-function FaaS: OpenWhisk [5], AWS Lambda [7].
//! - [`fastswap`] — remote-memory swapping baseline [10].
//! - [`migration`] — live-migration baselines: best-case + MigrOS [54].
//! - [`vpxenc`] — single-server native encoder [70].

pub mod dag;
pub mod faas;
pub mod fastswap;
pub mod kvstore;
pub mod migration;
pub mod orion;
pub mod vpxenc;

pub use dag::{DagParams, KvChoice};
pub use kvstore::KvStore;
