//! Intermediate KV storage for function DAGs (Redis / S3 models).
//!
//! Function-DAG systems persist inter-stage data in a disaggregated
//! store (§1, §2.2): every hop pays serialization + network, the data
//! occupies memory *twice* (worker copy + store copy), and a Redis
//! deployment is long-running and peak-provisioned (§6.1.3).

use crate::cluster::clock::Millis;
use crate::net::NetModel;

/// Store flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvStore {
    /// In-cluster Redis: fast, but provisioned (the paper runs 4
    /// dedicated Redis servers).
    Redis,
    /// S3-style object store: slower per hop, no provisioned memory
    /// charged to the tenant.
    S3,
}

impl KvStore {
    /// Latency of moving `mb` through the store once (read or write),
    /// including serialization.
    pub fn hop_ms(&self, net: &NetModel, mb: f64) -> Millis {
        match self {
            KvStore::Redis => net.kv_hop(mb),
            // S3: higher base latency, lower bandwidth, same serde
            KvStore::S3 => 25.0 + 2.0 * net.serialize_ms_per_mb * mb + mb / 1.2,
        }
    }

    /// Memory (MB) the store itself holds for `mb` of live data.
    pub fn store_copy_mb(&self, mb: f64) -> f64 {
        match self {
            KvStore::Redis => mb * 1.1, // structures overhead
            KvStore::S3 => 0.0,         // not charged as cluster memory
        }
    }

    /// Provisioned instance memory (MB) — Redis runs peak-provisioned
    /// regardless of current load (§6.1.3 "long-running Redis instance
    /// is provisioned for peak").
    pub fn provisioned_mb(&self, peak_live_mb: f64) -> f64 {
        match self {
            KvStore::Redis => (peak_live_mb * 1.5).max(4096.0),
            KvStore::S3 => 0.0,
        }
    }

    /// Extra worker-side memory for serialization buffers (§6.1.3:
    /// "serialization and deserialization also requires extra memory").
    pub fn serde_buffer_mb(&self, mb: f64) -> f64 {
        mb * 0.35
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn redis_hop_faster_than_s3() {
        let net = NetModel::default();
        for mb in [1.0, 100.0, 1000.0] {
            assert!(
                KvStore::Redis.hop_ms(&net, mb) < KvStore::S3.hop_ms(&net, mb),
                "mb={mb}"
            );
        }
    }

    #[test]
    fn redis_charges_memory_s3_does_not() {
        assert!(KvStore::Redis.store_copy_mb(100.0) >= 100.0);
        assert_eq!(KvStore::S3.store_copy_mb(100.0), 0.0);
        assert!(KvStore::Redis.provisioned_mb(100.0) >= 4096.0);
        assert_eq!(KvStore::S3.provisioned_mb(100.0), 0.0);
    }

    #[test]
    fn provisioning_scales_with_peak() {
        let small = KvStore::Redis.provisioned_mb(1000.0);
        let big = KvStore::Redis.provisioned_mb(100_000.0);
        assert!(big > small);
    }
}
