//! FastSwap-style remote-memory swapping baseline [10] (§6.1.3, Fig 18).
//!
//! The application runs with local memory equal to Zenix's compute-
//! component size while the *peak* memory is provisioned remotely for
//! the whole run (disaggregation systems "assume compute nodes have
//! insufficient memory and always make remote accesses", §2.3 — no
//! autoscaling of the remote pool). All beyond-local accesses swap.

use crate::apps::{Invocation, Program};
use crate::cluster::server::Consumption;
use crate::cluster::startup::{StartupModel, StartupPath};
use crate::memory::{AccessPattern, SwapConfig, SwapSim};
use crate::metrics::{Breakdown, RunReport};
use crate::net::{NetKind, NetModel};
use crate::util::rng::Rng;

/// Run under swap-based disaggregation.
///
/// `local_frac` — fraction of each phase's working set resident locally
/// (the paper matches Zenix's compute-component size).
pub fn run(
    program: &Program,
    inv: Invocation,
    local_frac: f64,
    net: &NetModel,
    startup: &StartupModel,
) -> RunReport {
    let scale = inv.input_scale;
    let peak = program.peak_estimate(scale);
    let remote_pool_mb = peak.mem_mb; // provisioned at peak, entire run
    let mut breakdown = Breakdown::default();
    let mut compute_total = 0.0f64;
    let mut t = 0.0f64;
    let mut used_mem_ms = 0.0f64;
    let mut local_mem = 0.0f64;
    let mut rng = Rng::new(0xFA57);

    breakdown.startup_ms = startup.cold(StartupPath::OpenWhisk);
    t += breakdown.startup_ms;

    for c in &program.computes {
        let workers = c.parallelism_at(scale).max(1);
        let phase_mem = workers as f64 * c.mem_at(scale);
        let local_mb = phase_mem * local_frac.clamp(0.05, 1.0);
        local_mem = local_mem.max(local_mb);
        let compute_ms = c.work_at(scale) / workers as f64 / 0.8;
        // Swap overhead: one pass over the phase's working set through
        // the page-granular simulator (calibrated slowdown), scaled by
        // the phase's access intensity.
        let mut sim = SwapSim::new(
            phase_mem.max(1.0),
            SwapConfig { local_mb, net: NetKind::Rdma, ..Default::default() },
            *net,
        );
        let run = sim.run_pass(AccessPattern::Sequential, &mut rng);
        let swap_factor = 1.0 + run.overhead().min(30.0) * c.access_intensity;
        let phase_ms = compute_ms * swap_factor;
        compute_total += compute_ms;
        breakdown.io_ms += phase_ms - compute_ms;
        used_mem_ms += phase_mem.min(local_mb + remote_pool_mb) * phase_ms;
        t += phase_ms;
    }
    breakdown.compute_ms = compute_total;

    let dur_s = t / 1000.0;
    let vcpus = peak.cpu.max(1.0);
    RunReport {
        system: "fastswap".into(),
        workload: program.name.into(),
        exec_ms: t,
        breakdown,
        consumption: Consumption {
            alloc_cpu_s: vcpus * dur_s,
            used_cpu_s: vcpus * 0.8 * (compute_total / 1000.0),
            // local + peak-provisioned remote pool for the whole run
            alloc_mem_mb_s: (local_mem + remote_pool_mb) * dur_s,
            used_mem_mb_s: (used_mem_ms / 1000.0).min((local_mem + remote_pool_mb) * dur_s),
        },
        local_fraction: local_frac,
        peak_cpu: vcpus,
        peak_mem_mb: local_mem + remote_pool_mb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lr;

    #[test]
    fn swap_slower_than_full_local() {
        let p = lr::program();
        let full = run(&p, Invocation::new(1.0), 1.0, &NetModel::default(), &StartupModel::default());
        let half = run(&p, Invocation::new(1.0), 0.3, &NetModel::default(), &StartupModel::default());
        assert!(half.exec_ms > full.exec_ms);
        assert!(half.breakdown.io_ms > full.breakdown.io_ms);
    }

    #[test]
    fn remote_pool_provisioned_at_peak() {
        let p = lr::program();
        let r = run(&p, Invocation::new(1.0), 0.3, &NetModel::default(), &StartupModel::default());
        let peak = p.peak_estimate(1.0);
        assert!(r.peak_mem_mb >= peak.mem_mb, "remote pool covers peak");
        // waste: allocation well above use
        assert!(r.consumption.alloc_mem_mb_s > r.consumption.used_mem_mb_s);
    }
}
