//! Orion-style function sizing [40] (§2.2, §6.1.1, §6.1.3 SF-Orion).
//!
//! Orion picks one cost-optimal size per function from its latency/size
//! profile — but that size is then *fixed* for the whole execution and
//! all invocations (the limitation Zenix removes). We model the
//! latency(size) curve as work-conserving with a memory floor: below the
//! true need the function thrashes/fails; above it, latency stops
//! improving, so cost (≈ mem × time) grows linearly.

/// AWS Lambda-style size menu (MB): 128 MB steps up to 10 GB.
pub fn lambda_menu() -> Vec<f64> {
    (1..=80).map(|i| 128.0 * i as f64).collect()
}

/// Latency of a function given `mem_mb`, with true need `need_mb` and
/// pure-compute latency `compute_ms` (CPU share scales with memory on
/// AWS: cpu = mem / 1769 MB).
pub fn latency_ms(mem_mb: f64, need_mb: f64, compute_ms: f64, cpu_per_1769mb: bool) -> f64 {
    if mem_mb < need_mb {
        return f64::INFINITY; // OOM — the paper's "application failure"
    }
    if cpu_per_1769mb {
        // AWS couples CPU to memory: 1 vCPU per 1769 MB.
        let vcpus = (mem_mb / 1769.0).max(1.0 / 16.0);
        compute_ms / vcpus
    } else {
        compute_ms
    }
}

/// Cost in GB·s for a size/latency pair.
pub fn cost_gb_s(mem_mb: f64, latency_ms: f64) -> f64 {
    (mem_mb / 1024.0) * (latency_ms / 1000.0)
}

/// Orion pick: minimize latency subject to cost ≤ (1 + slack) × the
/// cost-optimal configuration (Orion's "right-sizing" balances both; we
/// use its published behaviour of choosing near-cost-optimal but
/// latency-aware sizes).
pub fn orion_size(need_mb: f64, compute_ms: f64, slack: f64) -> f64 {
    let menu = lambda_menu();
    let co = cost_optimal_size(need_mb, compute_ms);
    let co_cost = cost_gb_s(co, latency_ms(co, need_mb, compute_ms, true));
    menu.iter()
        .copied()
        .filter(|&m| {
            let l = latency_ms(m, need_mb, compute_ms, true);
            l.is_finite() && cost_gb_s(m, l) <= co_cost * (1.0 + slack)
        })
        .min_by(|&a, &b| {
            latency_ms(a, need_mb, compute_ms, true)
                .partial_cmp(&latency_ms(b, need_mb, compute_ms, true))
                .unwrap()
                .then(a.partial_cmp(&b).unwrap())
        })
        .unwrap_or_else(|| menu.last().copied().unwrap())
}

/// Pure cost-optimal size (the SF-CO configuration / power-tuning
/// tools [6, 9, 27]).
pub fn cost_optimal_size(need_mb: f64, compute_ms: f64) -> f64 {
    lambda_menu()
        .into_iter()
        .filter(|&m| m >= need_mb)
        .min_by(|&a, &b| {
            let ca = cost_gb_s(a, latency_ms(a, need_mb, compute_ms, true));
            let cb = cost_gb_s(b, latency_ms(b, need_mb, compute_ms, true));
            ca.partial_cmp(&cb).unwrap().then(a.partial_cmp(&b).unwrap())
        })
        .unwrap_or(10240.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn menu_has_aws_shape() {
        let m = lambda_menu();
        assert_eq!(m[0], 128.0);
        assert_eq!(*m.last().unwrap(), 10240.0);
        assert_eq!(m.len(), 80);
    }

    #[test]
    fn undersized_is_infeasible() {
        assert!(latency_ms(128.0, 512.0, 100.0, true).is_infinite());
    }

    #[test]
    fn sizes_cover_need() {
        for need in [100.0, 700.0, 2400.0, 9000.0] {
            assert!(cost_optimal_size(need, 1000.0) >= need);
            assert!(orion_size(need, 1000.0, 0.15) >= need);
        }
    }

    #[test]
    fn orion_at_least_as_fast_as_cost_optimal() {
        let need = 700.0;
        let co = cost_optimal_size(need, 5000.0);
        let or = orion_size(need, 5000.0, 0.25);
        let l_co = latency_ms(co, need, 5000.0, true);
        let l_or = latency_ms(or, need, 5000.0, true);
        assert!(l_or <= l_co + 1e-9);
    }

    #[test]
    fn cpu_coupling_speeds_up_with_memory() {
        let slow = latency_ms(1769.0, 100.0, 1000.0, true);
        let fast = latency_ms(3538.0, 100.0, 1000.0, true);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }
}
