//! Generic function-DAG executor (§2.2): PyWren, gg, ExCamera and AWS
//! Step Functions are configurations of this engine.
//!
//! The defining properties the paper calls out — all modeled here:
//!
//! 1. **Fixed function sizes**: each stage's function size is chosen
//!    once (for the largest anticipated input, or by Orion/cost tuning)
//!    and used for *all* invocations and the *whole* stage duration.
//! 2. **Separate environments**: every function pays its own startup.
//! 3. **Disaggregated intermediates**: stage boundaries go through a KV
//!    store — serialization cost, extra buffer memory, a second copy of
//!    the data in the store, and (for Redis) a peak-provisioned
//!    long-running instance.

use crate::apps::{Invocation, Program};
use crate::cluster::server::Consumption;
use crate::cluster::startup::{StartupModel, StartupPath};
use crate::metrics::{Breakdown, RunReport};
use crate::net::NetModel;

use super::kvstore::KvStore;
use super::orion;

/// Intermediate-data strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvChoice {
    /// Peak-provisioned long-running Redis instance.
    Redis,
    /// Object store: slower hops, no provisioned instance.
    S3,
    /// Direct streaming through a long-running coordinator (original
    /// ExCamera's fixed VM).
    CoordinatorVm,
}

/// Stage function-sizing policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FnSizing {
    /// Provision for the largest anticipated input (the paper's
    /// ExCamera/gg behaviour): size at `max_scale`.
    PeakStatic { max_scale: f64 },
    /// Orion-tuned per stage at the profiled scale [40].
    Orion { profile_scale: f64 },
    /// Cost-optimal tuning (SF-CO / power-tuning tools).
    CostOptimal { profile_scale: f64 },
}

/// One function-DAG system configuration.
#[derive(Debug, Clone, Copy)]
pub struct DagParams {
    /// System label used in figure rows.
    pub name: &'static str,
    /// Intermediate-data strategy.
    pub kv: KvChoice,
    /// Stage function-sizing policy.
    pub sizing: FnSizing,
    /// Sub-functions per logical worker (gg represents one frame batch
    /// with 80 functions → more startups + more KV hops).
    pub split: usize,
    /// Achieved CPU utilization (§6.1.1: PyWren 63.8%).
    pub cpu_efficiency: f64,
    /// Fraction of function starts served warm.
    pub warm_fraction: f64,
    /// Which platform's startup-latency model applies.
    pub startup_path: StartupPath,
    /// AWS CPU-memory coupling (Lambda: 1 vCPU / 1769 MB).
    pub aws_coupling: bool,
}

impl DagParams {
    /// PyWren on OpenWhisk with Orion-tuned workers (§6.1.1 setup).
    pub fn pywren(profile_scale: f64) -> Self {
        Self {
            name: "pywren+orion",
            kv: KvChoice::Redis,
            sizing: FnSizing::Orion { profile_scale },
            split: 1,
            cpu_efficiency: 0.638,
            warm_fraction: 0.5,
            startup_path: StartupPath::OpenWhisk,
            aws_coupling: false,
        }
    }

    /// gg on OpenWhisk (§6.1.2: 80 functions per frame batch).
    pub fn gg(max_scale: f64) -> Self {
        Self {
            name: "gg",
            kv: KvChoice::Redis,
            sizing: FnSizing::PeakStatic { max_scale },
            split: 5,
            cpu_efficiency: 0.60,
            warm_fraction: 0.5,
            startup_path: StartupPath::OpenWhisk,
            aws_coupling: false,
        }
    }

    /// Original ExCamera: coordinator VM + serverless encode workers.
    pub fn excamera(max_scale: f64) -> Self {
        Self {
            name: "excamera",
            kv: KvChoice::CoordinatorVm,
            sizing: FnSizing::PeakStatic { max_scale },
            split: 1,
            cpu_efficiency: 0.65,
            warm_fraction: 0.5,
            startup_path: StartupPath::OpenWhisk,
            aws_coupling: false,
        }
    }

    /// AWS Step Functions, cost-optimized sizing, chosen store.
    pub fn sf_co(profile_scale: f64, kv: KvChoice) -> Self {
        Self {
            name: "sf-co",
            kv,
            sizing: FnSizing::CostOptimal { profile_scale },
            split: 1,
            cpu_efficiency: 0.70,
            warm_fraction: 0.4,
            startup_path: StartupPath::StepFunctions,
            aws_coupling: true,
        }
    }

    /// AWS Step Functions with Orion sizing.
    pub fn sf_orion(profile_scale: f64, kv: KvChoice) -> Self {
        Self {
            name: "sf-orion",
            kv,
            sizing: FnSizing::Orion { profile_scale },
            split: 1,
            cpu_efficiency: 0.70,
            warm_fraction: 0.4,
            startup_path: StartupPath::StepFunctions,
            aws_coupling: true,
        }
    }

    fn store(&self) -> Option<KvStore> {
        match self.kv {
            KvChoice::Redis => Some(KvStore::Redis),
            KvChoice::S3 => Some(KvStore::S3),
            KvChoice::CoordinatorVm => None,
        }
    }
}

/// Execute `program` at `inv` under this function-DAG configuration.
pub fn run(
    program: &Program,
    inv: Invocation,
    params: DagParams,
    net: &NetModel,
    startup: &StartupModel,
) -> RunReport {
    let scale = inv.input_scale;
    let graph = crate::coordinator::graph::ResourceGraph::from_program(program)
        .expect("program validated");
    let mut breakdown = Breakdown::default();
    let mut consumption = Consumption::default();
    let mut t = 0.0f64;
    let mut peak_cpu = 0.0f64;
    let mut peak_mem = 0.0f64;
    let mut peak_live_kv = 0.0f64;

    for wave in graph.waves() {
        let mut wave_ms = 0.0f64;
        let mut wave_cpu = 0.0f64;
        let mut wave_mem = 0.0f64;
        for &c in &wave {
            let spec = &program.computes[c];
            // `split` sub-functions per logical worker form a *serial
            // chain* (gg's 80-function batches): they multiply function
            // count (startups, KV hops) without adding parallelism.
            let logical_workers = spec.parallelism_at(scale).max(1);
            let workers = logical_workers * params.split;
            let need_worker_mb = spec.mem_at(scale) / params.split as f64;

            // ---- fixed function size (the DAG limitation) --------------
            let serde_extra = params
                .store()
                .map_or(0.0, |s| s.serde_buffer_mb(need_worker_mb));
            let fn_mem = match params.sizing {
                FnSizing::PeakStatic { max_scale } => {
                    spec.mem_at(max_scale) / params.split as f64 + serde_extra
                }
                FnSizing::Orion { profile_scale } => {
                    let prof_need =
                        spec.mem_at(profile_scale) / params.split as f64 + serde_extra;
                    let per_worker_ms = spec.work_at(profile_scale)
                        / (spec.parallelism_at(profile_scale).max(1) * params.split) as f64;
                    orion::orion_size(prof_need, per_worker_ms, 0.15)
                }
                FnSizing::CostOptimal { profile_scale } => {
                    let prof_need =
                        spec.mem_at(profile_scale) / params.split as f64 + serde_extra;
                    let per_worker_ms = spec.work_at(profile_scale)
                        / (spec.parallelism_at(profile_scale).max(1) * params.split) as f64;
                    orion::cost_optimal_size(prof_need, per_worker_ms)
                }
            };
            // Under-provisioned for this input → the function runs
            // degraded (spill/retry): charge a slowdown instead of
            // failing outright.
            let undersized = fn_mem < need_worker_mb + serde_extra;
            let degrade = if undersized { 1.8 } else { 1.0 };

            // ---- per-worker compute time --------------------------------
            let vcpus = if params.aws_coupling {
                (fn_mem / 1769.0).clamp(1.0 / 16.0, 6.0)
            } else {
                1.0
            };
            let compute_ms = spec.work_at(scale)
                / (logical_workers as f64 * vcpus * params.cpu_efficiency)
                * degrade;

            // ---- startup per function -----------------------------------
            let cold = startup.cold(params.startup_path);
            let warm = startup.warm(params.startup_path);
            // each link of the serial sub-function chain pays startup on
            // the critical path; parallel workers start concurrently.
            let start_ms = (params.warm_fraction * warm
                + (1.0 - params.warm_fraction) * cold)
                * params.split as f64;
            breakdown.startup_ms += start_ms;

            // ---- KV hops -----------------------------------------------
            let stage_data_mb: f64 = spec
                .accesses
                .iter()
                .map(|&d| program.data[d].size_at(scale))
                .sum();
            // "each worker fetches all the data it will access" (§6.1.1):
            // shared data (joins) is read in full by EVERY worker;
            // partitioned data splits across workers.
            let per_worker_data: f64 = spec
                .accesses
                .iter()
                .map(|&d| {
                    let sz = program.data[d].size_at(scale);
                    if program.data[d].shared {
                        sz
                    } else {
                        sz / logical_workers as f64
                    }
                })
                .sum();
            let (kv_ms, serde_ms) = match params.store() {
                Some(s) => {
                    let hop = s.hop_ms(net, per_worker_data);
                    let serde = 2.0 * net.serialize_ms_per_mb * per_worker_data;
                    // read before compute + write after (§6.1.1); every
                    // link of the sub-function chain repeats the hops
                    let chain = params.split as f64;
                    ((2.0 * hop - serde) * chain, serde * chain)
                }
                None => {
                    // coordinator VM streams data over TCP (no serde)
                    (2.0 * net.transfer(crate::net::NetKind::Tcp, per_worker_data, false), 0.0)
                }
            };
            breakdown.io_ms += kv_ms;
            breakdown.serialize_ms += serde_ms;
            breakdown.compute_ms += compute_ms;

            let stage_ms = start_ms + kv_ms + serde_ms + compute_ms;
            wave_ms = wave_ms.max(stage_ms);

            // ---- consumption --------------------------------------------
            let dur_s = stage_ms / 1000.0;
            let alloc_cpu = workers as f64 * vcpus;
            consumption.alloc_cpu_s += alloc_cpu * dur_s;
            consumption.used_cpu_s +=
                alloc_cpu * params.cpu_efficiency * (compute_ms / 1000.0);
            consumption.alloc_mem_mb_s += workers as f64 * fn_mem * dur_s;
            consumption.used_mem_mb_s += workers as f64
                * (need_worker_mb + serde_extra).min(fn_mem)
                * dur_s;
            // store copy of live intermediates (double-memory problem)
            if let Some(s) = params.store() {
                let copy = s.store_copy_mb(stage_data_mb);
                consumption.alloc_mem_mb_s += copy * dur_s;
                consumption.used_mem_mb_s += copy * dur_s;
                peak_live_kv = peak_live_kv.max(stage_data_mb);
            }
            wave_cpu += alloc_cpu;
            wave_mem += workers as f64 * fn_mem + stage_data_mb;
        }
        peak_cpu = peak_cpu.max(wave_cpu);
        peak_mem = peak_mem.max(wave_mem);
        t += wave_ms;
    }

    // Redis instance: provisioned for peak, alive the whole run.
    if let Some(s) = params.store() {
        let prov = s.provisioned_mb(peak_live_kv);
        consumption.alloc_mem_mb_s += prov * t / 1000.0;
        consumption.used_mem_mb_s += peak_live_kv * 0.5 * t / 1000.0;
        consumption.alloc_cpu_s += 4.0 * t / 1000.0; // redis cores
    }
    // Coordinator VM (ExCamera): fixed 8-core/16 GB VM for the whole run.
    if params.kv == KvChoice::CoordinatorVm {
        consumption.alloc_cpu_s += 8.0 * t / 1000.0;
        consumption.alloc_mem_mb_s += 16384.0 * t / 1000.0;
        consumption.used_cpu_s += 2.0 * t / 1000.0;
        consumption.used_mem_mb_s += 4096.0 * t / 1000.0;
    }

    RunReport {
        system: params.name.into(),
        workload: program.name.into(),
        exec_ms: t,
        breakdown,
        consumption,
        local_fraction: 0.0, // DAG functions never co-locate with data
        peak_cpu,
        peak_mem_mb: peak_mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lr, tpcds, video};

    fn net() -> NetModel {
        NetModel::default()
    }

    fn st() -> StartupModel {
        StartupModel::default()
    }

    #[test]
    fn pywren_runs_tpcds() {
        let p = tpcds::query(16);
        let r = run(&p, Invocation::new(0.2), DagParams::pywren(0.2), &net(), &st());
        assert!(r.exec_ms > 0.0);
        assert!(r.consumption.alloc_mem_mb_s > r.consumption.used_mem_mb_s);
        assert!(r.breakdown.serialize_ms > 0.0, "pays serde");
        assert_eq!(r.local_fraction, 0.0);
    }

    #[test]
    fn peak_static_wastes_on_small_inputs() {
        // sized for 4K (scale 9) but run at 240P: huge unused memory
        let p = video::pipeline();
        let big = run(&p, Invocation::new(0.11), DagParams::gg(9.0), &net(), &st());
        let fit = run(&p, Invocation::new(0.11), DagParams::gg(0.11), &net(), &st());
        assert!(big.unused_gb_s() > 3.0 * fit.unused_gb_s());
    }

    #[test]
    fn gg_split_pays_more_startup_than_excamera() {
        let p = video::pipeline();
        let gg = run(&p, Invocation::new(1.0), DagParams::gg(9.0), &net(), &st());
        let ex = run(&p, Invocation::new(1.0), DagParams::excamera(9.0), &net(), &st());
        assert!(gg.breakdown.startup_ms >= ex.breakdown.startup_ms);
    }

    #[test]
    fn sf_variants_size_above_need() {
        let p = lr::program();
        for params in [
            DagParams::sf_co(1.0, KvChoice::S3),
            DagParams::sf_orion(1.0, KvChoice::Redis),
        ] {
            let r = run(&p, Invocation::new(1.0), params, &net(), &st());
            assert!(r.exec_ms.is_finite() && r.exec_ms > 0.0, "{params:?}");
        }
    }

    #[test]
    fn s3_slower_than_redis() {
        let p = lr::program();
        let s3 = run(&p, Invocation::new(1.0), DagParams::sf_co(1.0, KvChoice::S3), &net(), &st());
        let redis =
            run(&p, Invocation::new(1.0), DagParams::sf_co(1.0, KvChoice::Redis), &net(), &st());
        assert!(s3.exec_ms > redis.exec_ms);
        // …but redis charges provisioned memory
        assert!(redis.consumption.alloc_mem_mb_s > s3.consumption.alloc_mem_mb_s * 0.5);
    }
}
