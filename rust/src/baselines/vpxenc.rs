//! Local `vpxenc` baseline [70] (§6.1.2, Figs 11-13).
//!
//! Everything runs natively on one server. The paper observes the
//! encoder cannot exploit the machine: only 18 of 32 allocated cores
//! and 14 of 16 GB allocated memory are actually used, and as a
//! single-unit execution its size is set to the peak and cannot adapt
//! over time.

use crate::apps::{Invocation, Program};
use crate::cluster::server::Consumption;
use crate::metrics::{Breakdown, RunReport};

/// Cores allocated to the encoder box (paper's measurement).
pub const ALLOC_CORES: f64 = 32.0;
/// Cores the encoder actually keeps busy (18 of 32).
pub const USED_CORES: f64 = 18.0;
/// Memory allocated to the encoder box (16 GB).
pub const ALLOC_MEM_MB: f64 = 16384.0;
/// Memory the encoder actually touches (14 of 16 GB).
pub const USED_MEM_MB: f64 = 14336.0;

/// Run the transcode natively on one server.
pub fn run(program: &Program, inv: Invocation) -> RunReport {
    let scale = inv.input_scale;
    // Serial pipeline over the single machine's achievable parallelism.
    let total_work: f64 = program.computes.iter().map(|c| c.work_at(scale)).sum();
    // encoder threads are bounded by tile/segment count: small videos
    // cannot use all 18 cores (the paper's "limited by the amount of
    // parallelism it can achieve ... more apparent with larger videos").
    let usable_cores = USED_CORES.min(4.0 + 24.0 * scale);
    let compute_ms = total_work / usable_cores / 0.9;
    let mem_needed: f64 = program
        .computes
        .iter()
        .map(|c| c.parallelism_at(scale) as f64 * c.mem_at(scale))
        .fold(0.0, f64::max);
    // If the input outgrows the box, it thrashes (the paper's "limited
    // by the amount of parallelism it can achieve").
    // paging against the box's memory: bounded slowdown (the encoder
    // streams; it degrades but does not collapse)
    let thrash = if mem_needed > ALLOC_MEM_MB {
        (1.0 + (mem_needed / ALLOC_MEM_MB - 1.0) * 0.15).min(1.6)
    } else {
        1.0
    };
    let exec_ms = compute_ms * thrash;

    let dur_s = exec_ms / 1000.0;
    RunReport {
        system: "vpxenc".into(),
        workload: program.name.into(),
        exec_ms,
        breakdown: Breakdown { compute_ms: exec_ms, ..Default::default() },
        consumption: Consumption {
            alloc_cpu_s: ALLOC_CORES * dur_s,
            used_cpu_s: usable_cores * dur_s,
            alloc_mem_mb_s: ALLOC_MEM_MB * dur_s,
            used_mem_mb_s: USED_MEM_MB.min(mem_needed) * dur_s,
        },
        local_fraction: 1.0,
        peak_cpu: ALLOC_CORES,
        peak_mem_mb: ALLOC_MEM_MB,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::video;

    #[test]
    fn underutilizes_the_box() {
        let p = video::pipeline();
        let r = run(&p, Invocation::new(1.0));
        assert!(r.consumption.cpu_utilization() < 0.7);
        assert!(r.consumption.alloc_mem_mb_s > r.consumption.used_mem_mb_s);
    }

    #[test]
    fn bigger_videos_take_longer() {
        let p = video::pipeline();
        let small = run(&p, Invocation::new(video::Resolution::P240.scale()));
        let big = run(&p, Invocation::new(video::Resolution::K4.scale()));
        assert!(big.exec_ms > 10.0 * small.exec_ms);
    }
}
