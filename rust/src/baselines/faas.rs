//! Single-function FaaS baselines: OpenWhisk [5] and AWS Lambda [7].
//!
//! The whole bulky application runs as ONE function sized at its peak
//! (§6.1.3): the function's fixed size is held for the entire execution,
//! so every non-peak phase wastes the difference — the core
//! function-model waste the paper quantifies (Figs 15/16, 27/28, 30).

use crate::apps::{Invocation, Program};
use crate::cluster::server::Consumption;
use crate::cluster::startup::{StartupModel, StartupPath};
use crate::metrics::{Breakdown, RunReport};

use super::orion;

/// Which FaaS provider semantics to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provider {
    /// OpenWhisk on the local cluster: free CPU-to-memory ratio.
    OpenWhisk,
    /// AWS Lambda: menu sizes + CPU coupled to memory.
    Lambda,
}

/// Run the whole program as a single peak-sized function.
pub fn run(
    program: &Program,
    inv: Invocation,
    provider: Provider,
    warm: bool,
    startup: &StartupModel,
) -> RunReport {
    let scale = inv.input_scale;
    // Peak concurrent demand across the app (what the single function
    // must be provisioned for — all phases share one allocation).
    let peak = program.peak_estimate(scale);
    let peak_with_data: f64 = peak.mem_mb
        + program
            .data
            .iter()
            .map(|d| d.size_at(scale))
            .sum::<f64>()
            .min(peak.mem_mb); // data lives inside the process
    let (fn_mem, vcpus, eff) = match provider {
        Provider::OpenWhisk => (peak_with_data, peak.cpu.max(1.0), 0.80),
        Provider::Lambda => {
            let m = orion::lambda_menu()
                .into_iter()
                .find(|&m| m >= peak_with_data.min(10240.0))
                .unwrap_or(10240.0);
            ((m).max(peak_with_data.min(10240.0)), (m / 1769.0).max(0.06), 0.80)
        }
    };

    // Phases run serially inside the single function at its fixed size.
    let mut compute_ms = 0.0f64;
    let mut used_mem_ms = 0.0f64; // ∫ used memory dt
    for c in &program.computes {
        let workers = c.parallelism_at(scale).min(vcpus.ceil() as usize).max(1);
        let phase_ms = c.work_at(scale) / (workers as f64).min(vcpus) / eff;
        compute_ms += phase_ms;
        let phase_mem = (workers as f64 * c.mem_at(scale)).min(fn_mem);
        used_mem_ms += phase_mem * phase_ms;
    }
    let path = match provider {
        Provider::OpenWhisk => StartupPath::OpenWhisk,
        Provider::Lambda => StartupPath::Lambda,
    };
    let start_ms = if warm { startup.warm(path) } else { startup.cold(path) };
    let total_ms = start_ms + compute_ms;

    let dur_s = total_ms / 1000.0;
    let consumption = Consumption {
        alloc_cpu_s: vcpus * dur_s,
        used_cpu_s: vcpus * eff * (compute_ms / 1000.0),
        alloc_mem_mb_s: fn_mem * dur_s,
        used_mem_mb_s: (used_mem_ms / 1000.0).min(fn_mem * dur_s),
    };
    RunReport {
        system: match provider {
            Provider::OpenWhisk => "openwhisk".into(),
            Provider::Lambda => "lambda".into(),
        },
        workload: program.name.into(),
        exec_ms: total_ms,
        breakdown: Breakdown {
            compute_ms,
            startup_ms: start_ms,
            ..Default::default()
        },
        consumption,
        local_fraction: 1.0, // single process: everything local
        peak_cpu: vcpus,
        peak_mem_mb: fn_mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lr;
    use crate::cluster::StartupModel;

    #[test]
    fn openwhisk_wastes_on_non_peak_phases() {
        let p = lr::program();
        let r = run(&p, Invocation::new(1.0), Provider::OpenWhisk, false, &StartupModel::default());
        assert!(r.exec_ms > 0.0);
        // allocated ≫ used: non-train phases hold the train-sized alloc
        assert!(r.consumption.alloc_mem_mb_s > 1.5 * r.consumption.used_mem_mb_s);
    }

    #[test]
    fn lambda_picks_menu_size_and_couples_cpu() {
        let p = lr::program();
        let r = run(&p, Invocation::new(1.0), Provider::Lambda, false, &StartupModel::default());
        assert_eq!(r.peak_mem_mb % 128.0, 0.0, "menu size");
        assert!(r.peak_cpu < 8.0, "coupled vCPUs are limited");
    }

    #[test]
    fn warm_start_faster() {
        let p = lr::program();
        let cold =
            run(&p, Invocation::new(1.0), Provider::OpenWhisk, false, &StartupModel::default());
        let warm =
            run(&p, Invocation::new(1.0), Provider::OpenWhisk, true, &StartupModel::default());
        assert!(warm.exec_ms < cold.exec_ms);
    }

    #[test]
    fn small_input_still_pays_small_peak() {
        let p = lr::program();
        let small = run(
            &p,
            Invocation::new(lr::scale_for_mb(lr::SMALL_INPUT_MB)),
            Provider::OpenWhisk,
            false,
            &StartupModel::default(),
        );
        let large =
            run(&p, Invocation::new(1.0), Provider::OpenWhisk, false, &StartupModel::default());
        assert!(small.consumption.alloc_gb_s() < large.consumption.alloc_gb_s());
    }
}
