//! Migration-based scaling baselines (§2.3, Fig 18).
//!
//! When a phase outgrows its server, migration-based systems move the
//! whole execution state: we model (a) a **best case** that only pays
//! pure data movement at full 100 Gbps line rate, and (b) **MigrOS**
//! [54]-style transparent container live-migration (pre-copy rounds +
//! downtime). Execution itself runs natively (no remote-access
//! overhead) — exactly the trade the paper describes.

use crate::apps::{Invocation, Program};
use crate::cluster::server::Consumption;
use crate::cluster::startup::{StartupModel, StartupPath};
use crate::metrics::{Breakdown, RunReport};

/// Migration flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    /// Pure data movement at line rate (lower bound).
    BestCase,
    /// MigrOS: pre-copy amplification + downtime per migration.
    MigrOs,
}

impl Flavor {
    /// Time (ms) to migrate `mb` of state.
    fn migrate_ms(&self, mb: f64) -> f64 {
        // 100 Gbps ≈ 12.5 GB/s ≈ 12.8 MB/ms line rate.
        let line = mb / 12.8;
        match self {
            Flavor::BestCase => line,
            // dirty-page re-copy rounds (~1.6× data) + stop-and-copy
            // downtime + RDMA connection state re-establishment
            Flavor::MigrOs => line * 1.6 + 180.0,
        }
    }
}

/// Run with migration as the only scaling mechanism: whenever the next
/// phase needs more memory than the current server allocation, migrate
/// to a bigger allocation (moving the live state).
pub fn run(
    program: &Program,
    inv: Invocation,
    flavor: Flavor,
    startup: &StartupModel,
) -> RunReport {
    let scale = inv.input_scale;
    let mut breakdown = Breakdown::default();
    let mut t = startup.cold(StartupPath::OpenWhisk);
    breakdown.startup_ms = t;
    let mut cur_alloc_mb = 0.0f64;
    let mut migrations = 0u32;
    let mut consumption = Consumption::default();
    let mut peak_mem = 0.0f64;

    for c in &program.computes {
        let workers = c.parallelism_at(scale).max(1);
        let need = workers as f64 * c.mem_at(scale)
            + c.accesses
                .iter()
                .map(|&d| program.data[d].size_at(scale))
                .sum::<f64>();
        if need > cur_alloc_mb {
            if cur_alloc_mb > 0.0 {
                // migrate the live state to a bigger placement
                let mv = flavor.migrate_ms(cur_alloc_mb);
                breakdown.io_ms += mv;
                // resources held on BOTH servers during migration
                consumption.alloc_mem_mb_s += (cur_alloc_mb + need) * mv / 1000.0;
                consumption.alloc_cpu_s += workers as f64 * mv / 1000.0;
                t += mv;
                migrations += 1;
            }
            cur_alloc_mb = need;
        }
        let compute_ms = c.work_at(scale) / workers as f64 / 0.85;
        breakdown.compute_ms += compute_ms;
        consumption.alloc_cpu_s += workers as f64 * compute_ms / 1000.0;
        consumption.used_cpu_s += workers as f64 * 0.85 * compute_ms / 1000.0;
        consumption.alloc_mem_mb_s += cur_alloc_mb * compute_ms / 1000.0;
        consumption.used_mem_mb_s += need.min(cur_alloc_mb) * compute_ms / 1000.0;
        peak_mem = peak_mem.max(cur_alloc_mb);
        t += compute_ms;
    }

    RunReport {
        system: match flavor {
            Flavor::BestCase => "migration-best".into(),
            Flavor::MigrOs => "migros".into(),
        },
        workload: format!("{} ({migrations} migrations)", program.name).into(),
        exec_ms: t,
        breakdown,
        consumption,
        local_fraction: 1.0, // native execution between migrations
        peak_cpu: program.peak_estimate(scale).cpu,
        peak_mem_mb: peak_mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lr, tpcds};

    #[test]
    fn migros_slower_than_best_case() {
        let p = tpcds::query(95);
        let best = run(&p, Invocation::new(1.0), Flavor::BestCase, &StartupModel::default());
        let migros = run(&p, Invocation::new(1.0), Flavor::MigrOs, &StartupModel::default());
        assert!(migros.exec_ms > best.exec_ms);
    }

    #[test]
    fn bigger_state_migrates_longer() {
        let p = lr::program();
        let small = run(&p, Invocation::new(0.27), Flavor::BestCase, &StartupModel::default());
        let large = run(&p, Invocation::new(1.0), Flavor::BestCase, &StartupModel::default());
        assert!(large.breakdown.io_ms >= small.breakdown.io_ms);
    }

    #[test]
    fn native_execution_no_remote_penalty() {
        let p = lr::program();
        let r = run(&p, Invocation::new(1.0), Flavor::BestCase, &StartupModel::default());
        assert_eq!(r.local_fraction, 1.0);
        // io time is migration only, bounded
        assert!(r.breakdown.io_ms < r.breakdown.compute_ms);
    }
}
