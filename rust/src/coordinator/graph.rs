//! The resource graph IR (§4.2).
//!
//! Each `@compute` site becomes a compute node, each `@data` site a data
//! node. Trigger edges come from the program's control flow, access
//! edges from its data-flow. The graph also records *wave* structure
//! (longest-path depth over trigger edges): components in the same wave
//! can run concurrently, which is what the adaptive scheduler exploits.

use crate::apps::Program;

/// Node identifier within one resource graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// What a node stands for (index into the program's spec tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// A `@compute` site: index into `program.computes`.
    Compute(usize),
    /// A `@data` site: index into `program.data`.
    Data(usize),
}

/// The resource graph of one application.
#[derive(Debug, Clone)]
pub struct ResourceGraph {
    /// The annotated program this graph was derived from.
    pub program: Program,
    /// node ids: computes first (same order as program.computes), then
    /// data nodes (same order as program.data).
    n_compute: usize,
    n_data: usize,
    /// trigger edges between compute nodes (by compute index).
    pub triggers: Vec<(usize, usize)>,
    /// access edges: (compute index, data index).
    pub accesses: Vec<(usize, usize)>,
    /// wave number per compute index (longest path from an entry).
    pub wave: Vec<usize>,
    /// Precomputed per-data lifetime window (first, last accessor wave)
    /// so [`Self::data_lifetime`] is an O(1) lookup — the executor asks
    /// for every data component at the end of every wave.
    data_life: Vec<Option<(usize, usize)>>,
    /// Precomputed CSR wave structure (see [`Self::waves_into`]): wave
    /// `w` = `wave_csr_comps[wave_csr_offsets[w]..wave_csr_offsets[w+1]]`.
    /// Built once at graph construction; per-invocation shell resets
    /// just memcpy it.
    wave_csr_offsets: Vec<usize>,
    wave_csr_comps: Vec<usize>,
}

impl ResourceGraph {
    /// Derive the resource graph from an annotated program (what the
    /// paper's Mira-based analyzer does offline).
    pub fn from_program(program: &Program) -> crate::Result<Self> {
        program.validate()?;
        let n_compute = program.computes.len();
        let n_data = program.data.len();
        let mut triggers = Vec::new();
        let mut accesses = Vec::new();
        for (i, c) in program.computes.iter().enumerate() {
            for &t in &c.triggers {
                triggers.push((i, t));
            }
            for &d in &c.accesses {
                accesses.push((i, d));
            }
        }
        // Longest-path wave numbers over trigger edges.
        let order = program.topo_order()?;
        let mut wave = vec![0usize; n_compute];
        for &i in &order {
            for &t in &program.computes[i].triggers {
                wave[t] = wave[t].max(wave[i] + 1);
            }
        }
        // Data lifetime windows (first/last accessor wave), precomputed
        // once so the per-wave executor query is a lookup.
        let mut data_life: Vec<Option<(usize, usize)>> = vec![None; n_data];
        for &(c, d) in &accesses {
            let w = wave[c];
            data_life[d] = Some(match data_life[d] {
                Some((lo, hi)) => (lo.min(w), hi.max(w)),
                None => (w, w),
            });
        }
        // CSR wave structure, single-pass counting sort (stable: within
        // a wave, compute indices ascend — same order as `waves()`).
        let n_waves = wave.iter().copied().max().unwrap_or(0) + 1;
        let mut wave_csr_offsets = vec![0usize; n_waves + 1];
        for &w in &wave {
            wave_csr_offsets[w + 1] += 1;
        }
        for i in 0..n_waves {
            wave_csr_offsets[i + 1] += wave_csr_offsets[i];
        }
        let mut cursor = wave_csr_offsets.clone();
        let mut wave_csr_comps = vec![0usize; n_compute];
        for (i, &w) in wave.iter().enumerate() {
            wave_csr_comps[cursor[w]] = i;
            cursor[w] += 1;
        }
        Ok(Self {
            program: program.clone(),
            n_compute,
            n_data,
            triggers,
            accesses,
            wave,
            data_life,
            wave_csr_offsets,
            wave_csr_comps,
        })
    }

    /// Number of compute nodes (the first `n_compute` node ids).
    pub fn n_compute(&self) -> usize {
        self.n_compute
    }

    /// Number of data nodes (node ids after the computes).
    pub fn n_data(&self) -> usize {
        self.n_data
    }

    /// Node id of compute index `i`.
    pub fn compute_node(&self, i: usize) -> NodeId {
        NodeId(i)
    }

    /// Node id of data index `d`.
    pub fn data_node(&self, d: usize) -> NodeId {
        NodeId(self.n_compute + d)
    }

    /// Resolve a node id back to its compute/data index.
    pub fn kind(&self, id: NodeId) -> NodeKind {
        if id.0 < self.n_compute {
            NodeKind::Compute(id.0)
        } else {
            NodeKind::Data(id.0 - self.n_compute)
        }
    }

    /// Compute indices grouped by wave, in wave order.
    pub fn waves(&self) -> Vec<Vec<usize>> {
        let max_wave = self.wave.iter().copied().max().unwrap_or(0);
        let mut out = vec![Vec::new(); max_wave + 1];
        for (i, &w) in self.wave.iter().enumerate() {
            out[w].push(i);
        }
        out
    }

    /// CSR-flattened wave structure into caller-owned buffers
    /// (allocation-free once the buffers have capacity): after the call
    /// wave `w`'s compute indices are
    /// `comps[offsets[w]..offsets[w + 1]]`, in the same order as
    /// [`Self::waves`]. A plain memcpy of the CSR precomputed at graph
    /// build — O(n_compute), no per-invocation rescan. The executor's
    /// pooled invocation shells reuse these buffers across invocations
    /// (`clone_from` keeps their capacity).
    pub fn waves_into(&self, offsets: &mut Vec<usize>, comps: &mut Vec<usize>) {
        offsets.clone_from(&self.wave_csr_offsets);
        comps.clone_from(&self.wave_csr_comps);
    }

    /// Data indices accessed by compute `c`.
    pub fn accessed_data(&self, c: usize) -> Vec<usize> {
        self.accessed_data_iter(c).collect()
    }

    /// Allocation-free variant of [`Self::accessed_data`] for the
    /// executor's wave loop.
    pub fn accessed_data_iter(&self, c: usize) -> impl Iterator<Item = usize> + '_ {
        self.accesses.iter().filter(move |&&(ci, _)| ci == c).map(|&(_, d)| d)
    }

    /// Compute indices accessing data `d`.
    pub fn accessors_of(&self, d: usize) -> Vec<usize> {
        self.accessors_of_iter(d).collect()
    }

    /// Allocation-free variant of [`Self::accessors_of`].
    pub fn accessors_of_iter(&self, d: usize) -> impl Iterator<Item = usize> + '_ {
        self.accesses.iter().filter(move |&&(_, di)| di == d).map(|&(c, _)| c)
    }

    /// Direct successors (triggered computes) of compute `c`.
    pub fn successors(&self, c: usize) -> Vec<usize> {
        self.triggers.iter().filter(|&&(a, _)| a == c).map(|&(_, b)| b).collect()
    }

    /// Shared-data detection (§4.2: analysis "similar to Mira" finds
    /// objects shared across compute components): data nodes with more
    /// than one accessor.
    pub fn shared_data(&self) -> Vec<usize> {
        // dense per-data accessor counts: data indices are compact, so a
        // Vec table gives ascending output with no hash-order hazard
        let mut count = vec![0usize; self.n_data];
        for &(_, d) in &self.accesses {
            count[d] += 1;
        }
        count.iter().enumerate().filter(|&(_, &n)| n > 1).map(|(d, _)| d).collect()
    }

    /// Data lifetime window in waves: (first accessor wave, last
    /// accessor wave). Data launches with its first accessor and dies
    /// with its last (§5.1.2). O(1): precomputed at graph build.
    pub fn data_lifetime(&self, d: usize) -> Option<(usize, usize)> {
        self.data_life.get(d).copied().flatten()
    }

    /// Neighbour materialization candidates (§5.1.2): chains of
    /// single-trigger compute pairs whose memory profiles are within
    /// `similarity` ratio — merged into one physical component when
    /// co-located.
    pub fn merge_candidates(&self, scale: f64, similarity: f64) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.merge_candidates_into(scale, similarity, &mut out);
        out
    }

    /// Allocation-free variant of [`Self::merge_candidates`] for the
    /// executor's pooled invocation shells: clears and refills `out`
    /// (capacity persists across invocations).
    pub fn merge_candidates_into(
        &self,
        scale: f64,
        similarity: f64,
        out: &mut Vec<(usize, usize)>,
    ) {
        out.clear();
        for &(a, b) in &self.triggers {
            let only_trigger = self.triggers.iter().filter(|&&(x, _)| x == a).count() == 1;
            let only_pred = self.triggers.iter().filter(|&&(_, t)| t == b).count() == 1;
            if !(only_trigger && only_pred) {
                continue;
            }
            let ca = &self.program.computes[a];
            let cb = &self.program.computes[b];
            if ca.parallelism_at(scale) != cb.parallelism_at(scale) {
                continue;
            }
            let (ma, mb) = (ca.mem_at(scale), cb.mem_at(scale));
            let ratio = if ma > mb { ma / mb.max(1e-9) } else { mb / ma.max(1e-9) };
            if ratio <= similarity {
                out.push((a, b));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lr, tpcds, video};

    #[test]
    fn lr_graph_structure() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        assert_eq!(g.n_compute(), 4);
        assert_eq!(g.n_data(), 3);
        // load -> split -> train -> validate: four waves of one
        assert_eq!(g.waves().len(), 4);
        assert_eq!(g.wave, vec![0, 1, 2, 3]);
        // weights (data 2) is shared by train+validate
        assert!(g.shared_data().contains(&2));
    }

    #[test]
    fn video_waves_parallel_units() {
        let g = ResourceGraph::from_program(&video::pipeline()).unwrap();
        let waves = g.waves();
        // slice+audio, decodes, encodes, merge, mux, finalize
        assert!(waves[1].len() >= video::UNITS);
        assert!(waves[2].len() >= video::UNITS);
    }

    #[test]
    fn waves_into_matches_waves() {
        for prog in [lr::program(), tpcds::query(16), video::pipeline()] {
            let g = ResourceGraph::from_program(&prog).unwrap();
            let waves = g.waves();
            let mut offsets = vec![99]; // stale content must be cleared
            let mut comps = vec![7];
            g.waves_into(&mut offsets, &mut comps);
            assert_eq!(offsets.len(), waves.len() + 1);
            for (w, wave) in waves.iter().enumerate() {
                assert_eq!(&comps[offsets[w]..offsets[w + 1]], &wave[..], "wave {w}");
            }
        }
    }

    #[test]
    fn data_lifetime_spans_accessors() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        // train_set (data 0): accessed by load(w0), split(w1), train(w2)
        assert_eq!(g.data_lifetime(0), Some((0, 2)));
        // weights (data 2): train(w2), validate(w3)
        assert_eq!(g.data_lifetime(2), Some((2, 3)));
    }

    #[test]
    fn merge_candidates_need_chain_and_similarity() {
        let g = ResourceGraph::from_program(&video::pipeline()).unwrap();
        // mux -> finalize is a 1:1 chain of single-worker components with
        // memory ratio ≈ 2.1: a candidate at similarity 2.5, not at 1.5.
        let has_pair = |merges: &[(usize, usize)]| {
            merges.iter().any(|&(a, b)| {
                g.program.computes[a].name == "mux" && g.program.computes[b].name == "finalize"
            })
        };
        assert!(has_pair(&g.merge_candidates(1.0, 2.5)));
        assert!(!has_pair(&g.merge_candidates(1.0, 1.5)));
        // decode -> encode differ in parallelism → never merged
        let merges = g.merge_candidates(1.0, 10.0);
        assert!(!merges.iter().any(|&(a, b)| {
            g.program.computes[a].name == "decode" && g.program.computes[b].name == "encode"
        }));
    }

    #[test]
    fn shared_data_is_sorted_and_matches_accessor_recount() {
        // Regression for the D1 fix (dense Vec table instead of a
        // HashMap recount): output must stay exactly what the old
        // sorted-HashMap path produced — every data index with > 1
        // accessor, ascending — so downstream placement decisions (and
        // with them the pinned driver digest) are byte-identical.
        for prog in [lr::program(), tpcds::query(16), video::pipeline()] {
            let g = ResourceGraph::from_program(&prog).unwrap();
            let expect: Vec<usize> =
                (0..g.n_data()).filter(|&d| g.accessors_of(d).len() > 1).collect();
            let got = g.shared_data();
            assert_eq!(got, expect, "{}", prog.name);
            assert!(got.windows(2).all(|w| w[0] < w[1]), "ascending: {got:?}");
        }
    }

    #[test]
    fn accessors_and_successors_consistent() {
        let g = ResourceGraph::from_program(&tpcds::query(16)).unwrap();
        for d in 0..g.n_data() {
            for c in g.accessors_of(d) {
                assert!(g.accessed_data(c).contains(&d));
            }
        }
        for (a, b) in g.triggers.clone() {
            assert!(g.successors(a).contains(&b));
        }
    }

    #[test]
    fn node_id_mapping_roundtrips() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        assert_eq!(g.kind(g.compute_node(2)), NodeKind::Compute(2));
        assert_eq!(g.kind(g.data_node(1)), NodeKind::Data(1));
    }
}
