//! The resource graph IR (§4.2).
//!
//! Each `@compute` site becomes a compute node, each `@data` site a data
//! node. Trigger edges come from the program's control flow, access
//! edges from its data-flow. The graph also records *wave* structure
//! (longest-path depth over trigger edges): components in the same wave
//! can run concurrently, which is what the adaptive scheduler exploits.

use std::collections::HashMap;

use crate::apps::Program;

/// Node identifier within one resource graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// What a node stands for (index into the program's spec tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    Compute(usize),
    Data(usize),
}

/// The resource graph of one application.
#[derive(Debug, Clone)]
pub struct ResourceGraph {
    pub program: Program,
    /// node ids: computes first (same order as program.computes), then
    /// data nodes (same order as program.data).
    n_compute: usize,
    n_data: usize,
    /// trigger edges between compute nodes (by compute index).
    pub triggers: Vec<(usize, usize)>,
    /// access edges: (compute index, data index).
    pub accesses: Vec<(usize, usize)>,
    /// wave number per compute index (longest path from an entry).
    pub wave: Vec<usize>,
}

impl ResourceGraph {
    /// Derive the resource graph from an annotated program (what the
    /// paper's Mira-based analyzer does offline).
    pub fn from_program(program: &Program) -> crate::Result<Self> {
        program.validate()?;
        let n_compute = program.computes.len();
        let n_data = program.data.len();
        let mut triggers = Vec::new();
        let mut accesses = Vec::new();
        for (i, c) in program.computes.iter().enumerate() {
            for &t in &c.triggers {
                triggers.push((i, t));
            }
            for &d in &c.accesses {
                accesses.push((i, d));
            }
        }
        // Longest-path wave numbers over trigger edges.
        let order = program.topo_order()?;
        let mut wave = vec![0usize; n_compute];
        for &i in &order {
            for &t in &program.computes[i].triggers {
                wave[t] = wave[t].max(wave[i] + 1);
            }
        }
        Ok(Self { program: program.clone(), n_compute, n_data, triggers, accesses, wave })
    }

    pub fn n_compute(&self) -> usize {
        self.n_compute
    }

    pub fn n_data(&self) -> usize {
        self.n_data
    }

    pub fn compute_node(&self, i: usize) -> NodeId {
        NodeId(i)
    }

    pub fn data_node(&self, d: usize) -> NodeId {
        NodeId(self.n_compute + d)
    }

    pub fn kind(&self, id: NodeId) -> NodeKind {
        if id.0 < self.n_compute {
            NodeKind::Compute(id.0)
        } else {
            NodeKind::Data(id.0 - self.n_compute)
        }
    }

    /// Compute indices grouped by wave, in wave order.
    pub fn waves(&self) -> Vec<Vec<usize>> {
        let max_wave = self.wave.iter().copied().max().unwrap_or(0);
        let mut out = vec![Vec::new(); max_wave + 1];
        for (i, &w) in self.wave.iter().enumerate() {
            out[w].push(i);
        }
        out
    }

    /// Data indices accessed by compute `c`.
    pub fn accessed_data(&self, c: usize) -> Vec<usize> {
        self.accessed_data_iter(c).collect()
    }

    /// Allocation-free variant of [`Self::accessed_data`] for the
    /// executor's wave loop.
    pub fn accessed_data_iter(&self, c: usize) -> impl Iterator<Item = usize> + '_ {
        self.accesses.iter().filter(move |&&(ci, _)| ci == c).map(|&(_, d)| d)
    }

    /// Compute indices accessing data `d`.
    pub fn accessors_of(&self, d: usize) -> Vec<usize> {
        self.accessors_of_iter(d).collect()
    }

    /// Allocation-free variant of [`Self::accessors_of`].
    pub fn accessors_of_iter(&self, d: usize) -> impl Iterator<Item = usize> + '_ {
        self.accesses.iter().filter(move |&&(_, di)| di == d).map(|&(c, _)| c)
    }

    /// Direct successors (triggered computes) of compute `c`.
    pub fn successors(&self, c: usize) -> Vec<usize> {
        self.triggers.iter().filter(|&&(a, _)| a == c).map(|&(_, b)| b).collect()
    }

    /// Shared-data detection (§4.2: analysis "similar to Mira" finds
    /// objects shared across compute components): data nodes with more
    /// than one accessor.
    pub fn shared_data(&self) -> Vec<usize> {
        let mut count: HashMap<usize, usize> = HashMap::new();
        for &(_, d) in &self.accesses {
            *count.entry(d).or_insert(0) += 1;
        }
        let mut v: Vec<usize> =
            count.into_iter().filter(|&(_, n)| n > 1).map(|(d, _)| d).collect();
        v.sort();
        v
    }

    /// Data lifetime window in waves: (first accessor wave, last
    /// accessor wave). Data launches with its first accessor and dies
    /// with its last (§5.1.2).
    pub fn data_lifetime(&self, d: usize) -> Option<(usize, usize)> {
        let waves: Vec<usize> = self.accessors_of(d).iter().map(|&c| self.wave[c]).collect();
        if waves.is_empty() {
            None
        } else {
            Some((
                waves.iter().copied().min().unwrap(),
                waves.iter().copied().max().unwrap(),
            ))
        }
    }

    /// Neighbour materialization candidates (§5.1.2): chains of
    /// single-trigger compute pairs whose memory profiles are within
    /// `similarity` ratio — merged into one physical component when
    /// co-located.
    pub fn merge_candidates(&self, scale: f64, similarity: f64) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for &(a, b) in &self.triggers {
            let only_trigger = self.successors(a).len() == 1;
            let only_pred = self.triggers.iter().filter(|&&(_, t)| t == b).count() == 1;
            if !(only_trigger && only_pred) {
                continue;
            }
            let ca = &self.program.computes[a];
            let cb = &self.program.computes[b];
            if ca.parallelism_at(scale) != cb.parallelism_at(scale) {
                continue;
            }
            let (ma, mb) = (ca.mem_at(scale), cb.mem_at(scale));
            let ratio = if ma > mb { ma / mb.max(1e-9) } else { mb / ma.max(1e-9) };
            if ratio <= similarity {
                out.push((a, b));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lr, tpcds, video};

    #[test]
    fn lr_graph_structure() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        assert_eq!(g.n_compute(), 4);
        assert_eq!(g.n_data(), 3);
        // load -> split -> train -> validate: four waves of one
        assert_eq!(g.waves().len(), 4);
        assert_eq!(g.wave, vec![0, 1, 2, 3]);
        // weights (data 2) is shared by train+validate
        assert!(g.shared_data().contains(&2));
    }

    #[test]
    fn video_waves_parallel_units() {
        let g = ResourceGraph::from_program(&video::pipeline()).unwrap();
        let waves = g.waves();
        // slice+audio, decodes, encodes, merge, mux, finalize
        assert!(waves[1].len() >= video::UNITS);
        assert!(waves[2].len() >= video::UNITS);
    }

    #[test]
    fn data_lifetime_spans_accessors() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        // train_set (data 0): accessed by load(w0), split(w1), train(w2)
        assert_eq!(g.data_lifetime(0), Some((0, 2)));
        // weights (data 2): train(w2), validate(w3)
        assert_eq!(g.data_lifetime(2), Some((2, 3)));
    }

    #[test]
    fn merge_candidates_need_chain_and_similarity() {
        let g = ResourceGraph::from_program(&video::pipeline()).unwrap();
        // mux -> finalize is a 1:1 chain of single-worker components with
        // memory ratio ≈ 2.1: a candidate at similarity 2.5, not at 1.5.
        let has_pair = |merges: &[(usize, usize)]| {
            merges.iter().any(|&(a, b)| {
                g.program.computes[a].name == "mux" && g.program.computes[b].name == "finalize"
            })
        };
        assert!(has_pair(&g.merge_candidates(1.0, 2.5)));
        assert!(!has_pair(&g.merge_candidates(1.0, 1.5)));
        // decode -> encode differ in parallelism → never merged
        let merges = g.merge_candidates(1.0, 10.0);
        assert!(!merges.iter().any(|&(a, b)| {
            g.program.computes[a].name == "decode" && g.program.computes[b].name == "encode"
        }));
    }

    #[test]
    fn accessors_and_successors_consistent() {
        let g = ResourceGraph::from_program(&tpcds::query(16)).unwrap();
        for d in 0..g.n_data() {
            for c in g.accessors_of(d) {
                assert!(g.accessed_data(c).contains(&d));
            }
        }
        for (a, b) in g.triggers.clone() {
            assert!(g.successors(a).contains(&b));
        }
    }

    #[test]
    fn node_id_mapping_roundtrips() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        assert_eq!(g.kind(g.compute_node(2)), NodeKind::Compute(2));
        assert_eq!(g.kind(g.data_node(1)), NodeKind::Data(1));
    }
}
