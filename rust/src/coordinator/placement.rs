//! Locality-based greedy placement (§5.1.1).
//!
//! Policy, in priority order:
//! 1. whole-application fit: choose the server with the *smallest*
//!    available resources among those that fit the entire app (leaves
//!    spacious servers for future larger invocations);
//! 2. co-locate a component with the data components it accesses;
//! 3. otherwise the smallest-available server that fits the component;
//! 4. scale-up prefers the current server, then servers already running
//!    accessors of the grown data component.
//!
//! Implementation: cluster- and rack-wide queries go through the
//! [`PlacementIndex`] (O(buckets + occupancy), allocation-free);
//! [`smallest_fit_linear`] keeps the original O(servers) scan as the
//! reference implementation for differential testing
//! (`rust/tests/proptests.rs` asserts decision identity). Candidate-
//! restricted queries ([`smallest_fit_among`]) stay linear over the
//! (small) candidate set but no longer allocate.
//!
//! [`PlacementIndex`]: crate::cluster::PlacementIndex

use crate::cluster::{Cluster, RackId, Resources, ServerId};

/// Choose the smallest-available server (by [`Resources::magnitude`])
/// among those whose *unmarked* availability fits `demand`; fall back to
/// marked capacity if necessary (marks are low-priority, not reserved).
///
/// Index-backed: O(buckets + bucket occupancy), no allocation.
pub fn smallest_fit(cluster: &Cluster, demand: Resources) -> Option<ServerId> {
    cluster.with_index(|ix| ix.smallest_fit(demand))
}

/// [`smallest_fit`] restricted to one rack, via the per-rack index.
pub fn smallest_fit_in_rack(
    cluster: &Cluster,
    rack: RackId,
    demand: Resources,
) -> Option<ServerId> {
    cluster.with_index(|ix| ix.smallest_fit_in_rack(rack, demand))
}

/// Reference implementation: the original O(servers) linear scan.
/// Kept (and exercised by benches + differential proptests) as the
/// semantic ground truth for [`smallest_fit`].
pub fn smallest_fit_linear(cluster: &Cluster, demand: Resources) -> Option<ServerId> {
    smallest_fit_among(cluster, demand, cluster.servers().iter().map(|s| s.id))
}

/// Same as [`smallest_fit`] but restricted to `candidates`.
///
/// Generic over any cloneable id iterator so callers pass slices or
/// filtered iterators directly — no per-call `Vec` collect (the old
/// `&mut dyn Iterator` signature forced one).
pub fn smallest_fit_among<I>(
    cluster: &Cluster,
    demand: Resources,
    candidates: I,
) -> Option<ServerId>
where
    I: IntoIterator<Item = ServerId>,
    I::IntoIter: Clone,
{
    let iter = candidates.into_iter();
    let pick = |respect_marks: bool| -> Option<ServerId> {
        iter.clone()
            .map(|id| cluster.server(id))
            .filter(|s| {
                let avail =
                    if respect_marks { s.available_unmarked() } else { s.available() };
                avail.fits(demand)
            })
            .min_by(|a, b| {
                a.available()
                    .magnitude()
                    .partial_cmp(&b.available().magnitude())
                    .unwrap()
            })
            .map(|s| s.id)
    };
    pick(true).or_else(|| pick(false))
}

/// Placement preference for a compute component that accesses data
/// currently resident on `data_servers`: co-locate if any of them fits,
/// else smallest fit anywhere in the rack.
pub fn place_component(
    cluster: &Cluster,
    demand: Resources,
    data_servers: &[ServerId],
) -> Option<(ServerId, bool)> {
    // Try servers already hosting the accessed data, smallest first.
    if let Some(id) = smallest_fit_among(cluster, demand, data_servers.iter().copied()) {
        return Some((id, true));
    }
    smallest_fit(cluster, demand).map(|id| {
        let colocated = data_servers.contains(&id);
        (id, colocated)
    })
}

/// Scale-up preference (§5.1.1 last paragraph): current server first,
/// then servers running accessors, then anywhere.
pub fn place_growth(
    cluster: &Cluster,
    demand: Resources,
    current: ServerId,
    accessor_servers: &[ServerId],
) -> Option<ServerId> {
    if cluster.server(current).available().fits(demand) {
        return Some(current);
    }
    if let Some(id) = smallest_fit_among(cluster, demand, accessor_servers.iter().copied())
    {
        return Some(id);
    }
    smallest_fit(cluster, demand)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn cluster() -> Cluster {
        Cluster::new(ClusterSpec {
            racks: 1,
            servers_per_rack: 4,
            server_capacity: Resources::new(32.0, 65536.0),
        })
    }

    #[test]
    fn picks_smallest_fitting_server() {
        let mut c = cluster();
        // server 0: heavily loaded; server 1: lightly; 2,3: empty
        c.server_mut(ServerId(0)).try_alloc(Resources::new(30.0, 60000.0), 0.0);
        c.server_mut(ServerId(1)).try_alloc(Resources::new(8.0, 10000.0), 0.0);
        // demand fits 1,2,3 → smallest available is 1
        let got = smallest_fit(&c, Resources::new(16.0, 20000.0)).unwrap();
        assert_eq!(got, ServerId(1));
        // tiny demand fits 0 too → 0 is the smallest remainder
        let got = smallest_fit(&c, Resources::new(1.0, 1000.0)).unwrap();
        assert_eq!(got, ServerId(0));
    }

    #[test]
    fn none_when_nothing_fits() {
        let c = cluster();
        assert!(smallest_fit(&c, Resources::new(64.0, 1.0)).is_none());
    }

    #[test]
    fn indexed_agrees_with_linear_reference() {
        let mut c = cluster();
        c.server_mut(ServerId(0)).try_alloc(Resources::new(30.0, 60000.0), 0.0);
        c.server_mut(ServerId(1)).try_alloc(Resources::new(8.0, 10000.0), 0.0);
        c.server_mut(ServerId(2)).mark(Resources::new(32.0, 65536.0));
        for demand in [
            Resources::new(1.0, 1000.0),
            Resources::new(16.0, 20000.0),
            Resources::new(31.0, 64000.0),
            Resources::new(64.0, 1.0),
        ] {
            assert_eq!(
                smallest_fit(&c, demand),
                smallest_fit_linear(&c, demand),
                "demand {demand:?}"
            );
        }
    }

    #[test]
    fn colocation_preferred() {
        let mut c = cluster();
        c.server_mut(ServerId(2)).try_alloc(Resources::new(4.0, 4000.0), 0.0);
        let (id, colo) =
            place_component(&c, Resources::new(4.0, 4096.0), &[ServerId(2)]).unwrap();
        assert_eq!(id, ServerId(2));
        assert!(colo);
    }

    #[test]
    fn falls_back_when_data_server_full() {
        let mut c = cluster();
        c.server_mut(ServerId(2)).try_alloc(Resources::new(32.0, 65536.0), 0.0);
        let (id, colo) =
            place_component(&c, Resources::new(4.0, 4096.0), &[ServerId(2)]).unwrap();
        assert_ne!(id, ServerId(2));
        assert!(!colo);
    }

    #[test]
    fn growth_prefers_current_then_accessors() {
        let mut c = cluster();
        let cur = ServerId(0);
        // current has room → stays
        assert_eq!(
            place_growth(&c, Resources::mem_only(1000.0), cur, &[ServerId(1)]),
            Some(cur)
        );
        // fill current: falls to the accessor server
        c.server_mut(cur).try_alloc(Resources::new(0.0, 65536.0), 0.0);
        assert_eq!(
            place_growth(&c, Resources::mem_only(1000.0), cur, &[ServerId(1)]),
            Some(ServerId(1))
        );
        // fill accessor too: any fitting server
        c.server_mut(ServerId(1)).try_alloc(Resources::new(0.0, 65536.0), 0.0);
        let got = place_growth(&c, Resources::mem_only(1000.0), cur, &[ServerId(1)]).unwrap();
        assert!(got == ServerId(2) || got == ServerId(3));
    }

    #[test]
    fn marks_demote_but_do_not_block() {
        let mut c = cluster();
        // servers 1-3 marked for a future app; 0 unmarked but larger load
        for i in 1..4 {
            c.server_mut(ServerId(i)).mark(Resources::new(32.0, 65536.0));
        }
        c.server_mut(ServerId(0)).try_alloc(Resources::new(16.0, 30000.0), 0.0);
        // prefers the unmarked server 0 even though 1-3 have more room
        let got = smallest_fit(&c, Resources::new(8.0, 8192.0)).unwrap();
        assert_eq!(got, ServerId(0));
        // but a demand only marked servers can fit still places
        let got = smallest_fit(&c, Resources::new(30.0, 60000.0)).unwrap();
        assert_ne!(got, ServerId(0));
    }

    #[test]
    fn in_rack_restriction_honored() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(2, 2));
        c.server_mut(ServerId(2)).try_alloc(Resources::new(1.0, 1024.0), 0.0);
        // rack 1's smallest fit is its loaded server; rack 0 unaffected
        let got = smallest_fit_in_rack(&c, RackId(1), Resources::new(4.0, 4096.0));
        assert_eq!(got, Some(ServerId(2)));
        let got = smallest_fit_in_rack(&c, RackId(0), Resources::new(4.0, 4096.0));
        assert_eq!(got, Some(ServerId(0)));
    }
}
