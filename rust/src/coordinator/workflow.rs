//! Workflow-structured tenants: inter-invocation DAGs with data
//! handoff, plus the bookkeeping behind rack-affinity placement of
//! downstream stages (§2.2's pipeline shape, driven end-to-end on the
//! shared cluster instead of being asserted from the function-DAG
//! baseline's closed-form model).
//!
//! A [`Workflow`] attached to a `TenantApp` turns each scheduled
//! arrival into the *root stage* of a run. When a stage's invocation
//! completes, its declared out-edges hand data off to downstream
//! stages: the handoff region is retained (memory-charged) on the
//! producer's rack until the consumer launches, so resident
//! intermediates genuinely compete with invocations for rack capacity.
//! A downstream stage becomes ready when all its in-edges have
//! completed; it is routed immediately — with rack affinity (prefer
//! the rack holding the most resident input bytes, spill to the
//! ordinary smallest-fit when the candidate cannot fit) or blind — and
//! enqueued as an ordinary `(time, seq)` heap event delayed by the
//! cross-rack transfer cost of its non-resident inputs.
//!
//! ## Determinism contract
//!
//! All workflow bookkeeping runs coordinator-side at `WaveDone` /
//! `StageLaunch` instants in canonical `(time, seq)` order — directly
//! in the sequential loop, as coordinator fence events in the sharded
//! epoch loop — so digests stay worker-count invariant. Downstream
//! enqueue order is fixed by edge declaration order (ready successors
//! are visited in ascending edge index and receive ascending event
//! sequence numbers). An app without a workflow, or with the trivial
//! [`Workflow::single`], performs no cluster mutation, pushes no
//! events and draws no randomness: the replay is byte-identical to the
//! independent-arrival replay.

use crate::apps::program::Program;
use crate::cluster::clock::Millis;
use crate::cluster::{RackId, ServerId};
use crate::metrics::streaming::{P2Quantile, StreamingMoments};
use crate::net::{NetKind, NetModel};
use crate::util::cast;

use super::exec::Platform;

/// Sentinel rack id for "not yet produced / not yet pinned".
const NO_RACK: u32 = u32::MAX;

/// One inter-invocation DAG edge: stage `from` hands `handoff_mb`
/// megabytes of output to stage `to`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkflowEdge {
    /// Producer stage index.
    pub from: u32,
    /// Consumer stage index (validation requires `from < to`).
    pub to: u32,
    /// Handoff payload size (MB) retained on the producer's rack until
    /// the consumer launches. Zero-byte edges carry ordering only.
    pub handoff_mb: f64,
}

/// An inter-invocation DAG declared by a tenant: each scheduled
/// arrival runs stage 0 (the sole root), and every edge `from → to`
/// spawns the consumer once all of its producers completed.
///
/// Stages reuse the tenant's program; a stage's invocation input scale
/// is the root arrival's scale times the stage's `scale_mult`.
#[derive(Debug, Clone)]
pub struct Workflow {
    /// Per-stage input-scale multiplier applied to the root scale.
    scale_mult: Vec<f64>,
    /// Declared edges (validated: `from < to`, so the graph is acyclic
    /// by construction and edge order is a topological order).
    edges: Vec<WorkflowEdge>,
    /// CSR out-adjacency: `succ[succ_off[s]..succ_off[s+1]]` holds the
    /// edge indices leaving stage `s`, in declaration order.
    succ_off: Vec<u32>,
    succ: Vec<u32>,
    /// CSR in-adjacency (edge indices entering each stage).
    pred_off: Vec<u32>,
    pred: Vec<u32>,
    /// In-degree per stage.
    indeg: Vec<u32>,
}

impl Workflow {
    /// Build and validate a workflow. Requirements: at least one
    /// stage, every `scale_mult > 0`, stage 0 has scale multiplier 1.0
    /// (so a workflow root replays byte-identically to an independent
    /// arrival), every edge satisfies `from < to` with both endpoints
    /// in range and `handoff_mb >= 0`, and stage 0 is the *only* root
    /// (every other stage has at least one in-edge).
    pub fn new(scale_mult: Vec<f64>, edges: Vec<WorkflowEdge>) -> crate::Result<Self> {
        if scale_mult.is_empty() {
            anyhow::bail!("workflow has no stages");
        }
        if (scale_mult[0] - 1.0).abs() >= 1e-12 {
            anyhow::bail!("stage 0 must keep the root arrival's scale (mult 1.0)");
        }
        for (i, &m) in scale_mult.iter().enumerate() {
            if m <= 0.0 {
                anyhow::bail!("stage {i} scale multiplier must be positive");
            }
        }
        let n = scale_mult.len();
        let mut indeg = vec![0u32; n];
        for (i, e) in edges.iter().enumerate() {
            let (f, t) = (cast::usize_of(u64::from(e.from)), cast::usize_of(u64::from(e.to)));
            if f >= n || t >= n {
                anyhow::bail!("edge {i} endpoint out of range");
            }
            if e.from >= e.to {
                anyhow::bail!("edge {i} must satisfy from < to (acyclic by construction)");
            }
            if e.handoff_mb < 0.0 {
                anyhow::bail!("edge {i} negative handoff");
            }
            indeg[t] += 1;
        }
        for (s, &d) in indeg.iter().enumerate().skip(1) {
            if d == 0 {
                anyhow::bail!("stage {s} is unreachable (only stage 0 may be a root)");
            }
        }
        // CSR out- and in-adjacency over edge indices, declaration order.
        let mut succ_off = vec![0u32; n + 1];
        let mut pred_off = vec![0u32; n + 1];
        for e in &edges {
            succ_off[cast::usize_of(u64::from(e.from)) + 1] += 1;
            pred_off[cast::usize_of(u64::from(e.to)) + 1] += 1;
        }
        for s in 0..n {
            succ_off[s + 1] += succ_off[s];
            pred_off[s + 1] += pred_off[s];
        }
        let mut succ = vec![0u32; edges.len()];
        let mut pred = vec![0u32; edges.len()];
        let mut scur = succ_off.clone();
        let mut pcur = pred_off.clone();
        for (i, e) in edges.iter().enumerate() {
            let idx = cast::u32_of(i);
            succ[cast::usize_of(u64::from(scur[cast::usize_of(u64::from(e.from))]))] = idx;
            scur[cast::usize_of(u64::from(e.from))] += 1;
            pred[cast::usize_of(u64::from(pcur[cast::usize_of(u64::from(e.to))]))] = idx;
            pcur[cast::usize_of(u64::from(e.to))] += 1;
        }
        Ok(Self { scale_mult, edges, succ_off, succ, pred_off, pred, indeg })
    }

    /// The trivial one-stage workflow (no edges): a run is exactly one
    /// independent invocation, byte-identical to no workflow at all.
    pub fn single() -> Self {
        Self::new(vec![1.0], vec![]).expect("trivial workflow is valid")
    }

    /// A linear pipeline of `stages` stages, each handing `handoff_mb`
    /// to the next.
    pub fn pipeline(stages: usize, handoff_mb: f64) -> Self {
        assert!(stages >= 1, "pipeline needs at least one stage");
        let edges = (1..stages)
            .map(|t| WorkflowEdge {
                from: cast::u32_of(t - 1),
                to: cast::u32_of(t),
                handoff_mb,
            })
            .collect();
        Self::new(vec![1.0; stages], edges).expect("pipeline shape is valid")
    }

    /// Fan-out/fan-in: a root scatters `handoff_mb` to `width` branch
    /// stages (each at `branch_mult` of the root scale), which gather
    /// into one final stage.
    pub fn fan_out_in(width: usize, branch_mult: f64, handoff_mb: f64) -> Self {
        assert!(width >= 1, "fan-out needs at least one branch");
        let gather = cast::u32_of(width + 1);
        let mut mults = vec![1.0];
        mults.extend(std::iter::repeat(branch_mult).take(width));
        mults.push(1.0);
        let mut edges = Vec::with_capacity(2 * width);
        for b in 1..=width {
            edges.push(WorkflowEdge { from: 0, to: cast::u32_of(b), handoff_mb });
        }
        for b in 1..=width {
            edges.push(WorkflowEdge { from: cast::u32_of(b), to: gather, handoff_mb });
        }
        Self::new(mults, edges).expect("fan-out/fan-in shape is valid")
    }

    /// Number of stages.
    pub fn n_stages(&self) -> usize {
        self.scale_mult.len()
    }

    /// True for the degenerate DAG-of-1 (one stage, no edges): the
    /// driver still books a run, but no handoff/affinity machinery can
    /// engage, so the replay matches the independent-arrival replay.
    pub fn is_trivial(&self) -> bool {
        self.scale_mult.len() == 1 && self.edges.is_empty()
    }

    /// The declared edges.
    pub fn edges(&self) -> &[WorkflowEdge] {
        &self.edges
    }

    /// Input-scale multiplier of `stage`.
    pub fn scale_mult(&self, stage: usize) -> f64 {
        self.scale_mult[stage]
    }

    /// Edge indices leaving `stage`, in declaration order.
    fn out_edges(&self, stage: usize) -> &[u32] {
        let lo = cast::usize_of(u64::from(self.succ_off[stage]));
        let hi = cast::usize_of(u64::from(self.succ_off[stage + 1]));
        &self.succ[lo..hi]
    }

    /// Edge indices entering `stage`, in declaration order.
    fn in_edges(&self, stage: usize) -> &[u32] {
        let lo = cast::usize_of(u64::from(self.pred_off[stage]));
        let hi = cast::usize_of(u64::from(self.pred_off[stage + 1]));
        &self.pred[lo..hi]
    }
}

/// A retained handoff region: where the producer parked the bytes.
#[derive(Debug, Clone, Copy)]
struct EdgeCharge {
    server: ServerId,
    mb: f64,
}

/// One live workflow run (all stages spawned by one root arrival).
#[derive(Debug, Default)]
struct WfRun {
    app: usize,
    /// Root arrival's schedule index: downstream stages reuse it for
    /// per-app attribution, exactly like the root invocation.
    sched: usize,
    root_scale: f64,
    t0: Millis,
    /// Remaining un-completed in-edges per stage.
    pending_in: Vec<u32>,
    /// Rack each completed stage ran on (`NO_RACK` before completion).
    produced_rack: Vec<u32>,
    /// Rack each enqueued stage was pinned to at ready time.
    pinned_rack: Vec<u32>,
    /// Per-edge retained handoff region (None: not produced yet,
    /// zero-byte, spilled, or already consumed/freed).
    charge: Vec<Option<EdgeCharge>>,
    /// Stages not yet completed.
    remaining: u32,
    /// Stage invocations admitted and still in flight.
    inflight: u32,
    /// `StageLaunch` events enqueued but not yet fired.
    pending_launch: u32,
    /// A stage failed (rejected launch or fault-aborted): downstream
    /// stages stop spawning and the run retires without an e2e sample.
    failed: bool,
    /// Slot is on the free list.
    free: bool,
}

/// A downstream launch the caller must enqueue as a heap event at
/// `at` (with its own monotone sequence number).
#[derive(Debug, Clone, Copy)]
pub struct StageLaunch {
    /// Run slot in the [`WorkflowRuntime`].
    pub run: u32,
    /// Stage to launch.
    pub stage: u32,
    /// Simulated launch instant (ready time + cross-rack transfer).
    pub at: Millis,
}

/// Digest-excluded workflow telemetry for the driver report.
#[derive(Debug)]
pub struct WorkflowStats {
    /// Workflow runs opened (= admitted root arrivals of workflow apps).
    pub runs: u64,
    /// Runs whose every stage completed.
    pub runs_completed: u64,
    /// Stage invocations admitted (roots + spawned downstream stages).
    pub stages_started: u64,
    /// Stage invocations completed.
    pub stages_completed: u64,
    /// Downstream stage launches attempted beyond the arrival schedule
    /// (the `spawned` term of the workflow conservation identity).
    pub spawned: u64,
    /// Handoff megabytes consumed from a different rack than the one
    /// the consumer stage ran on.
    pub cross_rack_mb: f64,
    /// End-to-end workflow latency (root arrival → last stage
    /// completion) over fully-successful runs.
    pub e2e: StreamingMoments,
    /// P² p95 estimator over the same samples.
    pub e2e_p95: P2Quantile,
    /// P² p99 estimator over the same samples.
    pub e2e_p99: P2Quantile,
}

impl Default for WorkflowStats {
    fn default() -> Self {
        Self {
            runs: 0,
            runs_completed: 0,
            stages_started: 0,
            stages_completed: 0,
            spawned: 0,
            cross_rack_mb: 0.0,
            e2e: StreamingMoments::default(),
            e2e_p95: P2Quantile::new(0.95),
            e2e_p99: P2Quantile::new(0.99),
        }
    }
}

/// Coordinator-side workflow state for one replay: live runs (slab
/// slots with an intrusive free list — shells recycle their vectors,
/// so steady state allocates nothing once capacities are warm) plus
/// the digest-excluded telemetry.
#[derive(Debug)]
pub struct WorkflowRuntime {
    runs: Vec<WfRun>,
    free: Vec<u32>,
    live: usize,
    /// Cross-rack handoff transfers price through the TCP path of this
    /// model (intermediates move through the memory controller, not
    /// the RDMA compute fabric).
    net: NetModel,
    /// Telemetry (digest-excluded in the driver report).
    pub stats: WorkflowStats,
}

impl Default for WorkflowRuntime {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkflowRuntime {
    /// Fresh runtime (default net model; the driver replaces it with
    /// the platform's own model at construction).
    pub fn new() -> Self {
        Self {
            runs: Vec::new(),
            free: Vec::new(),
            live: 0,
            net: NetModel::default(),
            stats: WorkflowStats::default(),
        }
    }

    /// Use `net` for cross-rack handoff pricing (the driver passes the
    /// platform's model so workflow transfers and data-path transfers
    /// price identically).
    pub fn set_net(&mut self, net: NetModel) {
        self.net = net;
    }

    /// Live (unretired) runs.
    pub fn live(&self) -> usize {
        self.live
    }

    /// The app index a run belongs to.
    pub fn run_app(&self, run: u32) -> usize {
        self.runs[cast::usize_of(u64::from(run))].app
    }

    /// The root arrival's schedule index (per-app attribution).
    pub fn run_sched(&self, run: u32) -> usize {
        self.runs[cast::usize_of(u64::from(run))].sched
    }

    /// Input scale for `stage` of `run`.
    pub fn stage_scale(&self, run: u32, stage: u32, wf: &Workflow) -> f64 {
        self.runs[cast::usize_of(u64::from(run))].root_scale
            * wf.scale_mult(cast::usize_of(u64::from(stage)))
    }

    /// The rack `stage` was pinned to at ready time.
    pub fn pinned_rack(&self, run: u32, stage: u32) -> RackId {
        let r = self.runs[cast::usize_of(u64::from(run))].pinned_rack
            [cast::usize_of(u64::from(stage))];
        debug_assert_ne!(r, NO_RACK, "stage launched without a pinned rack");
        RackId(cast::usize_of(u64::from(r)))
    }

    /// Open a run for an admitted root arrival. Returns the run slot
    /// to store in the root invocation's slab entry.
    pub fn on_root_admitted(
        &mut self,
        app: usize,
        sched: usize,
        scale: f64,
        t0: Millis,
        wf: &Workflow,
    ) -> u32 {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.runs.push(WfRun::default());
                cast::u32_of(self.runs.len() - 1)
            }
        };
        let n = wf.n_stages();
        let r = &mut self.runs[cast::usize_of(u64::from(id))];
        r.app = app;
        r.sched = sched;
        r.root_scale = scale;
        r.t0 = t0;
        r.pending_in.clear();
        r.pending_in.extend_from_slice(&wf.indeg);
        r.produced_rack.clear();
        r.produced_rack.resize(n, NO_RACK);
        r.pinned_rack.clear();
        r.pinned_rack.resize(n, NO_RACK);
        r.charge.clear();
        r.charge.resize(wf.edges().len(), None);
        r.remaining = cast::u32_of(n);
        r.inflight = 1; // the root is in flight
        r.pending_launch = 0;
        r.failed = false;
        r.free = false;
        self.live += 1;
        self.stats.runs += 1;
        self.stats.stages_started += 1;
        id
    }

    /// A stage invocation completed on `rack` at `now`: retain its
    /// out-edge handoffs on the producer rack, mark ready successors,
    /// route them (affinity-aware when `affinity`), and append their
    /// launch events to `out` in deterministic edge order. The caller
    /// pushes each [`StageLaunch`] into its event heap with the next
    /// monotone sequence number.
    #[allow(clippy::too_many_arguments)]
    pub fn on_stage_done(
        &mut self,
        run: u32,
        stage: u32,
        rack: RackId,
        now: Millis,
        wf: &Workflow,
        program: &Program,
        platform: &mut Platform,
        affinity: bool,
        out: &mut Vec<StageLaunch>,
    ) {
        let ri = cast::usize_of(u64::from(run));
        let si = cast::usize_of(u64::from(stage));
        self.stats.stages_completed += 1;
        {
            let r = &mut self.runs[ri];
            debug_assert!(!r.free, "completion for a retired run");
            r.inflight -= 1;
            r.remaining -= 1;
            r.produced_rack[si] = cast::u32_of(rack.0);
        }
        if self.runs[ri].failed {
            self.maybe_retire(run, platform, now);
            return;
        }
        // Retain this stage's out-edge handoffs on the producer rack.
        // A full rack spills the region to the disaggregated store
        // (charge None): nothing is retained, and the consumer prices
        // the edge as a cross-rack transfer regardless of placement.
        for k in 0..wf.out_edges(si).len() {
            let e = cast::usize_of(u64::from(wf.out_edges(si)[k]));
            let mb = wf.edges()[e].handoff_mb;
            if mb > 0.0 {
                self.runs[ri].charge[e] =
                    platform.retain_handoff(rack, mb, now).map(|server| EdgeCharge { server, mb });
            }
        }
        // Ready successors, in edge-declaration order.
        for k in 0..wf.out_edges(si).len() {
            let e = cast::usize_of(u64::from(wf.out_edges(si)[k]));
            let to = cast::usize_of(u64::from(wf.edges()[e].to));
            self.runs[ri].pending_in[to] -= 1;
            if self.runs[ri].pending_in[to] > 0 {
                continue;
            }
            let launch_at = self.route_ready_stage(run, to, wf, program, platform, affinity, now);
            self.runs[ri].pending_launch += 1;
            out.push(StageLaunch { run, stage: cast::u32_of(to), at: launch_at });
        }
        self.maybe_retire(run, platform, now);
    }

    /// Route a ready stage (all in-edges complete): pick its rack —
    /// affinity-aware (prefer the rack with the most resident input
    /// bytes, deterministic ties to the lowest rack id) or blind — pin
    /// it, price the cross-rack inputs, and return the launch instant.
    #[allow(clippy::too_many_arguments)]
    fn route_ready_stage(
        &mut self,
        run: u32,
        to: usize,
        wf: &Workflow,
        program: &Program,
        platform: &mut Platform,
        affinity: bool,
        now: Millis,
    ) -> Millis {
        let ri = cast::usize_of(u64::from(run));
        let scale = self.runs[ri].root_scale * wf.scale_mult(to);
        let estimate = program.peak_estimate(scale);
        // Affinity candidate: the rack holding the most *resident*
        // input bytes (spilled/zero edges contribute nothing).
        let prefer = if affinity {
            let mut best: Option<(usize, f64)> = None;
            for &ei in wf.in_edges(to) {
                let e = cast::usize_of(u64::from(ei));
                if let Some(c) = self.runs[ri].charge[e] {
                    let pr = cast::usize_of(u64::from(
                        self.runs[ri].produced_rack
                            [cast::usize_of(u64::from(wf.edges()[e].from))],
                    ));
                    let mut mb = c.mb;
                    // accumulate other resident in-edges on the same rack
                    if let Some((br, bmb)) = best {
                        if br == pr {
                            mb += bmb;
                        } else if bmb >= mb {
                            continue;
                        }
                    }
                    best = Some((pr, mb));
                }
            }
            best.map(|(r, _)| RackId(r))
        } else {
            None
        };
        let (dest, _hit) = platform.route_stage(estimate, prefer);
        self.runs[ri].pinned_rack[to] = cast::u32_of(dest.0);
        // Launch delay: the slowest non-resident input transfer. Edges
        // resident on the destination rack are consumed in place (the
        // compute maps the region, no bulk move).
        let mut xfer = 0.0f64;
        for &ei in wf.in_edges(to) {
            let e = cast::usize_of(u64::from(ei));
            let mb = wf.edges()[e].handoff_mb;
            if mb <= 0.0 {
                continue;
            }
            let resident_on_dest = self.runs[ri].charge[e].map_or(false, |c| {
                self.runs[ri].produced_rack[cast::usize_of(u64::from(wf.edges()[e].from))]
                    == cast::u32_of(dest.0)
                    && c.mb > 0.0
            });
            if !resident_on_dest {
                self.stats.cross_rack_mb += mb;
                xfer = xfer.max(self.net.transfer(NetKind::Tcp, mb, true));
            }
        }
        now + xfer
    }

    /// A `StageLaunch` event fired: consume (free) the stage's in-edge
    /// handoff regions and report whether the launch should proceed.
    /// Returns `false` (and retires the run if possible) when the run
    /// already failed — the stage is skipped, not admitted.
    pub fn begin_launch(
        &mut self,
        run: u32,
        stage: u32,
        wf: &Workflow,
        platform: &mut Platform,
        now: Millis,
    ) -> bool {
        let ri = cast::usize_of(u64::from(run));
        self.runs[ri].pending_launch -= 1;
        if self.runs[ri].failed {
            self.maybe_retire(run, platform, now);
            return false;
        }
        for &ei in wf.in_edges(cast::usize_of(u64::from(stage))) {
            let e = cast::usize_of(u64::from(ei));
            if let Some(c) = self.runs[ri].charge[e].take() {
                platform.release_handoff(c.server, c.mb, now);
            }
        }
        self.stats.spawned += 1;
        true
    }

    /// The launched stage was admitted: it is now in flight.
    pub fn on_stage_admitted(&mut self, run: u32) {
        let r = &mut self.runs[cast::usize_of(u64::from(run))];
        r.inflight += 1;
        self.stats.stages_started += 1;
    }

    /// The launched stage failed admission: the run fails (downstream
    /// stages stop spawning) and retires once nothing is in flight.
    pub fn on_stage_rejected(&mut self, run: u32, platform: &mut Platform, now: Millis) {
        self.runs[cast::usize_of(u64::from(run))].failed = true;
        self.maybe_retire(run, platform, now);
    }

    /// An in-flight stage invocation was aborted (fault-struck without
    /// recovery): the run fails and retires once drained.
    pub fn on_stage_aborted(&mut self, run: u32, platform: &mut Platform, now: Millis) {
        let ri = cast::usize_of(u64::from(run));
        let r = &mut self.runs[ri];
        debug_assert!(!r.free, "abort for a retired run");
        r.inflight -= 1;
        r.remaining -= 1;
        r.failed = true;
        self.maybe_retire(run, platform, now);
    }

    /// Retire the run if it is complete (every stage done → record the
    /// end-to-end sample) or failed and drained (free any still-held
    /// handoff charges so the cluster drains to exactly empty).
    fn maybe_retire(&mut self, run: u32, platform: &mut Platform, now: Millis) {
        let ri = cast::usize_of(u64::from(run));
        let r = &self.runs[ri];
        if r.free {
            return;
        }
        let done = r.remaining == 0 && r.pending_launch == 0 && r.inflight == 0;
        let dead = r.failed && r.inflight == 0 && r.pending_launch == 0;
        if !(done || dead) {
            return;
        }
        if done && !r.failed {
            self.stats.runs_completed += 1;
            let e2e = now - r.t0;
            self.stats.e2e.push(e2e);
            self.stats.e2e_p95.push(e2e);
            self.stats.e2e_p99.push(e2e);
        }
        let r = &mut self.runs[ri];
        for c in r.charge.iter_mut() {
            if let Some(c) = c.take() {
                platform.release_handoff(c.server, c.mb, now);
            }
        }
        r.free = true;
        self.live -= 1;
        self.free.push(run);
    }

    /// Debug invariant for the driver's end-of-replay leak asserts:
    /// every run retired and every handoff charge released.
    pub fn assert_idle(&self) {
        debug_assert_eq!(self.live, 0, "unretired workflow runs at end of replay");
        debug_assert!(
            self.runs.iter().all(|r| r.charge.iter().all(Option::is_none)),
            "leaked workflow handoff charges"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_shapes() {
        assert!(Workflow::new(vec![], vec![]).is_err());
        assert!(Workflow::new(vec![2.0], vec![]).is_err(), "root must keep scale");
        assert!(Workflow::new(
            vec![1.0, 1.0],
            vec![WorkflowEdge { from: 1, to: 0, handoff_mb: 1.0 }]
        )
        .is_err());
        assert!(Workflow::new(vec![1.0, 1.0], vec![]).is_err(), "stage 1 unreachable");
        assert!(Workflow::new(
            vec![1.0, 1.0],
            vec![WorkflowEdge { from: 0, to: 1, handoff_mb: -1.0 }]
        )
        .is_err());
        let ok = Workflow::new(
            vec![1.0, 0.5],
            vec![WorkflowEdge { from: 0, to: 1, handoff_mb: 64.0 }],
        )
        .unwrap();
        assert_eq!(ok.n_stages(), 2);
        assert!(!ok.is_trivial());
    }

    #[test]
    fn constructors_shape_csr() {
        let single = Workflow::single();
        assert!(single.is_trivial());
        assert_eq!(single.n_stages(), 1);
        assert!(single.out_edges(0).is_empty());

        let pipe = Workflow::pipeline(4, 32.0);
        assert_eq!(pipe.n_stages(), 4);
        assert_eq!(pipe.edges().len(), 3);
        assert_eq!(pipe.out_edges(0), &[0]);
        assert_eq!(pipe.in_edges(3), &[2]);
        assert_eq!(pipe.indeg, vec![0, 1, 1, 1]);

        let fan = Workflow::fan_out_in(3, 0.5, 16.0);
        assert_eq!(fan.n_stages(), 5);
        assert_eq!(fan.edges().len(), 6);
        assert_eq!(fan.out_edges(0).len(), 3, "root scatters to every branch");
        assert_eq!(fan.in_edges(4).len(), 3, "gather collects every branch");
        assert!((fan.scale_mult(2) - 0.5).abs() < 1e-12);
        assert!((fan.scale_mult(4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_of_one_is_trivial() {
        assert!(Workflow::pipeline(1, 64.0).is_trivial());
    }
}
