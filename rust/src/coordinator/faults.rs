//! Deterministic fault injection: the seeded chaos schedule the
//! multi-tenant driver replays alongside its arrival schedule.
//!
//! A [`FaultPlan`] is generated once per run from `DriverConfig::seed`
//! and the cluster shape, then consumed by the driver event loop as
//! ordinary heap events. Three fault kinds exist:
//!
//! - **Server crash** — the server goes down (`Cluster::fail_server`),
//!   every in-flight invocation with a compute running or a data
//!   region homed there takes a [`Crash`] and recovers through
//!   `failure::plan` + the message log; a paired repair event restores
//!   the capacity after `FaultConfig::repair_ms`.
//! - **Rack outage** — the same, fanned out over every server in one
//!   rack (correlated failure), with a paired rack repair.
//! - **Transient compute crash** — a software fault: one server's
//!   in-flight work crashes and recovers, but the server itself stays
//!   up (no capacity change, no repair event).
//!
//! # Determinism
//!
//! The plan draws from a *dedicated* RNG stream
//! (`seed ^ 0xFA17_7E57_D15A_57E5`), so enabling faults never perturbs
//! the arrival/scale streams. At `rate_per_min == 0.0` the generator
//! returns an empty plan **without constructing an RNG or drawing at
//! all**, and the driver pushes no heap events — the zero-fault replay
//! is byte-identical (same event sequence, same digest) to a build
//! that predates fault injection.
//!
//! # Modeling note
//!
//! A downed server maps onto an affected invocation as
//! `Crash::Compute` of a current-wave component placed there, else
//! `Crash::DataRegion` of a region homed there. Regions the plan does
//! not name are treated as durable (disaggregated or already logged),
//! matching the paper's §5.3.2 recovery-cut semantics.

use crate::cluster::clock::Millis;
use crate::cluster::{ClusterSpec, RackId, ServerId};
use crate::util::rng::Rng;

/// XOR'd into `DriverConfig::seed` to derive the fault RNG stream.
const FAULT_STREAM: u64 = 0xFA17_7E57_D15A_57E5;

/// Fault-schedule axis on `DriverConfig`. The default is chaos-free
/// and draws nothing from any RNG, preserving the pinned digest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Mean fault events per simulated minute (Poisson process over
    /// the arrival horizon). `0.0` disables fault injection entirely.
    pub rate_per_min: f64,
    /// Delay before a crashed server (or rack) comes back up.
    pub repair_ms: f64,
    /// When true, capacity faults take out a whole rack (correlated
    /// failure) instead of a single server.
    pub rack_outage: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self { rate_per_min: 0.0, repair_ms: 30_000.0, rack_outage: false }
    }
}

/// One scheduled fault (or repair) event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Take one server down; in-flight work there crashes.
    ServerCrash(ServerId),
    /// Take every server in the rack down (correlated outage).
    RackOutage(RackId),
    /// Crash in-flight work on one server without downing it.
    TransientCompute(ServerId),
    /// Bring a crashed server back up.
    ServerRepair(ServerId),
    /// Bring a crashed rack back up.
    RackRepair(RackId),
}

/// A fault event pinned to a simulated instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Simulated time of the event.
    pub at: Millis,
    /// What happens.
    pub kind: FaultKind,
}

/// The full, time-sorted fault schedule for one driver run.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Events in non-decreasing time order (generation-order
    /// tiebreak, so crashes precede their own repairs at equal time).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Generate the seeded fault schedule over `[0, horizon_ms)`.
    ///
    /// Returns an empty plan — with zero RNG draws — when the rate is
    /// zero or the horizon is empty, so the zero-fault digest contract
    /// holds structurally, not just statistically.
    pub fn generate(
        cfg: &FaultConfig,
        seed: u64,
        spec: &ClusterSpec,
        horizon_ms: Millis,
    ) -> FaultPlan {
        if cfg.rate_per_min <= 0.0 || horizon_ms <= 0.0 {
            return FaultPlan { events: Vec::new() };
        }
        let mut rng = Rng::new(seed ^ FAULT_STREAM);
        let rate = cfg.rate_per_min / 60_000.0; // events per ms
        let mut events = Vec::new();
        let mut t = rng.exponential(rate);
        while t < horizon_ms {
            if rng.chance(0.25) {
                let s = ServerId(rng.range(0, spec.total_servers()));
                events.push(FaultEvent { at: t, kind: FaultKind::TransientCompute(s) });
            } else if cfg.rack_outage {
                let r = RackId(rng.range(0, spec.racks));
                events.push(FaultEvent { at: t, kind: FaultKind::RackOutage(r) });
                events.push(FaultEvent {
                    at: t + cfg.repair_ms,
                    kind: FaultKind::RackRepair(r),
                });
            } else {
                let s = ServerId(rng.range(0, spec.total_servers()));
                events.push(FaultEvent { at: t, kind: FaultKind::ServerCrash(s) });
                events.push(FaultEvent {
                    at: t + cfg.repair_ms,
                    kind: FaultKind::ServerRepair(s),
                });
            }
            t += rng.exponential(rate);
        }
        // Stable time sort with generation-index tiebreak: repairs
        // scheduled at the same instant as a later crash keep their
        // relative generation order, deterministically.
        let mut keyed: Vec<(usize, FaultEvent)> = events.into_iter().enumerate().collect();
        keyed.sort_by(|a, b| a.1.at.total_cmp(&b.1.at).then(a.0.cmp(&b.0)));
        FaultPlan { events: keyed.into_iter().map(|(_, e)| e).collect() }
    }

    /// Number of scheduled events (crashes and repairs).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when the plan schedules nothing (the zero-fault case).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ClusterSpec {
        ClusterSpec::multi_rack(4, 2)
    }

    #[test]
    fn zero_rate_plan_is_empty() {
        let cfg = FaultConfig::default();
        let plan = FaultPlan::generate(&cfg, 7, &spec(), 1_000_000.0);
        assert!(plan.is_empty());
        let cfg = FaultConfig { rate_per_min: 5.0, ..FaultConfig::default() };
        let plan = FaultPlan::generate(&cfg, 7, &spec(), 0.0);
        assert!(plan.is_empty());
    }

    #[test]
    fn plan_is_deterministic_per_seed() {
        let cfg = FaultConfig { rate_per_min: 8.0, repair_ms: 4_000.0, rack_outage: false };
        let a = FaultPlan::generate(&cfg, 42, &spec(), 600_000.0);
        let b = FaultPlan::generate(&cfg, 42, &spec(), 600_000.0);
        assert!(!a.is_empty(), "8 faults/min over 10 min should schedule events");
        assert_eq!(a.events, b.events);
        let c = FaultPlan::generate(&cfg, 43, &spec(), 600_000.0);
        assert_ne!(a.events, c.events, "different seeds should differ");
    }

    #[test]
    fn events_are_time_sorted_and_repairs_trail_crashes() {
        let cfg = FaultConfig { rate_per_min: 10.0, repair_ms: 2_500.0, rack_outage: false };
        let plan = FaultPlan::generate(&cfg, 9, &spec(), 600_000.0);
        assert!(!plan.is_empty());
        for w in plan.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events out of order");
        }
        // every ServerCrash has a matching ServerRepair repair_ms later
        for ev in &plan.events {
            if let FaultKind::ServerCrash(s) = ev.kind {
                let repaired = plan.events.iter().any(|r| {
                    r.kind == FaultKind::ServerRepair(s)
                        && (r.at - ev.at - cfg.repair_ms).abs() < 1e-9
                });
                assert!(repaired, "crash of {s:?} at {} has no paired repair", ev.at);
            }
        }
    }

    #[test]
    fn rack_outage_flag_switches_capacity_fault_kind() {
        let cfg = FaultConfig { rate_per_min: 10.0, repair_ms: 2_000.0, rack_outage: true };
        let plan = FaultPlan::generate(&cfg, 11, &spec(), 600_000.0);
        assert!(!plan.is_empty());
        let mut saw_rack = false;
        for ev in &plan.events {
            match ev.kind {
                FaultKind::ServerCrash(_) | FaultKind::ServerRepair(_) => {
                    panic!("rack_outage plans must not contain single-server capacity faults")
                }
                FaultKind::RackOutage(r) => {
                    saw_rack = true;
                    assert!(r.0 < spec().racks);
                }
                _ => {}
            }
        }
        assert!(saw_rack, "expected at least one rack outage at 10/min over 10 min");
    }
}
