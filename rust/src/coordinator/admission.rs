//! Admission control & burst-arrival queueing for the multi-tenant
//! driver.
//!
//! The paper's resource-centric model only pays off under contention:
//! the 90% allocated-memory savings come from admitting bulky
//! invocations into *shared* capacity instead of statically
//! provisioning for peaks — which forces a decision when
//! [`Platform::start_wave`] cannot be satisfied at arrival time.
//! Historically the driver simply counted such arrivals as failed.
//! This module adds the missing policy layer:
//!
//! - [`AdmissionPolicy`] — what to do with an arrival the cluster
//!   cannot admit: reject it (the default, bit-identical to the old
//!   behavior), or park it in a bounded per-tenant deferred queue and
//!   retry when capacity frees ([`AdmissionPolicy::FifoQueue`] drains
//!   oldest-first across the fleet, [`AdmissionPolicy::FairShare`]
//!   drains round-robin by tenant,
//!   [`AdmissionPolicy::WeightedFairShare`] drains deficit-round-robin
//!   with per-tenant quanta derived from `TenantApp::weight`, and
//!   [`AdmissionPolicy::Deadline`] drains and evicts
//!   earliest-deadline-first against per-tenant SLO deadlines).
//! - [`DeferredQueues`] — the deferred-arrival queues themselves:
//!   per-tenant FIFO chains threaded through one slot pool with an
//!   intrusive free list (the driver's slab pattern), so steady-state
//!   parking/draining recycles slots instead of allocating, and total
//!   memory is O(peak queue depth), not O(arrivals).
//! - [`ArrivalModel`] — burst shaping for [`super::driver::Schedule`]
//!   generation: the existing deterministic Poisson process, a
//!   two-state MMPP (Markov-modulated Poisson: ON/OFF bursts at the
//!   same long-run offered load), and a piecewise-constant rate-replay
//!   hook for diurnal patterns. Queueing is only observable under
//!   bursts that transiently exceed capacity; these models produce
//!   them deterministically per seed.
//! - [`AdmissionOutcome`] / [`TenantAdmission`] — the per-tenant and
//!   fleet-wide accounting the driver folds into its report:
//!   admission-time rejections vs mid-run aborts vs queue timeouts
//!   (three *different* failure modes the old `failed` counter
//!   conflated), queue-depth high-water marks, and queueing-delay
//!   moments + P² p95 via [`crate::metrics::streaming`] — all O(apps)
//!   memory regardless of trace length.
//!
//! Determinism: every queue operation is driven by the driver's event
//! loop (arrivals and heap events in (time, sequence) order), queue
//! ordering ties break by enqueue sequence, and the burst models draw
//! from dedicated per-app RNG streams — so runs are bit-reproducible
//! per seed, and with the default [`AdmissionPolicy::RejectImmediately`]
//! the driver digest is unchanged from the pre-admission-control code.
//!
//! [`Platform::start_wave`]: super::Platform::start_wave

use crate::cluster::clock::Millis;
use crate::metrics::streaming::{P2Quantile, StreamingMoments};
use crate::util::cast;
use crate::util::rng::Rng;

/// Sentinel for "no slot" in the intrusive lists.
const NIL: usize = usize::MAX;

// ---- policy --------------------------------------------------------------

/// What the driver does with an arrival that fails admission
/// (`start_wave` error on wave 0: the cluster is saturated beyond
/// degradation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Count the arrival as rejected and move on — the pre-queueing
    /// behavior and the default (the seeded 1k driver digest pinned in
    /// `DRIVER_DIGEST.lock` is unchanged under this policy).
    RejectImmediately,
    /// Park failed arrivals in bounded per-tenant FIFO queues and
    /// drain them oldest-first *across the fleet* (global arrival
    /// order) when capacity frees. Entries whose wait would exceed
    /// `max_wait_ms` time out; a tenant whose queue is at `max_depth`
    /// has further arrivals rejected.
    FifoQueue {
        /// Maximum time an entry may wait before it times out (ms).
        max_wait_ms: f64,
        /// Maximum parked entries per tenant; beyond it arrivals are
        /// rejected (bounded memory under sustained overload).
        max_depth: usize,
    },
    /// Like [`AdmissionPolicy::FifoQueue`], but drains round-robin
    /// *by tenant* (each successful admission advances a tenant
    /// cursor), so one backlogged tenant cannot starve the others.
    FairShare {
        /// Maximum time an entry may wait before it times out (ms).
        max_wait_ms: f64,
        /// Maximum parked entries per tenant.
        max_depth: usize,
    },
    /// [`AdmissionPolicy::FairShare`] with *weighted* drain order:
    /// deficit round-robin (Shreedhar & Varghese) over per-tenant
    /// quanta derived from `TenantApp::weight` via
    /// [`DeferredQueues::set_weights`] — a tenant with twice the weight
    /// drains up to two entries per round-robin visit. With all
    /// weights equal every quantum is 1 and the drain sequence is
    /// pick-for-pick identical to [`AdmissionPolicy::FairShare`] (the
    /// differential contract `rust/tests/proptests.rs` pins).
    WeightedFairShare {
        /// Maximum time an entry may wait before it times out (ms).
        max_wait_ms: f64,
        /// Maximum parked entries per tenant.
        max_depth: usize,
    },
    /// SLO-aware queueing: each parked arrival carries an absolute
    /// deadline (`park time + its tenant's SLO`, per-tenant SLOs via
    /// [`DeferredQueues::set_deadlines`], default `deadline_ms`), and
    /// both *eviction* and *drain* run earliest-deadline-first over the
    /// whole fleet — strictly by `(deadline, enqueue seq)`, even when
    /// deadlines are non-monotone within one tenant's queue (per-entry
    /// SLO classes via [`DeferredQueues::park_with_deadline`]), the
    /// ordering a head-only FIFO timeout cannot represent.
    Deadline {
        /// Default per-tenant SLO: maximum queueing delay before an
        /// entry is evicted (ms). Per-tenant overrides come from
        /// `TenantApp::deadline_ms`.
        deadline_ms: f64,
        /// Maximum parked entries per tenant.
        max_depth: usize,
    },
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::RejectImmediately
    }
}

impl AdmissionPolicy {
    /// Whether this policy parks failed arrivals (false only for
    /// [`AdmissionPolicy::RejectImmediately`]).
    pub fn queues(&self) -> bool {
        !matches!(self, AdmissionPolicy::RejectImmediately)
    }

    /// The policy's queue-wait bound, if it queues (for
    /// [`AdmissionPolicy::Deadline`]: the default per-tenant SLO).
    pub fn max_wait_ms(&self) -> Option<f64> {
        match *self {
            AdmissionPolicy::RejectImmediately => None,
            AdmissionPolicy::FifoQueue { max_wait_ms, .. }
            | AdmissionPolicy::FairShare { max_wait_ms, .. }
            | AdmissionPolicy::WeightedFairShare { max_wait_ms, .. } => Some(max_wait_ms),
            AdmissionPolicy::Deadline { deadline_ms, .. } => Some(deadline_ms),
        }
    }

    /// The policy's per-tenant depth bound, if it queues.
    pub fn max_depth(&self) -> Option<usize> {
        match *self {
            AdmissionPolicy::RejectImmediately => None,
            AdmissionPolicy::FifoQueue { max_depth, .. }
            | AdmissionPolicy::FairShare { max_depth, .. }
            | AdmissionPolicy::WeightedFairShare { max_depth, .. }
            | AdmissionPolicy::Deadline { max_depth, .. } => Some(max_depth),
        }
    }

    /// Whether a failed admission retry should return the entry but
    /// move on to the next tenant within the same drain pass (the
    /// fair-share disciplines), as opposed to ending the pass (FIFO's
    /// global order and Deadline's strict EDF are head-of-line: if the
    /// most entitled entry does not fit, the pass is over).
    pub fn skips_blocked_tenant(&self) -> bool {
        matches!(
            self,
            AdmissionPolicy::FairShare { .. } | AdmissionPolicy::WeightedFairShare { .. }
        )
    }
}

// ---- burst arrival models ------------------------------------------------

/// How a tenant's arrival instants are drawn when the driver
/// materializes a [`super::driver::Schedule`].
///
/// All models are normalized to the *same long-run offered load* (the
/// per-app rate derived from `DriverConfig::mean_iat_ms`), so switching
/// models reshapes *when* arrivals cluster without changing how much
/// work the run carries — the right control for admission experiments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalModel {
    /// The original deterministic Poisson process (default). Schedule
    /// generation is byte-identical to the pre-burst-model code: the
    /// same RNG stream produces the same arrival instants.
    Poisson,
    /// Two-state Markov-modulated Poisson process: the instantaneous
    /// rate alternates between an ON (burst) and an OFF (background)
    /// state with exponentially distributed holding times. The ON rate
    /// is `on_mult ×` the OFF rate; both are scaled so the long-run
    /// mean rate matches the configured offered load.
    Mmpp {
        /// Burst intensity: ON-state rate relative to OFF (> 1 bursts;
        /// must be > 0).
        on_mult: f64,
        /// Mean ON-state holding time (ms).
        mean_on_ms: f64,
        /// Mean OFF-state holding time (ms).
        mean_off_ms: f64,
    },
    /// Diurnal rate-replay hook: a piecewise-constant rate-multiplier
    /// pattern, each entry held for `step_ms` and cycled for the whole
    /// schedule (e.g. 24 hourly multipliers replayed from a production
    /// trace). Entries may be zero (silent windows); the pattern mean
    /// must be positive. Multipliers are normalized by the pattern
    /// mean so the long-run offered load is preserved.
    RateReplay {
        /// Rate multipliers, one per step, cycled.
        pattern: &'static [f64],
        /// Duration each pattern entry is held (ms).
        step_ms: f64,
    },
}

impl Default for ArrivalModel {
    fn default() -> Self {
        ArrivalModel::Poisson
    }
}

impl ArrivalModel {
    /// True for the plain Poisson process (no modulation).
    pub fn is_poisson(&self) -> bool {
        matches!(self, ArrivalModel::Poisson)
    }
}

/// Inversion-method sampler for a modulated Poisson process: feed it
/// unit-rate exponential increments and it integrates them through the
/// piecewise-constant rate function, returning absolute arrival times.
///
/// Exact (no thinning/rejection, so the draw count per arrival is
/// fixed) and deterministic: state-holding times come from a dedicated
/// RNG so the caller's arrival/scale streams are untouched.
#[derive(Debug, Clone)]
pub struct RateModulator {
    model: ArrivalModel,
    /// Current absolute simulated time (ms).
    t: Millis,
    /// Current segment's absolute rate (arrivals/ms).
    rate: f64,
    /// Absolute time the current segment ends.
    seg_end: Millis,
    /// MMPP: normalized ON/OFF rates; `on` is the current state.
    rate_on: f64,
    rate_off: f64,
    on: bool,
    state_rng: Rng,
    /// RateReplay: normalized per-step rates and the replay cursor.
    base_rate: f64,
    pattern_norm: f64,
    step: usize,
}

impl RateModulator {
    /// Build a modulator for one tenant, or `None` for plain Poisson
    /// (the caller keeps its original, digest-pinned draw sequence).
    /// `base_rate` is the tenant's long-run rate in arrivals/ms; `seed`
    /// must be unique per tenant so streams do not correlate.
    pub fn new(model: ArrivalModel, base_rate: f64, seed: u64) -> Option<Self> {
        let base_rate = base_rate.max(1e-12);
        match model {
            ArrivalModel::Poisson => None,
            ArrivalModel::Mmpp { on_mult, mean_on_ms, mean_off_ms } => {
                assert!(on_mult > 0.0, "MMPP on_mult must be > 0");
                assert!(
                    mean_on_ms > 0.0 && mean_off_ms > 0.0,
                    "MMPP holding times must be > 0"
                );
                let p_on = mean_on_ms / (mean_on_ms + mean_off_ms);
                // normalize so the long-run mean rate equals base_rate
                let norm = p_on * on_mult + (1.0 - p_on);
                let rate_on = base_rate * on_mult / norm;
                let rate_off = base_rate / norm;
                let mut state_rng = Rng::new(seed);
                // start from the stationary distribution
                let on = state_rng.chance(p_on);
                let hold = if on {
                    state_rng.exponential(1.0 / mean_on_ms)
                } else {
                    state_rng.exponential(1.0 / mean_off_ms)
                };
                Some(Self {
                    model,
                    t: 0.0,
                    rate: if on { rate_on } else { rate_off },
                    seg_end: hold,
                    rate_on,
                    rate_off,
                    on,
                    state_rng,
                    base_rate,
                    pattern_norm: 1.0,
                    step: 0,
                })
            }
            ArrivalModel::RateReplay { pattern, step_ms } => {
                assert!(!pattern.is_empty(), "rate-replay pattern must be non-empty");
                assert!(step_ms > 0.0, "rate-replay step must be > 0");
                let mean: f64 = pattern.iter().sum::<f64>() / pattern.len() as f64;
                assert!(mean > 0.0, "rate-replay pattern mean must be > 0");
                assert!(
                    pattern.iter().all(|&p| p >= 0.0),
                    "rate-replay multipliers must be >= 0"
                );
                Some(Self {
                    model,
                    t: 0.0,
                    rate: base_rate * pattern[0] / mean,
                    seg_end: step_ms,
                    rate_on: 0.0,
                    rate_off: 0.0,
                    on: false,
                    state_rng: Rng::new(seed),
                    base_rate,
                    pattern_norm: mean,
                    step: 0,
                })
            }
        }
    }

    fn next_segment(&mut self) {
        match self.model {
            ArrivalModel::Poisson => unreachable!("Poisson never builds a modulator"),
            ArrivalModel::Mmpp { mean_on_ms, mean_off_ms, .. } => {
                self.on = !self.on;
                let (rate, mean) = if self.on {
                    (self.rate_on, mean_on_ms)
                } else {
                    (self.rate_off, mean_off_ms)
                };
                self.rate = rate;
                self.seg_end += self.state_rng.exponential(1.0 / mean);
            }
            ArrivalModel::RateReplay { pattern, step_ms } => {
                self.step += 1;
                self.rate =
                    self.base_rate * pattern[self.step % pattern.len()] / self.pattern_norm;
                self.seg_end += step_ms;
            }
        }
    }

    /// Advance past one unit-exponential increment `w` (one arrival's
    /// worth of integrated rate) and return the absolute arrival time.
    pub fn advance(&mut self, mut w: f64) -> Millis {
        loop {
            let span = self.seg_end - self.t;
            let cap = self.rate * span;
            if self.rate > 0.0 && w <= cap {
                self.t += w / self.rate;
                return self.t;
            }
            // consume this segment's integrated rate and roll over
            // (silent segments contribute nothing and are skipped)
            w -= cap;
            self.t = self.seg_end;
            self.next_segment();
        }
    }
}

// ---- deferred queues -----------------------------------------------------

/// One parked arrival, as handed out by [`DeferredQueues::pop_next`].
/// If the admission retry fails, hand it back via
/// [`DeferredQueues::unpop`] — queue order and the fair-share cursor
/// are restored exactly.
#[derive(Debug, Clone, Copy)]
pub struct Parked {
    /// Tenant (app index) the arrival belongs to.
    pub app: usize,
    /// Index into the generating schedule's arrival vector.
    pub sched: usize,
    /// Simulated time the entry was parked (ms).
    pub enqueued_at: Millis,
    /// Absolute timeout deadline (`enqueued_at + max_wait_ms`).
    pub deadline: Millis,
    /// Global enqueue sequence (FIFO order and deterministic ties).
    pub seq: u64,
    /// Fair-share cursor before the pop (restored by `unpop`).
    prev_cursor: usize,
    /// Remaining deficit-round-robin credit before the pop (restored
    /// by `unpop`; only meaningful for the fair-share disciplines).
    prev_credit: usize,
}

/// Storage slot: either a parked entry linked into its tenant's queue
/// (doubly linked, so earliest-deadline-first eviction can unlink from
/// the middle of a chain), or a free-list link. Slots recycle through
/// the free list, so the pool is O(peak parked entries) — the driver
/// slab pattern.
#[derive(Debug, Clone, Copy)]
struct Slot {
    /// Next slot in the tenant queue, or next free slot.
    next: usize,
    /// Previous slot in the tenant queue (`NIL` at the head; unused
    /// while the slot sits on the free list).
    prev: usize,
    sched: usize,
    enqueued_at: Millis,
    deadline: Millis,
    seq: u64,
}

/// Per-tenant queueing statistics (O(1) memory each: streaming moments
/// and a P² estimator, never stored samples).
#[derive(Debug, Clone)]
struct TenantQueueStats {
    /// Entries ever parked.
    enqueued: usize,
    /// Entries whose SLO deadline genuinely passed while parked.
    timed_out: usize,
    /// Entries still parked when the trace ended whose deadline lay
    /// *beyond* the last event — drained without an SLO violation.
    expired: usize,
    /// Peak queue depth.
    depth_hwm: usize,
    /// Queueing delay of entries admitted from the queue.
    delay: StreamingMoments,
    delay_p95: P2Quantile,
}

impl TenantQueueStats {
    fn new() -> Self {
        Self {
            enqueued: 0,
            timed_out: 0,
            expired: 0,
            depth_hwm: 0,
            delay: StreamingMoments::new(),
            delay_p95: P2Quantile::new(0.95),
        }
    }
}

/// Bounded per-tenant deferred-arrival queues with slab-recycled slots.
///
/// Invariant relied on for exact head-only timeout expiry under the
/// FIFO/fair-share policies: within one tenant's queue, deadlines are
/// non-decreasing (entries are parked at non-decreasing event times
/// with a per-tenant-constant wait bound, and [`DeferredQueues::unpop`]
/// restores an entry exactly where it came from), so the earliest
/// deadline of a tenant is always at its head. The
/// [`AdmissionPolicy::Deadline`] policy drops that assumption — entries
/// may carry arbitrary per-entry deadlines
/// ([`DeferredQueues::park_with_deadline`]) — and instead scans every
/// parked entry (O(parked), bounded by `tenants × max_depth`) for the
/// strict global `(deadline, seq)` minimum, unlinking mid-chain through
/// the doubly-linked slots.
#[derive(Debug)]
pub struct DeferredQueues {
    policy: AdmissionPolicy,
    slots: Vec<Slot>,
    free_head: usize,
    /// Per-tenant queue chain heads/tails (`NIL` when empty).
    head: Vec<usize>,
    tail: Vec<usize>,
    depth: Vec<usize>,
    total: usize,
    /// Fair-share round-robin cursor. With zero remaining `credit` it
    /// names the tenant the next scan starts from; with positive
    /// credit it names the tenant currently being served its quantum.
    cursor: usize,
    /// Remaining picks owed to `cursor`'s tenant in this deficit-
    /// round-robin visit (always 0 under plain [`AdmissionPolicy::FairShare`],
    /// whose quanta are all 1).
    credit: usize,
    /// Deficit-round-robin quantum per tenant (all 1 unless
    /// [`Self::set_weights`] derives otherwise; only the
    /// [`AdmissionPolicy::WeightedFairShare`] drain consults it).
    quantum: Vec<usize>,
    /// Per-tenant wait bound: `try_park` stamps `now + deadline_ms[t]`.
    /// Uniform (the policy's `max_wait_ms`) unless
    /// [`Self::set_deadlines`] installs per-tenant SLOs.
    deadline_ms: Vec<f64>,
    next_seq: u64,
    stats: Vec<TenantQueueStats>,
    fleet_delay: StreamingMoments,
    fleet_p95: P2Quantile,
}

impl DeferredQueues {
    /// Empty queues for `tenants` apps under `policy`.
    pub fn new(policy: AdmissionPolicy, tenants: usize) -> Self {
        let wait = policy.max_wait_ms().unwrap_or(f64::INFINITY);
        Self {
            policy,
            slots: Vec::new(),
            free_head: NIL,
            head: vec![NIL; tenants],
            tail: vec![NIL; tenants],
            depth: vec![0; tenants],
            total: 0,
            cursor: 0,
            credit: 0,
            quantum: vec![1; tenants],
            deadline_ms: vec![wait; tenants],
            next_seq: 0,
            stats: (0..tenants).map(|_| TenantQueueStats::new()).collect(),
            fleet_delay: StreamingMoments::new(),
            fleet_p95: P2Quantile::new(0.95),
        }
    }

    /// Derive the deficit-round-robin quanta from per-tenant weights:
    /// `quantum[t] = max(1, round(weight[t] / min positive weight))`,
    /// so a tenant with twice the weight drains up to two entries per
    /// round-robin visit. Uniform weights — whatever their absolute
    /// scale — produce all-1 quanta, which makes the
    /// [`AdmissionPolicy::WeightedFairShare`] drain pick-for-pick
    /// identical to plain [`AdmissionPolicy::FairShare`]. Non-positive
    /// weights get quantum 1. Only the weighted drain consults quanta;
    /// calling this under any other policy is a no-op by construction.
    pub fn set_weights(&mut self, weights: &[f64]) {
        assert_eq!(weights.len(), self.quantum.len(), "one weight per tenant");
        let min_w = weights
            .iter()
            .copied()
            .filter(|&w| w > 0.0)
            .fold(f64::INFINITY, f64::min);
        for (q, &w) in self.quantum.iter_mut().zip(weights) {
            *q = if w > 0.0 && min_w.is_finite() {
                // cast: safe(ratio of positive finite weights, >= 1.0 after max)
                (w / min_w).round().max(1.0) as usize
            } else {
                1
            };
        }
    }

    /// Install per-tenant SLO deadlines (ms of tolerated queueing
    /// delay) for the [`AdmissionPolicy::Deadline`] policy; `try_park`
    /// stamps each entry with `now + deadline_ms[tenant]`. Per-tenant
    /// *constants* keep within-tenant deadlines monotone, so this is
    /// also sound under the head-expiry policies, but the driver only
    /// wires it for `Deadline`.
    pub fn set_deadlines(&mut self, deadline_ms: &[f64]) {
        assert_eq!(deadline_ms.len(), self.deadline_ms.len(), "one deadline per tenant");
        self.deadline_ms.copy_from_slice(deadline_ms);
    }

    /// The policy these queues enforce.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Parked entries across all tenants.
    pub fn len(&self) -> usize {
        self.total
    }

    /// True when no entry is parked.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Current queue depth of one tenant.
    pub fn depth(&self, app: usize) -> usize {
        self.depth[app]
    }

    /// Slots ever allocated (capacity telemetry: stays at peak depth).
    pub fn slot_high_water(&self) -> usize {
        self.slots.len()
    }

    fn alloc_slot(&mut self, slot: Slot) -> usize {
        if self.free_head != NIL {
            let i = self.free_head;
            self.free_head = self.slots[i].next;
            self.slots[i] = slot;
            i
        } else {
            self.slots.push(slot);
            self.slots.len() - 1
        }
    }

    fn link_tail(&mut self, app: usize, i: usize) {
        self.slots[i].next = NIL;
        self.slots[i].prev = self.tail[app];
        if self.tail[app] == NIL {
            self.head[app] = i;
        } else {
            let t = self.tail[app];
            self.slots[t].next = i;
        }
        self.tail[app] = i;
        self.depth[app] += 1;
        self.total += 1;
    }

    /// Unlink slot `i` from anywhere in `app`'s chain (head, middle or
    /// tail — the doubly-linked slots make mid-chain eviction O(1))
    /// and push it onto the free list.
    fn detach(&mut self, app: usize, i: usize) -> Slot {
        debug_assert_ne!(i, NIL, "detach from empty queue");
        let slot = self.slots[i];
        if slot.prev == NIL {
            self.head[app] = slot.next;
        } else {
            self.slots[slot.prev].next = slot.next;
        }
        if slot.next == NIL {
            self.tail[app] = slot.prev;
        } else {
            self.slots[slot.next].prev = slot.prev;
        }
        self.slots[i].next = self.free_head;
        self.free_head = i;
        self.depth[app] -= 1;
        self.total -= 1;
        slot
    }

    fn unlink_head(&mut self, app: usize) -> Slot {
        let i = self.head[app];
        self.detach(app, i)
    }

    /// Park one failed arrival with the tenant's configured wait bound
    /// (`now + deadline_ms[app]` — the policy's uniform `max_wait_ms`
    /// unless [`Self::set_deadlines`] installed per-tenant SLOs).
    /// Returns `false` (caller counts a rejection) when the policy does
    /// not queue or the tenant's queue is at `max_depth`.
    pub fn try_park(&mut self, app: usize, sched: usize, now: Millis) -> bool {
        let deadline = now + self.deadline_ms[app];
        self.park_at(app, sched, now, deadline)
    }

    /// Park one failed arrival with an *explicit per-entry deadline*
    /// (an SLO class attached to the arrival itself rather than its
    /// tenant). Only exact under [`AdmissionPolicy::Deadline`]: the
    /// head-expiry policies assume within-tenant monotone deadlines,
    /// which arbitrary per-entry values break.
    pub fn park_with_deadline(
        &mut self,
        app: usize,
        sched: usize,
        now: Millis,
        deadline: Millis,
    ) -> bool {
        debug_assert!(
            matches!(self.policy, AdmissionPolicy::Deadline { .. }),
            "per-entry deadlines require the Deadline policy's full-scan expiry"
        );
        self.park_at(app, sched, now, deadline)
    }

    fn park_at(&mut self, app: usize, sched: usize, now: Millis, deadline: Millis) -> bool {
        let max_depth = match self.policy.max_depth() {
            None => return false,
            Some(d) => d,
        };
        if self.depth[app] >= max_depth {
            return false;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let i = self.alloc_slot(Slot {
            next: NIL,
            prev: NIL,
            sched,
            enqueued_at: now,
            deadline,
            seq,
        });
        self.link_tail(app, i);
        let st = &mut self.stats[app];
        st.enqueued += 1;
        st.depth_hwm = st.depth_hwm.max(self.depth[app]);
        true
    }

    /// Expire the single stalest entry whose deadline has passed by
    /// `now` (globally smallest `(deadline, seq)` — ties break by
    /// enqueue sequence). Returns its `(app, sched)` or `None` when
    /// nothing is overdue. Call in a loop before draining.
    ///
    /// Under the head-expiry policies this inspects only the tenant
    /// heads (exact because within-tenant deadlines are monotone —
    /// O(tenants)); under [`AdmissionPolicy::Deadline`] it scans every
    /// parked entry (per-entry deadlines may be non-monotone within a
    /// chain — O(parked)) and unlinks the winner mid-chain.
    pub fn pop_expired(&mut self, now: Millis) -> Option<(usize, usize)> {
        let (app, i) = self.find_overdue(now)?;
        let slot = self.detach(app, i);
        self.stats[app].timed_out += 1;
        Some((app, slot.sched))
    }

    /// Locate the globally smallest `(deadline, seq)` entry whose
    /// deadline is ≤ `now` without detaching or counting it — the
    /// shared selection behind [`Self::pop_expired`] and the end-of-
    /// trace [`Self::expire_all`] split. Returns `(app, slot index)`.
    fn find_overdue(&self, now: Millis) -> Option<(usize, usize)> {
        if matches!(self.policy, AdmissionPolicy::Deadline { .. }) {
            self.earliest_deadline_at_most(now)
        } else {
            let mut best: Option<(f64, u64, usize)> = None; // (deadline, seq, app)
            for app in 0..self.head.len() {
                let h = self.head[app];
                if h == NIL {
                    continue;
                }
                let s = &self.slots[h];
                if s.deadline > now {
                    continue;
                }
                let key = (s.deadline, s.seq, app);
                match best {
                    Some((d, q, _)) if (d, q) <= (key.0, key.1) => {}
                    _ => best = Some(key),
                }
            }
            let (_, _, app) = best?;
            Some((app, self.head[app]))
        }
    }

    /// Globally smallest `(deadline, seq)` entry whose deadline is
    /// ≤ `bound`, scanning every parked entry (the Deadline policy's
    /// EDF view). Returns `(app, slot index)`.
    fn earliest_deadline_at_most(&self, bound: Millis) -> Option<(usize, usize)> {
        let mut best: Option<(f64, u64, usize, usize)> = None; // (deadline, seq, app, slot)
        for app in 0..self.head.len() {
            let mut i = self.head[app];
            while i != NIL {
                let s = &self.slots[i];
                if s.deadline <= bound {
                    match best {
                        Some((d, q, _, _)) if (d, q) <= (s.deadline, s.seq) => {}
                        _ => best = Some((s.deadline, s.seq, app, i)),
                    }
                }
                i = s.next;
            }
        }
        best.map(|(_, _, app, i)| (app, i))
    }

    /// Drain *every* remaining entry at end of trace (`now` = the last
    /// event time; no further capacity-freeing events can admit them),
    /// splitting the accounting by whether the SLO was actually
    /// violated: an entry whose deadline passed by `now` timed out
    /// like any mid-trace expiry, while an entry whose deadline lies
    /// *beyond* the last event never violated its SLO and is counted
    /// as `expired` instead — the trace simply ended first.
    pub fn expire_all(&mut self, now: Millis) {
        while let Some((app, i)) = self.find_overdue(f64::INFINITY) {
            let slot = self.detach(app, i);
            if slot.deadline <= now {
                self.stats[app].timed_out += 1;
            } else {
                self.stats[app].expired += 1;
            }
        }
    }

    /// Hand out the next entry to retry, in policy order:
    /// [`AdmissionPolicy::FifoQueue`] picks the globally oldest entry
    /// (smallest enqueue sequence); [`AdmissionPolicy::FairShare`]
    /// picks the first non-empty tenant at/after the round-robin
    /// cursor and advances the cursor past it;
    /// [`AdmissionPolicy::WeightedFairShare`] is the same round-robin
    /// but a tenant with quantum q drains up to q consecutive entries
    /// per visit (deficit round-robin — with all quanta 1 the pick
    /// sequence is identical to plain FairShare);
    /// [`AdmissionPolicy::Deadline`] picks the globally most urgent
    /// entry (smallest `(deadline, seq)`, anywhere in any chain). If
    /// the admission retry fails, return the entry with
    /// [`Self::unpop`] (or [`Self::unpop_skip_tenant`] for the
    /// fair-share disciplines) and stop draining.
    pub fn pop_next(&mut self) -> Option<Parked> {
        if self.total == 0 {
            return None;
        }
        let n = self.head.len();
        let prev_cursor = self.cursor;
        let prev_credit = self.credit;
        let (app, slot) = match self.policy {
            AdmissionPolicy::RejectImmediately => return None,
            AdmissionPolicy::FifoQueue { .. } => {
                let mut best: Option<(u64, usize)> = None;
                for a in 0..n {
                    let h = self.head[a];
                    if h == NIL {
                        continue;
                    }
                    let seq = self.slots[h].seq;
                    match best {
                        Some((bs, _)) if bs <= seq => {}
                        _ => best = Some((seq, a)),
                    }
                }
                let a = best?.1;
                (a, self.unlink_head(a))
            }
            AdmissionPolicy::FairShare { .. } | AdmissionPolicy::WeightedFairShare { .. } => {
                let weighted = matches!(self.policy, AdmissionPolicy::WeightedFairShare { .. });
                // Serve out the current tenant's remaining quantum
                // first; a tenant that emptied mid-visit forfeits it.
                let mut serving = None;
                if self.credit > 0 {
                    if self.head[self.cursor] != NIL {
                        let a = self.cursor;
                        self.credit -= 1;
                        if self.credit == 0 {
                            self.cursor = (a + 1) % n;
                        }
                        serving = Some(a);
                    } else {
                        self.credit = 0;
                        self.cursor = (self.cursor + 1) % n;
                    }
                }
                let a = match serving {
                    Some(a) => a,
                    None => {
                        let mut chosen = None;
                        for off in 0..n {
                            let a = (self.cursor + off) % n;
                            if self.head[a] != NIL {
                                chosen = Some(a);
                                break;
                            }
                        }
                        let a = chosen?;
                        let quantum = if weighted { self.quantum[a] } else { 1 };
                        self.credit = quantum - 1;
                        self.cursor = if self.credit > 0 { a } else { (a + 1) % n };
                        a
                    }
                };
                (a, self.unlink_head(a))
            }
            AdmissionPolicy::Deadline { .. } => {
                // EDF: the most urgent entry fleet-wide, mid-chain ok.
                let (a, i) = self.earliest_deadline_at_most(f64::INFINITY)?;
                (a, self.detach(a, i))
            }
        };
        Some(Parked {
            app,
            sched: slot.sched,
            enqueued_at: slot.enqueued_at,
            deadline: slot.deadline,
            seq: slot.seq,
            prev_cursor,
            prev_credit,
        })
    }

    /// Return an entry whose admission retry failed to its exact prior
    /// position in its tenant's queue (chains are seq-sorted, so the
    /// sorted re-insert is position-exact even for the Deadline
    /// policy's mid-chain pops), restoring the fair-share cursor and
    /// credit — the next [`Self::pop_next`] hands the same entry out
    /// again.
    pub fn unpop(&mut self, p: Parked) {
        self.restore_entry(&p);
        self.cursor = p.prev_cursor;
        self.credit = p.prev_credit;
    }

    /// Like [`Self::unpop`], but move the fair-share round-robin past
    /// the entry's tenant (forfeiting any remaining weighted quantum):
    /// the failed head returns to its queue, and the next
    /// [`Self::pop_next`] moves on to the *next* non-empty tenant
    /// instead of retrying the same head — so one tenant whose head
    /// does not fit cannot starve the others within a drain pass.
    pub fn unpop_skip_tenant(&mut self, p: Parked) {
        let n = self.head.len();
        self.restore_entry(&p);
        self.credit = 0;
        self.cursor = (p.app + 1) % n;
    }

    /// Re-insert a popped entry at its seq-sorted position in its
    /// tenant's chain (head for head-pops; the exact middle slot for
    /// the Deadline policy's EDF pops).
    fn restore_entry(&mut self, p: &Parked) {
        let mut j = self.head[p.app];
        while j != NIL && self.slots[j].seq < p.seq {
            j = self.slots[j].next;
        }
        let prev = if j == NIL { self.tail[p.app] } else { self.slots[j].prev };
        let i = self.alloc_slot(Slot {
            next: j,
            prev,
            sched: p.sched,
            enqueued_at: p.enqueued_at,
            deadline: p.deadline,
            seq: p.seq,
        });
        if prev == NIL {
            self.head[p.app] = i;
        } else {
            self.slots[prev].next = i;
        }
        if j == NIL {
            self.tail[p.app] = i;
        } else {
            self.slots[j].prev = i;
        }
        self.depth[p.app] += 1;
        self.total += 1;
    }

    /// Number of tenants these queues track.
    pub fn tenants(&self) -> usize {
        self.head.len()
    }

    /// Number of tenants with at least one parked entry (O(tenants)).
    /// Bounds a fair-share drain pass: capacity is monotone within a
    /// pass (failed retries unwind fully), so one failed probe per
    /// non-empty tenant proves no further progress is possible.
    pub fn non_empty_tenants(&self) -> usize {
        self.head.iter().filter(|&&h| h != NIL).count()
    }

    /// Record the queueing delay of an entry successfully admitted
    /// from the queue.
    pub fn record_admitted(&mut self, app: usize, wait_ms: f64) {
        let st = &mut self.stats[app];
        st.delay.push(wait_ms);
        st.delay_p95.push(wait_ms);
        self.fleet_delay.push(wait_ms);
        self.fleet_p95.push(wait_ms);
    }

    /// Fold the queueing statistics together with the driver's
    /// admission-time rejection and mid-run abort counts into the
    /// per-tenant + fleet outcome the report consumes.
    pub fn finish(&self, rejected: &[usize], aborted: &[usize]) -> AdmissionOutcome {
        let per_tenant: Vec<TenantAdmission> = (0..self.stats.len())
            .map(|a| {
                let st = &self.stats[a];
                TenantAdmission {
                    rejected: rejected[a],
                    aborted: aborted[a],
                    timed_out: st.timed_out,
                    expired: st.expired,
                    queued: st.enqueued,
                    drained: cast::usize_of(st.delay.count()),
                    queue_depth_hwm: st.depth_hwm,
                    mean_queue_delay_ms: st.delay.mean(),
                    p95_queue_delay_ms: st.delay_p95.value(),
                }
            })
            .collect();
        let mut fleet = TenantAdmission {
            mean_queue_delay_ms: self.fleet_delay.mean(),
            p95_queue_delay_ms: self.fleet_p95.value(),
            ..TenantAdmission::default()
        };
        for t in &per_tenant {
            fleet.rejected += t.rejected;
            fleet.aborted += t.aborted;
            fleet.timed_out += t.timed_out;
            fleet.expired += t.expired;
            fleet.queued += t.queued;
            fleet.drained += t.drained;
            fleet.queue_depth_hwm = fleet.queue_depth_hwm.max(t.queue_depth_hwm);
        }
        AdmissionOutcome { per_tenant, fleet }
    }
}

/// One tenant's (or the fleet's) admission/queueing outcome.
#[derive(Debug, Clone, Default)]
pub struct TenantAdmission {
    /// Arrivals rejected at admission time (saturated cluster with
    /// [`AdmissionPolicy::RejectImmediately`], or a full queue).
    pub rejected: usize,
    /// Invocations admitted but aborted mid-run (a later wave could
    /// not allocate even degraded).
    pub aborted: usize,
    /// Parked entries whose SLO deadline genuinely passed before
    /// capacity freed (mid-trace or by the end of the trace).
    pub timed_out: usize,
    /// Parked entries drained at end of trace whose deadline lay
    /// beyond the last event — no SLO violation, the trace just ended.
    pub expired: usize,
    /// Entries parked in the deferred queue at least once.
    pub queued: usize,
    /// Parked entries later admitted successfully.
    pub drained: usize,
    /// Peak deferred-queue depth.
    pub queue_depth_hwm: usize,
    /// Mean queueing delay of drained entries (ms).
    pub mean_queue_delay_ms: f64,
    /// P² p95 queueing delay of drained entries (ms).
    pub p95_queue_delay_ms: f64,
}

impl TenantAdmission {
    /// Total arrivals that never completed for admission-side reasons.
    /// The end-of-trace `expired` refinement stays inside this sum, so
    /// the digest-folded total is byte-identical to the pre-split
    /// accounting.
    pub fn failed(&self) -> usize {
        self.rejected + self.aborted + self.timed_out + self.expired
    }
}

/// Per-tenant + fleet admission accounting for one driver run.
#[derive(Debug, Clone)]
pub struct AdmissionOutcome {
    /// Indexed by app.
    pub per_tenant: Vec<TenantAdmission>,
    /// Fleet-wide sums (high-water mark is the max across tenants;
    /// delay moments aggregate every drained entry).
    pub fleet: TenantAdmission,
}

impl AdmissionOutcome {
    /// All-zero outcome for paths that do not model admission (the
    /// closed-form FaaS baseline).
    pub fn zeros(tenants: usize) -> Self {
        Self {
            per_tenant: vec![TenantAdmission::default(); tenants],
            fleet: TenantAdmission::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fifo(max_wait_ms: f64, max_depth: usize) -> AdmissionPolicy {
        AdmissionPolicy::FifoQueue { max_wait_ms, max_depth }
    }

    fn fair(max_wait_ms: f64, max_depth: usize) -> AdmissionPolicy {
        AdmissionPolicy::FairShare { max_wait_ms, max_depth }
    }

    fn wfair(max_wait_ms: f64, max_depth: usize) -> AdmissionPolicy {
        AdmissionPolicy::WeightedFairShare { max_wait_ms, max_depth }
    }

    fn edf(deadline_ms: f64, max_depth: usize) -> AdmissionPolicy {
        AdmissionPolicy::Deadline { deadline_ms, max_depth }
    }

    #[test]
    fn reject_policy_never_parks() {
        let mut q = DeferredQueues::new(AdmissionPolicy::RejectImmediately, 2);
        assert!(!q.try_park(0, 0, 0.0));
        assert!(q.is_empty());
        assert!(q.pop_next().is_none());
    }

    #[test]
    fn fifo_drains_in_global_arrival_order() {
        let mut q = DeferredQueues::new(fifo(1e9, 16), 3);
        // interleave tenants; global FIFO must follow enqueue sequence
        assert!(q.try_park(2, 100, 0.0));
        assert!(q.try_park(0, 101, 1.0));
        assert!(q.try_park(2, 102, 2.0));
        assert!(q.try_park(1, 103, 3.0));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop_next().map(|p| p.sched)).collect();
        assert_eq!(order, vec![100, 101, 102, 103]);
        assert!(q.is_empty());
    }

    #[test]
    fn fair_share_round_robins_by_tenant() {
        let mut q = DeferredQueues::new(fair(1e9, 16), 3);
        for (app, sched) in [(0, 10), (0, 11), (0, 12), (1, 20), (2, 30)] {
            assert!(q.try_park(app, sched, 0.0));
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop_next().map(|p| p.sched)).collect();
        // cursor starts at tenant 0: 0,1,2,0,0
        assert_eq!(order, vec![10, 20, 30, 11, 12]);
    }

    #[test]
    fn unpop_restores_order_and_cursor() {
        let mut q = DeferredQueues::new(fair(1e9, 16), 2);
        assert!(q.try_park(0, 1, 0.0));
        assert!(q.try_park(1, 2, 1.0));
        let p = q.pop_next().expect("entry");
        assert_eq!(p.sched, 1);
        q.unpop(p);
        // cursor restored: the same entry comes out first again
        let again = q.pop_next().expect("entry");
        assert_eq!(again.sched, 1);
        assert_eq!(q.pop_next().expect("entry").sched, 2);
    }

    #[test]
    fn unpop_skip_tenant_advances_past_a_blocked_head() {
        let mut q = DeferredQueues::new(fair(1e9, 16), 3);
        assert!(q.try_park(0, 10, 0.0)); // pretend tenant 0's head is unadmittable
        assert!(q.try_park(1, 20, 0.0));
        assert!(q.try_park(2, 30, 0.0));
        let blocked = q.pop_next().expect("tenant 0 first");
        assert_eq!(blocked.app, 0);
        q.unpop_skip_tenant(blocked);
        // cursor stays advanced: the other tenants drain before 0 retries
        assert_eq!(q.pop_next().expect("next tenant").sched, 20);
        assert_eq!(q.pop_next().expect("next tenant").sched, 30);
        assert_eq!(q.pop_next().expect("back to 0").sched, 10);
        assert!(q.pop_next().is_none());
    }

    #[test]
    fn timeouts_expire_in_deadline_then_seq_order() {
        let mut q = DeferredQueues::new(fifo(10.0, 16), 2);
        // same deadline (parked at the same instant): ties break by seq
        assert!(q.try_park(1, 7, 0.0));
        assert!(q.try_park(0, 8, 0.0));
        assert!(q.try_park(0, 9, 5.0)); // deadline 15
        assert!(q.pop_expired(9.0).is_none(), "nothing overdue yet");
        assert_eq!(q.pop_expired(10.0), Some((1, 7)));
        assert_eq!(q.pop_expired(10.0), Some((0, 8)));
        assert!(q.pop_expired(10.0).is_none(), "deadline 15 still live");
        assert_eq!(q.pop_expired(20.0), Some((0, 9)));
        let out = q.finish(&[0, 0], &[0, 0]);
        assert_eq!(out.per_tenant[0].timed_out, 2);
        assert_eq!(out.per_tenant[1].timed_out, 1);
        assert_eq!(out.fleet.timed_out, 3);
    }

    #[test]
    fn depth_bound_rejects_and_tracks_high_water() {
        let mut q = DeferredQueues::new(fifo(1e9, 2), 1);
        assert!(q.try_park(0, 0, 0.0));
        assert!(q.try_park(0, 1, 0.0));
        assert!(!q.try_park(0, 2, 0.0), "queue full");
        assert_eq!(q.depth(0), 2);
        let out = q.finish(&[1], &[0]);
        assert_eq!(out.per_tenant[0].queue_depth_hwm, 2);
        assert_eq!(out.per_tenant[0].queued, 2);
        assert_eq!(out.per_tenant[0].rejected, 1);
    }

    #[test]
    fn slots_recycle_through_the_free_list() {
        let mut q = DeferredQueues::new(fifo(1e9, 8), 1);
        for round in 0..5 {
            assert!(q.try_park(0, round * 2, round as f64));
            assert!(q.try_park(0, round * 2 + 1, round as f64));
            assert!(q.pop_next().is_some());
            assert!(q.pop_next().is_some());
        }
        assert_eq!(q.slot_high_water(), 2, "pool stays at peak depth");
    }

    #[test]
    fn delay_stats_flow_into_outcome() {
        let mut q = DeferredQueues::new(fifo(1e9, 8), 2);
        assert!(q.try_park(0, 0, 0.0));
        let p = q.pop_next().expect("entry");
        q.record_admitted(p.app, 40.0);
        q.record_admitted(0, 60.0);
        let out = q.finish(&[0, 0], &[0, 0]);
        assert_eq!(out.per_tenant[0].drained, 2);
        assert!((out.per_tenant[0].mean_queue_delay_ms - 50.0).abs() < 1e-9);
        assert!(out.per_tenant[0].p95_queue_delay_ms >= 40.0);
        assert!((out.fleet.mean_queue_delay_ms - 50.0).abs() < 1e-9);
        assert_eq!(out.fleet.drained, 2);
    }

    #[test]
    fn expire_all_splits_violations_from_trace_end_expiries() {
        // Wait bound 100 ms: entries parked at t=0 deadline at t=100.
        let mut q = DeferredQueues::new(fair(100.0, 8), 3);
        for app in 0..3 {
            assert!(q.try_park(app, app, 0.0));
        }
        // Trace ends at t=250: every deadline has passed → timeouts.
        q.expire_all(250.0);
        assert!(q.is_empty());
        let out = q.finish(&[0; 3], &[0; 3]);
        assert_eq!(out.fleet.timed_out, 3);
        assert_eq!(out.fleet.expired, 0);
        assert_eq!(out.fleet.failed(), 3);
    }

    /// Satellite regression (ISSUE 10): a late arrival parked under a
    /// long deadline must drain as `expired` (its SLO was never
    /// violated — the trace just ended), not as `timed_out`, while an
    /// entry whose deadline genuinely passed stays a timeout. The sum
    /// the digest folds (`failed()`) covers both, so the refinement is
    /// invisible to pinned digests.
    #[test]
    fn expire_all_counts_unviolated_deadlines_as_expired_not_timed_out() {
        let mut q = DeferredQueues::new(edf(1e9, 16), 2);
        // tenant 0: deadline 50 — passed well before the trace ends
        assert!(q.park_with_deadline(0, 7, 0.0, 50.0));
        // tenant 1: parked late, deadline 10_000 — far beyond trace end
        assert!(q.park_with_deadline(1, 8, 190.0, 10_000.0));
        q.expire_all(200.0);
        assert!(q.is_empty());
        let out = q.finish(&[0, 0], &[0, 0]);
        assert_eq!(out.per_tenant[0].timed_out, 1, "violated SLO stays a timeout");
        assert_eq!(out.per_tenant[0].expired, 0);
        assert_eq!(out.per_tenant[1].timed_out, 0, "unviolated SLO is not a timeout");
        assert_eq!(out.per_tenant[1].expired, 1);
        assert_eq!(out.fleet.timed_out, 1);
        assert_eq!(out.fleet.expired, 1);
        assert_eq!(out.fleet.failed(), 2, "digest-folded sum unchanged by the split");
    }

    // ---- SLO-aware (Deadline) policy ------------------------------------

    /// Satellite regression (ISSUE 5): under `AdmissionPolicy::Deadline`
    /// entries expire strictly by `(deadline, enqueue seq)` even when
    /// deadlines are *non-monotone within one tenant's queue* — a later
    /// arrival with a tighter SLO class must evict before an earlier
    /// arrival with a loose one, which a head-only FIFO expiry cannot
    /// represent (the head hides the urgent entry behind it).
    #[test]
    fn deadline_eviction_is_strict_deadline_seq_order_even_non_monotone() {
        let mut q = DeferredQueues::new(edf(1e9, 16), 2);
        // tenant 0: loose head (deadline 50, seq 0), tight second entry
        // (deadline 10, seq 1) — non-monotone within the chain
        assert!(q.park_with_deadline(0, 100, 0.0, 50.0));
        assert!(q.park_with_deadline(0, 101, 0.0, 10.0));
        // tenant 1: same tight deadline, later seq (tie → seq order)
        assert!(q.park_with_deadline(1, 102, 0.0, 10.0));
        assert!(q.pop_expired(9.0).is_none(), "nothing overdue yet");
        // strict (deadline, seq): the mid-chain entry goes first
        assert_eq!(q.pop_expired(10.0), Some((0, 101)));
        assert_eq!(q.pop_expired(10.0), Some((1, 102)));
        assert!(q.pop_expired(10.0).is_none(), "deadline 50 still live");
        assert_eq!(q.pop_expired(50.0), Some((0, 100)));
        assert!(q.is_empty());
        let out = q.finish(&[0, 0], &[0, 0]);
        assert_eq!(out.per_tenant[0].timed_out, 2);
        assert_eq!(out.per_tenant[1].timed_out, 1);
    }

    #[test]
    fn deadline_drains_earliest_deadline_first() {
        let mut q = DeferredQueues::new(edf(1e9, 16), 3);
        assert!(q.park_with_deadline(0, 10, 0.0, 300.0));
        assert!(q.park_with_deadline(1, 20, 0.0, 100.0));
        assert!(q.park_with_deadline(2, 30, 0.0, 200.0));
        assert!(q.park_with_deadline(1, 21, 0.0, 100.0)); // tie with 20 → seq
        let order: Vec<usize> = std::iter::from_fn(|| q.pop_next().map(|p| p.sched)).collect();
        assert_eq!(order, vec![20, 21, 30, 10]);
    }

    #[test]
    fn deadline_unpop_restores_exact_mid_chain_position() {
        let mut q = DeferredQueues::new(edf(1e9, 16), 1);
        // one tenant, three entries; the urgent one sits mid-chain
        assert!(q.park_with_deadline(0, 1, 0.0, 500.0));
        assert!(q.park_with_deadline(0, 2, 1.0, 50.0));
        assert!(q.park_with_deadline(0, 3, 2.0, 900.0));
        let p = q.pop_next().expect("most urgent");
        assert_eq!(p.sched, 2);
        q.unpop(p);
        assert_eq!(q.depth(0), 3);
        // order unchanged: the same entry comes out first again, and
        // eviction at its deadline still finds it (mid-chain restore)
        assert_eq!(q.pop_next().expect("same entry").sched, 2);
        assert_eq!(q.pop_next().expect("next").sched, 1);
        assert_eq!(q.pop_next().expect("last").sched, 3);
    }

    #[test]
    fn per_tenant_slo_deadlines_apply_at_park_time() {
        let mut q = DeferredQueues::new(edf(1_000.0, 16), 2);
        q.set_deadlines(&[10.0, 100.0]);
        assert!(q.try_park(0, 0, 0.0));
        assert!(q.try_park(1, 1, 0.0));
        // tenant 0's tight SLO expires first despite identical parking
        assert_eq!(q.pop_expired(10.0), Some((0, 0)));
        assert!(q.pop_expired(10.0).is_none());
        assert_eq!(q.pop_expired(100.0), Some((1, 1)));
    }

    #[test]
    fn deadline_slots_recycle_through_the_free_list() {
        let mut q = DeferredQueues::new(edf(1e9, 8), 2);
        for round in 0..5 {
            let t = round as f64;
            assert!(q.park_with_deadline(0, round * 2, t, t + 100.0));
            assert!(q.park_with_deadline(1, round * 2 + 1, t, t + 50.0));
            assert!(q.pop_next().is_some());
            assert!(q.pop_next().is_some());
        }
        assert_eq!(q.slot_high_water(), 2, "pool stays at peak depth");
    }

    // ---- weighted fair share --------------------------------------------

    #[test]
    fn weighted_fair_share_serves_quanta_per_visit() {
        let mut q = DeferredQueues::new(wfair(1e9, 16), 2);
        q.set_weights(&[2.0, 1.0]);
        for (app, sched) in [(0, 10), (0, 11), (0, 12), (0, 13), (1, 20), (1, 21)] {
            assert!(q.try_park(app, sched, 0.0));
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop_next().map(|p| p.sched)).collect();
        // DRR: tenant 0 drains two per visit, tenant 1 one
        assert_eq!(order, vec![10, 11, 20, 12, 13, 21]);
    }

    #[test]
    fn weighted_quanta_normalize_by_min_positive_weight() {
        let mut q = DeferredQueues::new(wfair(1e9, 16), 3);
        // uniform at any scale → all quanta 1 (the FairShare contract)
        q.set_weights(&[2.5, 2.5, 2.5]);
        assert_eq!(q.quantum, vec![1, 1, 1]);
        q.set_weights(&[6.0, 2.0, 0.0]);
        assert_eq!(q.quantum, vec![3, 1, 1], "non-positive weight gets quantum 1");
    }

    /// Differential: with all weights equal, the weighted drain must be
    /// pick-for-pick identical to plain FairShare — including across a
    /// blocked-tenant skip (the driver-level digest differential in
    /// `rust/tests/proptests.rs` extends this end to end).
    #[test]
    fn equal_weight_weighted_fair_share_matches_fair_share_pick_for_pick() {
        let parks = [(0usize, 10usize), (1, 20), (2, 30), (0, 11), (2, 31), (1, 21)];
        let run = |policy: AdmissionPolicy, weighted: bool| -> Vec<usize> {
            let mut q = DeferredQueues::new(policy, 3);
            if weighted {
                q.set_weights(&[4.0, 4.0, 4.0]);
            }
            for &(app, sched) in &parks {
                assert!(q.try_park(app, sched, 0.0));
            }
            let mut order = Vec::new();
            let mut skipped = false;
            while let Some(p) = q.pop_next() {
                // fail tenant 1's first head once, as a blocked retry
                if p.app == 1 && !skipped {
                    skipped = true;
                    q.unpop_skip_tenant(p);
                    continue;
                }
                order.push(p.sched);
            }
            order
        };
        let plain = run(fair(1e9, 16), false);
        let weighted = run(wfair(1e9, 16), true);
        assert_eq!(plain, weighted, "equal weights must reduce to plain FairShare");
    }

    #[test]
    fn weighted_skip_forfeits_the_remaining_quantum() {
        let mut q = DeferredQueues::new(wfair(1e9, 16), 2);
        q.set_weights(&[3.0, 1.0]);
        for (app, sched) in [(0, 10), (0, 11), (0, 12), (1, 20)] {
            assert!(q.try_park(app, sched, 0.0));
        }
        let p = q.pop_next().expect("tenant 0 first");
        assert_eq!(p.sched, 10);
        // tenant 0's head is blocked: skip forfeits its two remaining
        // quantum picks — tenant 1 drains before tenant 0 returns
        q.unpop_skip_tenant(p);
        assert_eq!(q.pop_next().expect("tenant 1").sched, 20);
        assert_eq!(q.pop_next().expect("back to 0").sched, 10);
    }

    // ---- burst models ---------------------------------------------------

    #[test]
    fn poisson_builds_no_modulator() {
        assert!(RateModulator::new(ArrivalModel::Poisson, 0.01, 7).is_none());
        assert!(ArrivalModel::default().is_poisson());
    }

    #[test]
    fn mmpp_is_deterministic_and_monotone() {
        let model =
            ArrivalModel::Mmpp { on_mult: 8.0, mean_on_ms: 500.0, mean_off_ms: 2000.0 };
        let run = |seed: u64| -> Vec<f64> {
            let mut m = RateModulator::new(model, 1.0 / 200.0, seed).unwrap();
            let mut rng = Rng::new(42);
            (0..500).map(|_| m.advance(rng.exponential(1.0))).collect()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "deterministic per seed");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrival times monotone");
        let c = run(8);
        assert_ne!(a, c, "state seed must matter");
    }

    #[test]
    fn mmpp_preserves_offered_load_but_bursts() {
        let rate = 1.0 / 100.0; // one arrival per 100 ms
        let n = 20_000usize;
        let gaps = |model: ArrivalModel| -> Vec<f64> {
            let mut rng = Rng::new(3);
            let mut prev = 0.0;
            let mut out = Vec::with_capacity(n);
            match RateModulator::new(model, rate, 11) {
                Some(mut m) => {
                    for _ in 0..n {
                        let t = m.advance(rng.exponential(1.0));
                        out.push(t - prev);
                        prev = t;
                    }
                }
                None => {
                    for _ in 0..n {
                        out.push(rng.exponential(rate));
                    }
                }
            }
            out
        };
        let poisson = gaps(ArrivalModel::Poisson);
        let mmpp = gaps(ArrivalModel::Mmpp {
            on_mult: 10.0,
            mean_on_ms: 2_000.0,
            mean_off_ms: 8_000.0,
        });
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        let cv = |xs: &[f64]| {
            let m = mean(xs);
            let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
            var.sqrt() / m
        };
        // long-run offered load within 10% of the Poisson baseline
        assert!(
            (mean(&mmpp) - mean(&poisson)).abs() < 0.10 * mean(&poisson),
            "mmpp mean gap {} vs poisson {}",
            mean(&mmpp),
            mean(&poisson)
        );
        // but markedly burstier: inter-arrival CV well above exponential's 1
        assert!(cv(&poisson) < 1.15, "poisson CV {}", cv(&poisson));
        assert!(cv(&mmpp) > 1.3, "mmpp CV {} not bursty", cv(&mmpp));
    }

    #[test]
    fn rate_replay_respects_silent_windows() {
        // pattern [0, 1]: arrivals may only land in odd steps
        static PATTERN: [f64; 2] = [0.0, 1.0];
        let step = 1000.0;
        let mut m = RateModulator::new(
            ArrivalModel::RateReplay { pattern: &PATTERN, step_ms: step },
            1.0 / 500.0,
            5,
        )
        .unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..300 {
            let t = m.advance(rng.exponential(1.0));
            let step_idx = (t / step).floor() as u64;
            assert_eq!(step_idx % 2, 1, "arrival at {t} fell in a silent window");
        }
    }
}
