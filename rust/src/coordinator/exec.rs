//! Adaptive execution engine (§5.1-5.2): the Zenix [`Platform`].
//!
//! Executes an application invocation against the cluster substrate:
//!
//! 1. the global scheduler routes the invocation to a rack;
//! 2. the rack scheduler tries to fit the whole app on one server
//!    (smallest-fit; marks the server's potential demand at low
//!    priority);
//! 3. compute components execute wave-by-wave (resource-graph topology):
//!    sized from history (or the §9.3 solver / fixed sizes for the
//!    ablations), placed by locality, materialized into the anchor
//!    container when possible, auto-scaled when actual demand exceeds
//!    the initial allocation (growths may land remote → swap slowdown);
//! 4. data components launch with their first accessor, grow
//!    local-first, and die with their last accessor;
//! 5. component results go through the reliable message log, enabling
//!    graph-cut recovery ([`super::failure`]).
//!
//! All latency constants flow from [`StartupModel`], [`NetModel`] and
//! [`ControlPlane`] — the paper-calibrated models (DESIGN.md §1).
//!
//! ## Re-entrant execution (multi-tenant)
//!
//! [`Platform::invoke`] runs one invocation to completion, but the
//! engine itself is a *resumable state machine*: [`Platform::begin_at`]
//! opens an [`OngoingInvocation`] at an arbitrary simulated time,
//! [`Platform::start_wave`] executes one wave's scheduling/placement
//! and emits its deferred allocation timeline, and
//! [`Platform::wave_done`] advances to the next wave. A driver (see
//! [`super::driver`]) holds many `OngoingInvocation`s at once and
//! interleaves their timeline events in global time order, so
//! concurrent invocations from different applications genuinely overlap
//! on the shared cluster instead of serializing through `Platform::now`.
//!
//! ## Allocation-free steady state
//!
//! The per-invocation control path reuses state the way the platform it
//! models reuses environments: completed [`OngoingInvocation`] shells
//! are recycled through a pool on [`Platform`] (every buffer keeps its
//! capacity; [`Platform::begin_at`] clears instead of reallocating),
//! the per-component tables are dense `Vec`s indexed by the graph's
//! dense component ids rather than hash maps, wave structure is a
//! CSR-flattened pair of reused buffers, the §5.2.3 re-tune solver
//! reads history through a pooled scratch, and rack availability flows
//! to the global scheduler as incremental dirty-rack deltas from the
//! cluster hooks instead of an O(racks) sweep per admission. After
//! warm-up, a steady-state invocation performs zero heap allocations
//! (enforced by `rust/tests/alloc_free.rs` with a counting global
//! allocator).

use std::collections::HashMap;

use crate::apps::Invocation;
use crate::cluster::clock::Millis;
use crate::cluster::server::Consumption;
use crate::cluster::snapshot::{SnapshotCache, SnapshotStats};
use crate::cluster::{Cluster, ClusterSpec, RackId, Resources, ServerId, StartupModel, StartupTier};
use crate::memory::MemoryController;
use crate::metrics::{Breakdown, RunReport};
use crate::net::{ControlPath, ControlPlane, NetKind, NetModel};
use crate::util::cast;

use super::adjust::{self, AdjustParams};
use super::failure::{self, Crash};
use super::graph::ResourceGraph;
use super::history::{Metric, ProfileStore};
use super::msglog::{LogEntry, MessageLog};
use super::scheduler::{Allocation, GlobalScheduler, RackScheduler};

/// Feature switches — the paper's ablation axes (Figs 10/14/22).
#[derive(Debug, Clone, Copy)]
pub struct ZenixConfig {
    /// §5.1 adaptive scheduling/execution: co-location + materialization.
    pub adaptive: bool,
    /// §5.2.1-2 proactive: pre-warm, pre-launch, async connection setup.
    pub proactive: bool,
    /// §5.2.3 history-based init/step sizing (else fixed sizes below).
    pub history_sizing: bool,
    /// RDMA vs TCP stacks.
    pub rdma: bool,
    /// Fixed initial-size fallback (the paper's 256 MB default).
    pub fixed_init_mb: f64,
    /// Fixed growth-step fallback (the paper's 64 MB default).
    pub fixed_step_mb: f64,
    /// Provision every component at its historical peak (Fig 22 "peak").
    pub peak_provision: bool,
    /// Force all data components remote (Fig 18/21 "disaggregation").
    pub force_remote_data: bool,
    /// CPU utilization Zenix sustains on allocated vCPUs (§6.1.1: 91.2%).
    pub cpu_efficiency: f64,
}

impl Default for ZenixConfig {
    fn default() -> Self {
        Self {
            adaptive: true,
            proactive: true,
            history_sizing: true,
            rdma: true,
            fixed_init_mb: 256.0,
            fixed_step_mb: 64.0,
            peak_provision: false,
            force_remote_data: false,
            cpu_efficiency: 0.912,
        }
    }
}

impl ZenixConfig {
    /// Ablation step 1 (Fig 10): static resource graph only — separate
    /// environments, no adaptive/proactive/history machinery.
    pub fn static_graph() -> Self {
        Self {
            adaptive: false,
            proactive: false,
            history_sizing: false,
            ..Self::default()
        }
    }

    /// Ablation step 2: + adaptive scheduling/execution.
    pub fn adaptive_only() -> Self {
        Self { proactive: false, history_sizing: false, ..Self::default() }
    }

    fn net_kind(&self) -> NetKind {
        if self.rdma {
            NetKind::Rdma
        } else {
            NetKind::Tcp
        }
    }

    fn control_path(&self) -> ControlPath {
        if self.proactive {
            ControlPath::NetVirtAsync
        } else {
            ControlPath::NetVirt
        }
    }
}

/// The Zenix platform instance.
pub struct Platform {
    /// The shared cluster substrate every invocation allocates from.
    pub cluster: Cluster,
    /// Feature switches (ablation axes).
    pub config: ZenixConfig,
    /// Decaying-weight resource profiles (§5.2.3 sizing inputs).
    pub history: ProfileStore,
    /// Startup-latency model (paper-calibrated).
    pub startup: StartupModel,
    /// Network cost model (TCP vs RDMA).
    pub net: NetModel,
    /// Control-plane latency model.
    pub control: ControlPlane,
    /// The global (cluster-level) scheduler.
    pub global: GlobalScheduler,
    racks: Vec<RackScheduler>,
    /// Reliable message log for graph-cut recovery.
    pub msglog: MessageLog,
    now: Millis,
    next_invocation: u64,
    /// Apps with a kept-warm environment (§5.2.1 pre-warming of the
    /// first component based on invocation history). Keyed by the
    /// program's interned (`&'static`) name — membership tests on the
    /// hot path allocate nothing.
    warm_pool: std::collections::HashSet<&'static str>,
    /// Static resource-graph profile (§4.2): the per-node size captured
    /// by the offline sampling run (first observation). The non-history
    /// configurations size components with this fixed estimate — the
    /// function-model limitation the history mechanism removes.
    static_profile: HashMap<(&'static str, usize), f64>,
    /// Cached §9.3 solver output per node, re-tuned every
    /// [`RETUNE_EVERY`] executions (§5.2.3: "re-adjusts these two sizes
    /// periodically after K executions"). Stores (init, step, solved-at).
    /// Keyed by the interned program name: cache hits are
    /// allocation-free (no per-lookup `String`).
    sizing_cache: std::cell::RefCell<HashMap<(&'static str, usize), (f64, f64, usize)>>,
    /// Preallocated placement scratch reused across waves/invocations so
    /// the per-component decision loop performs no candidate-vector
    /// allocations (capacity grows once, then steady-state is
    /// allocation-free).
    scratch: PlacementCtx,
    /// Recycled [`OngoingInvocation`] shells: [`Self::begin_at`] pops
    /// one and clears it in place, so the per-invocation tables reuse
    /// capacity instead of allocating (pool size is bounded by the peak
    /// number of concurrently in-flight invocations).
    shell_pool: Vec<OngoingInvocation>,
    /// Pooled history-values buffer for the periodic §5.2.3 re-tune
    /// (`Profile::values_into`) — keeps the solver call allocation-free.
    solver_scratch: std::cell::RefCell<Vec<f64>>,
    /// Tiered cold-start state ([`Self::enable_snapshots`]): per-rack
    /// snapshot caches plus the predictive pre-warm inputs. `None` (the
    /// default) keeps the flat cold/warm model and the legacy replay
    /// byte-identical.
    snapshots: Option<SnapshotLayer>,
}

/// Coordinator-side snapshot/restore state: one byte-budgeted cache per
/// rack, and the pre-warm policy inputs the driver derives from its
/// arrival schedule. All mutation happens on the coordinator side of
/// both event loops (`begin_at` / `start_wave` / fault handling), so
/// tiered replays stay digest-identical at every worker count.
struct SnapshotLayer {
    /// Per-rack caches, indexed by rack id.
    caches: Vec<SnapshotCache>,
    /// Predictive pre-warm enabled.
    prewarm: bool,
    /// Whether the initial pre-warm fill ran (later passes trigger only
    /// at rack-dirty instants).
    primed: bool,
    /// Per-app snapshot image sizes in descending expected-arrival
    /// order (the driver scores apps by scheduled arrivals over the
    /// run's horizon — the normalized long-run rate of all three
    /// arrival models).
    images: Vec<(&'static str, u64)>,
    /// Pre-warm considers only the first `top_k` images per rack.
    top_k: usize,
    /// Live decayed arrival-rate score per image (`rate[i]` pairs with
    /// `images[i]`): `(score, last_update)`. Every admission folds in
    /// with half-life [`PREWARM_RATE_HALF_LIFE_MS`], so diurnal
    /// day/night turnover re-ranks the pre-warm candidates toward the
    /// tenants arriving *now* instead of the schedule's static
    /// expectation. All-zero scores (no arrivals yet) reproduce the
    /// static ranking exactly.
    rate: Vec<(f64, Millis)>,
    /// Scratch index order for the live re-rank (capacity persists —
    /// the pass stays allocation-free after warm-up).
    order: Vec<u32>,
}

/// Half-life (ms) of the live arrival-rate decay that ranks pre-warm
/// candidates: short enough that a diurnal phase flip (tens of seconds
/// in the driver's compressed traces) demotes the idle tenant within
/// one phase, long enough that Poisson gaps at the default 400 ms IAT
/// do not thrash the ranking.
const PREWARM_RATE_HALF_LIFE_MS: f64 = 5_000.0;

impl SnapshotLayer {
    /// Image size for `app` (linear scan of the interned-name table —
    /// app counts are small and the scan is allocation-free).
    fn image_bytes(&self, app: &'static str) -> u64 {
        self.images
            .iter()
            .find(|(name, _)| *name == app)
            .map_or(0, |&(_, bytes)| bytes)
    }
}

/// Snapshot image size in MB for cluster memory charging.
fn image_mb(bytes: u64) -> f64 {
    // cast: safe(image sizes are clamped to single-digit GiB by the
    // driver's sizing rule, far below f64's 2^53 integer range)
    bytes as f64 / (1024.0 * 1024.0)
}

/// The server in `rack` with the most available memory that can hold
/// `need_mb` (ties break to the lowest id; down servers report zero
/// availability and are skipped naturally). `None` when nothing fits.
fn best_mem_server(cluster: &Cluster, rack: RackId, need_mb: f64) -> Option<ServerId> {
    let mut best: Option<(ServerId, f64)> = None;
    for id in cluster.rack_servers(rack) {
        let avail = cluster.server(id).available().mem_mb;
        if avail + 1e-9 >= need_mb && best.map_or(true, |(_, b)| avail > b) {
            best = Some((id, avail));
        }
    }
    best.map(|(id, _)| id)
}

/// Scratch buffers for the wave loop's placement decisions. Taken out
/// of the platform at the top of a wave (`std::mem::take`) and
/// restored at the end; every buffer is `clear()`ed before reuse so
/// only capacity persists.
#[derive(Debug, Default)]
struct PlacementCtx {
    /// Servers hosting the data a component accesses.
    data_servers: Vec<ServerId>,
    /// Servers running accessors of a growing data component.
    accessors: Vec<ServerId>,
    /// Remote servers already charged for connection setup (QP reuse).
    conn_seen: Vec<ServerId>,
}

/// Re-tune period K for the init/step solver (§5.2.3; the paper uses
/// ~1000 — we re-tune more eagerly since test runs are short).
pub const RETUNE_EVERY: usize = 16;

/// Per-invocation execution state for the re-entrant entry points.
///
/// One `OngoingInvocation` is the paused continuation of one
/// application invocation: which wave is next, where its components and
/// data live, the deferred allocation timeline of the wave in flight,
/// and the per-invocation accounting. The single-tenant
/// [`Platform::invoke`] drives exactly one of these to completion; the
/// multi-tenant [`super::driver`] holds many and interleaves them.
///
/// Component ids are dense per graph, so every per-component table is a
/// dense `Vec` (index = component id) rather than a hash map, and the
/// whole shell is recycled through [`Platform`]'s pool: capacity
/// persists across invocations, steady-state admission allocates
/// nothing.
pub struct OngoingInvocation {
    pub(crate) scale: f64,
    pub(crate) inv_id: u64,
    pub(crate) t0: Millis,
    pub(crate) consumed_before: Consumption,
    pub(crate) breakdown: Breakdown,
    pub(crate) mem: MemoryController,
    /// Dense by data index: the server holding the data's home region.
    pub(crate) data_home: Vec<Option<ServerId>>,
    /// Dense by compute index: where the component was placed.
    pub(crate) comp_server: Vec<Option<ServerId>>,
    pub(crate) merge_pairs: Vec<(usize, usize)>,
    pub(crate) colocated_components: usize,
    pub(crate) total_components: usize,
    pub(crate) peak_cpu: f64,
    pub(crate) peak_mem: f64,
    /// Start time of the wave about to run (after [`Platform::wave_done`]
    /// it is the end of the previous wave).
    pub(crate) wave_start: Millis,
    pub(crate) prev_wave_dur: f64,
    /// Duration of the wave most recently started.
    pub(crate) wave_dur: f64,
    pub(crate) crash_state: Option<(Crash, usize)>,
    pub(crate) anchor: Option<ServerId>,
    pub(crate) estimate: Resources,
    pub(crate) rack_id: RackId,
    /// CSR-flattened wave structure (see `ResourceGraph::waves_into`):
    /// wave `w` = `wave_comps[wave_offsets[w]..wave_offsets[w + 1]]`.
    pub(crate) wave_offsets: Vec<usize>,
    pub(crate) wave_comps: Vec<usize>,
    pub(crate) wave_idx: usize,
    /// Growths that actually landed, dense by compute index:
    /// (extra alloc MB, used MB added, applied-at). `Finish` releases
    /// exactly these — a failed `Grow` (saturated cluster) leaves
    /// nothing to subtract.
    pub(crate) grown: Vec<Option<(f64, f64, Millis)>>,
    /// Deferred allocation-timeline events of the wave in flight as
    /// (time, push-sequence, server, event); drained by the caller
    /// (sorted by (time, sequence) single-tenant — reproducing stable
    /// push order without a stable sort's scratch allocation — or
    /// merged into the driver's global heap multi-tenant).
    pub(crate) pending: Vec<(Millis, u32, ServerId, TimelineEv)>,
    /// Attributed per-invocation consumption (compute allocations,
    /// landed growths and data-component regions integrated over their
    /// own lifetimes). The multi-tenant driver reports this — a
    /// cluster-wide before/after diff would include the other tenants.
    pub(crate) attrib: Consumption,
    /// Live data components, dense by data index: (last stamp, MB).
    pub(crate) data_track: Vec<Option<(Millis, f64)>>,
    /// Runtime growth events this invocation needed (sizing convergence
    /// signal: history sizing drives this toward zero).
    pub(crate) growth_count: usize,
    /// Whether wave 0 hit the warm pool (None before wave 0 ran).
    pub(crate) first_wave_warm: Option<bool>,
    /// Which start tier the first environment resolved to (None before
    /// wave 0 ran). Resolved exactly once — rewound wave-0 re-runs
    /// after a crash reuse it (the environment is already up).
    pub(crate) start_tier: Option<StartupTier>,
    /// Start latency the resolved tier charged (0 before wave 0 ran).
    pub(crate) start_latency_ms: f64,
    /// Simulated instant the driver's fault injector marked this
    /// invocation as hit (None when unaffected). Set at most once;
    /// completion then counts as a recovery and the delta to the
    /// completion instant is the recovery latency.
    pub(crate) fault_at: Option<Millis>,
}

impl OngoingInvocation {
    /// A blank shell (no capacity); [`Platform::begin_at`] sizes it for
    /// a concrete graph via [`Self::reset`].
    fn empty() -> Self {
        Self {
            scale: 0.0,
            inv_id: 0,
            t0: 0.0,
            consumed_before: Consumption::default(),
            breakdown: Breakdown::default(),
            mem: MemoryController::new(),
            data_home: Vec::new(),
            comp_server: Vec::new(),
            merge_pairs: Vec::new(),
            colocated_components: 0,
            total_components: 0,
            peak_cpu: 0.0,
            peak_mem: 0.0,
            wave_start: 0.0,
            prev_wave_dur: 0.0,
            wave_dur: 0.0,
            crash_state: None,
            anchor: None,
            estimate: Resources::ZERO,
            rack_id: RackId(0),
            wave_offsets: Vec::new(),
            wave_comps: Vec::new(),
            wave_idx: 0,
            grown: Vec::new(),
            pending: Vec::new(),
            attrib: Consumption::default(),
            data_track: Vec::new(),
            growth_count: 0,
            first_wave_warm: None,
            start_tier: None,
            start_latency_ms: 0.0,
            fault_at: None,
        }
    }

    /// Clear the shell in place and size its dense tables for `graph`
    /// — allocation-free once every buffer has seen a graph at least
    /// this large.
    fn reset(
        &mut self,
        graph: &ResourceGraph,
        scale: f64,
        inv_id: u64,
        at: Millis,
        crash: Option<(Crash, usize)>,
    ) {
        self.scale = scale;
        self.inv_id = inv_id;
        self.t0 = at;
        self.consumed_before = Consumption::default();
        self.breakdown = Breakdown::default();
        self.mem.reset();
        self.data_home.clear();
        self.data_home.resize(graph.n_data(), None);
        self.comp_server.clear();
        self.comp_server.resize(graph.n_compute(), None);
        self.grown.clear();
        self.grown.resize(graph.n_compute(), None);
        self.data_track.clear();
        self.data_track.resize(graph.n_data(), None);
        self.merge_pairs.clear();
        self.colocated_components = 0;
        self.total_components = 0;
        self.peak_cpu = 0.0;
        self.peak_mem = 0.0;
        self.wave_start = at;
        self.prev_wave_dur = 0.0;
        self.wave_dur = 0.0;
        self.crash_state = crash;
        self.anchor = None;
        self.estimate = Resources::ZERO;
        self.rack_id = RackId(0);
        graph.waves_into(&mut self.wave_offsets, &mut self.wave_comps);
        self.wave_idx = 0;
        self.pending.clear();
        self.attrib = Consumption::default();
        self.growth_count = 0;
        self.first_wave_warm = None;
        self.start_tier = None;
        self.start_latency_ms = 0.0;
        self.fault_at = None;
    }

    /// Simulated time at which the wave in flight completes.
    pub fn wave_done_at(&self) -> Millis {
        self.wave_start + self.wave_dur
    }

    /// Platform-assigned invocation id.
    pub fn inv_id(&self) -> u64 {
        self.inv_id
    }

    /// Runtime growth events so far (sizing-convergence telemetry).
    pub fn growths(&self) -> usize {
        self.growth_count
    }

    /// Whether the first environment hit the warm pool.
    pub fn first_wave_warm(&self) -> Option<bool> {
        self.first_wave_warm
    }

    /// Which start tier the first environment resolved to (None before
    /// wave 0 ran).
    pub fn start_tier(&self) -> Option<StartupTier> {
        self.start_tier
    }

    /// Start latency the resolved tier charged (0 before wave 0 ran).
    pub fn start_latency_ms(&self) -> f64 {
        self.start_latency_ms
    }

    /// Map a crashed `server` onto this invocation's execution state:
    /// a current-wave compute placed there crashes as
    /// [`Crash::Compute`]; else a data region homed there crashes as
    /// [`Crash::DataRegion`]; `None` when the invocation has no state
    /// on the server (regions elsewhere are treated as durable /
    /// disaggregated, per the faults module's modeling note).
    pub(crate) fn crash_for_server(&self, server: ServerId) -> Option<Crash> {
        if self.wave_idx < self.n_waves() {
            for k in 0..self.wave_len(self.wave_idx) {
                let c = self.wave_comp(self.wave_idx, k);
                if self.comp_server[c] == Some(server) {
                    return Some(Crash::Compute(c));
                }
            }
        }
        for (d, home) in self.data_home.iter().enumerate() {
            if *home == Some(server) {
                return Some(Crash::DataRegion(d));
            }
        }
        None
    }

    fn n_waves(&self) -> usize {
        self.wave_offsets.len().saturating_sub(1)
    }

    fn wave_len(&self, w: usize) -> usize {
        self.wave_offsets[w + 1] - self.wave_offsets[w]
    }

    fn wave_comp(&self, w: usize, k: usize) -> usize {
        self.wave_comps[self.wave_offsets[w] + k]
    }

    /// Integrate a live data component's footprint up to `now`.
    fn data_stamp(&mut self, d: usize, now: Millis) {
        if let Some((last, mb)) = self.data_track[d].as_mut() {
            let dt_s = (now - *last).max(0.0) / 1000.0;
            self.attrib.alloc_mem_mb_s += *mb * dt_s;
            // data regions are fully resident: used == allocated
            self.attrib.used_mem_mb_s += *mb * dt_s;
            *last = now;
        }
    }

    fn data_open(&mut self, d: usize, now: Millis, mb: f64) {
        self.data_track[d] = Some((now, mb));
    }

    fn data_grow(&mut self, d: usize, now: Millis, extra_mb: f64) {
        self.data_stamp(d, now);
        if let Some((_, mb)) = self.data_track[d].as_mut() {
            *mb += extra_mb;
        }
    }

    fn data_close(&mut self, d: usize, now: Millis) {
        self.data_stamp(d, now);
        self.data_track[d] = None;
    }
}

impl Platform {
    /// Fresh platform over a new cluster of the given shape.
    pub fn new(spec: ClusterSpec, config: ZenixConfig) -> Self {
        let cluster = Cluster::new(spec);
        let racks = cluster
            .racks()
            .map(|r| RackScheduler::new(&cluster, r))
            .collect();
        let mut global = GlobalScheduler::new(spec.racks);
        let tmp = &cluster;
        for r in tmp.racks() {
            global.update_rack(r, tmp.rack_available(r));
        }
        Self {
            cluster,
            config,
            history: ProfileStore::new(),
            startup: StartupModel::default(),
            net: NetModel::default(),
            control: ControlPlane::default(),
            global,
            racks,
            msglog: MessageLog::new(),
            now: 0.0,
            next_invocation: 0,
            warm_pool: std::collections::HashSet::new(),
            static_profile: HashMap::new(),
            sizing_cache: std::cell::RefCell::new(HashMap::new()),
            scratch: PlacementCtx::default(),
            shell_pool: Vec::new(),
            solver_scratch: std::cell::RefCell::new(Vec::new()),
            snapshots: None,
        }
    }

    /// Paper-testbed platform with default config.
    pub fn testbed() -> Self {
        Self::new(ClusterSpec::paper_testbed(), ZenixConfig::default())
    }

    /// Current simulated time (single-tenant clock).
    pub fn now(&self) -> Millis {
        self.now
    }

    /// Execute one invocation; returns the run report.
    pub fn invoke(&mut self, graph: &ResourceGraph, inv: Invocation) -> crate::Result<RunReport> {
        self.invoke_inner(graph, inv, None)
    }

    /// Execute one invocation dispatched at simulated time `at` (the
    /// re-entrant single-shot entry: the invocation starts no earlier
    /// than the platform's clock, so per-server consumption integrals
    /// stay monotonic). For genuinely *overlapping* invocations use
    /// [`super::driver::MultiTenantDriver`], which interleaves many
    /// [`OngoingInvocation`]s in global time order.
    pub fn invoke_at(
        &mut self,
        graph: &ResourceGraph,
        inv: Invocation,
        at: Millis,
    ) -> crate::Result<RunReport> {
        self.now = self.now.max(at);
        self.invoke_inner(graph, inv, None)
    }

    /// Execute with a crash injected before the given wave completes;
    /// recovery re-executes from the latest durable graph cut (§5.3.2).
    pub fn invoke_with_crash(
        &mut self,
        graph: &ResourceGraph,
        inv: Invocation,
        crash: Crash,
        at_wave: usize,
    ) -> crate::Result<RunReport> {
        self.invoke_inner(graph, inv, Some((crash, at_wave)))
    }

    fn invoke_inner(
        &mut self,
        graph: &ResourceGraph,
        inv: Invocation,
        crash: Option<(Crash, usize)>,
    ) -> crate::Result<RunReport> {
        // Cluster-wide baseline for the before/after consumption diff —
        // only the single-tenant path needs it (the driver reports
        // attributed integrals instead), so the O(servers) sweep stays
        // out of `begin_at`.
        let consumed_before = self.cluster.total_consumption(self.now);
        let mut st = self.begin_at(graph, inv, self.now, crash);
        st.consumed_before = consumed_before;
        loop {
            if let Err(e) = self.start_wave(graph, &mut st) {
                // already aborted/cleaned up; recycle the shell
                self.shell_pool.push(st);
                return Err(e);
            }
            // Single-tenant: apply this wave's deferred events in time
            // order right away. `total_cmp` + the push-sequence
            // tiebreak reproduce a stable sort's tie order (like the
            // driver's sequence-numbered heap) without the stable
            // sort's scratch allocation, and cannot panic on NaN.
            let mut evs = std::mem::take(&mut st.pending);
            evs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for (at, _seq, server, ev) in evs.drain(..) {
                self.apply_timeline(&mut st, server, ev, at);
            }
            st.pending = evs; // keep capacity
            if self.wave_done(graph, &mut st) {
                break;
            }
        }
        Ok(self.finish_invocation(graph, st, false))
    }

    // ---- re-entrant entry points (multi-tenant driver interface) --------

    /// Open an invocation at simulated time `at`: route to a rack, mark
    /// the whole-app anchor, and return the paused per-invocation state
    /// (wave 0 not yet started — call [`Self::start_wave`]). The state
    /// is a recycled pool shell; steady state allocates nothing.
    pub fn begin_at(
        &mut self,
        graph: &ResourceGraph,
        inv: Invocation,
        at: Millis,
        crash: Option<(Crash, usize)>,
    ) -> OngoingInvocation {
        self.begin_at_on(graph, inv, at, crash, None)
    }

    /// [`Self::begin_at`] with an optionally pinned destination rack.
    /// Workflow downstream stages route at stage-*ready* time (the
    /// affinity scorer picked the rack holding their resident inputs,
    /// or the blind router its smallest fit) and must not be re-routed
    /// at launch; every other caller passes `None` and takes the
    /// ordinary routing path below, byte-for-byte.
    pub fn begin_at_on(
        &mut self,
        graph: &ResourceGraph,
        inv: Invocation,
        at: Millis,
        crash: Option<(Crash, usize)>,
        pinned: Option<RackId>,
    ) -> OngoingInvocation {
        let scale = inv.input_scale;
        let program = &graph.program;
        let inv_id = self.next_invocation;
        self.next_invocation += 1;

        let mut st = self.shell_pool.pop().unwrap_or_else(OngoingInvocation::empty);
        st.reset(graph, scale, inv_id, at, crash);

        // ---- live arrival-rate state (pre-warm ranking input) -----------
        // Fold this admission into the decayed per-app rate scores
        // *before* the pre-warm pass so the ranking reflects the arrival
        // being admitted. No-op with the snapshot layer off.
        self.note_arrival(program.name, at);

        // ---- predictive pre-warm (tiered cold starts) -------------------
        // Refresh the per-rack snapshot caches at rack-dirty instants
        // (capacity moved since the last admission) so the routing below
        // sees post-pre-warm availability — the cache genuinely competes
        // with this invocation for rack memory. No-op with the snapshot
        // layer off.
        self.prewarm_pass(at);

        // ---- global scheduling: route to a rack -------------------------
        // Rack availability reaches the global scheduler as incremental
        // deltas: the cluster hooks record which racks changed and this
        // drain refreshes exactly those — O(changed racks), not
        // O(racks), per admission.
        let estimate = program.peak_estimate(scale);
        let global = &mut self.global;
        self.cluster
            .for_each_dirty_rack(|r, avail| global.update_rack(r, avail));
        let rack_id = match pinned {
            Some(r) => r,
            None => self.global.route(estimate),
        };
        st.breakdown.sched_ms += 2.0 * self.control.sched_msg_ms; // request + dispatch
        let rack = &self.racks[rack_id.0];

        // ---- whole-app anchor (smallest fit) + low-priority mark --------
        let anchor = if self.config.adaptive {
            rack.whole_app_fit(&self.cluster, estimate)
        } else {
            None
        };
        if let Some(a) = anchor {
            self.cluster.mark(a, estimate);
        }

        if self.config.adaptive {
            graph.merge_candidates_into(scale, 1.6, &mut st.merge_pairs);
        }
        st.anchor = anchor;
        st.estimate = estimate;
        st.rack_id = rack_id;
        st
    }

    // ---- workflow stage routing & handoff retention ---------------------

    /// Route a workflow stage at its ready instant: drain the
    /// incremental rack-availability deltas (same freshness contract as
    /// admission routing), then take the affinity path when a preferred
    /// (data-resident) rack is given, or the ordinary smallest-fit when
    /// not. Returns the chosen rack and whether the preference held.
    pub fn route_stage(&mut self, estimate: Resources, prefer: Option<RackId>) -> (RackId, bool) {
        let global = &mut self.global;
        self.cluster
            .for_each_dirty_rack(|r, avail| global.update_rack(r, avail));
        match prefer {
            Some(p) => self.global.route_with_affinity(estimate, p),
            None => (self.global.route(estimate), false),
        }
    }

    /// Retain a workflow handoff region on the producer's rack: charge
    /// `mb` of memory on the rack's most-available server until the
    /// consumer stage launches, so resident intermediates genuinely
    /// compete with invocations for rack capacity. `None` when no
    /// server can hold the region — it spills to the disaggregated
    /// store and the consumer prices it as a cross-rack transfer.
    pub fn retain_handoff(&mut self, rack: RackId, mb: f64, now: Millis) -> Option<ServerId> {
        let server = best_mem_server(&self.cluster, rack, mb)?;
        if self.cluster.try_alloc(server, Resources::mem_only(mb), now) {
            Some(server)
        } else {
            None
        }
    }

    /// Release a retained handoff region (the consumer launched, or its
    /// run retired without consuming it).
    pub fn release_handoff(&mut self, server: ServerId, mb: f64, now: Millis) {
        self.cluster.free(server, Resources::mem_only(mb), now);
    }

    /// Execute the scheduling/placement of the next wave at
    /// `st.wave_start`: size and place every component, launch/grow its
    /// data, commit the immediate allocations, and emit the deferred
    /// mid-wave/end-of-wave timeline into `st.pending`. On error the
    /// invocation is fully aborted (no resource leak) before returning.
    pub fn start_wave(
        &mut self,
        graph: &ResourceGraph,
        st: &mut OngoingInvocation,
    ) -> crate::Result<()> {
        let scale = st.scale;
        let program = &graph.program;
        let rack_id = st.rack_id;
        let anchor = st.anchor;
        let wave_start = st.wave_start;
        let mut wave_dur = 0.0f64;
        let mut wave_cpu = 0.0f64;
        let mut wave_mem = 0.0f64;
        let mut ctx = std::mem::take(&mut self.scratch);

        let n_comps = st.wave_len(st.wave_idx);
        for k in 0..n_comps {
            let c = st.wave_comp(st.wave_idx, k);
            let spec = &program.computes[c];
            st.total_components += 1;

            // -- sizing ---------------------------------------------
            let workers = spec
                .parallelism_at(scale)
                // cast: safe(app_limit.cpu is a small positive vCPU count)
                .min(program.app_limit.cpu.max(1.0) as usize)
                .max(1);
            let need_mb_worker = spec.mem_at(scale);
            let need_mb = need_mb_worker * workers as f64;
            let (init_mb, step_mb) = self.sizing(program.name, c, need_mb);
            let vcpus = self.cpu_sizing(program.name, c, workers);
            // first observation becomes the static profile estimate
            self.static_profile
                .entry((program.name, c))
                .or_insert(need_mb);

            // -- placement ------------------------------------------
            ctx.data_servers.clear();
            ctx.data_servers
                .extend(spec.accesses.iter().filter_map(|&d| st.data_home[d]));
            let demand = Resources::new(vcpus as f64, init_mb);
            let (server, colocated, granted) =
                self.place(rack_id, anchor, demand, &ctx.data_servers, wave_start);
            st.comp_server[c] = Some(server);
            // run on what was actually granted (degraded when the
            // cluster is saturated)
            let vcpus_granted = granted.cpu.max(0.25);
            let init_mb = granted.mem_mb;

            // -- data components launched by first accessor ----------
            let mut remote_frac = 0.0f64;
            let mut n_accessed = 0usize;
            for &d in &spec.accesses {
                let dspec = &program.data[d];
                let dsize = dspec.size_at(scale);
                if st.mem.get(cast::u64_of(d)).is_none() {
                    let prefer = if self.config.force_remote_data {
                        // disaggregation mode: data lives away from compute
                        self.other_server(rack_id, server)
                    } else {
                        server
                    };
                    let target = self.pick_data_server(rack_id, prefer, dsize);
                    let mut launched = dsize;
                    if st
                        .mem
                        .launch(&mut self.cluster, cast::u64_of(d), target, dsize, wave_start)
                        .is_err()
                    {
                        // overloaded cluster: take what fits and leave
                        // the rest to swap space (§5.1.2)
                        let avail =
                            (self.cluster.server(target).available().mem_mb * 0.9).max(1.0);
                        launched = avail.min(dsize);
                        if let Err(e) = st.mem.launch(
                            &mut self.cluster,
                            cast::u64_of(d),
                            target,
                            launched,
                            wave_start,
                        ) {
                            // current component's placement has no
                            // Finish event yet: release it directly
                            self.cluster.free(server, granted, wave_start);
                            self.abort_invocation(ctx, st, wave_start);
                            return Err(e);
                        }
                    }
                    st.data_open(d, wave_start, launched);
                    st.data_home[d] = Some(target);
                } else {
                    // growth if this invocation needs more
                    let cur = st.mem.get(cast::u64_of(d)).unwrap().total_mb();
                    if dsize > cur {
                        ctx.accessors.clear();
                        ctx.accessors.extend(
                            graph
                                .accessors_of_iter(d)
                                .filter_map(|a| st.comp_server[a]),
                        );
                        let grow_to = super::placement::place_growth(
                            &self.cluster,
                            Resources::mem_only(dsize - cur),
                            st.data_home[d].expect("live data has a home server"),
                            &ctx.accessors,
                        );
                        if let Some(s) = grow_to {
                            if st
                                .mem
                                .grow(&mut self.cluster, cast::u64_of(d), dsize - cur, &[s], wave_start)
                                .is_ok()
                            {
                                st.data_grow(d, wave_start, dsize - cur);
                            }
                        }
                    }
                }
                if let Err(e) = st.mem.attach(cast::u64_of(d), cast::u64_of(c)) {
                    // current component's placement has no Finish
                    // event yet: release it directly
                    self.cluster.free(server, granted, wave_start);
                    self.abort_invocation(ctx, st, wave_start);
                    return Err(e);
                }
                if let Some(state) = st.mem.get(cast::u64_of(d)) {
                    remote_frac += state.remote_fraction(server);
                    n_accessed += 1;
                }
            }
            if n_accessed > 0 {
                remote_frac /= n_accessed as f64;
            }
            if self.config.force_remote_data {
                remote_frac = 1.0;
            }

            // -- startup --------------------------------------------
            let merged = st.merge_pairs.iter().any(|&(_, b)| b == c)
                && anchor.map_or(false, |a| a == server);
            let app_warm = self.warm_pool.contains(program.name);
            if st.wave_idx == 0 && st.first_wave_warm.is_none() {
                st.first_wave_warm = Some(self.config.proactive && app_warm);
            }
            let startup_ms = if st.wave_idx == 0 && self.snapshots.is_some() {
                // Tiered start (snapshot layer on): the tier is resolved
                // once per invocation; sibling wave-0 components and
                // rewound wave-0 re-runs after a crash reuse its latency.
                if st.start_tier.is_none() {
                    let (tier, ms) =
                        self.resolve_start_tier(program.name, rack_id, app_warm, wave_start);
                    st.start_tier = Some(tier);
                    st.start_latency_ms = ms;
                }
                st.start_latency_ms
            } else {
                let ms = self.startup_cost(
                    st.wave_idx,
                    merged,
                    colocated && self.config.adaptive,
                    st.prev_wave_dur,
                    app_warm,
                );
                if st.wave_idx == 0 && st.start_tier.is_none() {
                    // Flat-model bookkeeping (snapshot layer off): record
                    // the warm/cold split and wave-0 cost as the tier so
                    // the telemetry and its conservation identity hold in
                    // every configuration. Digest-excluded state only.
                    st.start_tier = Some(if self.config.proactive && app_warm {
                        StartupTier::WarmHit
                    } else {
                        StartupTier::ColdBoot
                    });
                    st.start_latency_ms = ms;
                }
                ms
            };
            st.breakdown.startup_ms += startup_ms;

            // -- connection setup for remote data --------------------
            let mut conn_ms = 0.0;
            let kind = self.config.net_kind();
            let path = self.config.control_path();
            ctx.conn_seen.clear();
            for &d in &spec.accesses {
                for s in st.mem.region_server_iter(cast::u64_of(d)) {
                    if s != server {
                        let reuse = ctx.conn_seen.contains(&s);
                        conn_ms += self.control.conn_setup(path, kind, reuse);
                        ctx.conn_seen.push(s);
                    }
                }
            }
            st.breakdown.sched_ms += conn_ms;

            // -- compute duration ------------------------------------
            // Historical-utilization CPU trimming (§5.1.2: 50% util
            // on 10 vCPUs → 5 vCPUs next time) removes *idle* CPU:
            // effective throughput is the smaller of the allocation
            // and the workers' useful parallelism.
            let work = spec.work_at(scale);
            let eff = self.config.cpu_efficiency.max(0.05);
            let throughput = vcpus_granted.min(workers as f64 * eff).max(0.05);
            let compute_ms = work / throughput;
            let slowdown = self
                .net
                .remote_slowdown(kind, remote_frac * spec.access_intensity);
            let mut stage_ms = compute_ms * slowdown;
            st.breakdown.compute_ms += compute_ms;
            st.breakdown.io_ms += compute_ms * (slowdown - 1.0);

            // -- memory autoscaling ----------------------------------
            let mut alloc_now = init_mb;
            if need_mb > init_mb {
                let growths = adjust::growths(init_mb, step_mb, need_mb);
                // cast: safe(growths is a small non-negative whole f64 count)
                st.growth_count += growths as usize;
                // each growth: scheduler round-trip + brief stall
                let growth_overhead = growths * (2.0 * self.control.sched_msg_ms + 2.0);
                stage_ms += growth_overhead;
                st.breakdown.sched_ms += growth_overhead;
                // growth lands local if it fits, else swap-remote
                let extra = need_mb - init_mb;
                let fits_local = self
                    .cluster
                    .server(server)
                    .available()
                    .fits(Resources::mem_only(extra));
                if !fits_local {
                    // remote swap space for the overflow (§5.1.2)
                    let swap_pen = self
                        .net
                        .remote_slowdown(kind, (extra / need_mb).min(1.0))
                        - 1.0;
                    stage_ms += compute_ms * swap_pen * 0.5;
                    st.breakdown.io_ms += compute_ms * swap_pen * 0.5;
                }
                alloc_now = need_mb.min(alloc_now + growths * step_mb);
            }

            // -- commit allocation timeline --------------------------
            // Allocations happened at wave_start (placement); the
            // growth and free events are deferred and applied in
            // time order after the wave's scheduling pass —
            // same-server events from concurrently-running components
            // must reach the integrator monotonically or consumption
            // double-counts.
            let end = wave_start + startup_ms + stage_ms;
            wave_dur = wave_dur.max(startup_ms + stage_ms);
            let used_cpu = throughput.min(vcpus_granted);
            let base_used = Resources::new(used_cpu, init_mb.min(need_mb));
            self.cluster.add_used(server, base_used, wave_start);
            let mid = wave_start + (startup_ms + stage_ms) / 2.0;
            if alloc_now > init_mb {
                let seq = cast::u32_of(st.pending.len());
                st.pending.push((
                    mid,
                    seq,
                    server,
                    TimelineEv::Grow {
                        comp: c,
                        extra_mb: alloc_now - init_mb,
                        used_mb: (need_mb - init_mb).max(0.0),
                    },
                ));
            }
            // `used` carries exactly the base share added above —
            // `Finish` subtracts it plus whatever the (possibly
            // failed) `Grow` actually added, never more.
            let seq = cast::u32_of(st.pending.len());
            st.pending.push((
                end,
                seq,
                server,
                TimelineEv::Finish {
                    comp: c,
                    started: wave_start,
                    base_alloc: granted,
                    used: base_used,
                },
            ));

            wave_cpu += vcpus_granted;
            wave_mem += alloc_now.max(init_mb)
                + graph
                    .accessed_data_iter(c)
                    .map(|d| program.data[d].size_at(scale))
                    .sum::<f64>();
            if colocated
                || ctx.data_servers.is_empty()
                || ctx.data_servers.contains(&server)
            {
                st.colocated_components += 1;
            }

            // -- reliable result message -----------------------------
            self.msglog.append(LogEntry {
                invocation: st.inv_id,
                compute: c,
                result_mb: need_mb_worker * 0.1,
            });
            self.msglog.flush();

            // -- record history --------------------------------------
            self.history.record(program.name, c, Metric::MemMb, need_mb);
            self.history.record(program.name, c, Metric::Cpu, workers as f64);
            self.history
                .record(program.name, c, Metric::CpuUtil, eff);
            self.history
                .record(program.name, c, Metric::LifetimeMs, stage_ms);
        }

        st.wave_dur = wave_dur;
        st.peak_cpu = st.peak_cpu.max(wave_cpu);
        st.peak_mem = st.peak_mem.max(wave_mem);
        self.scratch = ctx;
        Ok(())
    }

    /// Apply one deferred timeline event at its own time. The caller
    /// (single-tenant loop or multi-tenant driver) guarantees events
    /// reach this in global time order.
    pub fn apply_timeline(
        &mut self,
        st: &mut OngoingInvocation,
        server: ServerId,
        ev: TimelineEv,
        at: Millis,
    ) {
        apply_timeline_on(&mut self.cluster, st, server, ev, at);
    }

    /// Complete the wave in flight (all its timeline events applied):
    /// release end-of-life data components, run crash recovery if one
    /// was injected at this wave, and advance to the next wave.
    /// Returns `true` when the invocation has no waves left — call
    /// [`Self::finish_invocation`] next.
    pub fn wave_done(&mut self, graph: &ResourceGraph, st: &mut OngoingInvocation) -> bool {
        let now = st.wave_start + st.wave_dur;
        // -- data lifetime: release components whose last accessor ran
        for d in 0..graph.n_data() {
            if let Some((_, last)) = graph.data_lifetime(d) {
                if last == st.wave_idx && st.mem.get(cast::u64_of(d)).is_some() {
                    st.data_close(d, now);
                    let _ = st.mem.release(&mut self.cluster, cast::u64_of(d), now);
                    st.data_home[d] = None;
                }
            }
        }
        st.prev_wave_dur = st.wave_dur;
        st.wave_start = now;

        // -- crash injection + recovery ------------------------------
        if let Some((cr, at)) = st.crash_state {
            if st.wave_idx == at {
                st.crash_state = None;
                let plan = failure::plan(graph, &self.msglog, st.inv_id, cr);
                // discard data components named by the plan
                for &d in &plan.discard_data {
                    if st.mem.get(cast::u64_of(d)).is_some() {
                        st.data_close(d, now);
                        let _ = st.mem.release(&mut self.cluster, cast::u64_of(d), now);
                        st.data_home[d] = None;
                    }
                }
                // re-execution: rewind to the earliest dirty wave; the
                // per-component loop will recreate data/allocations.
                if let Some(&first) = plan.reexecute.first() {
                    let redo_wave = graph.wave[first];
                    st.breakdown.sched_ms += 5.0; // recovery decision
                    st.wave_idx = redo_wave;
                    // A rewind to wave 0 restarts the invocation's first
                    // environment — a fresh start like any other, so it
                    // must re-resolve its tier instead of replaying the
                    // pre-crash latency: the original cold boot
                    // demand-installed the app's image, so the post-repair
                    // start restores from the rack's snapshot cache.
                    // Gated on the image actually being resident: with a
                    // zero cache budget (or the layer off) nothing is ever
                    // resident and the replay stays byte-identical.
                    if redo_wave == 0 {
                        if let Some(sn) = &self.snapshots {
                            if sn.caches[st.rack_id.0].contains(graph.program.name) {
                                st.start_tier = None;
                                st.start_latency_ms = 0.0;
                            }
                        }
                    }
                    return false;
                }
            }
        }
        st.wave_idx += 1;
        st.wave_idx >= st.n_waves()
    }

    /// Shared completion epilogue: release surviving data, drop the
    /// anchor mark, admit the app to the warm pool, retire the
    /// invocation's message-log entries (its recovery window is over —
    /// keeps the log O(in-flight), not O(run)), and advance the clock.
    /// Returns the invocation's end time.
    fn close_invocation(&mut self, graph: &ResourceGraph, st: &mut OngoingInvocation) -> Millis {
        let wave_end = st.wave_start;
        // release any data still live (defensive; lifetimes should cover)
        for d in 0..graph.n_data() {
            if st.mem.get(cast::u64_of(d)).is_some() {
                st.data_close(d, wave_end);
                let _ = st.mem.release(&mut self.cluster, cast::u64_of(d), wave_end);
            }
        }
        if let Some(a) = st.anchor {
            self.cluster.unmark(a, st.estimate);
        }
        self.warm_pool.insert(graph.program.name);
        self.msglog.retire(st.inv_id);
        self.now = self.now.max(wave_end + 1.0);
        wave_end
    }

    /// Close a completed invocation: release surviving data, drop the
    /// anchor mark, admit the app to the warm pool, and build the run
    /// report. With `attributed` the consumption is the invocation's
    /// own integral ([`OngoingInvocation::attrib`]); otherwise it is
    /// the cluster-wide before/after diff (exact when single-tenant).
    /// The shell is recycled into the platform's pool.
    pub fn finish_invocation(
        &mut self,
        graph: &ResourceGraph,
        mut st: OngoingInvocation,
        attributed: bool,
    ) -> RunReport {
        let wave_end = self.close_invocation(graph, &mut st);
        let consumption = if attributed {
            st.attrib
        } else {
            let consumed_after = self.cluster.total_consumption(self.now);
            sub_consumption(consumed_after, st.consumed_before)
        };

        let report = RunReport {
            system: "zenix".into(),
            workload: graph.program.name.into(),
            exec_ms: wave_end - st.t0,
            breakdown: st.breakdown,
            consumption,
            local_fraction: if st.total_components == 0 {
                1.0
            } else {
                st.colocated_components as f64 / st.total_components as f64
            },
            peak_cpu: st.peak_cpu,
            peak_mem_mb: st.peak_mem,
        };
        self.shell_pool.push(st);
        report
    }

    /// Allocation-free completion for the multi-tenant driver: same
    /// cleanup as [`Self::finish_invocation`] but returns only
    /// (exec ms, attributed consumption) — no report labels, no heap
    /// traffic. The shell is recycled into the platform's pool.
    pub fn finish_invocation_attrib(
        &mut self,
        graph: &ResourceGraph,
        mut st: OngoingInvocation,
    ) -> (Millis, Consumption) {
        let wave_end = self.close_invocation(graph, &mut st);
        let out = (wave_end - st.t0, st.attrib);
        self.shell_pool.push(st);
        out
    }

    /// Return an abandoned invocation shell (e.g. after a failed
    /// admission) to the pool so its capacity is reused.
    pub fn recycle_shell(&mut self, st: OngoingInvocation) {
        self.shell_pool.push(st);
    }

    // ---- helpers --------------------------------------------------------

    /// Best-effort error-path cleanup so a failed invocation cannot
    /// leak placement state: apply the pending completion events of
    /// the current wave (releasing committed compute allocations and
    /// exactly the used shares that were added), unwind any landed
    /// growths, release every live data component, drop the anchor's
    /// low-priority mark, and restore the scratch buffers.
    fn abort_invocation(&mut self, ctx: PlacementCtx, st: &mut OngoingInvocation, now: Millis) {
        for (_, _, server, ev) in st.pending.drain(..) {
            // Grow events were never applied to the cluster; only the
            // base allocations behind Finish events are live.
            if let TimelineEv::Finish { base_alloc, used, .. } = ev {
                self.cluster.sub_used(server, used, now);
                self.cluster.free(server, base_alloc, now);
            }
        }
        // Landed growths from earlier waves whose Finish never ran
        // (defensive: normally empty by the time a new wave starts).
        // Dense table: index order == the old sorted order.
        for comp in 0..st.grown.len() {
            if let Some((extra, grown_used, _)) = st.grown[comp].take() {
                if let Some(server) = st.comp_server[comp] {
                    self.cluster.sub_used(server, Resources::mem_only(grown_used), now);
                    self.cluster.free(server, Resources::mem_only(extra), now);
                }
            }
        }
        // Release live data in index order (deterministic float
        // accumulation).
        for d in 0..st.data_track.len() {
            if st.data_track[d].is_some() {
                st.data_close(d, now);
                let _ = st.mem.release(&mut self.cluster, cast::u64_of(d), now);
            }
        }
        st.mem.release_all(&mut self.cluster, now); // backstop: empty by now
        if let Some(a) = st.anchor {
            self.cluster.unmark(a, st.estimate);
        }
        self.msglog.retire(st.inv_id);
        self.scratch = ctx;
    }

    /// Initial + incremental sizing for one compute component. The app
    /// name is the program's interned `&'static str`, so the re-tune
    /// cache lookup is allocation-free on hits (the PR-2 satellite fix;
    /// see `benches/hotpath.rs platform_invoke_lr_warm_sizing_hit`).
    fn sizing(&self, app: &'static str, node: usize, need_mb: f64) -> (f64, f64) {
        if self.config.peak_provision {
            let peak = self
                .history
                .profile(app, node, Metric::MemMb)
                .and_then(|p| p.max())
                .unwrap_or(need_mb);
            return (peak.max(need_mb), self.config.fixed_step_mb);
        }
        if self.config.history_sizing {
            if let Some(p) = self.history.profile(app, node, Metric::MemMb) {
                if p.len() >= 3 {
                    // periodic re-tune (§5.2.3): solve once, reuse for K
                    // executions — the solver is off the per-invocation
                    // hot path (EXPERIMENTS.md §Perf). Counted against
                    // the *cumulative* observation count: the retention
                    // window saturates at its cap, which would stop
                    // re-tuning forever on long-running apps.
                    let recorded = cast::usize_of(p.total_recorded());
                    let key = (app, node);
                    let mut cache = self.sizing_cache.borrow_mut();
                    if let Some(&(init, step, at)) = cache.get(&key) {
                        if recorded < at + RETUNE_EVERY {
                            return (init, step);
                        }
                    }
                    // pooled scratch: the re-tune itself allocates
                    // nothing in steady state
                    let mut vals = self.solver_scratch.borrow_mut();
                    p.values_into(&mut *vals);
                    let s = adjust::solve(&vals[..], None, AdjustParams::default());
                    cache.insert(key, (s.init_mb, s.step_mb, recorded));
                    return (s.init_mb, s.step_mb);
                }
            }
            // First invocations: the offline sampling profile gives the
            // static resource-graph estimate (§4.2) — start at the
            // graph's own estimate.
            return (need_mb, self.config.fixed_step_mb);
        }
        // Non-history configurations: the static profile estimate, fixed
        // across invocations (grown at runtime when exceeded).
        let static_init = self
            .static_profile
            .get(&(app, node))
            .copied()
            .unwrap_or(need_mb);
        (static_init, self.config.fixed_step_mb)
    }

    /// CPU sizing: workers shaped by historical utilization (§5.1.2:
    /// 50% util on 10 vCPUs → 5 vCPUs next time).
    fn cpu_sizing(&self, app: &str, node: usize, workers: usize) -> usize {
        if !self.config.history_sizing {
            return workers;
        }
        let util = self
            .history
            .profile(app, node, Metric::CpuUtil)
            .and_then(|p| p.mean())
            .unwrap_or(1.0);
        // cast: safe(ceil of workers * util in [0,1], bounded by workers)
        ((workers as f64 * util).ceil() as usize).max(1)
    }

    /// Place a component; returns (server, colocated, granted). The
    /// granted resources are what was *actually* allocated — under
    /// cluster pressure the demand is halved until it fits (resource-cap
    /// behaviour), and the component runs degraded on the grant.
    fn place(
        &mut self,
        rack: crate::cluster::RackId,
        anchor: Option<ServerId>,
        demand: Resources,
        data_servers: &[ServerId],
        now: Millis,
    ) -> (ServerId, bool, Resources) {
        // anchor continuation: same container, resized (§5.1.1)
        if let Some(a) = anchor {
            if self.config.adaptive && self.cluster.server(a).available().fits(demand) {
                let ok = self.cluster.try_alloc(a, demand, now);
                debug_assert!(ok);
                return (a, true, demand);
            }
        }
        match self.racks[rack.0].allocate(&mut self.cluster, demand, data_servers, now) {
            Allocation::Placed { server, colocated } => (server, colocated, demand),
            Allocation::Spill => {
                // §5.3.1: bounce to global for another rack; single-rack
                // clusters degrade to the least-loaded server with a
                // halved demand (resource cap behaviour).
                let mut d = demand;
                loop {
                    d = Resources::new((d.cpu / 2.0).max(1.0), d.mem_mb / 2.0);
                    if let Some(id) = super::placement::smallest_fit(&self.cluster, d) {
                        let ok = self.cluster.try_alloc(id, d, now);
                        debug_assert!(ok);
                        return (id, false, d);
                    }
                    if d.cpu <= 1.0 && d.mem_mb < 64.0 {
                        // take the emptiest server and grab what fits
                        // (cold overload path: linear max is fine here)
                        let id = self
                            .cluster
                            .servers()
                            .iter()
                            .max_by(|a, b| {
                                a.available()
                                    .magnitude()
                                    .total_cmp(&b.available().magnitude())
                            })
                            .map(|s| s.id)
                            .unwrap();
                        let avail = self.cluster.server(id).available();
                        let grant = Resources::new(
                            avail.cpu.min(d.cpu).max(0.0),
                            (avail.mem_mb * 0.5).min(d.mem_mb).max(0.0),
                        );
                        let ok = self.cluster.try_alloc(id, grant, now);
                        debug_assert!(ok);
                        return (id, false, grant);
                    }
                }
            }
        }
    }

    /// Pick the server for a new data component: the accessor's server
    /// when it fits (co-location, §5.1.1), else smallest fit in-rack,
    /// else anywhere, else the emptiest server (overload).
    fn pick_data_server(
        &self,
        rack: crate::cluster::RackId,
        prefer: ServerId,
        mb: f64,
    ) -> ServerId {
        let mem_demand = Resources::mem_only(mb);
        if !self.config.force_remote_data
            && self.cluster.server(prefer).available().fits(mem_demand)
        {
            return prefer;
        }
        // In-rack pass: indexed when unrestricted; a (non-allocating)
        // filtered linear pass when disaggregation excludes `prefer`.
        let in_rack = if self.config.force_remote_data {
            super::placement::smallest_fit_among(
                &self.cluster,
                mem_demand,
                self.racks[rack.0].servers().iter().copied().filter(|&s| s != prefer),
            )
        } else {
            super::placement::smallest_fit_in_rack(&self.cluster, rack, mem_demand)
        };
        in_rack
            .or_else(|| super::placement::smallest_fit(&self.cluster, mem_demand))
            .unwrap_or_else(|| {
                self.cluster
                    .servers()
                    .iter()
                    .max_by(|a, b| a.available().mem_mb.total_cmp(&b.available().mem_mb))
                    .map(|s| s.id)
                    .unwrap_or(prefer)
            })
    }

    fn other_server(&self, rack: crate::cluster::RackId, not: ServerId) -> ServerId {
        self.racks[rack.0]
            .servers()
            .iter()
            .copied()
            .find(|&s| s != not)
            .unwrap_or(not)
    }

    fn startup_cost(
        &self,
        wave_idx: usize,
        merged: bool,
        continued: bool,
        prev_wave_ms: Millis,
        app_warm: bool,
    ) -> Millis {
        use crate::cluster::startup::StartupPath;
        if wave_idx == 0 {
            // First environment of the invocation: warm-pool hit for
            // frequently-invoked apps, else pre-warmed/cold container.
            return if self.config.proactive && app_warm {
                self.startup.warm(StartupPath::Zenix)
            } else if self.config.proactive {
                self.startup.cold(StartupPath::ZenixPrewarmed)
            } else {
                self.startup.cold(StartupPath::Zenix)
            };
        }
        if merged || continued {
            // same container, resized: negligible (cgroup update)
            1.0
        } else if self.config.proactive {
            // pre-launched during the previous wave (§5.2.1)
            (self.startup.cold(StartupPath::Zenix) - prev_wave_ms).max(0.0)
        } else {
            self.startup.cold(StartupPath::Zenix)
        }
    }

    // ---- tiered cold starts (snapshot/restore layer) --------------------

    /// Turn the tiered cold-start model on: one byte-budgeted snapshot
    /// cache per rack, and (optionally) the predictive pre-warm pass.
    /// `images` lists every app's snapshot image size in descending
    /// expected-arrival order; pre-warm considers only the first
    /// `top_k` per rack. With the layer off (the default) the platform
    /// runs the flat cold/warm model byte-for-byte.
    pub fn enable_snapshots(
        &mut self,
        budget_bytes: u64,
        prewarm: bool,
        images: Vec<(&'static str, u64)>,
        top_k: usize,
    ) {
        let caches = self
            .cluster
            .racks()
            .map(|_| SnapshotCache::new(budget_bytes))
            .collect();
        let rate = vec![(0.0, 0.0); images.len()];
        self.snapshots = Some(SnapshotLayer {
            caches,
            prewarm,
            primed: false,
            images,
            top_k,
            rate,
            order: Vec::new(),
        });
    }

    /// Fold one admitted arrival of `app` into the live arrival-rate
    /// scores the pre-warm pass ranks by (exponentially-decayed count,
    /// the platform-side mirror of the admission layer's rate state).
    /// Runs coordinator-side at admission instants in both event loops,
    /// so the ranking — and therefore the digest — stays worker-count
    /// invariant. No-op with the snapshot layer or pre-warm off.
    fn note_arrival(&mut self, app: &'static str, now: Millis) {
        let Some(sn) = self.snapshots.as_mut() else { return };
        if !sn.prewarm {
            return;
        }
        for (i, &(name, _)) in sn.images.iter().enumerate() {
            if name == app {
                let (score, last) = sn.rate[i];
                let decay = (-((now - last).max(0.0)) / PREWARM_RATE_HALF_LIFE_MS).exp2();
                sn.rate[i] = (score * decay + 1.0, now);
                return;
            }
        }
    }

    /// Predictive pre-warm: install the top-k images by *live* decayed
    /// arrival rate (static expected-arrival order breaks ties, and is
    /// the ranking until the first arrivals land) into each rack's
    /// spare snapshot budget. Runs on the first admission and then at
    /// rack-dirty instants (capacity moved since the last pass); never
    /// evicts — demand installs own the contended end of the budget.
    /// Allocation-free after warm-up.
    fn prewarm_pass(&mut self, now: Millis) {
        let Some(sn) = self.snapshots.as_mut() else { return };
        if !sn.prewarm || (sn.primed && !self.cluster.has_dirty_racks()) {
            return;
        }
        sn.primed = true;
        let k = sn.top_k.min(sn.images.len());
        // Live re-rank: decayed score descending, static order (index
        // ascending) as the tie-break — an all-zero score table keeps
        // the static ranking byte-for-byte.
        let mut order = std::mem::take(&mut sn.order);
        order.clear();
        order.extend((0..sn.images.len()).map(cast::u32_of));
        let decayed = |i: usize| {
            let (score, last) = sn.rate[i];
            score * (-((now - last).max(0.0)) / PREWARM_RATE_HALF_LIFE_MS).exp2()
        };
        order.sort_unstable_by(|&a, &b| {
            let (ua, ub) = (cast::usize_of(u64::from(a)), cast::usize_of(u64::from(b)));
            decayed(ub).total_cmp(&decayed(ua)).then(a.cmp(&b))
        });
        for r in 0..sn.caches.len() {
            for &oi in &order[..k] {
                let (app, bytes) = sn.images[cast::usize_of(u64::from(oi))];
                let cache = &mut sn.caches[r];
                if cache.contains(app) || !cache.fits(bytes) {
                    continue; // already resident, or would need an eviction
                }
                let mb = image_mb(bytes);
                let Some(server) = best_mem_server(&self.cluster, RackId(r), mb) else {
                    continue; // rack memory is contended: invocations win
                };
                if self.cluster.try_alloc(server, Resources::mem_only(mb), now) {
                    let installed = cache.insert(app, bytes, server);
                    debug_assert!(installed, "fit and absence were pre-checked");
                    if installed {
                        cache.stats.prewarms += 1;
                    } else {
                        self.cluster.free(server, Resources::mem_only(mb), now);
                    }
                }
            }
        }
        sn.order = order; // keep the scratch capacity
    }

    /// Resolve the start tier of an invocation's first environment
    /// against the routed rack's snapshot cache (requires the snapshot
    /// layer). A warm-pool hit wins outright (a live environment beats
    /// any restore — the cache is not consulted); a resident image
    /// restores at size-scaled cost; a miss pays the flat cold path and
    /// demand-installs the image — evicting least-recently-used images
    /// as needed — so repeat misses turn into restores.
    fn resolve_start_tier(
        &mut self,
        app: &'static str,
        rack: RackId,
        app_warm: bool,
        now: Millis,
    ) -> (StartupTier, Millis) {
        use crate::cluster::startup::StartupPath;
        if self.config.proactive && app_warm {
            return (StartupTier::WarmHit, self.startup.warm(StartupPath::Zenix));
        }
        let sn = self
            .snapshots
            .as_mut()
            .expect("tier resolution runs only with the snapshot layer on");
        let bytes = sn.image_bytes(app);
        let cache = &mut sn.caches[rack.0];
        if cache.touch(app) {
            return (StartupTier::SnapshotRestore, self.startup.restore(bytes));
        }
        let cold = if self.config.proactive {
            self.startup.cold(StartupPath::ZenixPrewarmed)
        } else {
            self.startup.cold(StartupPath::Zenix)
        };
        if bytes <= cache.budget() {
            while !cache.fits(bytes) {
                match cache.evict_lru() {
                    Some((_, b, home)) => {
                        self.cluster.free(home, Resources::mem_only(image_mb(b)), now);
                    }
                    None => break,
                }
            }
            let mb = image_mb(bytes);
            if let Some(server) = best_mem_server(&self.cluster, rack, mb) {
                if self.cluster.try_alloc(server, Resources::mem_only(mb), now) {
                    let installed = cache.insert(app, bytes, server);
                    debug_assert!(installed, "budget was made available above");
                    if !installed {
                        self.cluster.free(server, Resources::mem_only(mb), now);
                    }
                }
            }
        }
        (StartupTier::ColdBoot, cold)
    }

    /// Wipe cached images homed on a crashed server, releasing their
    /// memory charges (the crash destroyed them; [`Cluster::free`]
    /// works on downed servers, mirroring how invocation allocations
    /// unwind after a crash). Both event loops call this at the same
    /// fault instants, coordinator-side, so tiered replays stay
    /// digest-identical at every worker count.
    pub fn evict_snapshots_on(&mut self, server: ServerId, now: Millis) {
        let Some(sn) = self.snapshots.as_mut() else { return };
        let cluster = &mut self.cluster;
        let rack = cluster.server(server).rack;
        sn.caches[rack.0].evict_homed_on(server, |_, bytes| {
            cluster.free(server, Resources::mem_only(image_mb(bytes)), now);
        });
    }

    /// Tear the snapshot caches down at `now`, releasing every image's
    /// memory charge. The drivers call this after their event loops
    /// drain, before the end-of-run leak asserts and the fleet
    /// consumption readout.
    pub fn drain_snapshot_caches(&mut self, now: Millis) {
        let Some(sn) = self.snapshots.as_mut() else { return };
        let cluster = &mut self.cluster;
        for cache in &mut sn.caches {
            cache.drain(|_, bytes, home| {
                cluster.free(home, Resources::mem_only(image_mb(bytes)), now);
            });
        }
    }

    /// Aggregate snapshot-cache telemetry across racks (counters sum;
    /// the bytes high-water mark is the per-rack maximum, comparable to
    /// the per-rack budget). Zeros with the layer off.
    pub fn snapshot_stats(&self) -> SnapshotStats {
        let mut total = SnapshotStats::default();
        if let Some(sn) = &self.snapshots {
            for cache in &sn.caches {
                total.absorb(&cache.stats);
            }
        }
        total
    }
}

/// Deferred per-component allocation timeline event (applied in time
/// order so per-server consumption integrals stay monotonic).
#[derive(Debug, Clone, Copy)]
pub enum TimelineEv {
    /// Mid-stage memory growth (autoscaling). Applied best-effort: on a
    /// saturated cluster the growth silently fails and the matching
    /// `Finish` releases nothing for it.
    Grow { comp: usize, extra_mb: f64, used_mb: f64 },
    /// Component completion: release the base allocation plus whatever
    /// growth actually landed, and drop exactly the used share that was
    /// added (`used` is the base share committed at placement).
    Finish { comp: usize, started: Millis, base_alloc: Resources, used: Resources },
}

/// The four cluster mutations a timeline event may perform, abstracted
/// so [`apply_timeline_on`] can run against either the real [`Cluster`]
/// (hooks keep the placement index and dirty-rack feed in sync
/// immediately — the sequential replay) or a shard worker's rack-local
/// server slice (the parallel replay applies servers directly and
/// records index/dirty effects as notes, replayed at the next epoch
/// barrier in canonical `(time, seq)` order — see
/// [`super::epoch`]). Both sinks drive the *identical* `Server`
/// mutation sequence, which is what makes the parallel digest
/// bit-identical to the sequential one.
pub(crate) trait AllocSink {
    /// Try to allocate `amount` on `id`; true iff it landed.
    fn try_alloc(&mut self, id: ServerId, amount: Resources, now: Millis) -> bool;
    /// Raise the used share (accounting only — no index effect).
    fn add_used(&mut self, id: ServerId, delta: Resources, now: Millis);
    /// Lower the used share (accounting only — no index effect).
    fn sub_used(&mut self, id: ServerId, delta: Resources, now: Millis);
    /// Release an allocation on `id`.
    fn free(&mut self, id: ServerId, amount: Resources, now: Millis);
}

impl AllocSink for Cluster {
    fn try_alloc(&mut self, id: ServerId, amount: Resources, now: Millis) -> bool {
        Cluster::try_alloc(self, id, amount, now)
    }
    fn add_used(&mut self, id: ServerId, delta: Resources, now: Millis) {
        Cluster::add_used(self, id, delta, now);
    }
    fn sub_used(&mut self, id: ServerId, delta: Resources, now: Millis) {
        Cluster::sub_used(self, id, delta, now);
    }
    fn free(&mut self, id: ServerId, amount: Resources, now: Millis) {
        Cluster::free(self, id, amount, now);
    }
}

/// [`Platform::apply_timeline`]'s body, generic over the allocation
/// sink: the one copy of the Grow/Finish semantics both the sequential
/// and the sharded replay execute.
pub(crate) fn apply_timeline_on<S: AllocSink>(
    sink: &mut S,
    st: &mut OngoingInvocation,
    server: ServerId,
    ev: TimelineEv,
    at: Millis,
) {
    match ev {
        TimelineEv::Grow { comp, extra_mb, used_mb } => {
            if sink.try_alloc(server, Resources::mem_only(extra_mb), at) {
                sink.add_used(server, Resources::mem_only(used_mb), at);
                st.grown[comp] = Some((extra_mb, used_mb, at));
            }
            // else: cluster full — the growth never landed, so the
            // Finish below must not release or un-use it.
        }
        TimelineEv::Finish { comp, started, base_alloc, used } => {
            let (extra, grown_used, grown_at) = st.grown[comp].take().unwrap_or((0.0, 0.0, at));
            sink.sub_used(server, used.plus(Resources::mem_only(grown_used)), at);
            sink.free(server, base_alloc.plus(Resources::mem_only(extra)), at);
            // attributed per-invocation integrals
            let dur_s = (at - started).max(0.0) / 1000.0;
            let grown_s = (at - grown_at).max(0.0) / 1000.0;
            st.attrib.alloc_cpu_s += base_alloc.cpu * dur_s;
            st.attrib.alloc_mem_mb_s += base_alloc.mem_mb * dur_s + extra * grown_s;
            st.attrib.used_cpu_s += used.cpu * dur_s;
            st.attrib.used_mem_mb_s += used.mem_mb * dur_s + grown_used * grown_s;
        }
    }
}

/// Consumption difference (after - before), saturating at zero.
pub fn sub_consumption(after: Consumption, before: Consumption) -> Consumption {
    Consumption {
        alloc_cpu_s: (after.alloc_cpu_s - before.alloc_cpu_s).max(0.0),
        alloc_mem_mb_s: (after.alloc_mem_mb_s - before.alloc_mem_mb_s).max(0.0),
        used_cpu_s: (after.used_cpu_s - before.used_cpu_s).max(0.0),
        used_mem_mb_s: (after.used_mem_mb_s - before.used_mem_mb_s).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{lr, tpcds, video, Invocation};
    use crate::coordinator::graph::ResourceGraph;

    fn run(config: ZenixConfig, graph: &ResourceGraph, scale: f64) -> RunReport {
        let mut p = Platform::new(ClusterSpec::paper_testbed(), config);
        p.invoke(graph, Invocation::new(scale)).unwrap()
    }

    /// Warm the history with a few invocations, then measure.
    fn run_warm(config: ZenixConfig, graph: &ResourceGraph, scale: f64) -> RunReport {
        let mut p = Platform::new(ClusterSpec::paper_testbed(), config);
        for _ in 0..4 {
            p.invoke(graph, Invocation::new(scale)).unwrap();
        }
        p.invoke(graph, Invocation::new(scale)).unwrap()
    }

    #[test]
    fn lr_runs_and_accounts() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        let r = run(ZenixConfig::default(), &g, 1.0);
        assert!(r.exec_ms > 0.0);
        assert!(r.consumption.alloc_mem_mb_s > 0.0);
        assert!(r.consumption.used_mem_mb_s <= r.consumption.alloc_mem_mb_s + 1e-6);
        assert!(r.local_fraction > 0.5, "LR fits one server: {}", r.local_fraction);
        assert!(r.peak_cpu > 0.0 && r.peak_mem_mb > 0.0);
    }

    #[test]
    fn cluster_resources_restored_after_invocation() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        let mut p = Platform::testbed();
        p.invoke(&g, Invocation::new(1.0)).unwrap();
        for s in p.cluster.servers() {
            assert_eq!(s.allocated(), Resources::ZERO, "leak on {:?}", s.id);
            assert_eq!(s.marked(), Resources::ZERO);
        }
    }

    /// Pooled invocation shells must be invisible: interleaving graphs
    /// of different shapes through the same platform (shells resized
    /// per graph) leaves the cluster clean every time.
    #[test]
    fn pooled_shells_resize_across_different_graphs() {
        let small = ResourceGraph::from_program(&lr::program()).unwrap();
        let big = ResourceGraph::from_program(&tpcds::query(16)).unwrap();
        let mut p = Platform::testbed();
        for _ in 0..3 {
            p.invoke(&small, Invocation::new(0.5)).unwrap();
            p.invoke(&big, Invocation::new(0.2)).unwrap();
        }
        for s in p.cluster.servers() {
            assert_eq!(s.allocated(), Resources::ZERO, "leak on {:?}", s.id);
            assert_eq!(s.marked(), Resources::ZERO);
        }
    }

    #[test]
    fn adaptive_beats_static_graph() {
        let g = ResourceGraph::from_program(&tpcds::query(16)).unwrap();
        let stat = run_warm(ZenixConfig::static_graph(), &g, 0.2);
        let adap = run_warm(ZenixConfig::adaptive_only(), &g, 0.2);
        assert!(
            adap.exec_ms < stat.exec_ms,
            "adaptive {} vs static {}",
            adap.exec_ms,
            stat.exec_ms
        );
        assert!(adap.local_fraction >= stat.local_fraction);
    }

    #[test]
    fn proactive_reduces_startup() {
        let g = ResourceGraph::from_program(&video::pipeline()).unwrap();
        let no = run_warm(ZenixConfig::adaptive_only(), &g, 1.0);
        let yes = run_warm(ZenixConfig { history_sizing: false, ..ZenixConfig::default() }, &g, 1.0);
        assert!(
            yes.breakdown.startup_ms < no.breakdown.startup_ms,
            "proactive {} vs {}",
            yes.breakdown.startup_ms,
            no.breakdown.startup_ms
        );
    }

    #[test]
    fn history_sizing_cuts_allocation_vs_fixed() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        // fixed 256/64 under-provisions the 2.4 GB train stage (lots of
        // growths); history converges to right-sizing.
        let fixed = run_warm(
            ZenixConfig { history_sizing: false, ..ZenixConfig::default() },
            &g,
            1.0,
        );
        let hist = run_warm(ZenixConfig::default(), &g, 1.0);
        assert!(
            hist.exec_ms <= fixed.exec_ms * 1.05,
            "history {} vs fixed {}",
            hist.exec_ms,
            fixed.exec_ms
        );
    }

    #[test]
    fn rdma_faster_than_tcp_when_remote() {
        let g = ResourceGraph::from_program(&tpcds::query(95)).unwrap();
        let scale = 1.0; // big enough to spread across servers
        let rdma = run_warm(
            ZenixConfig { force_remote_data: true, ..ZenixConfig::default() },
            &g,
            scale,
        );
        let tcp = run_warm(
            ZenixConfig { force_remote_data: true, rdma: false, ..ZenixConfig::default() },
            &g,
            scale,
        );
        assert!(rdma.exec_ms < tcp.exec_ms);
    }

    #[test]
    fn forced_remote_slower_than_local() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        let local = run_warm(ZenixConfig::default(), &g, 1.0);
        let remote = run_warm(
            ZenixConfig { force_remote_data: true, ..ZenixConfig::default() },
            &g,
            1.0,
        );
        assert!(remote.exec_ms > local.exec_ms);
        assert!(remote.breakdown.io_ms > local.breakdown.io_ms);
    }

    #[test]
    fn crash_recovery_reexecutes_and_costs_time() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        let mut p = Platform::testbed();
        let clean = p.invoke(&g, Invocation::new(1.0)).unwrap();
        let crashed = p
            .invoke_with_crash(&g, Invocation::new(1.0), Crash::Compute(2), 2)
            .unwrap();
        assert!(crashed.exec_ms > clean.exec_ms, "redo adds time");
        // no resource leak after recovery
        for s in p.cluster.servers() {
            assert_eq!(s.allocated(), Resources::ZERO);
        }
    }

    #[test]
    fn larger_inputs_cost_more() {
        let g = ResourceGraph::from_program(&tpcds::query(1)).unwrap();
        let small = run_warm(ZenixConfig::default(), &g, 0.05);
        let large = run_warm(ZenixConfig::default(), &g, 1.0);
        assert!(large.exec_ms > small.exec_ms);
        assert!(large.consumption.alloc_gb_s() > small.consumption.alloc_gb_s());
    }

    #[test]
    fn invoke_at_dispatches_at_future_time() {
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        let mut p = Platform::testbed();
        let r = p.invoke_at(&g, Invocation::new(0.5), 10_000.0).unwrap();
        assert!(r.exec_ms > 0.0);
        assert!(p.now() >= 10_000.0 + r.exec_ms);
        // dispatching in the past clamps to the platform clock (server
        // consumption integrals must stay monotone)
        let clock = p.now();
        p.invoke_at(&g, Invocation::new(0.5), 0.0).unwrap();
        assert!(p.now() > clock);
        for s in p.cluster.servers() {
            assert_eq!(s.allocated(), Resources::ZERO);
        }
    }

    /// Satellite-2 regression: when a mid-wave growth cannot land
    /// (saturated cluster), `Finish` must subtract only the used share
    /// that was actually added — the old code subtracted the full grown
    /// amount, eating other tenants' used integrals on the same server.
    #[test]
    fn failed_growth_does_not_steal_foreign_used_share() {
        let spec = ClusterSpec {
            racks: 1,
            servers_per_rack: 1,
            server_capacity: Resources::new(32.0, 4096.0),
        };
        let mut p = Platform::new(spec, ZenixConfig::default());
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        // Warm the history at a small scale: the later big invocation
        // is then history-sized well below its need, forcing runtime
        // growths (§5.2.3).
        for _ in 0..4 {
            p.invoke(&g, Invocation::new(0.3)).unwrap();
        }
        // A foreign tenant holds (and uses) most of the server, so the
        // big invocation's Grow events cannot land.
        let tenant = Resources::new(0.0, 3500.0);
        assert!(p.cluster.try_alloc(ServerId(0), tenant, p.now()));
        p.cluster.add_used(ServerId(0), tenant, p.now());
        // The invocation runs degraded (or aborts) — either way it must
        // clean up exactly what it added, nothing more.
        let _ = p.invoke(&g, Invocation::new(1.0));
        let s = p.cluster.server(ServerId(0));
        assert!(
            (s.allocated().mem_mb - tenant.mem_mb).abs() < 1e-6
                && s.allocated().cpu.abs() < 1e-6,
            "foreign allocation intact: {:?}",
            s.allocated()
        );
        assert!(
            (s.used().mem_mb - tenant.mem_mb).abs() < 1e-6,
            "foreign used share must survive: {:?} vs {:?}",
            s.used(),
            tenant
        );
    }

    /// Regression (PR 10 satellite): a crash that rewinds to wave 0
    /// must re-resolve its start tier instead of replaying the
    /// pre-crash cold-boot latency. The original cold boot
    /// demand-installed the app's image, so the post-repair restart
    /// restores from the rack's snapshot cache: exactly one miss (the
    /// first start) and one hit (the restart).
    #[test]
    fn post_repair_wave0_restart_restores_from_snapshot_cache() {
        const MIB: u64 = 1024 * 1024;
        let g = ResourceGraph::from_program(&lr::program()).unwrap();
        let mut p = Platform::testbed();
        p.enable_snapshots(2048 * MIB, false, vec![(g.program.name, 256 * MIB)], 4);
        // Crash compute 0 after wave 1: the recovery plan's earliest
        // dirty component is the entry, so the rewind lands on wave 0.
        p.invoke_with_crash(&g, Invocation::new(1.0), Crash::Compute(0), 1).unwrap();
        let stats = p.snapshot_stats();
        assert_eq!(stats.misses, 1, "first start cold-boots and demand-installs");
        assert_eq!(stats.hits, 1, "post-repair wave-0 restart restores from cache");
        p.drain_snapshot_caches(p.now());
        for s in p.cluster.servers() {
            assert_eq!(s.allocated(), Resources::ZERO, "leak on {:?}", s.id);
        }
    }

    /// Regression (PR 10 satellite): the pre-warm ranking follows the
    /// *live* decayed arrival rate, not the static expected-arrival
    /// order. With a top-1 pre-warm and a workload that shifts from app
    /// A to app B across an idle gap, the live ranking pre-warms B
    /// before its first start — zero misses, which the static ranking
    /// (pinned to A forever) provably cannot achieve.
    #[test]
    fn prewarm_reranks_from_live_arrival_rates() {
        const MIB: u64 = 1024 * 1024;
        let a = ResourceGraph::from_program(&lr::program()).unwrap();
        let b = ResourceGraph::from_program(&tpcds::query(16)).unwrap();
        // proactive off: every start resolves through the snapshot
        // cache, so hit/miss counts cover all six invocations.
        let cfg = ZenixConfig { proactive: false, ..ZenixConfig::default() };
        let mut p = Platform::new(ClusterSpec::paper_testbed(), cfg);
        // The budget fits BOTH images (pre-warm never evicts), but with
        // top_k = 1 the ranking alone decides which app is resident
        // before its own first start.
        p.enable_snapshots(
            2048 * MIB,
            true,
            vec![(a.program.name, 256 * MIB), (b.program.name, 256 * MIB)],
            1,
        );
        // Phase 1: app A arrivals back-to-back (A tops the live rank).
        for _ in 0..3 {
            p.invoke(&a, Invocation::new(0.5)).unwrap();
        }
        // Phase 2, several half-lives later: the workload shifts to B.
        // A's decayed score falls under B's fresh arrival, the pass
        // re-ranks, and B is resident before its first start resolves.
        let shift = p.now() + 40_000.0;
        for i in 0..3u32 {
            p.invoke_at(&b, Invocation::new(0.2), shift + 1_000.0 * f64::from(i)).unwrap();
        }
        let stats = p.snapshot_stats();
        assert_eq!(stats.misses, 0, "live re-rank pre-warms B before its first start");
        assert_eq!(stats.hits, 6, "every start restores from a pre-warmed image");
        assert!(stats.prewarms >= 2, "both apps pre-warmed in turn: {stats:?}");
        p.drain_snapshot_caches(p.now());
        for s in p.cluster.servers() {
            assert_eq!(s.allocated(), Resources::ZERO, "leak on {:?}", s.id);
        }
    }
}
