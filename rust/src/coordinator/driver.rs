//! Multi-tenant trace-driven workload driver.
//!
//! The paper's headline numbers (Figs 22/26/29: up to 90% allocated-
//! memory reduction) are measured under *concurrent multi-application
//! load* shaped like the Azure serverless characterization [64]. This
//! driver reproduces that scenario end-to-end:
//!
//! - register N applications (the bulky evaluation programs plus
//!   synthetic apps drawn from the [`crate::trace::azure`] archetypes),
//! - draw deterministic Poisson arrivals per app over simulated time,
//! - dispatch *overlapping* invocations against one shared
//!   [`Platform`], interleaving their per-wave allocation timelines in
//!   global time order through the re-entrant engine entry points
//!   ([`Platform::begin_at`] / [`Platform::start_wave`] /
//!   [`Platform::apply_timeline`] / [`Platform::wave_done`]),
//! - replay the *identical* arrival schedule through the peak-provision
//!   ablation and a statically-sized FaaS baseline (§6.1.3 semantics:
//!   a function's memory size is configured once to cover its largest
//!   observed invocation, not per invocation),
//! - aggregate per-app and fleet-wide [`Consumption`], warm-pool hit
//!   rates, and history-sizing convergence (runtime growths early vs
//!   late in the run).
//!
//! Everything is deterministic per seed: arrivals, scales, event
//! ordering (time, then insertion sequence) and the report digest.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::apps::program::{compute, data, Program};
use crate::apps::{lr, tpcds, video, Invocation};
use crate::baselines::faas;
use crate::cluster::clock::Millis;
use crate::cluster::server::Consumption;
use crate::cluster::{ClusterSpec, Resources, ServerId, StartupModel};
use crate::trace::{Archetype, UsageTrace};
use crate::util::rng::Rng;
use crate::util::stats;

use super::exec::{OngoingInvocation, TimelineEv};
use super::graph::ResourceGraph;
use super::{Platform, ZenixConfig};

/// How one tenant draws its per-invocation input scale.
#[derive(Debug, Clone, Copy)]
pub enum ScaleModel {
    /// Every invocation uses the same input scale (the paper's
    /// fixed-input evaluation programs).
    Fixed(f64),
    /// Scales follow an Azure usage archetype: each invocation's scale
    /// is a peak-memory draw (MB) from the synthetic trace, driven
    /// through a unit-memory synthetic program (see
    /// [`synthetic_program`]).
    AzureTrace(Archetype),
}

/// One registered application.
pub struct TenantApp {
    pub graph: ResourceGraph,
    /// Share of the fleet-wide arrival stream this app receives.
    pub weight: f64,
    pub scales: ScaleModel,
}

/// Driver parameters. The same config (and therefore the same
/// schedule) is replayed against every system under comparison.
#[derive(Debug, Clone, Copy)]
pub struct DriverConfig {
    pub seed: u64,
    /// Total invocations across all apps.
    pub invocations: usize,
    /// Fleet-wide mean inter-arrival time (ms); per-app Poisson rates
    /// are weighted shares of `1 / mean_iat_ms`.
    pub mean_iat_ms: f64,
    pub cluster: ClusterSpec,
    pub config: ZenixConfig,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            seed: 7,
            invocations: 200,
            mean_iat_ms: 400.0,
            cluster: ClusterSpec::paper_testbed(),
            config: ZenixConfig::default(),
        }
    }
}

/// One scheduled invocation.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    pub at: Millis,
    pub app: usize,
    pub scale: f64,
}

/// A fully materialized arrival schedule, sorted by time. Generating it
/// once and replaying it per system guarantees every system sees the
/// *identical* workload.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub arrivals: Vec<Arrival>,
}

impl Schedule {
    /// Deterministic per-app Poisson arrivals + per-invocation scales.
    pub fn generate(apps: &[TenantApp], cfg: &DriverConfig) -> Schedule {
        assert!(!apps.is_empty(), "driver needs at least one app");
        let total_w: f64 = apps.iter().map(|a| a.weight.max(0.0)).sum::<f64>().max(1e-9);
        let n = cfg.invocations;
        // Invocation counts proportional to weight; remainder round-robin.
        let mut counts: Vec<usize> = apps
            .iter()
            .map(|a| ((a.weight.max(0.0) / total_w) * n as f64).floor() as usize)
            .collect();
        let mut assigned: usize = counts.iter().sum();
        let mut i = 0usize;
        while assigned < n {
            counts[i % apps.len()] += 1;
            assigned += 1;
            i += 1;
        }

        let mut arrivals = Vec::with_capacity(n);
        for (a, app) in apps.iter().enumerate() {
            let ni = counts[a];
            if ni == 0 {
                continue;
            }
            let mut rng = Rng::new(cfg.seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(a as u64 + 1)));
            // per-app mean IAT so the fleet-wide mean is cfg.mean_iat_ms
            let iat = cfg.mean_iat_ms * n as f64 / ni as f64;
            let rate = 1.0 / iat.max(1e-9);
            let peaks: Option<Vec<f64>> = match app.scales {
                ScaleModel::AzureTrace(arch) => Some(
                    UsageTrace::generate(arch, ni, cfg.seed ^ (0xA5A5 + a as u64)).peaks(),
                ),
                ScaleModel::Fixed(_) => None,
            };
            let mut t = 0.0f64;
            for k in 0..ni {
                t += rng.exponential(rate);
                let scale = match app.scales {
                    ScaleModel::Fixed(s) => s,
                    ScaleModel::AzureTrace(_) => peaks.as_ref().expect("trace peaks")[k],
                };
                arrivals.push(Arrival { at: t, app: a, scale });
            }
        }
        arrivals.sort_by(|x, y| x.at.total_cmp(&y.at).then(x.app.cmp(&y.app)));
        Schedule { arrivals }
    }

    /// Arrivals per app (diagnostics).
    pub fn count_for(&self, app: usize) -> usize {
        self.arrivals.iter().filter(|a| a.app == app).count()
    }
}

/// Per-app aggregate over one driver run.
#[derive(Debug, Clone)]
pub struct AppStats {
    pub name: &'static str,
    pub completed: usize,
    pub failed: usize,
    pub mean_exec_ms: f64,
    pub p95_exec_ms: f64,
    /// Attributed consumption (the invocations' own integrals, not a
    /// cluster-wide diff — concurrent tenants share the cluster).
    pub consumption: Consumption,
    pub warm_hits: usize,
    pub cold_starts: usize,
    /// Mean runtime growths per invocation in the first quarter of the
    /// app's completions vs the last quarter: history sizing converging
    /// drives the late value toward zero (§5.2.3).
    pub early_growths_per_inv: f64,
    pub late_growths_per_inv: f64,
}

/// Fleet-wide result of one driver run.
#[derive(Debug, Clone)]
pub struct DriverReport {
    pub system: String,
    pub apps: Vec<AppStats>,
    /// Cluster-integrated consumption over the whole run (for the
    /// closed-form FaaS baseline: the sum over invocations).
    pub fleet: Consumption,
    pub makespan_ms: f64,
    pub completed: usize,
    pub failed: usize,
    pub warm_hits: usize,
    pub cold_starts: usize,
    /// Peak number of simultaneously in-flight invocations — > 1 means
    /// the run genuinely overlapped tenants on the cluster.
    pub max_in_flight: usize,
    /// Index-aligned with the schedule: which arrivals this system
    /// completed (all-true for the closed-form FaaS baseline).
    pub completed_mask: Vec<bool>,
    /// Order-stable digest of the quantized results (determinism gate).
    pub digest: u64,
}

impl DriverReport {
    pub fn alloc_gb_s(&self) -> f64 {
        self.fleet.alloc_mem_mb_s / 1024.0
    }

    /// Relative allocated-memory savings of `self` vs `other`
    /// (0.9 == 90% less GB·s, the paper's headline unit).
    pub fn savings_vs(&self, other: &DriverReport) -> f64 {
        if other.fleet.alloc_mem_mb_s <= 0.0 {
            0.0
        } else {
            1.0 - self.fleet.alloc_mem_mb_s / other.fleet.alloc_mem_mb_s
        }
    }
}

/// The three-way comparison the Fig 22/26-style rows need.
pub struct MultiTenantOutcome {
    pub zenix: DriverReport,
    pub peak: DriverReport,
    /// FaaS baseline charged for the full schedule (standalone view).
    pub faas: DriverReport,
    /// FaaS baseline charged only for the arrivals the Zenix run
    /// completed — the apples-to-apples denominator for savings gates
    /// (identical to `faas` when nothing failed). The Zenix integral
    /// still includes failed invocations' partial work, so gating on
    /// this is conservative.
    pub faas_on_completed: DriverReport,
}

impl MultiTenantOutcome {
    /// Allocated-memory savings of the Zenix run vs the statically-
    /// sized FaaS baseline over the *same completed work* (the gated
    /// metric in `scripts/ci.sh` and the integration test).
    pub fn gated_savings(&self) -> f64 {
        self.zenix.savings_vs(&self.faas_on_completed)
    }
}

// ---- event heap ---------------------------------------------------------

enum EvKind {
    /// Index into the schedule's arrival list.
    Arrival(usize),
    /// Deferred allocation-timeline event of one ongoing invocation.
    Timeline { slot: usize, server: ServerId, ev: TimelineEv },
    /// The in-flight wave of `slot` completes.
    WaveDone { slot: usize },
}

struct HeapEv {
    at: Millis,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for HeapEv {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for HeapEv {}
impl PartialOrd for HeapEv {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEv {
    /// Reversed (min-heap): earliest time first, then insertion order —
    /// ties resolve deterministically and a wave's timeline events
    /// apply before its `WaveDone` (they are pushed first).
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// ---- the driver ---------------------------------------------------------

/// Drives a registered multi-tenant mix against the systems under
/// comparison over one deterministic arrival schedule.
pub struct MultiTenantDriver<'a> {
    apps: &'a [TenantApp],
    cfg: DriverConfig,
}

/// Completion record (internal aggregation).
struct DoneInv {
    app: usize,
    exec_ms: f64,
    growths: usize,
    warm: bool,
    consumption: Consumption,
}

impl<'a> MultiTenantDriver<'a> {
    pub fn new(apps: &'a [TenantApp], cfg: DriverConfig) -> Self {
        assert!(!apps.is_empty(), "driver needs at least one app");
        Self { apps, cfg }
    }

    /// Materialize the deterministic arrival schedule for this config.
    pub fn schedule(&self) -> Schedule {
        Schedule::generate(self.apps, &self.cfg)
    }

    /// Run the schedule on the full Zenix platform.
    pub fn run_zenix(&self, schedule: &Schedule) -> DriverReport {
        self.run_platform(schedule, self.cfg.config, "zenix")
    }

    /// Run the identical schedule with peak-provisioned sizing
    /// (Fig 22 "peak" ablation).
    pub fn run_peak_provision(&self, schedule: &Schedule) -> DriverReport {
        let config = ZenixConfig { peak_provision: true, ..self.cfg.config };
        self.run_platform(schedule, config, "peak-provision")
    }

    /// All three systems over one freshly generated schedule.
    pub fn run_comparison(&self) -> MultiTenantOutcome {
        let schedule = self.schedule();
        let zenix = self.run_zenix(&schedule);
        let peak = self.run_peak_provision(&schedule);
        let faas = self.run_faas_static(&schedule);
        let faas_on_completed = if zenix.failed == 0 {
            faas.clone()
        } else {
            self.run_faas_static_on(&schedule, Some(&zenix.completed_mask))
        };
        MultiTenantOutcome { zenix, peak, faas, faas_on_completed }
    }

    /// The discrete-event loop: one shared [`Platform`], overlapping
    /// invocations interleaved in global time order.
    fn run_platform(&self, schedule: &Schedule, config: ZenixConfig, label: &str) -> DriverReport {
        let mut platform = Platform::new(self.cfg.cluster, config);
        let mut heap: BinaryHeap<HeapEv> = BinaryHeap::with_capacity(schedule.arrivals.len() * 4);
        let mut seq = 0u64;
        for (i, arr) in schedule.arrivals.iter().enumerate() {
            heap.push(HeapEv { at: arr.at, seq, kind: EvKind::Arrival(i) });
            seq += 1;
        }

        let mut slots: Vec<Option<(usize, usize, OngoingInvocation)>> = Vec::new();
        let mut done: Vec<DoneInv> = Vec::new();
        let mut completed_mask = vec![false; schedule.arrivals.len()];
        let mut failed_per_app = vec![0usize; self.apps.len()];
        let mut in_flight = 0usize;
        let mut max_in_flight = 0usize;
        let mut end_time = 0.0f64;

        while let Some(HeapEv { at, kind, .. }) = heap.pop() {
            end_time = end_time.max(at);
            match kind {
                EvKind::Arrival(i) => {
                    let arr = schedule.arrivals[i];
                    let graph = &self.apps[arr.app].graph;
                    let mut st =
                        platform.begin_at(graph, Invocation::new(arr.scale), at, None);
                    let slot = slots.len();
                    match platform.start_wave(graph, &mut st) {
                        Ok(()) => {
                            in_flight += 1;
                            max_in_flight = max_in_flight.max(in_flight);
                            drain_pending(&mut heap, &mut seq, slot, &mut st);
                            heap.push(HeapEv {
                                at: st.wave_done_at(),
                                seq,
                                kind: EvKind::WaveDone { slot },
                            });
                            seq += 1;
                            slots.push(Some((arr.app, i, st)));
                        }
                        Err(_) => {
                            // saturated beyond degradation: admission fails
                            failed_per_app[arr.app] += 1;
                            slots.push(None);
                        }
                    }
                }
                EvKind::Timeline { slot, server, ev } => {
                    if let Some((_, _, st)) = slots[slot].as_mut() {
                        platform.apply_timeline(st, server, ev, at);
                    }
                }
                EvKind::WaveDone { slot } => {
                    let taken = slots[slot].take();
                    let (app_idx, sched_idx, mut st) = match taken {
                        Some(tuple) => tuple,
                        None => continue,
                    };
                    let graph = &self.apps[app_idx].graph;
                    if platform.wave_done(graph, &mut st) {
                        in_flight -= 1;
                        let warm = st.first_wave_warm().unwrap_or(false);
                        let growths = st.growths();
                        let report = platform.finish_invocation(graph, st, true);
                        completed_mask[sched_idx] = true;
                        done.push(DoneInv {
                            app: app_idx,
                            exec_ms: report.exec_ms,
                            growths,
                            warm,
                            consumption: report.consumption,
                        });
                    } else {
                        match platform.start_wave(graph, &mut st) {
                            Ok(()) => {
                                drain_pending(&mut heap, &mut seq, slot, &mut st);
                                heap.push(HeapEv {
                                    at: st.wave_done_at(),
                                    seq,
                                    kind: EvKind::WaveDone { slot },
                                });
                                seq += 1;
                                slots[slot] = Some((app_idx, sched_idx, st));
                            }
                            Err(_) => {
                                // mid-run abort (already cleaned up)
                                in_flight -= 1;
                                failed_per_app[app_idx] += 1;
                            }
                        }
                    }
                }
            }
        }

        let fleet = platform.cluster.total_consumption(end_time);
        self.aggregate(
            label,
            done,
            failed_per_app,
            fleet,
            end_time,
            max_in_flight,
            completed_mask,
        )
    }

    /// The statically-sized FaaS baseline over the identical schedule.
    ///
    /// §6.1.3 semantics: a FaaS function's memory size is *configured
    /// once per function*; to keep the workload feasible it must cover
    /// the largest invocation, so the deployed size is the running max
    /// of observed peaks (the "peak-provision" strategy of Fig 22 at
    /// whole-app granularity). Consumption is closed-form per
    /// invocation ([`faas::run`]), summed — single-function runs don't
    /// contend for placement, so no cluster replay is needed.
    pub fn run_faas_static(&self, schedule: &Schedule) -> DriverReport {
        self.run_faas_static_on(schedule, None)
    }

    /// Like [`Self::run_faas_static`], but only *charges* the arrivals
    /// selected by `mask` (schedule-index aligned) — the deployed
    /// function size is still configured from the full schedule, a
    /// deployment-time decision. Used to compare against a platform run
    /// on exactly the work that run completed.
    pub fn run_faas_static_on(
        &self,
        schedule: &Schedule,
        mask: Option<&[bool]>,
    ) -> DriverReport {
        let startup = StartupModel::default();
        // Pass 1: per-invocation reports + the per-app deployed size —
        // the max over the whole schedule, so the charge is independent
        // of arrival order (the function is configured once, up front).
        let mut fn_mem = vec![0.0f64; self.apps.len()];
        let mut fn_cpu = vec![0.0f64; self.apps.len()];
        let mut seen = vec![false; self.apps.len()];
        let mut runs: Vec<(bool, crate::metrics::RunReport)> =
            Vec::with_capacity(schedule.arrivals.len());
        for arr in &schedule.arrivals {
            let program = &self.apps[arr.app].graph.program;
            let warm = seen[arr.app];
            let r = faas::run(
                program,
                Invocation::new(arr.scale),
                faas::Provider::OpenWhisk,
                warm,
                &startup,
            );
            seen[arr.app] = true;
            fn_mem[arr.app] = fn_mem[arr.app].max(r.peak_mem_mb);
            fn_cpu[arr.app] = fn_cpu[arr.app].max(r.peak_cpu);
            runs.push((warm, r));
        }
        // Pass 2: every charged invocation holds the deployed (max)
        // size for its full duration.
        let mut done: Vec<DoneInv> = Vec::with_capacity(schedule.arrivals.len());
        let mut makespan = 0.0f64;
        for (idx, (arr, (warm, r))) in schedule.arrivals.iter().zip(runs).enumerate() {
            if mask.map_or(false, |m| !m[idx]) {
                continue;
            }
            let dur_s = r.exec_ms / 1000.0;
            let consumption = Consumption {
                alloc_cpu_s: fn_cpu[arr.app] * dur_s,
                alloc_mem_mb_s: fn_mem[arr.app] * dur_s,
                used_cpu_s: r.consumption.used_cpu_s,
                used_mem_mb_s: r.consumption.used_mem_mb_s,
            };
            makespan = makespan.max(arr.at + r.exec_ms);
            done.push(DoneInv {
                app: arr.app,
                exec_ms: r.exec_ms,
                growths: 0,
                warm,
                consumption,
            });
        }
        let fleet = done
            .iter()
            .fold(Consumption::default(), |acc, d| acc.plus(&d.consumption));
        let failed = vec![0usize; self.apps.len()];
        // FaaS functions overlap freely (provider capacity is opaque).
        let max_in_flight = 0;
        let charged = mask
            .map(|m| m.to_vec())
            .unwrap_or_else(|| vec![true; schedule.arrivals.len()]);
        self.aggregate("faas-static", done, failed, fleet, makespan, max_in_flight, charged)
    }

    #[allow(clippy::too_many_arguments)]
    fn aggregate(
        &self,
        label: &str,
        done: Vec<DoneInv>,
        failed_per_app: Vec<usize>,
        fleet: Consumption,
        makespan_ms: f64,
        max_in_flight: usize,
        completed_mask: Vec<bool>,
    ) -> DriverReport {
        let n_apps = self.apps.len();
        let mut exec: Vec<Vec<f64>> = vec![Vec::new(); n_apps];
        let mut growths: Vec<Vec<f64>> = vec![Vec::new(); n_apps];
        let mut warm = vec![0usize; n_apps];
        let mut cold = vec![0usize; n_apps];
        let mut consumption = vec![Consumption::default(); n_apps];
        for d in &done {
            exec[d.app].push(d.exec_ms);
            growths[d.app].push(d.growths as f64);
            if d.warm {
                warm[d.app] += 1;
            } else {
                cold[d.app] += 1;
            }
            consumption[d.app] = consumption[d.app].plus(&d.consumption);
        }

        let quarter_mean = |xs: &[f64], late: bool| -> f64 {
            if xs.is_empty() {
                return 0.0;
            }
            let q = (xs.len() + 3) / 4;
            let slice = if late { &xs[xs.len() - q..] } else { &xs[..q] };
            stats::mean(slice)
        };

        let apps: Vec<AppStats> = (0..n_apps)
            .map(|a| AppStats {
                name: self.apps[a].graph.program.name,
                completed: exec[a].len(),
                failed: failed_per_app[a],
                mean_exec_ms: if exec[a].is_empty() { 0.0 } else { stats::mean(&exec[a]) },
                p95_exec_ms: if exec[a].is_empty() {
                    0.0
                } else {
                    stats::percentile(&exec[a], 95.0)
                },
                consumption: consumption[a],
                warm_hits: warm[a],
                cold_starts: cold[a],
                early_growths_per_inv: quarter_mean(&growths[a], false),
                late_growths_per_inv: quarter_mean(&growths[a], true),
            })
            .collect();

        let completed = done.len();
        let failed: usize = failed_per_app.iter().sum();
        let warm_hits: usize = warm.iter().sum();
        let cold_starts: usize = cold.iter().sum();

        // order-stable FNV-style digest over quantized results
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |v: u64| {
            h = (h ^ v).wrapping_mul(0x0000_0100_0000_01B3);
        };
        let q = |x: f64| (x * 1024.0).round() as i64 as u64;
        mix(completed as u64);
        mix(failed as u64);
        mix(warm_hits as u64);
        mix(q(fleet.alloc_mem_mb_s));
        mix(q(fleet.used_mem_mb_s));
        mix(q(makespan_ms));
        for a in &apps {
            mix(a.completed as u64);
            mix(q(a.mean_exec_ms));
            mix(q(a.consumption.alloc_mem_mb_s));
        }

        DriverReport {
            system: label.to_string(),
            apps,
            fleet,
            makespan_ms,
            completed,
            failed,
            warm_hits,
            cold_starts,
            max_in_flight,
            completed_mask,
            digest: h,
        }
    }
}

fn drain_pending(
    heap: &mut BinaryHeap<HeapEv>,
    seq: &mut u64,
    slot: usize,
    st: &mut OngoingInvocation,
) {
    for (at, server, ev) in st.pending.drain(..) {
        heap.push(HeapEv { at, seq: *seq, kind: EvKind::Timeline { slot, server, ev } });
        *seq += 1;
    }
}

// ---- standard mixes -----------------------------------------------------

/// A unit-scale synthetic app: one compute whose per-invocation peak
/// memory equals the invocation's input scale (MB), so an Azure trace
/// drives it directly, with execution time following the trace
/// characterization's duration-memory correlation (`40 · peak^0.6` ms,
/// the mean of [`crate::trace::azure`]'s duration model).
pub fn synthetic_program(name: &'static str) -> Program {
    let mut c = compute(name, 40.0, 1.0, 1.0);
    c.work_exp = 0.6;
    c.mem_exp = 1.0;
    c.accesses = vec![0];
    c.access_intensity = 0.2;
    let mut payload = data("payload", 0.15);
    payload.size_exp = 1.0;
    Program {
        name,
        app_limit: Resources::new(8.0, 65536.0),
        computes: vec![c],
        data: vec![payload],
        entry: 0,
    }
}

/// Intern a dynamic name as `&'static str`. A process-global table
/// deduplicates, so repeated [`standard_mix`] calls (e.g. inside a
/// bench loop) leak at most one copy per *distinct* name.
fn intern_name(name: String) -> &'static str {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    static INTERNED: OnceLock<Mutex<HashMap<String, &'static str>>> = OnceLock::new();
    let mut table = INTERNED
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .expect("intern table poisoned");
    if let Some(&s) = table.get(&name) {
        return s;
    }
    let leaked: &'static str = Box::leak(name.clone().into_boxed_str());
    table.insert(name, leaked);
    leaked
}

/// A paper-shaped multi-tenant mix: the bulky evaluation programs (LR,
/// TPC-DS Q16, video transcode) at fixed scales plus synthetic apps
/// drawn from the given archetype, `n_apps` total. Synthetic app names
/// are interned `&'static str`s — leaked once per distinct name.
pub fn standard_mix(n_apps: usize, arch: Archetype) -> Vec<TenantApp> {
    let mut apps: Vec<TenantApp> = Vec::with_capacity(n_apps);
    let real: [(Program, f64); 3] =
        [(lr::program(), 0.5), (tpcds::query(16), 0.2), (video::pipeline(), 0.2)];
    for (program, scale) in real {
        if apps.len() >= n_apps {
            break;
        }
        apps.push(TenantApp {
            graph: ResourceGraph::from_program(&program).expect("evaluation program"),
            weight: 1.0,
            scales: ScaleModel::Fixed(scale),
        });
    }
    let mut i = 0usize;
    while apps.len() < n_apps {
        let name = intern_name(format!("azure-{}-{i}", arch.name()));
        let program = synthetic_program(name);
        apps.push(TenantApp {
            graph: ResourceGraph::from_program(&program).expect("synthetic program"),
            weight: 1.0,
            scales: ScaleModel::AzureTrace(arch),
        });
        i += 1;
    }
    apps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(seed: u64, invocations: usize) -> DriverConfig {
        DriverConfig { seed, invocations, mean_iat_ms: 300.0, ..DriverConfig::default() }
    }

    #[test]
    fn schedule_is_sorted_weighted_and_deterministic() {
        let apps = standard_mix(6, Archetype::Average);
        let cfg = small_cfg(3, 120);
        let s = Schedule::generate(&apps, &cfg);
        assert_eq!(s.arrivals.len(), 120);
        for w in s.arrivals.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        for a in 0..apps.len() {
            assert!(s.count_for(a) >= 120 / apps.len(), "app {a} starved");
        }
        let s2 = Schedule::generate(&apps, &cfg);
        assert_eq!(s.arrivals.len(), s2.arrivals.len());
        for (x, y) in s.arrivals.iter().zip(&s2.arrivals) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.app, y.app);
            assert_eq!(x.scale, y.scale);
        }
    }

    #[test]
    fn driver_overlaps_invocations_and_conserves_cluster() {
        let apps = standard_mix(6, Archetype::Average);
        let driver = MultiTenantDriver::new(&apps, small_cfg(5, 80));
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.completed + r.failed, 80);
        assert!(r.completed > 60, "most invocations complete: {}", r.completed);
        assert!(r.max_in_flight > 1, "no overlap: {}", r.max_in_flight);
        assert!(r.fleet.alloc_mem_mb_s > 0.0);
        assert!(r.fleet.used_mem_mb_s <= r.fleet.alloc_mem_mb_s + 1e-6);
        // warm pool engages after first invocations per app
        assert!(r.warm_hits > r.cold_starts, "{} warm vs {} cold", r.warm_hits, r.cold_starts);
    }

    #[test]
    fn driver_is_deterministic_per_seed() {
        let apps = standard_mix(5, Archetype::Varying);
        let a = MultiTenantDriver::new(&apps, small_cfg(9, 60)).run_comparison();
        let apps2 = standard_mix(5, Archetype::Varying);
        let b = MultiTenantDriver::new(&apps2, small_cfg(9, 60)).run_comparison();
        assert_eq!(a.zenix.digest, b.zenix.digest);
        assert_eq!(a.peak.digest, b.peak.digest);
        assert_eq!(a.faas.digest, b.faas.digest);
        let c = MultiTenantDriver::new(&apps, small_cfg(10, 60)).run_comparison();
        assert_ne!(a.zenix.digest, c.zenix.digest, "seed must matter");
    }

    #[test]
    fn zenix_beats_static_faas_and_peak_on_allocation() {
        let apps = standard_mix(8, Archetype::Average);
        let out = MultiTenantDriver::new(&apps, small_cfg(7, 160)).run_comparison();
        let z = out.zenix.fleet.alloc_mem_mb_s;
        // gate against the FaaS charge for the *same completed work*
        let f = out.faas_on_completed.fleet.alloc_mem_mb_s;
        let p = out.peak.fleet.alloc_mem_mb_s;
        assert!(z < f, "zenix {z} vs faas-static {f}");
        assert!(z <= p * 1.02, "zenix {z} vs peak-provision {p}");
        assert!(out.gated_savings() > 0.3, "savings {}", out.gated_savings());
        // full-schedule baseline is charged at least as much as the
        // completed-work subset
        assert!(out.faas.fleet.alloc_mem_mb_s >= f - 1e-9);
    }

    #[test]
    fn history_sizing_converges_under_load() {
        let apps = standard_mix(4, Archetype::Stable);
        let driver = MultiTenantDriver::new(&apps, small_cfg(21, 120));
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        // Stable usage: after history warms up, growths should not
        // increase; for most apps they shrink or stay flat.
        let improving = r
            .apps
            .iter()
            .filter(|a| a.completed >= 8)
            .filter(|a| a.late_growths_per_inv <= a.early_growths_per_inv + 1e-9)
            .count();
        let eligible = r.apps.iter().filter(|a| a.completed >= 8).count();
        assert!(
            improving * 2 >= eligible,
            "sizing diverged: {improving}/{eligible} improving"
        );
    }

    #[test]
    fn synthetic_program_tracks_scale() {
        let p = synthetic_program("azure-test");
        p.validate().unwrap();
        assert!((p.computes[0].mem_at(300.0) - 300.0).abs() < 1e-9);
        assert!(p.computes[0].work_at(300.0) > p.computes[0].work_at(100.0));
    }
}
