//! Multi-tenant trace-driven workload driver.
//!
//! The paper's headline numbers (Figs 22/26/29: up to 90% allocated-
//! memory reduction) are measured under *concurrent multi-application
//! load* shaped like the Azure serverless characterization [64]. This
//! driver reproduces that scenario end-to-end:
//!
//! - register N applications (the bulky evaluation programs plus
//!   synthetic apps drawn from the [`crate::trace::azure`] archetypes),
//! - draw deterministic Poisson arrivals per app over simulated time,
//! - dispatch *overlapping* invocations against one shared
//!   [`Platform`], interleaving their per-wave allocation timelines in
//!   global time order through the re-entrant engine entry points
//!   ([`Platform::begin_at`] / [`Platform::start_wave`] /
//!   [`Platform::apply_timeline`] / [`Platform::wave_done`]),
//! - replay the *identical* arrival schedule through the peak-provision
//!   ablation and a statically-sized FaaS baseline (§6.1.3 semantics:
//!   a function's memory size is configured once to cover its largest
//!   observed invocation, not per invocation),
//! - aggregate per-app and fleet-wide [`Consumption`], warm-pool hit
//!   rates, and history-sizing convergence (runtime growths early vs
//!   late in the run).
//!
//! Everything is deterministic per seed: arrivals, scales, event
//! ordering (time, then insertion sequence) and the report digest.
//!
//! ## Event-loop architecture (allocation-free steady state)
//!
//! The loop is built to replay 100k+ invocation traces in bounded
//! memory with zero steady-state allocation per arrival:
//!
//! - **Arrival cursor** — the schedule is already time-sorted, so
//!   arrivals are consumed through an index cursor instead of being
//!   pre-pushed into the event heap; the [`BinaryHeap`] holds only the
//!   *in-flight* timeline/wave events (O(overlap), not
//!   O(invocations)). Ties between an arrival and a heap event resolve
//!   to the arrival, reproducing the old all-in-heap sequence order.
//! - **Slab slot table** — in-flight [`OngoingInvocation`]s live in a
//!   slab with an intrusive free list: completed slots are reused, so
//!   the table is O(peak overlap) instead of growing one slot per
//!   arrival, and lookups stay dense-indexed. Slot indices embedded in
//!   heap events are never stale: a wave's timeline events always
//!   sort before its `WaveDone` (same time, lower sequence), so a slot
//!   is only freed when no events reference it.
//! - **Streaming aggregation** — with `DriverConfig::exact_stats`
//!   false, per-app latency/growth samples are *not* stored; the
//!   report keeps streaming moments + P² quantile estimators
//!   ([`crate::metrics::streaming`]) so report memory is O(apps).
//!   Exact storage remains the default for the small CI traces. Both
//!   modes produce the identical digest (the digest folds counts,
//!   ordered-sum means and consumption integrals — none of which
//!   differ between modes).
//! - Invocation shells, message-log entries and rack-availability
//!   refreshes are pooled/retired/incremental on the [`Platform`] side
//!   (see `exec.rs`); the counting-allocator test
//!   `rust/tests/alloc_free.rs` pins the end-to-end property.
//!
//! ## Admission control & burst arrivals
//!
//! When `start_wave` fails at arrival time the driver consults
//! [`DriverConfig::admission`] ([`super::admission`]): the default
//! [`AdmissionPolicy::RejectImmediately`] counts a rejection exactly
//! like the pre-queueing code (the pinned digest is unchanged), while
//! the queueing policies park the arrival in bounded per-tenant
//! deferred queues and retry on capacity-freeing events, signalled by
//! the cluster's existing dirty-rack feed
//! ([`crate::cluster::Cluster::has_dirty_racks`]). While a deferred
//! queue is non-empty, new arrivals join it instead of jumping the
//! line. Stale entries time out; entries still parked when the trace
//! ends are expired likewise. [`DriverConfig::arrivals`] selects the
//! arrival process ([`ArrivalModel`]): deterministic Poisson
//! (default, digest-pinned), two-state MMPP bursts, or a diurnal
//! rate-replay pattern — all at the same long-run offered load. The
//! report splits the old conflated failure counter into
//! admission-time `rejected`, mid-run `aborted` and queue `timed_out`,
//! and carries per-tenant queue-depth high-water marks plus
//! queueing-delay moments and P² p95 — O(apps) memory, slot-recycled
//! queues, still allocation-free in steady state.
//!
//! ## Fairness, SLOs & multi-rack sharding
//!
//! Beyond FIFO/round-robin queueing, [`AdmissionPolicy::WeightedFairShare`]
//! drains deficit-round-robin with quanta from [`TenantApp::weight`]
//! and [`AdmissionPolicy::Deadline`] evicts and drains earliest-
//! deadline-first against per-tenant SLOs ([`TenantApp::deadline_ms`]).
//! Every report carries Jain's fairness index over per-tenant
//! completions and goodput/demand ratios
//! ([`crate::metrics::fairness`], O(apps) streaming), so asymmetric-
//! overload replays quantify *who* the admission policy served.
//! [`DriverConfig::with_racks`] reshards the cluster at fixed total
//! capacity (the multi-rack sweep axis of
//! [`crate::figures::sharding_figs`]); the report's
//! `route_fast_hits`/`route_scans` expose how often the global
//! scheduler's incremental best-rack cache answered a routing decision
//! without an O(racks) scan.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::apps::program::{compute, data, Program};
use crate::apps::{lr, tpcds, video, Invocation};
use crate::baselines::faas;
use crate::cluster::clock::Millis;
use crate::cluster::server::Consumption;
use crate::cluster::{ClusterSpec, RackId, Resources, ServerId, StartupModel, StartupTier};
use crate::metrics::fairness;
use crate::metrics::streaming::{P2Quantile, StreamingMoments};
use crate::trace::{Archetype, UsageTrace};
use crate::util::cast;
use crate::util::rng::Rng;
use crate::util::stats;

use super::admission::{
    AdmissionOutcome, AdmissionPolicy, ArrivalModel, DeferredQueues, RateModulator,
};
use super::exec::{OngoingInvocation, TimelineEv};
use super::faults::{FaultConfig, FaultKind, FaultPlan};
use super::graph::ResourceGraph;
use super::workflow::{StageLaunch, Workflow, WorkflowRuntime};
use super::{Platform, ZenixConfig};

/// How one tenant draws its per-invocation input scale.
#[derive(Debug, Clone, Copy)]
pub enum ScaleModel {
    /// Every invocation uses the same input scale (the paper's
    /// fixed-input evaluation programs).
    Fixed(f64),
    /// Scales follow an Azure usage archetype: each invocation's scale
    /// is a peak-memory draw (MB) from the synthetic trace, driven
    /// through a unit-memory synthetic program (see
    /// [`synthetic_program`]).
    AzureTrace(Archetype),
}

/// One registered application.
pub struct TenantApp {
    /// The app's compiled resource graph.
    pub graph: ResourceGraph,
    /// Share of the fleet-wide arrival stream this app receives. Also
    /// the tenant's drain weight under
    /// [`AdmissionPolicy::WeightedFairShare`] (deficit-round-robin
    /// quanta are derived from the weight ratios).
    pub weight: f64,
    /// How per-invocation input scales are drawn.
    pub scales: ScaleModel,
    /// Per-tenant SLO for [`AdmissionPolicy::Deadline`]: the maximum
    /// queueing delay (ms) this tenant tolerates before a parked
    /// arrival is evicted. `None` uses the policy's default
    /// `deadline_ms`. Ignored by the other policies.
    pub deadline_ms: Option<f64>,
    /// Inter-invocation DAG this tenant's arrivals drive
    /// ([`super::workflow`]): each scheduled arrival runs the DAG's
    /// root stage, and stage completions spawn the declared downstream
    /// invocations with data handoff. `None` (and the trivial
    /// [`Workflow::single`]) replay byte-identically to independent
    /// arrivals.
    pub workflow: Option<Workflow>,
}

/// Driver parameters. The same config (and therefore the same
/// schedule) is replayed against every system under comparison.
#[derive(Debug, Clone, Copy)]
pub struct DriverConfig {
    /// Seed for arrivals, scales and everything downstream.
    pub seed: u64,
    /// Total invocations across all apps.
    pub invocations: usize,
    /// Fleet-wide mean inter-arrival time (ms); per-app Poisson rates
    /// are weighted shares of `1 / mean_iat_ms`.
    pub mean_iat_ms: f64,
    /// Cluster shape the platforms run on.
    pub cluster: ClusterSpec,
    /// Platform feature configuration (the Zenix run; the peak ablation
    /// derives from it).
    pub config: ZenixConfig,
    /// Store every per-invocation sample for exact report statistics
    /// (default; right for the small CI traces). `false` switches the
    /// report path to streaming moments + P² quantile estimators so a
    /// 1M-invocation trace runs in O(apps) report memory; the digest is
    /// identical in both modes, only `p95_exec_ms` and the early/late
    /// growth telemetry become (tightly bounded) estimates.
    pub exact_stats: bool,
    /// What to do when admission fails (default:
    /// [`AdmissionPolicy::RejectImmediately`], the digest-pinned
    /// pre-queueing behavior).
    pub admission: AdmissionPolicy,
    /// Arrival process shaping (default: [`ArrivalModel::Poisson`],
    /// the digest-pinned generator; MMPP/rate-replay add bursts at the
    /// same offered load).
    pub arrivals: ArrivalModel,
    /// Deterministic fault injection (default: chaos-free — zero
    /// events, zero RNG draws, digest byte-identical to a build
    /// without fault injection). See [`super::faults`].
    pub faults: FaultConfig,
    /// Replay worker threads. `1` (the default) runs the sequential
    /// event loop, byte-identical to every pinned digest. `> 1`
    /// switches to the sharded epoch-barrier loop
    /// ([`super::epoch`]): per-rack shard workers advance their local
    /// event heaps inside bounded epochs and the coordinator exchanges
    /// cross-shard effects at a deterministic barrier — the digest is
    /// identical for every worker count (pinned by tests and CI).
    /// Values above the rack count are clamped to it.
    pub workers: usize,
    /// Maximum epoch width (simulated ms) of the sharded loop: a shard
    /// batch never spans more than this much simulated time, bounding
    /// how much work one barrier exchange covers. Ignored when
    /// `workers == 1`. Clamped below to 1 ms.
    pub epoch_ms: f64,
    /// Per-rack snapshot-cache byte budget. `0` (the default) disables
    /// the snapshot layer entirely — the replay is byte-identical to a
    /// build without it (pinned by tests and CI). A positive budget
    /// charges resident images against rack memory, so the cache
    /// genuinely competes with invocations for capacity.
    pub snapshot_budget_bytes: u64,
    /// Predictive pre-warming: at rack-dirty instants the coordinator
    /// installs the top-[`PREWARM_TOP_K`] expected-rate app images into
    /// each rack's spare snapshot budget. Ignored (and digest-inert)
    /// while `snapshot_budget_bytes == 0`.
    pub prewarm: bool,
    /// Rack-affinity placement for workflow downstream stages (the
    /// default): a ready stage prefers the rack holding the most
    /// resident input bytes, spilling to the ordinary smallest-fit
    /// when the candidate cannot fit. `false` routes every stage
    /// blind (smallest-fit) — the ablation axis of the workflow
    /// figure sweep. Digest-inert for DAG-less mixes.
    pub workflow_affinity: bool,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            seed: 7,
            invocations: 200,
            mean_iat_ms: 400.0,
            cluster: ClusterSpec::paper_testbed(),
            config: ZenixConfig::default(),
            exact_stats: true,
            admission: AdmissionPolicy::RejectImmediately,
            arrivals: ArrivalModel::Poisson,
            faults: FaultConfig::default(),
            workers: 1,
            epoch_ms: 250.0,
            snapshot_budget_bytes: 0,
            prewarm: false,
            workflow_affinity: true,
        }
    }
}

impl DriverConfig {
    /// The rack-topology axis of the multi-rack sharding sweeps: the
    /// same config with the cluster resharded into `racks` racks at
    /// *fixed total capacity* (server count and per-server resources
    /// unchanged — see [`ClusterSpec::resharded`]). The arrival
    /// schedule is cluster-independent, so replays across this axis
    /// see the identical workload and differences are attributable to
    /// sharding alone (two-level scheduling, dirty-rack feed fan-out,
    /// per-rack placement indexing).
    pub fn with_racks(self, racks: usize) -> Self {
        Self { cluster: self.cluster.resharded(racks), ..self }
    }
}

/// One scheduled invocation.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Arrival instant (simulated ms).
    pub at: Millis,
    /// Index of the tenant app.
    pub app: usize,
    /// Input scale of this invocation.
    pub scale: f64,
}

/// A fully materialized arrival schedule, sorted by time. Generating it
/// once and replaying it per system guarantees every system sees the
/// *identical* workload.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Time-sorted arrivals (ties break by app index).
    pub arrivals: Vec<Arrival>,
}

impl Schedule {
    /// Deterministic per-app arrivals + per-invocation scales. With the
    /// default [`ArrivalModel::Poisson`] the generated schedule is
    /// byte-identical to the pre-burst-model generator; the burst
    /// models reshape arrival instants through a [`RateModulator`]
    /// (dedicated per-app state RNG) at the same long-run offered load.
    pub fn generate(apps: &[TenantApp], cfg: &DriverConfig) -> Schedule {
        assert!(!apps.is_empty(), "driver needs at least one app");
        let total_w: f64 = apps.iter().map(|a| a.weight.max(0.0)).sum::<f64>().max(1e-9);
        let n = cfg.invocations;
        // Invocation counts proportional to weight; remainder round-robin.
        let mut counts: Vec<usize> = apps
            .iter()
            // cast: safe(weight/total_w in [0,1], so the floor is in 0..=n)
            .map(|a| ((a.weight.max(0.0) / total_w) * n as f64).floor() as usize)
            .collect();
        let mut assigned: usize = counts.iter().sum();
        let mut i = 0usize;
        while assigned < n {
            counts[i % apps.len()] += 1;
            assigned += 1;
            i += 1;
        }

        let mut arrivals = Vec::with_capacity(n);
        for (a, app) in apps.iter().enumerate() {
            let ni = counts[a];
            if ni == 0 {
                continue;
            }
            let mut rng =
                Rng::new(cfg.seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(cast::u64_of(a) + 1)));
            // per-app mean IAT so the fleet-wide mean is cfg.mean_iat_ms
            let iat = cfg.mean_iat_ms * n as f64 / ni as f64;
            let rate = 1.0 / iat.max(1e-9);
            let peaks: Option<Vec<f64>> = match app.scales {
                ScaleModel::AzureTrace(arch) => Some(
                    UsageTrace::generate(arch, ni, cfg.seed ^ (0xA5A5 + cast::u64_of(a))).peaks(),
                ),
                ScaleModel::Fixed(_) => None,
            };
            // Burst modulation (None for Poisson — that branch must
            // keep the original draw sequence bit-for-bit, it is
            // digest-pinned). The modulator's state RNG is seeded
            // independently of the arrival/scale stream.
            let mut modulator = RateModulator::new(
                cfg.arrivals,
                rate,
                cfg.seed ^ 0xB157_0000 ^ (0xD1B5_4A32_D192_ED03u64.wrapping_mul(cast::u64_of(a) + 1)),
            );
            let mut t = 0.0f64;
            for k in 0..ni {
                t = match modulator.as_mut() {
                    None => t + rng.exponential(rate),
                    Some(m) => m.advance(rng.exponential(1.0)),
                };
                let scale = match app.scales {
                    ScaleModel::Fixed(s) => s,
                    ScaleModel::AzureTrace(_) => peaks.as_ref().expect("trace peaks")[k],
                };
                arrivals.push(Arrival { at: t, app: a, scale });
            }
        }
        arrivals.sort_by(|x, y| x.at.total_cmp(&y.at).then(x.app.cmp(&y.app)));
        Schedule { arrivals }
    }

    /// Arrivals per app (diagnostics).
    pub fn count_for(&self, app: usize) -> usize {
        self.arrivals.iter().filter(|a| a.app == app).count()
    }
}

/// Per-app aggregate over one driver run.
#[derive(Debug, Clone)]
pub struct AppStats {
    /// Program name (interned).
    pub name: &'static str,
    /// Arrivals the schedule carried for this app (its demand).
    pub scheduled: usize,
    /// Invocations that ran to completion.
    pub completed: usize,
    /// Arrivals rejected at admission time (saturated cluster under
    /// [`AdmissionPolicy::RejectImmediately`], or a full deferred
    /// queue).
    pub rejected: usize,
    /// Invocations admitted but aborted mid-run (a later wave could not
    /// allocate even degraded).
    pub aborted: usize,
    /// Deferred-queue entries that timed out before capacity freed.
    pub timed_out: usize,
    /// Entries still parked when the trace ended whose deadline lay
    /// beyond the last event — drained, not SLO-violated (the
    /// end-of-trace split of [`AppStats::timed_out`]).
    pub expired: usize,
    /// Workflow downstream-stage launch attempts this app spawned
    /// beyond its scheduled arrivals (zero for DAG-less tenants).
    /// These widen the conservation identity's right-hand side:
    /// `completed + failed() == scheduled + spawned`.
    pub spawned: usize,
    /// Arrivals parked in the deferred queue at least once.
    pub queued: usize,
    /// Peak deferred-queue depth for this tenant.
    pub queue_depth_hwm: usize,
    /// Mean queueing delay of queue-admitted invocations (ms; 0 when
    /// nothing queued).
    pub mean_queue_delay_ms: f64,
    /// P² p95 queueing delay of queue-admitted invocations (ms).
    pub p95_queue_delay_ms: f64,
    /// Mean execution latency of completions (ms).
    pub mean_exec_ms: f64,
    /// p95 execution latency of completions (ms; P² estimate in
    /// streaming mode).
    pub p95_exec_ms: f64,
    /// Attributed consumption (the invocations' own integrals, not a
    /// cluster-wide diff — concurrent tenants share the cluster).
    pub consumption: Consumption,
    /// Invocations whose first environment hit the warm pool.
    pub warm_hits: usize,
    /// Invocations that paid a cold start.
    pub cold_starts: usize,
    /// Mean runtime growths per invocation in the first quarter of the
    /// app's completions vs the last quarter: history sizing converging
    /// drives the late value toward zero (§5.2.3).
    pub early_growths_per_inv: f64,
    /// See [`AppStats::early_growths_per_inv`].
    pub late_growths_per_inv: f64,
    /// Invocations hit by an injected fault mid-run (crashed compute
    /// or lost data region; see [`super::faults`]).
    pub faulted: usize,
    /// Faulted invocations that recovered through the graph-cut replay
    /// and ran to completion.
    pub recovered: usize,
    /// Faulted invocations that could not recover (re-admission after
    /// the recovery rewind failed on the shrunken cluster). Counted
    /// here *instead of* `aborted`, so the failure split stays a
    /// partition of arrivals.
    pub faulted_unrecovered: usize,
    /// Invocations admitted and started. The tier split below is a
    /// partition of it: `tier_cold + tier_restored + tier_warm ==
    /// started` (pinned by the conservation regression test).
    pub started: usize,
    /// Started invocations whose first environment paid a full cold
    /// boot (no warm-pool hit, no resident snapshot image).
    pub tier_cold: usize,
    /// Started invocations restored from a resident snapshot image.
    pub tier_restored: usize,
    /// Started invocations served straight from the warm pool.
    pub tier_warm: usize,
    /// Mean start latency (ms) over this app's started invocations.
    pub mean_start_ms: f64,
    /// P² p95 start latency (ms) over this app's started invocations.
    pub p95_start_ms: f64,
}

impl AppStats {
    /// Arrivals that never completed: admission-time rejections plus
    /// mid-run aborts plus queue timeouts plus end-of-trace expiries
    /// plus unrecovered faults (the distinct failure modes the old
    /// conflated `failed` counter merged). Together with `completed`
    /// this partitions the app's invocations: `completed + failed() ==
    /// scheduled + spawned` (the `spawned` term covers workflow
    /// downstream stages; it is zero for DAG-less tenants).
    pub fn failed(&self) -> usize {
        self.rejected + self.aborted + self.timed_out + self.expired + self.faulted_unrecovered
    }

    /// This tenant's goodput/demand ratio: completed over scheduled
    /// (1.0 when nothing was scheduled) — the demand-normalized input
    /// to [`DriverReport::jain_goodput`].
    pub fn goodput_ratio(&self) -> f64 {
        fairness::goodput_ratio(self.completed, self.scheduled)
    }
}

/// Fleet-wide result of one driver run.
#[derive(Debug, Clone)]
pub struct DriverReport {
    /// Label of the system that produced this run.
    // digest: excluded(presentation label; folding it would make renames a digest break)
    pub system: String,
    /// Per-app aggregates, index-aligned with the registered mix.
    // digest: folded
    pub apps: Vec<AppStats>,
    /// Cluster-integrated consumption over the whole run (for the
    /// closed-form FaaS baseline: the sum over invocations).
    // digest: folded
    pub fleet: Consumption,
    /// End of the last event (simulated ms).
    // digest: folded
    pub makespan_ms: f64,
    /// Invocations that ran to completion.
    // digest: folded
    pub completed: usize,
    /// Total failed arrivals: `rejected + aborted + timed_out` (kept as
    /// one number because the digest folds it; the split fields below
    /// are the meaningful breakdown; since the end-of-trace split,
    /// `timed_out + expired` together replace the old drain-everything
    /// `timed_out`, so the folded sum is byte-identical). Unrecovered
    /// faults are *not* folded in — they live in
    /// [`DriverReport::faulted_unrecovered`] so the digest-folded
    /// quantity keeps its pre-chaos meaning; the full conservation
    /// identity is `completed + rejected + aborted + timed_out +
    /// expired + faulted_unrecovered == arrivals + spawned`.
    // digest: folded
    pub failed: usize,
    /// Admission-time rejections across the fleet.
    // digest: excluded(breakdown of the folded `failed` total; folding both would double-count)
    pub rejected: usize,
    /// Mid-run aborts across the fleet.
    // digest: excluded(breakdown of the folded `failed` total; folding both would double-count)
    pub aborted: usize,
    /// Deferred-queue timeouts across the fleet (entries whose
    /// deadline genuinely passed — SLO violations).
    // digest: excluded(breakdown of the folded `failed` total; folding both would double-count)
    pub timed_out: usize,
    /// Entries still parked at end-of-trace whose deadline lay beyond
    /// the last event: drained because the trace ended, not because
    /// their SLO was violated.
    // digest: excluded(breakdown of the folded `failed` total; folding both would double-count)
    pub expired: usize,
    /// Invocations hit by an injected fault mid-run (fleet-wide;
    /// `faulted == recovered + faulted_unrecovered`).
    // digest: excluded(chaos telemetry added after the digest was pinned; zero in default-policy runs)
    pub faulted: usize,
    /// Faulted invocations that recovered and completed.
    // digest: excluded(chaos telemetry added after the digest was pinned; zero in default-policy runs)
    pub recovered: usize,
    /// Faulted invocations that never completed (the recovery rewind
    /// could not be re-placed). Disjoint from `aborted`.
    // digest: excluded(chaos telemetry added after the digest was pinned; zero in default-policy runs)
    pub faulted_unrecovered: usize,
    /// Mean fault-to-completion latency over recovered invocations
    /// (ms; 0 when nothing recovered).
    // digest: excluded(chaos telemetry added after the digest was pinned; zero in default-policy runs)
    pub mean_recovery_ms: f64,
    /// P² p95 fault-to-completion latency over recovered invocations.
    // digest: excluded(chaos telemetry added after the digest was pinned; zero in default-policy runs)
    pub p95_recovery_ms: f64,
    /// Fleet-wide P² p99 execution latency of completions (the chaos
    /// sweep's tail-latency axis; exact-mode runs use the same
    /// streaming estimator so the value is mode-independent).
    // digest: excluded(tail-latency estimate; derived from folded per-app exec latencies)
    pub p99_exec_ms: f64,
    /// Arrivals parked in a deferred queue at least once.
    // digest: excluded(admission telemetry added after the digest was pinned)
    pub queued: usize,
    /// Mean queueing delay across every queue-admitted invocation (ms).
    // digest: excluded(admission telemetry added after the digest was pinned)
    pub mean_queue_delay_ms: f64,
    /// P² p95 queueing delay across every queue-admitted invocation.
    // digest: excluded(admission telemetry added after the digest was pinned)
    pub p95_queue_delay_ms: f64,
    /// Jain's fairness index over per-tenant completion counts (equal
    /// to the index over completion *rates* — Jain is scale-invariant).
    /// 1.0 = every tenant completed the same amount; 1/apps = one
    /// tenant monopolized the fleet. Not folded into the digest.
    // digest: excluded(derived index over folded per-app completion counts)
    pub jain_completion: f64,
    /// Jain's fairness index over per-tenant goodput/demand ratios
    /// (completed/scheduled) — the demand-normalized view for mixes
    /// whose tenants *ask* for asymmetric shares on purpose.
    // digest: excluded(derived index over folded per-app completion counts)
    pub jain_goodput: f64,
    /// Global-scheduler routing decisions served by the incremental
    /// best-rack cache (multi-rack telemetry; 0 for the closed-form
    /// FaaS baseline, which routes nothing).
    // digest: excluded(scheduler cache telemetry; an optimization counter, not a result)
    pub route_fast_hits: u64,
    /// Global-scheduler routing decisions that fell back to the
    /// O(racks) scan (stale cache or best rack could not fit).
    // digest: excluded(scheduler cache telemetry; an optimization counter, not a result)
    pub route_scans: u64,
    /// Fleet-wide warm-pool hits.
    // digest: folded
    pub warm_hits: usize,
    /// Fleet-wide cold starts.
    // digest: excluded(complement of folded warm_hits over the same invocation set)
    pub cold_starts: usize,
    /// Peak number of simultaneously in-flight invocations — > 1 means
    /// the run genuinely overlapped tenants on the cluster.
    // digest: excluded(concurrency telemetry added after the digest was pinned)
    pub max_in_flight: usize,
    /// Replay worker threads this run was configured with (clamped to
    /// the rack count; 1 = the sequential loop).
    // digest: excluded(execution-strategy telemetry; every worker count produces the identical digest by construction)
    pub workers: usize,
    /// Epoch windows the sharded loop executed (0 for the sequential
    /// loop).
    // digest: excluded(parallel-loop telemetry; worker-count dependent batching, results are not)
    pub epochs: u64,
    /// Epoch windows whose shard batches engaged the worker pool (the
    /// rest ran inline — too little work to amortize a dispatch).
    // digest: excluded(parallel-loop telemetry; worker-count dependent batching, results are not)
    pub parallel_batches: u64,
    /// Timeline events applied inside shard batches (rack-local work
    /// that never crossed the epoch barrier).
    // digest: excluded(parallel-loop telemetry; worker-count dependent batching, results are not)
    pub parallel_local_events: u64,
    /// Mean shard-batch size (events per shard per epoch, idle shards
    /// included — the barrier-overhead axis).
    // digest: excluded(parallel-loop telemetry; worker-count dependent batching, results are not)
    pub epoch_batch_mean: f64,
    /// P² p95 shard-batch size.
    // digest: excluded(parallel-loop telemetry; worker-count dependent batching, results are not)
    pub epoch_batch_p95: f64,
    /// Jain's fairness index over per-shard local-event totals: 1.0 =
    /// perfectly balanced shards, 1/shards = one shard did everything
    /// (then the parallel loop degenerates to sequential + barriers).
    // digest: excluded(parallel-loop telemetry; worker-count dependent batching, results are not)
    pub epoch_shard_jain: f64,
    /// Invocations admitted and started, fleet-wide. The tier split is
    /// a partition of it: `tier_cold + tier_restored + tier_warm ==
    /// started`, fleet-wide and per app.
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub started: usize,
    /// Started invocations that paid a full cold boot (no warm-pool
    /// hit, no resident snapshot image).
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub tier_cold: usize,
    /// Started invocations restored from a resident snapshot image
    /// (restore cost scales with the per-program image size).
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub tier_restored: usize,
    /// Started invocations served straight from the warm pool.
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub tier_warm: usize,
    /// Mean start latency (ms) over every started invocation.
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub mean_start_ms: f64,
    /// P² p95 start latency (ms) over every started invocation.
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub p95_start_ms: f64,
    /// P² p99 start latency (ms) over every started invocation — the
    /// cold-start-vs-cache-size sweep's tail axis.
    // digest: excluded(cold-start tier telemetry added after the digest was pinned)
    pub p99_start_ms: f64,
    /// Snapshot-cache hits (tier resolutions served by a resident image).
    // digest: excluded(snapshot-cache telemetry; an optimization counter, not a result)
    pub snap_hits: u64,
    /// Snapshot-cache misses (cold boots that consulted the cache).
    // digest: excluded(snapshot-cache telemetry; an optimization counter, not a result)
    pub snap_misses: u64,
    /// Images evicted to make room (LRU displacement or a fault taking
    /// their home server down).
    // digest: excluded(snapshot-cache telemetry; an optimization counter, not a result)
    pub snap_evictions: u64,
    /// Images installed proactively by the pre-warm policy.
    // digest: excluded(snapshot-cache telemetry; an optimization counter, not a result)
    pub snap_prewarms: u64,
    /// High-water mark of resident snapshot bytes, max over racks.
    // digest: excluded(snapshot-cache telemetry; an optimization counter, not a result)
    pub snap_bytes_hwm: u64,
    /// Workflow runs opened (one per admitted arrival of a tenant with
    /// a non-trivial DAG; 0 for DAG-less mixes).
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_runs: u64,
    /// Workflow runs whose every stage completed.
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_runs_completed: u64,
    /// Workflow stage invocations admitted and started (roots
    /// included).
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_stages_started: u64,
    /// Workflow stage invocations that ran to completion.
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_stages_completed: u64,
    /// Downstream-stage launch attempts (the `spawned` term of the
    /// conservation identity, fleet-wide).
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_spawned: u64,
    /// Handoff megabytes transferred across racks because a consumer
    /// stage was placed off the producer's rack — the quantity
    /// rack-affinity placement exists to shrink.
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_cross_rack_mb: f64,
    /// Mean end-to-end workflow latency (root admission to last stage
    /// completion, ms; 0 when no run completed).
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_e2e_mean_ms: f64,
    /// P² p95 end-to-end workflow latency (ms).
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_e2e_p95_ms: f64,
    /// P² p99 end-to-end workflow latency (ms).
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_e2e_p99_ms: f64,
    /// Downstream-stage placements that landed on the preferred
    /// (input-resident) rack.
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_affinity_hits: u64,
    /// Downstream-stage placements whose preferred rack could not fit,
    /// spilling to the ordinary smallest-fit route.
    // digest: excluded(workflow telemetry added after the digest was pinned; zero in DAG-less runs)
    pub wf_affinity_spills: u64,
    /// Index-aligned with the schedule: which arrivals this system
    /// completed (all-true for the closed-form FaaS baseline). A
    /// bitset — one bit per arrival, the only per-invocation structure
    /// the report retains (needed for the apples-to-apples FaaS
    /// replay over exactly the completed work).
    // digest: excluded(per-invocation replay bookkeeping; its content is already summarized by the folded counters)
    pub completed_mask: BitMask,
    /// Order-stable digest of the quantized results (determinism gate).
    // digest: excluded(the digest itself cannot fold itself)
    pub digest: u64,
}

/// Dense bitset, one bit per schedule index.
#[derive(Debug, Clone, Default)]
pub struct BitMask {
    words: Vec<u64>,
    len: usize,
}

impl BitMask {
    /// All-false mask of length `len`.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0u64; (len + 63) / 64], len }
    }

    /// All-true mask of length `len`.
    pub fn ones(len: usize) -> Self {
        let mut m = Self::new(len);
        for (i, w) in m.words.iter_mut().enumerate() {
            let bits = (len - i * 64).min(64);
            *w = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        }
        m
    }

    /// Number of bits tracked.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the mask tracks zero bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i`.
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Read bit `i`.
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        // cast: safe(u32 popcount of a u64 word, <= 64)
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

impl DriverReport {
    /// Fleet allocated memory in GB·s (the paper's headline unit).
    pub fn alloc_gb_s(&self) -> f64 {
        self.fleet.alloc_mem_mb_s / 1024.0
    }

    /// Relative allocated-memory savings of `self` vs `other`
    /// (0.9 == 90% less GB·s, the paper's headline unit).
    pub fn savings_vs(&self, other: &DriverReport) -> f64 {
        if other.fleet.alloc_mem_mb_s <= 0.0 {
            0.0
        } else {
            1.0 - self.fleet.alloc_mem_mb_s / other.fleet.alloc_mem_mb_s
        }
    }
}

/// The three-way comparison the Fig 22/26-style rows need.
pub struct MultiTenantOutcome {
    /// The full Zenix platform run.
    pub zenix: DriverReport,
    /// The peak-provision ablation over the identical schedule.
    pub peak: DriverReport,
    /// FaaS baseline charged for the full schedule (standalone view).
    pub faas: DriverReport,
    /// FaaS baseline charged only for the arrivals the Zenix run
    /// completed — the apples-to-apples denominator for savings gates
    /// (identical to `faas` when nothing failed). The Zenix integral
    /// still includes failed invocations' partial work, so gating on
    /// this is conservative.
    pub faas_on_completed: DriverReport,
}

impl MultiTenantOutcome {
    /// Allocated-memory savings of the Zenix run vs the statically-
    /// sized FaaS baseline over the *same completed work* (the gated
    /// metric in `scripts/ci.sh` and the integration test).
    pub fn gated_savings(&self) -> f64 {
        self.zenix.savings_vs(&self.faas_on_completed)
    }
}

// ---- event heap ---------------------------------------------------------

enum EvKind {
    /// Deferred allocation-timeline event of one ongoing invocation.
    Timeline { slot: usize, server: ServerId, ev: TimelineEv },
    /// The in-flight wave of `slot` completes.
    WaveDone { slot: usize },
    /// Scheduled fault/repair event `idx` of the run's [`FaultPlan`]
    /// fires (server crash, rack outage, transient compute crash, or
    /// a repair bringing capacity back).
    Fault { idx: usize },
    /// A workflow downstream stage becomes launchable: its inputs have
    /// arrived on the pinned rack (transfer delay included) and the
    /// coordinator attempts admission. Enqueued by the producing
    /// stage's `WaveDone` in edge-declaration order, so replay stays
    /// deterministic.
    StageLaunch { run: u32, stage: u32 },
}

struct HeapEv {
    at: Millis,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for HeapEv {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for HeapEv {}
impl PartialOrd for HeapEv {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEv {
    /// Reversed (min-heap): earliest time first, then insertion order —
    /// ties resolve deterministically and a wave's timeline events
    /// apply before its `WaveDone` (they are pushed first).
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// ---- in-flight slot slab ------------------------------------------------

/// Sentinel for "no next free slot".
const NIL: usize = usize::MAX;

enum Slot {
    /// Intrusive free-list link.
    Free { next: usize },
    Busy { app: usize, sched: usize, st: OngoingInvocation },
}

/// Slab of in-flight invocations: O(peak overlap) slots, reused through
/// an intrusive free list (the old `Vec<Option<_>>` grew one slot per
/// arrival — O(invocations) memory and a pointless linear footprint at
/// 100k+ traces). `pub(crate)`: the sharded epoch loop
/// ([`super::epoch`]) keeps one slab per shard plus a global one.
pub(crate) struct Slab {
    slots: Vec<Slot>,
    free_head: usize,
    live: usize,
    /// Workflow `(run, stage)` side table, index-aligned with `slots`
    /// (`(NO_WF, _)` for non-workflow invocations). A side table so the
    /// `Slot::Busy` shape — pattern-matched across both event loops —
    /// stays untouched.
    wf: Vec<(u32, u32)>,
}

/// Sentinel run id marking a slab slot as not workflow-tracked.
const NO_WF: u32 = u32::MAX;

impl Slab {
    pub(crate) fn new() -> Self {
        Self { slots: Vec::with_capacity(64), free_head: NIL, live: 0, wf: Vec::new() }
    }

    pub(crate) fn insert(&mut self, app: usize, sched: usize, st: OngoingInvocation) -> usize {
        self.live += 1;
        let i = if self.free_head != NIL {
            let i = self.free_head;
            self.free_head = match self.slots[i] {
                Slot::Free { next } => next,
                Slot::Busy { .. } => unreachable!("free list points at a busy slot"),
            };
            self.slots[i] = Slot::Busy { app, sched, st };
            i
        } else {
            self.slots.push(Slot::Busy { app, sched, st });
            self.slots.len() - 1
        };
        if self.wf.len() <= i {
            self.wf.resize(i + 1, (NO_WF, 0));
        }
        self.wf[i] = (NO_WF, 0);
        i
    }

    /// Tag a busy slot as workflow stage `(run, stage)`.
    pub(crate) fn set_wf(&mut self, i: usize, run: u32, stage: u32) {
        self.wf[i] = (run, stage);
    }

    /// Workflow `(run, stage)` of a busy slot, if it is one.
    pub(crate) fn wf_meta(&self, i: usize) -> Option<(u32, u32)> {
        match self.wf.get(i) {
            Some(&(run, stage)) if run != NO_WF => Some((run, stage)),
            _ => None,
        }
    }

    /// (app, schedule index) of a busy slot.
    pub(crate) fn meta(&self, i: usize) -> Option<(usize, usize)> {
        match self.slots.get(i) {
            Some(&Slot::Busy { app, sched, .. }) => Some((app, sched)),
            _ => None,
        }
    }

    pub(crate) fn state_mut(&mut self, i: usize) -> Option<&mut OngoingInvocation> {
        match self.slots.get_mut(i) {
            Some(Slot::Busy { st, .. }) => Some(st),
            _ => None,
        }
    }

    /// Remove a busy slot, linking it into the free list.
    pub(crate) fn take(&mut self, i: usize) -> Option<(usize, usize, OngoingInvocation)> {
        match self.slots.get(i) {
            Some(Slot::Busy { .. }) => {}
            _ => return None,
        }
        let prev = std::mem::replace(&mut self.slots[i], Slot::Free { next: self.free_head });
        self.free_head = i;
        self.live -= 1;
        match prev {
            Slot::Busy { app, sched, st } => Some((app, sched, st)),
            Slot::Free { .. } => unreachable!("checked busy above"),
        }
    }

    /// Currently busy slots.
    pub(crate) fn live(&self) -> usize {
        self.live
    }

    /// Total slots ever needed at once (capacity telemetry).
    pub(crate) fn high_water(&self) -> usize {
        self.slots.len()
    }
}

// ---- streaming aggregation ----------------------------------------------

/// Fixed-capacity ring holding the most recent samples (for the "late
/// quarter" growth telemetry without storing the whole run).
struct RingMean {
    buf: Vec<f64>,
    cap: usize,
    next: usize,
}

impl RingMean {
    fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self { buf: Vec::with_capacity(cap), cap, next: 0 }
    }

    fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % self.cap;
    }

    fn mean(&self) -> f64 {
        stats::mean(&self.buf)
    }
}

/// Per-app accumulator: exact sample storage (exact mode) or streaming
/// moments + P² p95 + bounded growth windows (streaming mode).
struct AppAgg {
    // exact mode
    exec: Vec<f64>,
    growths: Vec<f64>,
    // streaming mode
    moments: StreamingMoments,
    p95: P2Quantile,
    early_cap: usize,
    early_n: usize,
    early_growth_sum: f64,
    late_growths: RingMean,
    // both modes
    warm: usize,
    cold: usize,
    consumption: Consumption,
}

/// Streams completion records into per-app aggregates and folds the
/// order-stable digest exactly like the old stored-sample path (counts,
/// ordered-sum means and consumption integrals are identical in both
/// modes, so the digest is too). `pub(crate)`: the sharded epoch loop
/// ([`super::epoch`]) records completions in the identical canonical
/// `WaveDone` order, so both loops share one aggregator (and one
/// digest fold).
pub(crate) struct Aggregator<'a> {
    apps: &'a [TenantApp],
    exact: bool,
    per_app: Vec<AppAgg>,
    /// Arrivals the schedule carried per app (its demand vector; the
    /// denominator of the goodput fairness index).
    sched_counts: Vec<usize>,
    completed: usize,
    /// Fleet-wide p99 execution latency (always streaming — O(1)
    /// memory either mode, and the chaos sweep reads it per cell).
    p99: P2Quantile,
}

impl<'a> Aggregator<'a> {
    /// `sched_counts[a]` = arrivals scheduled for app `a` (sizes the
    /// streaming early/late quarter windows; completions aren't known
    /// up front in streaming mode).
    pub(crate) fn new(apps: &'a [TenantApp], sched_counts: &[usize], exact: bool) -> Self {
        // Bounded window: quarter of the scheduled arrivals, capped so
        // report memory stays O(apps) for arbitrarily long traces.
        const WINDOW_CAP: usize = 512;
        let per_app = (0..apps.len())
            .map(|a| {
                let quarter = (sched_counts[a] + 3) / 4;
                let window = quarter.clamp(1, WINDOW_CAP);
                AppAgg {
                    exec: Vec::new(),
                    growths: Vec::new(),
                    moments: StreamingMoments::new(),
                    p95: P2Quantile::new(0.95),
                    early_cap: window,
                    early_n: 0,
                    early_growth_sum: 0.0,
                    late_growths: RingMean::new(window),
                    warm: 0,
                    cold: 0,
                    consumption: Consumption::default(),
                }
            })
            .collect();
        Self {
            apps,
            exact,
            per_app,
            sched_counts: sched_counts.to_vec(),
            completed: 0,
            p99: P2Quantile::new(0.99),
        }
    }

    pub(crate) fn record(&mut self, app: usize, exec_ms: f64, growths: usize, warm: bool, c: Consumption) {
        self.completed += 1;
        self.p99.push(exec_ms);
        let a = &mut self.per_app[app];
        if self.exact {
            a.exec.push(exec_ms);
            a.growths.push(growths as f64);
        } else {
            a.moments.push(exec_ms);
            a.p95.push(exec_ms);
            if a.early_n < a.early_cap {
                a.early_n += 1;
                a.early_growth_sum += growths as f64;
            }
            a.late_growths.push(growths as f64);
        }
        if warm {
            a.warm += 1;
        } else {
            a.cold += 1;
        }
        a.consumption = a.consumption.plus(&c);
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn finish(
        self,
        label: &str,
        adm: AdmissionOutcome,
        fleet: Consumption,
        makespan_ms: f64,
        max_in_flight: usize,
        completed_mask: BitMask,
    ) -> DriverReport {
        let quarter_mean = |xs: &[f64], late: bool| -> f64 {
            if xs.is_empty() {
                return 0.0;
            }
            let q = (xs.len() + 3) / 4;
            let slice = if late { &xs[xs.len() - q..] } else { &xs[..q] };
            stats::mean(slice)
        };

        let exact = self.exact;
        let apps: Vec<AppStats> = self
            .per_app
            .iter()
            .enumerate()
            .map(|(i, a)| {
                let (completed, mean, p95, early, late) = if exact {
                    (
                        a.exec.len(),
                        if a.exec.is_empty() { 0.0 } else { stats::mean(&a.exec) },
                        if a.exec.is_empty() {
                            0.0
                        } else {
                            stats::percentile(&a.exec, 95.0)
                        },
                        quarter_mean(&a.growths, false),
                        quarter_mean(&a.growths, true),
                    )
                } else {
                    (
                        cast::usize_of(a.moments.count()),
                        a.moments.mean(),
                        a.p95.value(),
                        if a.early_n == 0 {
                            0.0
                        } else {
                            a.early_growth_sum / a.early_n as f64
                        },
                        a.late_growths.mean(),
                    )
                };
                let t = &adm.per_tenant[i];
                AppStats {
                    name: self.apps[i].graph.program.name,
                    scheduled: self.sched_counts[i],
                    completed,
                    rejected: t.rejected,
                    aborted: t.aborted,
                    timed_out: t.timed_out,
                    expired: t.expired,
                    // overwritten by the driver for workflow tenants;
                    // DAG-less apps and the baselines spawn nothing
                    spawned: 0,
                    queued: t.queued,
                    queue_depth_hwm: t.queue_depth_hwm,
                    mean_queue_delay_ms: t.mean_queue_delay_ms,
                    p95_queue_delay_ms: t.p95_queue_delay_ms,
                    mean_exec_ms: mean,
                    p95_exec_ms: p95,
                    consumption: a.consumption,
                    warm_hits: a.warm,
                    cold_starts: a.cold,
                    early_growths_per_inv: early,
                    late_growths_per_inv: late,
                    // overwritten by the driver when fault injection
                    // is live; the closed-form baselines see no faults
                    faulted: 0,
                    recovered: 0,
                    faulted_unrecovered: 0,
                    // overwritten by the driver's admission-time tier
                    // telemetry; the closed-form baselines start nothing
                    started: 0,
                    tier_cold: 0,
                    tier_restored: 0,
                    tier_warm: 0,
                    mean_start_ms: 0.0,
                    p95_start_ms: 0.0,
                }
            })
            .collect();

        let completed = self.completed;
        let p99_exec_ms = self.p99.value();
        // rejected + aborted + timed_out + expired: identical to the
        // old conflated sum under RejectImmediately (timeouts and
        // end-of-trace expiries only exist with queueing), and the
        // timed_out/expired split re-partitions the exact entries the
        // old drain counted — the digest below is unchanged for every
        // previously pinned configuration.
        let failed = adm.fleet.failed();
        let warm_hits: usize = self.per_app.iter().map(|a| a.warm).sum();
        let cold_starts: usize = self.per_app.iter().map(|a| a.cold).sum();

        // Fairness indices, streaming over the O(apps) aggregates.
        // Scale invariance makes the completion-count index identical
        // to the completion-*rate* index (counts / makespan). Not
        // folded into the digest: the pinned digest predates them.
        let jain_completion = fairness::jains_index(apps.iter().map(|a| a.completed as f64));
        let jain_goodput = fairness::jains_index(apps.iter().map(|a| a.goodput_ratio()));

        // order-stable FNV-style digest over quantized results
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |v: u64| {
            h = (h ^ v).wrapping_mul(0x0000_0100_0000_01B3);
        };
        // cast: safe(pinned digest semantics: i64 wrap of round(x*1024) reinterpreted as u64 is what DRIVER_DIGEST.lock records)
        let q = |x: f64| (x * 1024.0).round() as i64 as u64;
        mix(cast::u64_of(completed));
        mix(cast::u64_of(failed));
        mix(cast::u64_of(warm_hits));
        mix(q(fleet.alloc_mem_mb_s));
        mix(q(fleet.used_mem_mb_s));
        mix(q(makespan_ms));
        for a in &apps {
            mix(cast::u64_of(a.completed));
            mix(q(a.mean_exec_ms));
            mix(q(a.consumption.alloc_mem_mb_s));
        }

        DriverReport {
            system: label.to_string(),
            apps,
            fleet,
            makespan_ms,
            completed,
            failed,
            rejected: adm.fleet.rejected,
            aborted: adm.fleet.aborted,
            timed_out: adm.fleet.timed_out,
            expired: adm.fleet.expired,
            // overwritten by the driver when fault injection is live
            faulted: 0,
            recovered: 0,
            faulted_unrecovered: 0,
            mean_recovery_ms: 0.0,
            p95_recovery_ms: 0.0,
            p99_exec_ms,
            queued: adm.fleet.queued,
            mean_queue_delay_ms: adm.fleet.mean_queue_delay_ms,
            p95_queue_delay_ms: adm.fleet.p95_queue_delay_ms,
            jain_completion,
            jain_goodput,
            route_fast_hits: 0,
            route_scans: 0,
            warm_hits,
            cold_starts,
            max_in_flight,
            // overwritten by the sharded loop; the sequential loop and
            // the closed-form baselines report the idle defaults
            workers: 1,
            epochs: 0,
            parallel_batches: 0,
            parallel_local_events: 0,
            epoch_batch_mean: 0.0,
            epoch_batch_p95: 0.0,
            epoch_shard_jain: 1.0,
            // overwritten by the event loops' tier telemetry; the
            // closed-form baselines replay no platform, start nothing
            // and keep no snapshot caches
            started: 0,
            tier_cold: 0,
            tier_restored: 0,
            tier_warm: 0,
            mean_start_ms: 0.0,
            p95_start_ms: 0.0,
            p99_start_ms: 0.0,
            snap_hits: 0,
            snap_misses: 0,
            snap_evictions: 0,
            snap_prewarms: 0,
            snap_bytes_hwm: 0,
            // overwritten by the event loops when workflow tenants are
            // present; DAG-less runs keep the idle defaults
            wf_runs: 0,
            wf_runs_completed: 0,
            wf_stages_started: 0,
            wf_stages_completed: 0,
            wf_spawned: 0,
            wf_cross_rack_mb: 0.0,
            wf_e2e_mean_ms: 0.0,
            wf_e2e_p95_ms: 0.0,
            wf_e2e_p99_ms: 0.0,
            wf_affinity_hits: 0,
            wf_affinity_spills: 0,
            completed_mask,
            digest: h,
        }
    }
}

// ---- cold-start tier telemetry ------------------------------------------

/// Pre-warm breadth: the coordinator keeps at most this many of the
/// highest-expected-rate app images resident per rack.
pub const PREWARM_TOP_K: usize = 8;

/// Snapshot image size for one program: a fixed fraction of its
/// unit-scale peak-memory estimate (a checkpoint captures the resident
/// set after init, not the peak working set), clamped to [64 MiB, 1 GiB].
pub fn snapshot_image_bytes(program: &Program) -> u64 {
    const MIB: f64 = 1024.0 * 1024.0;
    let image_mb = (program.peak_estimate(1.0).mem_mb * 0.25).clamp(64.0, 1024.0);
    // cast: safe(image_mb clamped to [64, 1024] MiB, so the product is an exact u64)
    (image_mb * MIB) as u64
}

/// Pre-warm candidate order: every app's image, sorted by expected
/// arrivals descending. Scheduled counts are proportional to each app's
/// long-run offered rate under all three arrival models (Poisson, MMPP
/// and rate-replay modulate instants at fixed per-app totals), so they
/// are the rate signal the coordinator already has. Ties break to the
/// lower app index — the order is deterministic and permutation-stable.
pub(crate) fn prewarm_order(apps: &[TenantApp], sched_counts: &[usize]) -> Vec<(&'static str, u64)> {
    let mut order: Vec<usize> = (0..apps.len()).collect();
    order.sort_by(|&a, &b| sched_counts[b].cmp(&sched_counts[a]).then(a.cmp(&b)));
    order
        .into_iter()
        .map(|i| {
            let program = &apps[i].graph.program;
            (program.name, snapshot_image_bytes(program))
        })
        .collect()
}

/// Start-tier telemetry, accumulated at admission time (the instant a
/// start's tier resolves) by both event loops so the sequential and
/// sharded replays report identical tier splits. Digest-excluded
/// throughout: the pinned digest predates the tier model.
pub(crate) struct TierTelemetry {
    started: usize,
    started_per_app: Vec<usize>,
    cold: Vec<usize>,
    restored: Vec<usize>,
    warm: Vec<usize>,
    app_start: Vec<StreamingMoments>,
    app_p95: Vec<P2Quantile>,
    fleet_start: StreamingMoments,
    fleet_p95: P2Quantile,
    fleet_p99: P2Quantile,
}

impl TierTelemetry {
    pub(crate) fn new(n_apps: usize) -> Self {
        Self {
            started: 0,
            started_per_app: vec![0; n_apps],
            cold: vec![0; n_apps],
            restored: vec![0; n_apps],
            warm: vec![0; n_apps],
            app_start: vec![StreamingMoments::new(); n_apps],
            app_p95: vec![P2Quantile::new(0.95); n_apps],
            fleet_start: StreamingMoments::new(),
            fleet_p95: P2Quantile::new(0.95),
            fleet_p99: P2Quantile::new(0.99),
        }
    }

    /// Record one admitted invocation's resolved tier + start latency.
    pub(crate) fn record(&mut self, app: usize, tier: StartupTier, start_ms: f64) {
        self.started += 1;
        self.started_per_app[app] += 1;
        match tier {
            StartupTier::ColdBoot => self.cold[app] += 1,
            StartupTier::SnapshotRestore => self.restored[app] += 1,
            StartupTier::WarmHit => self.warm[app] += 1,
        }
        self.app_start[app].push(start_ms);
        self.app_p95[app].push(start_ms);
        self.fleet_start.push(start_ms);
        self.fleet_p95.push(start_ms);
        self.fleet_p99.push(start_ms);
    }

    /// Copy the tier split and start-latency estimates into a finished
    /// report. The aggregator writes zeros for these fields; this
    /// overwrites them — the same pattern the chaos telemetry uses, so
    /// the `Aggregator::finish` signature stays put.
    pub(crate) fn apply_to(&self, report: &mut DriverReport) {
        report.started = self.started;
        report.tier_cold = self.cold.iter().sum();
        report.tier_restored = self.restored.iter().sum();
        report.tier_warm = self.warm.iter().sum();
        if self.fleet_start.count() > 0 {
            report.mean_start_ms = self.fleet_start.mean();
            report.p95_start_ms = self.fleet_p95.value();
            report.p99_start_ms = self.fleet_p99.value();
        }
        for (i, a) in report.apps.iter_mut().enumerate() {
            a.started = self.started_per_app[i];
            a.tier_cold = self.cold[i];
            a.tier_restored = self.restored[i];
            a.tier_warm = self.warm[i];
            if self.app_start[i].count() > 0 {
                a.mean_start_ms = self.app_start[i].mean();
                a.p95_start_ms = self.app_p95[i].value();
            }
        }
    }
}

// ---- the driver ---------------------------------------------------------

/// Drives a registered multi-tenant mix against the systems under
/// comparison over one deterministic arrival schedule.
pub struct MultiTenantDriver<'a> {
    pub(crate) apps: &'a [TenantApp],
    pub(crate) cfg: DriverConfig,
}

impl<'a> MultiTenantDriver<'a> {
    /// Driver over a registered (non-empty) app mix.
    pub fn new(apps: &'a [TenantApp], cfg: DriverConfig) -> Self {
        assert!(!apps.is_empty(), "driver needs at least one app");
        Self { apps, cfg }
    }

    /// Materialize the deterministic arrival schedule for this config.
    pub fn schedule(&self) -> Schedule {
        Schedule::generate(self.apps, &self.cfg)
    }

    /// Run the schedule on the full Zenix platform.
    pub fn run_zenix(&self, schedule: &Schedule) -> DriverReport {
        self.run_platform(schedule, self.cfg.config, "zenix")
    }

    /// Run the identical schedule with peak-provisioned sizing
    /// (Fig 22 "peak" ablation).
    pub fn run_peak_provision(&self, schedule: &Schedule) -> DriverReport {
        let config = ZenixConfig { peak_provision: true, ..self.cfg.config };
        self.run_platform(schedule, config, "peak-provision")
    }

    /// All three systems over one freshly generated schedule.
    pub fn run_comparison(&self) -> MultiTenantOutcome {
        let schedule = self.schedule();
        let zenix = self.run_zenix(&schedule);
        let peak = self.run_peak_provision(&schedule);
        let faas = self.run_faas_static(&schedule);
        let faas_on_completed = if zenix.failed == 0 {
            faas.clone()
        } else {
            self.run_faas_static_on(&schedule, Some(&zenix.completed_mask))
        };
        MultiTenantOutcome { zenix, peak, faas, faas_on_completed }
    }

    /// [`Self::run_comparison`] with the independent system replays
    /// fanned out across OS threads: the Zenix and peak-provision runs
    /// each get a thread while the closed-form FaaS baseline runs on
    /// the calling thread. Every replay consumes the identical
    /// pre-generated schedule and is deterministic in isolation, so
    /// the outcome is byte-identical to the sequential comparison —
    /// only the wall clock changes. `fanout <= 1` falls back to
    /// [`Self::run_comparison`] exactly.
    ///
    /// Composes with [`DriverConfig::workers`]: the fan-out
    /// parallelizes *across* systems, the sharded epoch loop *within*
    /// one replay.
    pub fn run_comparison_with_workers(&self, fanout: usize) -> MultiTenantOutcome {
        if fanout <= 1 {
            return self.run_comparison();
        }
        let schedule = self.schedule();
        let sched = &schedule;
        let (zenix, peak, faas) = std::thread::scope(|scope| {
            let z = scope.spawn(move || self.run_zenix(sched));
            let p = scope.spawn(move || self.run_peak_provision(sched));
            let f = self.run_faas_static(sched);
            (
                z.join().expect("zenix replay thread panicked"),
                p.join().expect("peak-provision replay thread panicked"),
                f,
            )
        });
        let faas_on_completed = if zenix.failed == 0 {
            faas.clone()
        } else {
            self.run_faas_static_on(&schedule, Some(&zenix.completed_mask))
        };
        MultiTenantOutcome { zenix, peak, faas, faas_on_completed }
    }

    /// The discrete-event loop: one shared [`Platform`], overlapping
    /// invocations interleaved in global time order.
    ///
    /// Arrivals are consumed through a cursor over the (time-sorted)
    /// schedule; the heap holds only in-flight events. An arrival tied
    /// with a heap event wins — identical to the old all-in-heap
    /// ordering, where every arrival carried a lower sequence number
    /// than any timeline event.
    ///
    /// Admission: a failed `start_wave` at arrival time is handled per
    /// [`DriverConfig::admission`]. Queueing policies park the arrival
    /// (strict line discipline: while the deferred set is non-empty,
    /// new arrivals join it rather than jump it) and retry drains at
    /// deterministic points — at arrival instants and after heap
    /// events, both gated on the cluster's dirty-rack feed reporting
    /// freed/changed capacity (an unchanged cluster cannot admit what
    /// it already refused), plus one forced final drain when the trace
    /// runs out. Stale entries expire at every such point regardless
    /// of capacity, oldest deadline first, ties by enqueue sequence.
    fn run_platform(&self, schedule: &Schedule, config: ZenixConfig, label: &str) -> DriverReport {
        if self.cfg.workers > 1 {
            // The sharded epoch-barrier loop: digest-identical to this
            // sequential loop for every worker count (pinned by the
            // epoch module's tests, the proptests and CI).
            return super::epoch::run_platform_sharded(self, schedule, config, label);
        }
        let mut platform = Platform::new(self.cfg.cluster, config);
        let mut heap: BinaryHeap<HeapEv> = BinaryHeap::with_capacity(256);
        let mut seq = 0u64;
        let mut slab = Slab::new();
        let mut sched_counts = vec![0usize; self.apps.len()];
        for arr in &schedule.arrivals {
            sched_counts[arr.app] += 1;
        }
        // A zero budget leaves the snapshot layer entirely off — the
        // replay is byte-identical to a build without it.
        if self.cfg.snapshot_budget_bytes > 0 {
            platform.enable_snapshots(
                self.cfg.snapshot_budget_bytes,
                self.cfg.prewarm,
                prewarm_order(self.apps, &sched_counts),
                PREWARM_TOP_K,
            );
        }
        let mut tiers = TierTelemetry::new(self.apps.len());
        let mut agg = Aggregator::new(self.apps, &sched_counts, self.cfg.exact_stats);
        let mut completed_mask = BitMask::new(schedule.arrivals.len());
        let mut rejected_per_app = vec![0usize; self.apps.len()];
        let mut aborted_per_app = vec![0usize; self.apps.len()];
        let mut queues = DeferredQueues::new(self.cfg.admission, self.apps.len());
        let queueing = queues.policy().queues();
        if queueing {
            // One-time (not per-invocation) wiring of the per-tenant
            // drain weights and SLO deadlines into the queues.
            if matches!(self.cfg.admission, AdmissionPolicy::WeightedFairShare { .. }) {
                let weights: Vec<f64> = self.apps.iter().map(|a| a.weight).collect();
                queues.set_weights(&weights);
            }
            if let AdmissionPolicy::Deadline { deadline_ms, .. } = self.cfg.admission {
                let slos: Vec<f64> = self
                    .apps
                    .iter()
                    .map(|a| a.deadline_ms.unwrap_or(deadline_ms))
                    .collect();
                queues.set_deadlines(&slos);
            }
        }
        let mut in_flight = 0usize;
        let mut max_in_flight = 0usize;
        let mut end_time = 0.0f64;
        let mut next_arrival = 0usize;

        // Deterministic fault schedule: generated from its own RNG
        // stream over the arrival horizon, pushed as ordinary heap
        // events up front. The zero-fault default pushes nothing, so
        // `seq` starts at 0 for the first invocation's events exactly
        // as before — the pinned digest is byte-identical.
        let horizon = schedule.arrivals.last().map_or(0.0, |a| a.at);
        let fault_plan =
            FaultPlan::generate(&self.cfg.faults, self.cfg.seed, &self.cfg.cluster, horizon);
        for idx in 0..fault_plan.events.len() {
            heap.push(HeapEv { at: fault_plan.events[idx].at, seq, kind: EvKind::Fault { idx } });
            seq += 1;
        }
        let spr = self.cfg.cluster.servers_per_rack;
        let mut faulted_per_app = vec![0usize; self.apps.len()];
        let mut recovered_per_app = vec![0usize; self.apps.len()];
        let mut faulted_unrec_per_app = vec![0usize; self.apps.len()];
        let mut recovery_moments = StreamingMoments::new();
        let mut recovery_p95 = P2Quantile::new(0.95);

        // Workflow runtime: inert (no runs, no events, no cluster
        // mutation) unless some tenant declares a non-trivial DAG, so
        // DAG-less replays stay byte-identical to the pinned digest.
        let mut wfrt = WorkflowRuntime::new();
        wfrt.set_net(platform.config.net);
        let mut spawned_per_app = vec![0usize; self.apps.len()];
        let mut stage_buf: Vec<StageLaunch> = Vec::new();

        loop {
            let take_arrival = match (schedule.arrivals.get(next_arrival), heap.peek()) {
                (Some(a), Some(h)) => a.at <= h.at,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => {
                    if queues.is_empty() {
                        break;
                    }
                    // Trace exhausted with entries still parked: the
                    // cluster is idle (no in-flight events), so give
                    // the queue one full drain at the end of the run;
                    // whatever still cannot be admitted will never be
                    // — expire it.
                    let before = queues.len();
                    drain_deferred(
                        &mut platform,
                        self.apps,
                        schedule,
                        &mut queues,
                        end_time,
                        &mut heap,
                        &mut seq,
                        &mut slab,
                        &mut in_flight,
                        &mut max_in_flight,
                        &mut tiers,
                        &mut wfrt,
                    );
                    if queues.len() == before {
                        queues.expire_all(end_time);
                    }
                    continue;
                }
            };

            if take_arrival {
                let i = next_arrival;
                next_arrival += 1;
                let arr = schedule.arrivals[i];
                end_time = end_time.max(arr.at);
                if queueing && !queues.is_empty() {
                    // Older work first: timeouts expire at this instant
                    // regardless of capacity; admission retries run
                    // only if the dirty-rack feed says availability
                    // changed since the last (failed) probe — an
                    // unchanged cluster cannot admit what it already
                    // refused. Then join the line if it is occupied.
                    while queues.pop_expired(arr.at).is_some() {}
                    if !queues.is_empty() && platform.cluster.has_dirty_racks() {
                        drain_deferred(
                            &mut platform,
                            self.apps,
                            schedule,
                            &mut queues,
                            arr.at,
                            &mut heap,
                            &mut seq,
                            &mut slab,
                            &mut in_flight,
                            &mut max_in_flight,
                            &mut tiers,
                            &mut wfrt,
                        );
                    }
                    if !queues.is_empty() {
                        if !queues.try_park(arr.app, i, arr.at) {
                            rejected_per_app[arr.app] += 1;
                        }
                        continue;
                    }
                }
                let admitted = try_admit(
                    &mut platform,
                    self.apps,
                    arr,
                    i,
                    arr.at,
                    &mut heap,
                    &mut seq,
                    &mut slab,
                    &mut in_flight,
                    &mut max_in_flight,
                    &mut tiers,
                    &mut wfrt,
                );
                if !admitted && !queues.try_park(arr.app, i, arr.at) {
                    // saturated beyond degradation and nowhere to park:
                    // the arrival is rejected
                    rejected_per_app[arr.app] += 1;
                }
                continue;
            }

            let HeapEv { at, kind, .. } = heap.pop().expect("peeked above");
            end_time = end_time.max(at);
            match kind {
                EvKind::Timeline { slot, server, ev } => {
                    if let Some(st) = slab.state_mut(slot) {
                        platform.apply_timeline(st, server, ev, at);
                    }
                }
                EvKind::Fault { idx } => match fault_plan.events[idx].kind {
                    FaultKind::ServerCrash(s) => {
                        if platform.cluster.fail_server(s, at) {
                            platform.evict_snapshots_on(s, at);
                            crash_scan(&mut slab, &mut faulted_per_app, s, at);
                        }
                    }
                    FaultKind::RackOutage(r) => {
                        for i in r.0 * spr..(r.0 + 1) * spr {
                            let s = ServerId(i);
                            if platform.cluster.fail_server(s, at) {
                                platform.evict_snapshots_on(s, at);
                                crash_scan(&mut slab, &mut faulted_per_app, s, at);
                            }
                        }
                    }
                    FaultKind::TransientCompute(s) => {
                        // software fault: in-flight work crashes but
                        // the server's capacity stays up
                        crash_scan(&mut slab, &mut faulted_per_app, s, at);
                    }
                    FaultKind::ServerRepair(s) => {
                        platform.cluster.repair_server(s, at);
                    }
                    FaultKind::RackRepair(r) => {
                        for i in r.0 * spr..(r.0 + 1) * spr {
                            platform.cluster.repair_server(ServerId(i), at);
                        }
                    }
                    // repairs mark every rack dirty, so the deferred-
                    // queue drain below retries parked arrivals against
                    // the restored capacity
                },
                EvKind::WaveDone { slot } => {
                    let (app_idx, _sched_idx) = match slab.meta(slot) {
                        Some(m) => m,
                        None => continue,
                    };
                    let graph = &self.apps[app_idx].graph;
                    let finished = {
                        let st = slab.state_mut(slot).expect("busy slot");
                        platform.wave_done(graph, st)
                    };
                    if finished {
                        let wf_meta = slab.wf_meta(slot);
                        let (app_idx, sched_idx, st) =
                            slab.take(slot).expect("busy slot");
                        in_flight -= 1;
                        let warm = st.first_wave_warm().unwrap_or(false);
                        let growths = st.growths();
                        let done_rack = st.rack_id;
                        if let Some(t_fault) = st.fault_at {
                            recovered_per_app[app_idx] += 1;
                            recovery_moments.push(at - t_fault);
                            recovery_p95.push(at - t_fault);
                        }
                        let (exec_ms, consumption) =
                            platform.finish_invocation_attrib(graph, st);
                        completed_mask.set(sched_idx);
                        agg.record(app_idx, exec_ms, growths, warm, consumption);
                        if let Some((run, stage)) = wf_meta {
                            // Stage completion: retain out-edge handoffs
                            // on this rack and enqueue ready successors
                            // as ordinary heap events in edge order.
                            let wf = self.apps[app_idx]
                                .workflow
                                .as_ref()
                                .expect("workflow-tagged slot without a DAG");
                            stage_buf.clear();
                            wfrt.on_stage_done(
                                run,
                                stage,
                                done_rack,
                                at,
                                wf,
                                &graph.program,
                                &mut platform,
                                self.cfg.workflow_affinity,
                                &mut stage_buf,
                            );
                            for l in stage_buf.drain(..) {
                                heap.push(HeapEv {
                                    at: l.at,
                                    seq,
                                    kind: EvKind::StageLaunch { run: l.run, stage: l.stage },
                                });
                                seq += 1;
                            }
                        }
                    } else {
                        let start = {
                            let st = slab.state_mut(slot).expect("busy slot");
                            platform.start_wave(graph, st)
                        };
                        match start {
                            Ok(()) => {
                                let st = slab.state_mut(slot).expect("busy slot");
                                drain_pending(&mut heap, &mut seq, slot, st);
                                heap.push(HeapEv {
                                    at: st.wave_done_at(),
                                    seq,
                                    kind: EvKind::WaveDone { slot },
                                });
                                seq += 1;
                            }
                            Err(_) => {
                                // mid-run abort (already cleaned up).
                                // A fault-struck invocation that dies
                                // here counts as an unrecovered fault,
                                // not an abort — the failure split
                                // stays a partition of arrivals.
                                in_flight -= 1;
                                let wf_meta = slab.wf_meta(slot);
                                if let Some((_, _, st)) = slab.take(slot) {
                                    if st.fault_at.is_some() {
                                        faulted_unrec_per_app[app_idx] += 1;
                                    } else {
                                        aborted_per_app[app_idx] += 1;
                                    }
                                    platform.recycle_shell(st);
                                } else {
                                    aborted_per_app[app_idx] += 1;
                                }
                                if let Some((run, _)) = wf_meta {
                                    // The run fails: downstream stages
                                    // stop spawning and held handoff
                                    // charges release at retirement.
                                    wfrt.on_stage_aborted(run, &mut platform, at);
                                }
                            }
                        }
                    }
                }
                EvKind::StageLaunch { run, stage } => {
                    let app = wfrt.run_app(run);
                    let wf = self.apps[app]
                        .workflow
                        .as_ref()
                        .expect("stage launch for a DAG-less tenant");
                    if wfrt.begin_launch(run, stage, wf, &mut platform, at) {
                        spawned_per_app[app] += 1;
                        let admitted = try_admit_stage(
                            &mut platform,
                            self.apps,
                            app,
                            wfrt.run_sched(run),
                            run,
                            stage,
                            wfrt.stage_scale(run, stage, wf),
                            wfrt.pinned_rack(run, stage),
                            at,
                            &mut heap,
                            &mut seq,
                            &mut slab,
                            &mut in_flight,
                            &mut max_in_flight,
                            &mut tiers,
                        );
                        if admitted {
                            wfrt.on_stage_admitted(run);
                        } else {
                            // A stage the cluster cannot place fails
                            // its run; the attempt still conserves as
                            // a rejection against the spawned total.
                            rejected_per_app[app] += 1;
                            wfrt.on_stage_rejected(run, &mut platform, at);
                        }
                    }
                }
            }

            // Retry parked arrivals whenever this event may have freed
            // capacity: the cluster hooks record availability changes in
            // the dirty-rack feed (a completed wave frees allocations,
            // an aborted start unwinds them, a data component dies...),
            // so an empty feed means nothing changed and the retry is
            // skipped.
            if queueing && !queues.is_empty() && platform.cluster.has_dirty_racks() {
                drain_deferred(
                    &mut platform,
                    self.apps,
                    schedule,
                    &mut queues,
                    at,
                    &mut heap,
                    &mut seq,
                    &mut slab,
                    &mut in_flight,
                    &mut max_in_flight,
                    &mut tiers,
                    &mut wfrt,
                );
            }
        }

        // Tear down the snapshot layer before the leak asserts: resident
        // images return their rack-memory charge at end of trace (not
        // counted as evictions — nothing displaced them).
        platform.drain_snapshot_caches(end_time);
        // Every workflow run must have retired (the heap drained, so no
        // stage can still be pending) with its handoff charges freed.
        wfrt.assert_idle();

        debug_assert!(slab.high_water() <= schedule.arrivals.len() + spawned_per_app.iter().sum::<usize>());
        debug_assert_eq!(slab.live(), in_flight, "slab/in-flight accounting out of sync");
        debug_assert_eq!(in_flight, 0, "events drained with invocations still in flight");
        #[cfg(debug_assertions)]
        for s in platform.cluster.servers() {
            // The cluster drains to empty: every completion, abort and
            // fault-recovery unwind returned its allocations and marks
            // through the hooks that created them (small float residue
            // from out-of-order add/subtract is tolerated).
            debug_assert!(
                s.allocated().cpu < 1e-3 && s.allocated().mem_mb < 1e-3,
                "server {:?} leaked allocations: {:?}",
                s.id,
                s.allocated()
            );
            debug_assert!(
                s.marked().cpu < 1e-3 && s.marked().mem_mb < 1e-3,
                "server {:?} leaked marks: {:?}",
                s.id,
                s.marked()
            );
        }
        let fleet = platform.cluster.total_consumption(end_time);
        let adm = queues.finish(&rejected_per_app, &aborted_per_app);
        let route = platform.global.route_stats();
        let mut report = agg.finish(label, adm, fleet, end_time, max_in_flight, completed_mask);
        report.route_fast_hits = route.fast_hits;
        report.route_scans = route.scans;
        report.faulted = faulted_per_app.iter().sum();
        report.recovered = recovered_per_app.iter().sum();
        report.faulted_unrecovered = faulted_unrec_per_app.iter().sum();
        if recovery_moments.count() > 0 {
            report.mean_recovery_ms = recovery_moments.mean();
            report.p95_recovery_ms = recovery_p95.value();
        }
        for (i, a) in report.apps.iter_mut().enumerate() {
            a.faulted = faulted_per_app[i];
            a.recovered = recovered_per_app[i];
            a.faulted_unrecovered = faulted_unrec_per_app[i];
        }
        tiers.apply_to(&mut report);
        let snap = platform.snapshot_stats();
        report.snap_hits = snap.hits;
        report.snap_misses = snap.misses;
        report.snap_evictions = snap.evictions;
        report.snap_prewarms = snap.prewarms;
        report.snap_bytes_hwm = snap.bytes_hwm;
        let wstats = &wfrt.stats;
        report.wf_runs = wstats.runs;
        report.wf_runs_completed = wstats.runs_completed;
        report.wf_stages_started = wstats.stages_started;
        report.wf_stages_completed = wstats.stages_completed;
        report.wf_spawned = wstats.spawned;
        report.wf_cross_rack_mb = wstats.cross_rack_mb;
        if wstats.e2e.count() > 0 {
            report.wf_e2e_mean_ms = wstats.e2e.mean();
            report.wf_e2e_p95_ms = wstats.e2e_p95.value();
            report.wf_e2e_p99_ms = wstats.e2e_p99.value();
        }
        report.wf_affinity_hits = route.affinity_hits;
        report.wf_affinity_spills = route.affinity_spills;
        for (i, a) in report.apps.iter_mut().enumerate() {
            a.spawned = spawned_per_app[i];
        }
        report
    }

    /// The statically-sized FaaS baseline over the identical schedule.
    ///
    /// §6.1.3 semantics: a FaaS function's memory size is *configured
    /// once per function*; to keep the workload feasible it must cover
    /// the largest invocation, so the deployed size is the running max
    /// of observed peaks (the "peak-provision" strategy of Fig 22 at
    /// whole-app granularity). Consumption is closed-form per
    /// invocation ([`faas::run`]), summed — single-function runs don't
    /// contend for placement, so no cluster replay is needed.
    pub fn run_faas_static(&self, schedule: &Schedule) -> DriverReport {
        self.run_faas_static_on(schedule, None)
    }

    /// Like [`Self::run_faas_static`], but only *charges* the arrivals
    /// selected by `mask` (schedule-index aligned) — the deployed
    /// function size is still configured from the full schedule, a
    /// deployment-time decision. Used to compare against a platform run
    /// on exactly the work that run completed.
    ///
    /// Two passes, both O(apps) memory: pass 1 derives the deployed
    /// (max) sizes, pass 2 *recomputes* each closed-form report and
    /// streams it into the aggregator — nothing per-invocation is
    /// stored (the old implementation kept every `RunReport` from pass
    /// 1, O(invocations) heap for a deterministic recomputation).
    pub fn run_faas_static_on(
        &self,
        schedule: &Schedule,
        mask: Option<&BitMask>,
    ) -> DriverReport {
        let startup = StartupModel::default();
        let n_apps = self.apps.len();
        // Pass 1: the per-app deployed size — the max over the whole
        // schedule, so the charge is independent of arrival order (the
        // function is configured once, up front).
        let mut fn_mem = vec![0.0f64; n_apps];
        let mut fn_cpu = vec![0.0f64; n_apps];
        let mut seen = vec![false; n_apps];
        let mut sched_counts = vec![0usize; n_apps];
        for arr in &schedule.arrivals {
            let program = &self.apps[arr.app].graph.program;
            let warm = seen[arr.app];
            let r = faas::run(
                program,
                Invocation::new(arr.scale),
                faas::Provider::OpenWhisk,
                warm,
                &startup,
            );
            seen[arr.app] = true;
            sched_counts[arr.app] += 1;
            fn_mem[arr.app] = fn_mem[arr.app].max(r.peak_mem_mb);
            fn_cpu[arr.app] = fn_cpu[arr.app].max(r.peak_cpu);
        }
        // Pass 2: every charged invocation holds the deployed (max)
        // size for its full duration (faas::run is deterministic, so
        // re-evaluating beats storing 100k reports).
        let mut agg = Aggregator::new(self.apps, &sched_counts, self.cfg.exact_stats);
        let mut fleet = Consumption::default();
        let mut makespan = 0.0f64;
        let mut seen2 = vec![false; n_apps];
        for (idx, arr) in schedule.arrivals.iter().enumerate() {
            let program = &self.apps[arr.app].graph.program;
            let warm = seen2[arr.app];
            seen2[arr.app] = true;
            if mask.map_or(false, |m| !m.get(idx)) {
                continue;
            }
            let r = faas::run(
                program,
                Invocation::new(arr.scale),
                faas::Provider::OpenWhisk,
                warm,
                &startup,
            );
            let dur_s = r.exec_ms / 1000.0;
            let consumption = Consumption {
                alloc_cpu_s: fn_cpu[arr.app] * dur_s,
                alloc_mem_mb_s: fn_mem[arr.app] * dur_s,
                used_cpu_s: r.consumption.used_cpu_s,
                used_mem_mb_s: r.consumption.used_mem_mb_s,
            };
            makespan = makespan.max(arr.at + r.exec_ms);
            fleet = fleet.plus(&consumption);
            agg.record(arr.app, r.exec_ms, 0, warm, consumption);
        }
        // FaaS functions overlap freely (provider capacity is opaque),
        // and the closed-form replay models no admission layer.
        let max_in_flight = 0;
        let charged = match mask {
            Some(m) => m.clone(),
            None => BitMask::ones(schedule.arrivals.len()),
        };
        agg.finish(
            "faas-static",
            AdmissionOutcome::zeros(n_apps),
            fleet,
            makespan,
            max_in_flight,
            charged,
        )
    }
}

/// Open and start one invocation (`begin_at` + first `start_wave`),
/// registering it in the slab and pushing its events. Returns `false`
/// — with the shell recycled and the cluster fully unwound — when the
/// cluster cannot admit it.
#[allow(clippy::too_many_arguments)]
fn try_admit(
    platform: &mut Platform,
    apps: &[TenantApp],
    arr: Arrival,
    sched_idx: usize,
    at: Millis,
    heap: &mut BinaryHeap<HeapEv>,
    seq: &mut u64,
    slab: &mut Slab,
    in_flight: &mut usize,
    max_in_flight: &mut usize,
    tiers: &mut TierTelemetry,
    wfrt: &mut WorkflowRuntime,
) -> bool {
    let graph = &apps[arr.app].graph;
    let mut st = platform.begin_at(graph, Invocation::new(arr.scale), at, None);
    match platform.start_wave(graph, &mut st) {
        Ok(()) => {
            *in_flight += 1;
            *max_in_flight = (*max_in_flight).max(*in_flight);
            let slot = slab.insert(arr.app, sched_idx, st);
            let st = slab.state_mut(slot).expect("just inserted");
            tiers.record(
                arr.app,
                st.start_tier().unwrap_or(StartupTier::ColdBoot),
                st.start_latency_ms(),
            );
            drain_pending(heap, seq, slot, st);
            heap.push(HeapEv { at: st.wave_done_at(), seq: *seq, kind: EvKind::WaveDone { slot } });
            *seq += 1;
            if let Some(wf) = apps[arr.app].workflow.as_ref() {
                // The admitted arrival is a workflow root: open its run
                // so this invocation's completion spawns the DAG.
                let run = wfrt.on_root_admitted(arr.app, sched_idx, arr.scale, at, wf);
                slab.set_wf(slot, run, 0);
            }
            true
        }
        Err(_) => {
            platform.recycle_shell(st);
            false
        }
    }
}

/// Admit one workflow downstream stage on its pinned rack: `begin_at_on`
/// (no re-route) + first `start_wave`, slab registration tagged with the
/// `(run, stage)` workflow metadata. Stages bypass the deferred queues —
/// a stage that cannot be admitted fails its run (counted as a
/// rejection of the spawning tenant), it does not park.
#[allow(clippy::too_many_arguments)]
fn try_admit_stage(
    platform: &mut Platform,
    apps: &[TenantApp],
    app: usize,
    sched_idx: usize,
    run: u32,
    stage: u32,
    scale: f64,
    rack: RackId,
    at: Millis,
    heap: &mut BinaryHeap<HeapEv>,
    seq: &mut u64,
    slab: &mut Slab,
    in_flight: &mut usize,
    max_in_flight: &mut usize,
    tiers: &mut TierTelemetry,
) -> bool {
    let graph = &apps[app].graph;
    let mut st = platform.begin_at_on(graph, Invocation::new(scale), at, None, Some(rack));
    match platform.start_wave(graph, &mut st) {
        Ok(()) => {
            *in_flight += 1;
            *max_in_flight = (*max_in_flight).max(*in_flight);
            let slot = slab.insert(app, sched_idx, st);
            slab.set_wf(slot, run, stage);
            let st = slab.state_mut(slot).expect("just inserted");
            tiers.record(
                app,
                st.start_tier().unwrap_or(StartupTier::ColdBoot),
                st.start_latency_ms(),
            );
            drain_pending(heap, seq, slot, st);
            heap.push(HeapEv { at: st.wave_done_at(), seq: *seq, kind: EvKind::WaveDone { slot } });
            *seq += 1;
            true
        }
        Err(_) => {
            platform.recycle_shell(st);
            false
        }
    }
}

/// One deferred-queue service pass at simulated time `now`: expire
/// every overdue entry (earliest deadline first, ties by enqueue
/// sequence), then re-attempt admission in policy order. FIFO and
/// Deadline are head-of-line: the first failed retry returns to its
/// exact queue position and ends the pass (global arrival order /
/// strict EDF is the contract). The fair-share disciplines instead
/// *skip* a tenant whose head fails — the entry returns to its queue
/// but the round-robin moves past the tenant (forfeiting any remaining
/// weighted quantum) — and the pass ends only after a full cycle of
/// consecutive failures, so one unadmittable head cannot starve the
/// other tenants. Queueing delays of admitted entries are recorded as
/// they drain.
#[allow(clippy::too_many_arguments)]
fn drain_deferred(
    platform: &mut Platform,
    apps: &[TenantApp],
    schedule: &Schedule,
    queues: &mut DeferredQueues,
    now: Millis,
    heap: &mut BinaryHeap<HeapEv>,
    seq: &mut u64,
    slab: &mut Slab,
    in_flight: &mut usize,
    max_in_flight: &mut usize,
    tiers: &mut TierTelemetry,
    wfrt: &mut WorkflowRuntime,
) {
    while queues.pop_expired(now).is_some() {}
    let fair = queues.policy().skips_blocked_tenant();
    let mut consecutive_failures = 0usize;
    while let Some(p) = queues.pop_next() {
        let arr = schedule.arrivals[p.sched];
        let admitted = try_admit(
            platform,
            apps,
            arr,
            p.sched,
            now,
            heap,
            seq,
            slab,
            in_flight,
            max_in_flight,
            tiers,
            wfrt,
        );
        if admitted {
            queues.record_admitted(p.app, now - p.enqueued_at);
            consecutive_failures = 0;
        } else if fair {
            queues.unpop_skip_tenant(p);
            consecutive_failures += 1;
            // Capacity is monotone within a pass (failures unwind
            // fully), so after one failed probe per currently
            // non-empty tenant the round-robin has proven every head
            // blocked — stop, don't re-probe them.
            if consecutive_failures >= queues.non_empty_tenants() {
                break;
            }
        } else {
            queues.unpop(p);
            break;
        }
    }
}

fn drain_pending(
    heap: &mut BinaryHeap<HeapEv>,
    seq: &mut u64,
    slot: usize,
    st: &mut OngoingInvocation,
) {
    // `pending` is in push order; the global sequence numbers preserve
    // that order among same-time events (the per-wave sequence in the
    // tuple is only needed by the single-tenant sort).
    for (at, _wave_seq, server, ev) in st.pending.drain(..) {
        heap.push(HeapEv { at, seq: *seq, kind: EvKind::Timeline { slot, server, ev } });
        *seq += 1;
    }
}

/// Mark every in-flight invocation with state on `server` as crashed:
/// the engine's `wave_done` then routes it through `failure::plan` +
/// the message log and rewinds to the recovery cut. `fault_at` is set
/// at most once per invocation (a rack outage hitting two of its
/// servers is still one fault), and an already-pending crash is not
/// overwritten — the first recovery's rewind re-runs the wave anyway.
pub(crate) fn crash_scan(
    slab: &mut Slab,
    faulted_per_app: &mut [usize],
    server: ServerId,
    at: Millis,
) {
    for i in 0..slab.slots.len() {
        if let Slot::Busy { app, st, .. } = &mut slab.slots[i] {
            if let Some(crash) = st.crash_for_server(server) {
                if st.fault_at.is_none() {
                    st.fault_at = Some(at);
                    faulted_per_app[*app] += 1;
                }
                if st.crash_state.is_none() {
                    st.crash_state = Some((crash, st.wave_idx));
                }
            }
        }
    }
}

// ---- standard mixes -----------------------------------------------------

/// A unit-scale synthetic app: one compute whose per-invocation peak
/// memory equals the invocation's input scale (MB), so an Azure trace
/// drives it directly, with execution time following the trace
/// characterization's duration-memory correlation (`40 · peak^0.6` ms,
/// the mean of [`crate::trace::azure`]'s duration model).
pub fn synthetic_program(name: &'static str) -> Program {
    let mut c = compute(name, 40.0, 1.0, 1.0);
    c.work_exp = 0.6;
    c.mem_exp = 1.0;
    c.accesses = vec![0];
    c.access_intensity = 0.2;
    let mut payload = data("payload", 0.15);
    payload.size_exp = 1.0;
    Program {
        name,
        app_limit: Resources::new(8.0, 65536.0),
        computes: vec![c],
        data: vec![payload],
        entry: 0,
    }
}

/// Intern a dynamic name as `&'static str`. A process-global table
/// deduplicates, so repeated [`standard_mix`] calls (e.g. inside a
/// bench loop) leak at most one copy per *distinct* name.
fn intern_name(name: String) -> &'static str {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    static INTERNED: OnceLock<Mutex<HashMap<String, &'static str>>> = OnceLock::new();
    let mut table = INTERNED
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .expect("intern table poisoned");
    if let Some(&s) = table.get(&name) {
        return s;
    }
    let leaked: &'static str = Box::leak(name.clone().into_boxed_str());
    table.insert(name, leaked);
    leaked
}

/// A paper-shaped multi-tenant mix: the bulky evaluation programs (LR,
/// TPC-DS Q16, video transcode) at fixed scales plus synthetic apps
/// drawn from the given archetype, `n_apps` total. Synthetic app names
/// are interned `&'static str`s — leaked once per distinct name.
pub fn standard_mix(n_apps: usize, arch: Archetype) -> Vec<TenantApp> {
    let mut apps: Vec<TenantApp> = Vec::with_capacity(n_apps);
    let real: [(Program, f64); 3] =
        [(lr::program(), 0.5), (tpcds::query(16), 0.2), (video::pipeline(), 0.2)];
    for (program, scale) in real {
        if apps.len() >= n_apps {
            break;
        }
        apps.push(TenantApp {
            graph: ResourceGraph::from_program(&program).expect("evaluation program"),
            weight: 1.0,
            scales: ScaleModel::Fixed(scale),
            deadline_ms: None,
            workflow: None,
        });
    }
    let mut i = 0usize;
    while apps.len() < n_apps {
        let name = intern_name(format!("azure-{}-{i}", arch.name()));
        let program = synthetic_program(name);
        apps.push(TenantApp {
            graph: ResourceGraph::from_program(&program).expect("synthetic program"),
            weight: 1.0,
            scales: ScaleModel::AzureTrace(arch),
            deadline_ms: None,
            workflow: None,
        });
        i += 1;
    }
    apps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(seed: u64, invocations: usize) -> DriverConfig {
        DriverConfig { seed, invocations, mean_iat_ms: 300.0, ..DriverConfig::default() }
    }

    #[test]
    fn schedule_is_sorted_weighted_and_deterministic() {
        let apps = standard_mix(6, Archetype::Average);
        let cfg = small_cfg(3, 120);
        let s = Schedule::generate(&apps, &cfg);
        assert_eq!(s.arrivals.len(), 120);
        for w in s.arrivals.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        for a in 0..apps.len() {
            assert!(s.count_for(a) >= 120 / apps.len(), "app {a} starved");
        }
        let s2 = Schedule::generate(&apps, &cfg);
        assert_eq!(s.arrivals.len(), s2.arrivals.len());
        for (x, y) in s.arrivals.iter().zip(&s2.arrivals) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.app, y.app);
            assert_eq!(x.scale, y.scale);
        }
    }

    #[test]
    fn driver_overlaps_invocations_and_conserves_cluster() {
        let apps = standard_mix(6, Archetype::Average);
        let driver = MultiTenantDriver::new(&apps, small_cfg(5, 80));
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.completed + r.failed, 80);
        assert!(r.completed > 60, "most invocations complete: {}", r.completed);
        assert!(r.max_in_flight > 1, "no overlap: {}", r.max_in_flight);
        assert!(r.fleet.alloc_mem_mb_s > 0.0);
        assert!(r.fleet.used_mem_mb_s <= r.fleet.alloc_mem_mb_s + 1e-6);
        // warm pool engages after first invocations per app
        assert!(r.warm_hits > r.cold_starts, "{} warm vs {} cold", r.warm_hits, r.cold_starts);
    }

    #[test]
    fn driver_is_deterministic_per_seed() {
        let apps = standard_mix(5, Archetype::Varying);
        let a = MultiTenantDriver::new(&apps, small_cfg(9, 60)).run_comparison();
        let apps2 = standard_mix(5, Archetype::Varying);
        let b = MultiTenantDriver::new(&apps2, small_cfg(9, 60)).run_comparison();
        assert_eq!(a.zenix.digest, b.zenix.digest);
        assert_eq!(a.peak.digest, b.peak.digest);
        assert_eq!(a.faas.digest, b.faas.digest);
        let c = MultiTenantDriver::new(&apps, small_cfg(10, 60)).run_comparison();
        assert_ne!(a.zenix.digest, c.zenix.digest, "seed must matter");
    }

    #[test]
    fn zenix_beats_static_faas_and_peak_on_allocation() {
        let apps = standard_mix(8, Archetype::Average);
        let out = MultiTenantDriver::new(&apps, small_cfg(7, 160)).run_comparison();
        let z = out.zenix.fleet.alloc_mem_mb_s;
        // gate against the FaaS charge for the *same completed work*
        let f = out.faas_on_completed.fleet.alloc_mem_mb_s;
        let p = out.peak.fleet.alloc_mem_mb_s;
        assert!(z < f, "zenix {z} vs faas-static {f}");
        assert!(z <= p * 1.02, "zenix {z} vs peak-provision {p}");
        assert!(out.gated_savings() > 0.3, "savings {}", out.gated_savings());
        // full-schedule baseline is charged at least as much as the
        // completed-work subset
        assert!(out.faas.fleet.alloc_mem_mb_s >= f - 1e-9);
    }

    #[test]
    fn history_sizing_converges_under_load() {
        let apps = standard_mix(4, Archetype::Stable);
        let driver = MultiTenantDriver::new(&apps, small_cfg(21, 120));
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        // Stable usage: after history warms up, growths should not
        // increase; for most apps they shrink or stay flat.
        let improving = r
            .apps
            .iter()
            .filter(|a| a.completed >= 8)
            .filter(|a| a.late_growths_per_inv <= a.early_growths_per_inv + 1e-9)
            .count();
        let eligible = r.apps.iter().filter(|a| a.completed >= 8).count();
        assert!(
            improving * 2 >= eligible,
            "sizing diverged: {improving}/{eligible} improving"
        );
    }

    /// Streaming aggregation must be digest-identical to exact storage
    /// (counts, ordered-sum means and consumption integrals agree
    /// bit-for-bit); only p95 becomes a tightly bounded P² estimate.
    #[test]
    fn streaming_stats_preserve_digest_and_means() {
        let apps = standard_mix(6, Archetype::Average);
        let exact_cfg = small_cfg(9, 240);
        let stream_cfg = DriverConfig { exact_stats: false, ..exact_cfg };
        let exact = MultiTenantDriver::new(&apps, exact_cfg).run_comparison();
        let streaming = MultiTenantDriver::new(&apps, stream_cfg).run_comparison();
        assert_eq!(exact.zenix.digest, streaming.zenix.digest);
        assert_eq!(exact.peak.digest, streaming.peak.digest);
        assert_eq!(exact.faas.digest, streaming.faas.digest);
        assert_eq!(exact.zenix.completed, streaming.zenix.completed);
        assert_eq!(
            exact.zenix.completed_mask.count_ones(),
            streaming.zenix.completed_mask.count_ones()
        );
        for (a, b) in exact.zenix.apps.iter().zip(&streaming.zenix.apps) {
            assert_eq!(a.completed, b.completed, "{}", a.name);
            assert_eq!(
                a.mean_exec_ms.to_bits(),
                b.mean_exec_ms.to_bits(),
                "{}: streaming mean must be bit-identical",
                a.name
            );
            if a.completed >= 30 {
                assert!(
                    (b.p95_exec_ms - a.p95_exec_ms).abs()
                        <= 0.10 * a.p95_exec_ms.abs() + 5.0,
                    "{}: P² p95 {} vs exact {}",
                    a.name,
                    b.p95_exec_ms,
                    a.p95_exec_ms
                );
            }
        }
    }

    #[test]
    fn slab_free_list_reuses_slots() {
        let mut p = Platform::new(ClusterSpec::paper_testbed(), ZenixConfig::default());
        let g = ResourceGraph::from_program(&crate::apps::lr::program()).unwrap();
        let mut slab = Slab::new();
        let st_a = p.begin_at(&g, Invocation::new(0.1), 0.0, None);
        let st_b = p.begin_at(&g, Invocation::new(0.1), 1.0, None);
        let a = slab.insert(0, 0, st_a);
        let b = slab.insert(1, 7, st_b);
        assert_eq!((a, b), (0, 1));
        assert_eq!(slab.meta(b), Some((1, 7)));
        let (app, sched, st_back) = slab.take(a).expect("busy");
        assert_eq!((app, sched), (0, 0));
        assert!(slab.take(a).is_none(), "double-take must be a no-op");
        assert!(slab.state_mut(a).is_none());
        // freed slot is reused before the slab grows
        let c = slab.insert(2, 9, st_back);
        assert_eq!(c, a, "intrusive free list must hand back the freed slot");
        assert_eq!(slab.high_water(), 2, "slab stays at peak overlap");
        assert_eq!(slab.meta(c), Some((2, 9)));
    }

    #[test]
    fn bitmask_set_get_ones() {
        let mut m = BitMask::new(70);
        assert_eq!(m.len(), 70);
        assert_eq!(m.count_ones(), 0);
        m.set(0);
        m.set(63);
        m.set(64);
        m.set(69);
        assert!(m.get(0) && m.get(63) && m.get(64) && m.get(69));
        assert!(!m.get(1) && !m.get(65));
        assert_eq!(m.count_ones(), 4);
        let all = BitMask::ones(70);
        assert_eq!(all.count_ones(), 70);
        assert!(all.get(69));
        assert_eq!(BitMask::ones(64).count_ones(), 64);
        assert!(BitMask::new(0).is_empty());
    }

    #[test]
    fn synthetic_program_tracks_scale() {
        let p = synthetic_program("azure-test");
        p.validate().unwrap();
        assert!((p.computes[0].mem_at(300.0) - 300.0).abs() < 1e-9);
        assert!(p.computes[0].work_at(300.0) > p.computes[0].work_at(100.0));
    }

    // ---- admission control & burst arrivals -----------------------------

    #[test]
    fn default_config_is_digest_pinned_reject_poisson() {
        let cfg = DriverConfig::default();
        assert_eq!(cfg.admission, AdmissionPolicy::RejectImmediately);
        assert!(cfg.arrivals.is_poisson());
    }

    /// A queueing policy on an uncontended schedule never engages the
    /// queue, so the run must be event-for-event identical to the
    /// default policy — the digest proves queueing is a strict
    /// extension, not a behavior change.
    #[test]
    fn idle_queue_is_digest_identical_to_reject() {
        let apps = standard_mix(4, Archetype::Stable);
        // generous IAT: nothing saturates
        let base = DriverConfig { seed: 5, invocations: 60, mean_iat_ms: 2000.0, ..DriverConfig::default() };
        let fifo = DriverConfig {
            admission: AdmissionPolicy::FifoQueue { max_wait_ms: 60_000.0, max_depth: 32 },
            ..base
        };
        let driver_a = MultiTenantDriver::new(&apps, base);
        let schedule = driver_a.schedule();
        let a = driver_a.run_zenix(&schedule);
        let b = MultiTenantDriver::new(&apps, fifo).run_zenix(&schedule);
        assert_eq!(a.rejected + a.aborted, 0, "schedule must be uncontended");
        assert_eq!(b.queued, 0, "queue must never engage");
        assert_eq!(a.digest, b.digest, "idle queueing must not perturb the run");
    }

    /// Regression for the conflated-failure split: every arrival lands
    /// in exactly one of completed / rejected / aborted / timed_out,
    /// per app and fleet-wide, and `failed` is their sum.
    #[test]
    fn failure_accounting_is_conserved_and_split() {
        let apps = standard_mix(8, Archetype::Average);
        // saturating load so rejections actually occur
        let cfg = DriverConfig { seed: 7, invocations: 300, mean_iat_ms: 50.0, ..DriverConfig::default() };
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.failed, r.rejected + r.aborted + r.timed_out);
        assert_eq!(r.completed + r.failed, 300);
        assert_eq!(r.timed_out, 0, "no queueing under RejectImmediately");
        assert!(r.rejected > 0, "load must saturate admission for this regression");
        let (mut rej, mut abt, mut to) = (0usize, 0usize, 0usize);
        for a in &r.apps {
            assert_eq!(a.failed(), a.rejected + a.aborted + a.timed_out);
            rej += a.rejected;
            abt += a.aborted;
            to += a.timed_out;
        }
        assert_eq!((rej, abt, to), (r.rejected, r.aborted, r.timed_out));
    }

    /// Queueing under the same saturated schedule completes at least as
    /// much work as rejecting, fails no arrival twice, and reports
    /// queueing delays.
    #[test]
    fn fifo_queue_conserves_work_and_reports_delays() {
        let apps = standard_mix(8, Archetype::Average);
        let base = DriverConfig { seed: 7, invocations: 300, mean_iat_ms: 50.0, ..DriverConfig::default() };
        let fifo = DriverConfig {
            admission: AdmissionPolicy::FifoQueue { max_wait_ms: 120_000.0, max_depth: 64 },
            ..base
        };
        let driver_r = MultiTenantDriver::new(&apps, base);
        let schedule = driver_r.schedule();
        let reject = driver_r.run_zenix(&schedule);
        let queued = MultiTenantDriver::new(&apps, fifo).run_zenix(&schedule);
        assert_eq!(
            queued.completed + queued.rejected + queued.aborted + queued.timed_out,
            300,
            "conservation under queueing"
        );
        assert!(queued.queued > 0, "saturated run must park arrivals");
        // abort-tolerant: shifted admission times can turn a reject-run
        // completion into a queued-run mid-run abort, but never lose it
        assert!(
            queued.completed + queued.aborted >= reject.completed,
            "queueing completed {}+{} aborted < reject {}",
            queued.completed,
            queued.aborted,
            reject.completed
        );
        assert!(
            queued.rejected + queued.timed_out <= reject.rejected,
            "queueing must not fail more than rejecting: {}+{} vs {}",
            queued.rejected,
            queued.timed_out,
            reject.rejected
        );
        // delays are observable whenever something drained
        let drained_any = queued.apps.iter().any(|a| a.queued > a.timed_out);
        if drained_any {
            assert!(queued.mean_queue_delay_ms > 0.0);
            assert!(queued.p95_queue_delay_ms >= queued.mean_queue_delay_ms * 0.1);
        }
        let hwm: usize = queued.apps.iter().map(|a| a.queue_depth_hwm).max().unwrap_or(0);
        assert!(hwm > 0, "depth high-water must register");
        // determinism of the queued replay
        let queued2 = MultiTenantDriver::new(&apps, fifo).run_zenix(&schedule);
        assert_eq!(queued.digest, queued2.digest);
    }

    #[test]
    fn fair_share_spreads_drains_across_tenants() {
        let apps = standard_mix(6, Archetype::Average);
        let fair = DriverConfig {
            seed: 11,
            invocations: 240,
            mean_iat_ms: 50.0,
            admission: AdmissionPolicy::FairShare { max_wait_ms: 120_000.0, max_depth: 64 },
            ..DriverConfig::default()
        };
        let driver = MultiTenantDriver::new(&apps, fair);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.completed + r.failed, 240);
        if r.queued > 0 {
            // fairness smoke: no single tenant monopolizes the drains
            let max_queued = r.apps.iter().map(|a| a.queued).max().unwrap_or(0);
            assert!(max_queued < r.queued || r.apps.iter().filter(|a| a.queued > 0).count() == 1);
        }
        let r2 = driver.run_zenix(&schedule);
        assert_eq!(r.digest, r2.digest, "fair-share replay deterministic");
    }

    /// The Deadline policy on a saturating schedule: conservation
    /// holds, per-tenant SLOs actually evict (timeouts register), and
    /// the replay is deterministic per seed.
    #[test]
    fn deadline_policy_conserves_and_times_out_deterministically() {
        let apps = standard_mix(6, Archetype::Average);
        let cfg = DriverConfig {
            seed: 17,
            invocations: 240,
            mean_iat_ms: 40.0,
            admission: AdmissionPolicy::Deadline { deadline_ms: 2_000.0, max_depth: 64 },
            ..DriverConfig::default()
        };
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.completed + r.rejected + r.aborted + r.timed_out, 240);
        assert!(r.queued > 0, "saturated run must park arrivals");
        assert!(
            r.timed_out > 0,
            "a 2 s SLO under this overload must evict something"
        );
        let r2 = driver.run_zenix(&schedule);
        assert_eq!(r.digest, r2.digest, "deadline replay deterministic");
        // the fairness indices ride along on every report
        let n = apps.len() as f64;
        assert!(r.jain_completion >= 1.0 / n - 1e-9 && r.jain_completion <= 1.0 + 1e-9);
        assert!(r.jain_goodput >= 1.0 / n - 1e-9 && r.jain_goodput <= 1.0 + 1e-9);
    }

    /// `TenantApp::deadline_ms` overrides the policy default: a tenant
    /// with an (effectively) infinite SLO never times out while the
    /// default-SLO tenants do.
    #[test]
    fn per_tenant_slo_override_shields_a_tenant_from_eviction() {
        let mut apps = standard_mix(6, Archetype::Average);
        apps[0].deadline_ms = Some(f64::INFINITY);
        let cfg = DriverConfig {
            seed: 17,
            invocations: 240,
            mean_iat_ms: 40.0,
            admission: AdmissionPolicy::Deadline { deadline_ms: 2_000.0, max_depth: 64 },
            ..DriverConfig::default()
        };
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.apps[0].timed_out, 0, "infinite SLO must never evict");
        assert!(
            r.apps.iter().skip(1).map(|a| a.timed_out).sum::<usize>() > 0,
            "default-SLO tenants must still time out under this overload"
        );
    }

    #[test]
    fn with_racks_reshards_without_changing_the_schedule() {
        let apps = standard_mix(5, Archetype::Average);
        let base = small_cfg(3, 80);
        let sharded = base.with_racks(4);
        assert_eq!(sharded.cluster.racks, 4);
        assert_eq!(sharded.cluster.total_servers(), base.cluster.total_servers());
        // the schedule is cluster-independent: both configs draw the
        // identical workload
        let a = Schedule::generate(&apps, &base);
        let b = Schedule::generate(&apps, &sharded);
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.app, y.app);
            assert_eq!(x.scale, y.scale);
        }
        // and the sharded replay runs to completion deterministically
        let r1 = MultiTenantDriver::new(&apps, sharded).run_zenix(&a);
        let r2 = MultiTenantDriver::new(&apps, sharded).run_zenix(&a);
        assert_eq!(r1.digest, r2.digest);
        assert_eq!(r1.completed + r1.failed, 80);
        assert!(
            r1.route_fast_hits + r1.route_scans >= 80,
            "every admission attempt routes through the global scheduler: {} + {}",
            r1.route_fast_hits,
            r1.route_scans
        );
    }

    #[test]
    fn mmpp_schedule_is_deterministic_and_burstier() {
        // few apps: the fleet superposition of independent MMPPs keeps
        // a clear burstiness margin over Poisson (it dilutes ~1/apps)
        let apps = standard_mix(3, Archetype::Average);
        let mmpp_cfg = DriverConfig {
            seed: 13,
            invocations: 400,
            mean_iat_ms: 200.0,
            arrivals: ArrivalModel::Mmpp {
                on_mult: 10.0,
                mean_on_ms: 3_000.0,
                mean_off_ms: 12_000.0,
            },
            ..DriverConfig::default()
        };
        let poisson_cfg = DriverConfig { arrivals: ArrivalModel::Poisson, ..mmpp_cfg };
        let m1 = Schedule::generate(&apps, &mmpp_cfg);
        let m2 = Schedule::generate(&apps, &mmpp_cfg);
        assert_eq!(m1.arrivals.len(), 400);
        for (x, y) in m1.arrivals.iter().zip(&m2.arrivals) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.app, y.app);
        }
        let p = Schedule::generate(&apps, &poisson_cfg);
        // same arrival counts per app, different instants
        for a in 0..apps.len() {
            assert_eq!(m1.count_for(a), p.count_for(a));
        }
        let gaps = |s: &Schedule| -> Vec<f64> {
            s.arrivals.windows(2).map(|w| w[1].at - w[0].at).collect()
        };
        let cv = |xs: &[f64]| {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
            v.sqrt() / m
        };
        assert!(
            cv(&gaps(&m1)) > cv(&gaps(&p)),
            "MMPP fleet arrivals must be burstier than Poisson: {} vs {}",
            cv(&gaps(&m1)),
            cv(&gaps(&p))
        );
    }

    #[test]
    fn rate_replay_schedule_avoids_silent_windows() {
        static PATTERN: [f64; 2] = [0.0, 1.0];
        let apps = standard_mix(3, Archetype::Stable);
        let cfg = DriverConfig {
            seed: 3,
            invocations: 90,
            mean_iat_ms: 100.0,
            arrivals: ArrivalModel::RateReplay { pattern: &PATTERN, step_ms: 5_000.0 },
            ..DriverConfig::default()
        };
        let s = Schedule::generate(&apps, &cfg);
        assert_eq!(s.arrivals.len(), 90);
        for arr in &s.arrivals {
            let step = (arr.at / 5_000.0).floor() as u64;
            assert_eq!(step % 2, 1, "arrival at {} fell in a silent window", arr.at);
        }
    }

    // ---- fault injection & crash recovery --------------------------------

    /// The fault RNG stream must not perturb anything at rate zero: a
    /// config with fault injection *configured* but disabled
    /// (`rate_per_min == 0.0`) is digest-identical to the default — the
    /// zero-fault replay pushes no events and draws nothing.
    #[test]
    fn zero_fault_rate_is_digest_identical_to_default() {
        let apps = standard_mix(6, Archetype::Average);
        let base = small_cfg(7, 120);
        let chaos_off = DriverConfig {
            faults: FaultConfig { rate_per_min: 0.0, repair_ms: 999.0, rack_outage: true },
            ..base
        };
        let driver = MultiTenantDriver::new(&apps, base);
        let schedule = driver.schedule();
        let a = driver.run_zenix(&schedule);
        let b = MultiTenantDriver::new(&apps, chaos_off).run_zenix(&schedule);
        assert_eq!(a.digest, b.digest, "zero-rate faults must not perturb the replay");
        assert_eq!(b.faulted, 0);
        assert_eq!(b.recovered, 0);
        assert_eq!(b.faulted_unrecovered, 0);
    }

    /// Under live fault injection the failure split stays a partition
    /// of arrivals (`completed + rejected + aborted + timed_out +
    /// faulted_unrecovered == n`), faults split exactly into recovered
    /// vs unrecovered, and the faulted replay is digest-stable per
    /// seed.
    #[test]
    fn fault_injection_conserves_arrivals_and_is_digest_stable() {
        let apps = standard_mix(6, Archetype::Average);
        let cfg = DriverConfig {
            faults: FaultConfig { rate_per_min: 10.0, repair_ms: 5_000.0, rack_outage: false },
            ..small_cfg(7, 200)
        };
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert!(r.faulted > 0, "10 faults/min over this horizon must hit something");
        assert_eq!(r.faulted, r.recovered + r.faulted_unrecovered);
        assert_eq!(
            r.completed + r.rejected + r.aborted + r.timed_out + r.faulted_unrecovered,
            200,
            "fault accounting must partition arrivals"
        );
        assert!(r.recovered > 0, "graph-cut recovery must complete some faulted work");
        if r.recovered > 0 {
            assert!(r.mean_recovery_ms > 0.0);
            assert!(r.p95_recovery_ms > 0.0);
        }
        // per-app sums equal the fleet counters
        let sum = |f: fn(&AppStats) -> usize| r.apps.iter().map(f).sum::<usize>();
        assert_eq!(sum(|a| a.faulted), r.faulted);
        assert_eq!(sum(|a| a.recovered), r.recovered);
        assert_eq!(sum(|a| a.faulted_unrecovered), r.faulted_unrecovered);
        for a in &r.apps {
            assert_eq!(a.completed + a.failed(), a.scheduled, "{}", a.name);
            assert_eq!(a.faulted, a.recovered + a.faulted_unrecovered, "{}", a.name);
        }
        let r2 = driver.run_zenix(&schedule);
        assert_eq!(r.digest, r2.digest, "faulted replay must be digest-stable");
        assert_eq!(r.faulted, r2.faulted);
        assert_eq!(r.recovered, r2.recovered);
    }

    /// A rack outage is a *correlated* failure: one fault event fans
    /// out over every server in the rack and can strike several
    /// in-flight invocations at once. Scan a few seeds until one run
    /// shows a multi-invocation fan-out; conservation must hold in
    /// every scanned run.
    #[test]
    fn rack_outage_fans_out_over_multiple_invocations() {
        let apps = standard_mix(6, Archetype::Average);
        let mut saw_fanout = false;
        for seed in 0..12u64 {
            let cfg = DriverConfig {
                faults: FaultConfig {
                    rate_per_min: 12.0,
                    repair_ms: 4_000.0,
                    rack_outage: true,
                },
                ..small_cfg(seed, 150)
            }
            .with_racks(2);
            let driver = MultiTenantDriver::new(&apps, cfg);
            let schedule = driver.schedule();
            let r = driver.run_zenix(&schedule);
            assert_eq!(
                r.completed + r.rejected + r.aborted + r.timed_out + r.faulted_unrecovered,
                150,
                "seed {seed}: conservation under rack outages"
            );
            assert_eq!(r.faulted, r.recovered + r.faulted_unrecovered, "seed {seed}");
            if r.faulted >= 2 {
                saw_fanout = true;
                break;
            }
        }
        assert!(
            saw_fanout,
            "no scanned seed produced a multi-invocation rack-outage fan-out"
        );
    }
}
