//! Reliable message log (§5.3.2) — the Kafka substitute.
//!
//! Every compute component's result is appended here via "reliable
//! messaging"; recovery replays from the latest resource-graph cut whose
//! crossing edges are all persisted. Only the durability/replay
//! semantics matter for the reproduction, so this is an append-only log
//! with an explicit persistence watermark (messages below the watermark
//! survive failures; above it they are lost with the crash).

/// One logged component-completion message.
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    /// Invocation this entry belongs to.
    pub invocation: u64,
    /// Compute index that completed.
    pub compute: usize,
    /// Opaque result payload size (MB) — replayed as stage input.
    pub result_mb: f64,
}

/// Append-only reliable log with a persistence watermark.
#[derive(Debug, Default)]
pub struct MessageLog {
    entries: Vec<LogEntry>,
    /// Entries `< persisted` are durable.
    persisted: usize,
}

impl MessageLog {
    /// Fresh empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a completion message; returns its sequence number.
    /// Messages are durable once [`flush`](Self::flush) passes them.
    pub fn append(&mut self, entry: LogEntry) -> usize {
        self.entries.push(entry);
        self.entries.len() - 1
    }

    /// Persist everything appended so far (the paper's reliable-message
    /// send is synchronous; tests use partial flushes to model loss).
    pub fn flush(&mut self) {
        self.persisted = self.entries.len();
    }

    /// Persist only up to `seq` (exclusive) — for failure injection.
    pub fn flush_to(&mut self, seq: usize) {
        self.persisted = seq.min(self.entries.len());
    }

    /// Durable entries (what recovery can replay).
    pub fn durable(&self) -> &[LogEntry] {
        &self.entries[..self.persisted]
    }

    /// Simulate a crash: lose everything past the watermark.
    pub fn crash(&mut self) {
        self.entries.truncate(self.persisted);
    }

    /// Compact away every entry of a completed invocation (its graph-cut
    /// recovery window is over). Keeps the log's memory proportional to
    /// the *in-flight* invocations instead of the whole run — at 100k+
    /// driver arrivals an ever-growing log dominates heap otherwise.
    /// O(live entries); preserves order and the persistence watermark of
    /// the surviving entries. In-place: no allocation.
    pub fn retire(&mut self, invocation: u64) {
        let persisted = self.persisted;
        let mut idx = 0usize;
        let mut kept_below = 0usize;
        self.entries.retain(|e| {
            let keep = e.invocation != invocation;
            if keep && idx < persisted {
                kept_below += 1;
            }
            idx += 1;
            keep
        });
        self.persisted = kept_below;
    }

    /// Completed computes for `invocation` that are durably recorded.
    pub fn durable_computes(&self, invocation: u64) -> Vec<usize> {
        self.durable()
            .iter()
            .filter(|e| e.invocation == invocation)
            .map(|e| e.compute)
            .collect()
    }

    /// Total entries (durable prefix + unflushed tail).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the log holds no entries at all.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(c: usize) -> LogEntry {
        LogEntry { invocation: 1, compute: c, result_mb: 10.0 }
    }

    #[test]
    fn append_flush_durable() {
        let mut log = MessageLog::new();
        log.append(entry(0));
        log.append(entry(1));
        assert!(log.durable().is_empty());
        log.flush();
        assert_eq!(log.durable().len(), 2);
        log.append(entry(2));
        assert_eq!(log.durable().len(), 2);
    }

    #[test]
    fn crash_loses_unpersisted_tail() {
        let mut log = MessageLog::new();
        log.append(entry(0));
        log.flush();
        log.append(entry(1));
        log.append(entry(2));
        log.crash();
        assert_eq!(log.len(), 1);
        assert_eq!(log.durable_computes(1), vec![0]);
    }

    #[test]
    fn partial_flush_watermark() {
        let mut log = MessageLog::new();
        for c in 0..5 {
            log.append(entry(c));
        }
        log.flush_to(3);
        log.crash();
        assert_eq!(log.durable_computes(1), vec![0, 1, 2]);
    }

    #[test]
    fn retire_drops_only_one_invocation_and_keeps_watermark() {
        let mut log = MessageLog::new();
        log.append(LogEntry { invocation: 1, compute: 0, result_mb: 1.0 });
        log.append(LogEntry { invocation: 2, compute: 1, result_mb: 1.0 });
        log.append(LogEntry { invocation: 1, compute: 2, result_mb: 1.0 });
        log.flush();
        log.append(LogEntry { invocation: 2, compute: 3, result_mb: 1.0 });
        log.retire(1);
        assert_eq!(log.len(), 2);
        // invocation 2's durable prefix survives; its unflushed tail is
        // still above the watermark
        assert_eq!(log.durable_computes(2), vec![1]);
        log.flush();
        assert_eq!(log.durable_computes(2), vec![1, 3]);
        assert!(log.durable_computes(1).is_empty());
    }

    #[test]
    fn filters_by_invocation() {
        let mut log = MessageLog::new();
        log.append(LogEntry { invocation: 1, compute: 0, result_mb: 1.0 });
        log.append(LogEntry { invocation: 2, compute: 5, result_mb: 1.0 });
        log.flush();
        assert_eq!(log.durable_computes(1), vec![0]);
        assert_eq!(log.durable_computes(2), vec![5]);
    }
}
