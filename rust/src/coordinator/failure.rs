//! Failure handling via resource-graph cuts (§5.3.2).
//!
//! On a compute crash: discard the crashed component and all data
//! components it accesses; on a data-region crash: discard all compute
//! components accessing that data component and the component's sibling
//! regions. Then find the latest cut of the resource graph where every
//! crossing edge is durably recorded in the message log, and re-execute
//! everything past the cut from the logged inputs — *at-least-once*
//! semantics, without re-running the whole bulky application.

use std::collections::BTreeSet;

use super::graph::ResourceGraph;
use super::msglog::MessageLog;

/// What crashed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Crash {
    /// A compute component (by compute index).
    Compute(usize),
    /// A memory region of a data component (by data index).
    DataRegion(usize),
}

/// The recovery plan: what to discard and what to re-execute.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryPlan {
    /// Data components whose regions are discarded.
    pub discard_data: BTreeSet<usize>,
    /// Compute components to re-execute (in topo order).
    pub reexecute: Vec<usize>,
}

/// Build the recovery plan for `crash` of `invocation`.
///
/// `log` supplies the durably-completed computes; everything else that
/// is affected (directly or transitively through trigger edges) must
/// re-run. A durably-completed compute only re-runs if it accesses
/// discarded data *and* a discarded-downstream component needs its
/// output regenerated — with at-least-once semantics we conservatively
/// re-run any accessor of discarded data whose results are not durable,
/// plus the full downstream closure of the crash.
pub fn plan(
    graph: &ResourceGraph,
    log: &MessageLog,
    invocation: u64,
    crash: Crash,
) -> RecoveryPlan {
    let durable: BTreeSet<usize> = log.durable_computes(invocation).into_iter().collect();

    // Seed: crashed computes + discarded data.
    let mut discard_data: BTreeSet<usize> = BTreeSet::new();
    let mut dirty: BTreeSet<usize> = BTreeSet::new();
    match crash {
        Crash::Compute(c) => {
            dirty.insert(c);
            // discard all data the crashed component accesses
            for d in graph.accessed_data(c) {
                discard_data.insert(d);
            }
        }
        Crash::DataRegion(d) => {
            // sibling regions of the same data component go too
            discard_data.insert(d);
            for c in graph.accessors_of(d) {
                dirty.insert(c);
            }
        }
    }

    // Any live accessor of discarded data is dirty (its reads are gone).
    loop {
        let before = (dirty.len(), discard_data.len());
        for &d in discard_data.clone().iter() {
            for c in graph.accessors_of(d) {
                // Durable results survive: a completed accessor's output
                // is in the log, so it need not re-run *unless* it is
                // downstream of another dirty node (handled below).
                if !durable.contains(&c) {
                    dirty.insert(c);
                }
            }
        }
        // Dirty computes invalidate the data they write/access.
        for &c in dirty.clone().iter() {
            for d in graph.accessed_data(c) {
                discard_data.insert(d);
            }
        }
        // Downstream closure over trigger edges: a dirty node's
        // successors consume a re-generated output → they re-run
        // (at-least-once), unless their input edge is durably logged.
        for &c in dirty.clone().iter() {
            for s in graph.successors(c) {
                if !durable.contains(&s) {
                    dirty.insert(s);
                }
            }
        }
        if (dirty.len(), discard_data.len()) == before {
            break;
        }
    }

    // Re-execution set in wave order (a topological order that the
    // engine's wave rewind can follow directly).
    let mut reexecute: Vec<usize> = dirty.into_iter().collect();
    reexecute.sort_by_key(|&c| (graph.wave[c], c));
    RecoveryPlan { discard_data, reexecute }
}

/// The latest graph cut: computes whose results are durable and which
/// the plan does not re-execute — execution resumes after them.
pub fn resume_frontier(
    graph: &ResourceGraph,
    log: &MessageLog,
    invocation: u64,
    plan: &RecoveryPlan,
) -> Vec<usize> {
    let durable: BTreeSet<usize> = log.durable_computes(invocation).into_iter().collect();
    (0..graph.n_compute())
        .filter(|c| durable.contains(c) && !plan.reexecute.contains(c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lr;
    use crate::coordinator::msglog::LogEntry;

    fn graph() -> ResourceGraph {
        // load(0) -> split(1) -> train(2) -> validate(3)
        // data: train_set(0) r/w by 0,1,2; val_set(1) by 1,3; weights(2) by 2,3
        ResourceGraph::from_program(&lr::program()).unwrap()
    }

    fn log_with(computes: &[usize]) -> MessageLog {
        let mut log = MessageLog::new();
        for &c in computes {
            log.append(LogEntry { invocation: 1, compute: c, result_mb: 1.0 });
        }
        log.flush();
        log
    }

    #[test]
    fn crash_late_component_reexecutes_suffix_only() {
        let g = graph();
        let log = log_with(&[0, 1]);
        let p = plan(&g, &log, 1, Crash::Compute(2));
        // train crashed: re-run train + validate, NOT load/split
        assert_eq!(p.reexecute, vec![2, 3]);
        assert!(!p.reexecute.contains(&0));
        let frontier = resume_frontier(&g, &log, 1, &p);
        assert_eq!(frontier, vec![0, 1]);
    }

    #[test]
    fn data_region_crash_discards_siblings_and_accessors() {
        let g = graph();
        let log = log_with(&[0]);
        // weights (data 2) crashes: train + validate re-run
        let p = plan(&g, &log, 1, Crash::DataRegion(2));
        assert!(p.discard_data.contains(&2));
        assert!(p.reexecute.contains(&2) && p.reexecute.contains(&3));
        assert!(!p.reexecute.contains(&0), "durable load survives");
    }

    #[test]
    fn nothing_durable_means_full_restart() {
        let g = graph();
        let log = MessageLog::new();
        let p = plan(&g, &log, 1, Crash::Compute(0));
        assert_eq!(p.reexecute, vec![0, 1, 2, 3]);
        assert!(resume_frontier(&g, &log, 1, &p).is_empty());
    }

    #[test]
    fn reexecute_is_topologically_ordered() {
        let g = ResourceGraph::from_program(&crate::apps::video::pipeline()).unwrap();
        let log = MessageLog::new();
        let p = plan(&g, &log, 1, Crash::Compute(0));
        // positions must respect wave order
        for w in p.reexecute.windows(2) {
            assert!(g.wave[w[0]] <= g.wave[w[1]]);
        }
    }

    #[test]
    fn unrelated_branch_not_reexecuted() {
        let g = ResourceGraph::from_program(&crate::apps::video::pipeline()).unwrap();
        // All decodes durable; one encode (compute 2+16..) crashes.
        let durable: Vec<usize> = (0..2 + crate::apps::video::UNITS).collect();
        let log = log_with(&durable);
        let crash_enc = 2 + crate::apps::video::UNITS; // first encode
        let p = plan(&g, &log, 1, Crash::Compute(crash_enc));
        // sibling encodes are NOT durable here, but they are not affected
        // either (disjoint data) — except through merge downstream.
        assert!(p.reexecute.contains(&crash_enc));
        // decodes stay durable / not re-executed
        for d in 2..2 + crate::apps::video::UNITS {
            assert!(!p.reexecute.contains(&d), "decode {d} should survive");
        }
    }
}
