//! The init/incremental sizing optimizer (§5.2.3 + §9.3).
//!
//! For each component, pick `(init, step)` minimizing
//!
//! ```text
//!   init + Σ_h  step · k_h · cost_factor          (expected alloc cost)
//!   s.t.  ∀h:  init + k_h · step ≥ h              (coverage)
//!         Σ_h max(init − h, 0) · t_h / Σ_h h · t_h  <  Thres   (waste bound)
//! ```
//!
//! where `k_h = ⌈(h − init)⁺ / step⌉` is the number of runtime growths
//! invocation `h` needs. The paper solves this as a MIP with OR-Tools;
//! the domain is tiny (two variables over value grids derived from the
//! history), so an exact search over the candidate grid is equivalent
//! and fast — the appendix reports 10-15 ms for 10 000 candidate sets of
//! 32 components, which `benches/scheduler.rs tab_solver_perf`
//! reproduces.

/// Solver parameters.
#[derive(Debug, Clone, Copy)]
pub struct AdjustParams {
    /// Relative cost of one increment allocation vs initial allocation
    /// (growths happen at runtime: scheduling + possible remote region).
    pub cost_factor: f64,
    /// Waste-bound threshold (fraction of total demand).
    pub threshold: f64,
    /// Candidate grid resolution per axis.
    pub grid: usize,
}

impl Default for AdjustParams {
    fn default() -> Self {
        Self { cost_factor: 1.6, threshold: 0.30, grid: 24 }
    }
}

/// Solver output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sizing {
    pub init_mb: f64,
    pub step_mb: f64,
    /// Objective value at the optimum.
    pub cost: f64,
}

/// Number of growth increments history point `h` requires.
#[inline]
pub fn growths(init: f64, step: f64, h: f64) -> f64 {
    if h <= init {
        0.0
    } else {
        ((h - init) / step).ceil()
    }
}

/// Exact objective for a candidate `(init, step)`.
fn objective(init: f64, step: f64, history: &[f64], cost_factor: f64) -> f64 {
    let growth_cost: f64 = history.iter().map(|&h| growths(init, step, h) * step).sum::<f64>()
        / history.len() as f64;
    init + growth_cost * cost_factor
}

/// Waste constraint (the module-header formulation): over-allocation as
/// a fraction of demand, both sides weighted by execution time —
///
/// ```text
///   waste(init) = Σ_h max(init − h, 0) · t_h  /  Σ_h h · t_h
/// ```
///
/// so over-provisioning a *long-running* invocation costs
/// proportionally more. `exec_ms[i]` defaults to 1.0 (uniform) when not
/// supplied, which reduces to Σ (init − h)⁺ / Σ h. (The code previously
/// divided by `Σ h · t̄`, which disagrees with itself whenever exec
/// time correlates with demand; the time-weighted demand integral is
/// the dimensionally consistent reading of the doc comment.)
fn waste(init: f64, history: &[f64], exec_ms: Option<&[f64]>) -> f64 {
    let mut over = 0.0f64;
    let mut demand = 0.0f64;
    for (i, &h) in history.iter().enumerate() {
        let t = exec_ms.map_or(1.0, |t| t[i]);
        over += (init - h).max(0.0) * t;
        demand += h * t;
    }
    if demand <= 0.0 {
        0.0
    } else {
        over / demand
    }
}

/// Solve for one component given its usage history (peak MB per past
/// invocation) and optional execution times.
pub fn solve(history: &[f64], exec_ms: Option<&[f64]>, params: AdjustParams) -> Sizing {
    assert!(!history.is_empty(), "adjust::solve needs at least one observation");
    let lo = history.iter().cloned().fold(f64::MAX, f64::min);
    let hi = history.iter().cloned().fold(0.0, f64::max);
    let hi = hi.max(1.0);
    let lo = lo.min(hi);

    // Candidate grids: inits span [lo/2, hi]; steps span a useful range
    // of the spread (min 16 MB granularity — page/slab rounding).
    let g = params.grid.max(2);
    let mut best = Sizing { init_mb: hi, step_mb: (hi / 4.0).max(16.0), cost: f64::MAX };
    for i in 0..g {
        let init = lo * 0.5 + (hi - lo * 0.5) * i as f64 / (g - 1) as f64;
        if waste(init, history, exec_ms) >= params.threshold {
            continue;
        }
        for s in 0..g {
            let step = 16.0 + (hi - lo * 0.5).max(16.0) * s as f64 / (g - 1) as f64;
            let cost = objective(init, step, history, params.cost_factor);
            if cost < best.cost {
                best = Sizing { init_mb: init, step_mb: step, cost };
            }
        }
    }
    if best.cost == f64::MAX {
        // Waste bound unsatisfiable (e.g. huge variance): fall back to
        // covering the minimum and growing — the least-waste choice.
        let step = ((hi - lo) / 4.0).max(16.0);
        best = Sizing {
            init_mb: lo,
            step_mb: step,
            cost: objective(lo, step, history, params.cost_factor),
        };
    }
    best
}

/// Solve a whole candidate set (one entry per component). This is the
/// batched call the appendix benchmarks (10 000 candidates × 32
/// components in 10-15 ms).
pub fn solve_batch(histories: &[Vec<f64>], params: AdjustParams) -> Vec<Sizing> {
    histories.iter().map(|h| solve(h, None, params)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_history_converges_to_peak() {
        // identical invocations: best init covers them, zero growths
        let history = vec![400.0; 50];
        let s = solve(&history, None, AdjustParams::default());
        assert!(s.init_mb >= 400.0 * 0.99, "{s:?}");
        assert!((s.cost - s.init_mb).abs() < 1.0, "no growth cost expected");
    }

    #[test]
    fn small_usage_gets_small_init() {
        let history = vec![64.0, 70.0, 60.0, 66.0, 68.0];
        let s = solve(&history, None, AdjustParams::default());
        assert!(s.init_mb <= 80.0, "{s:?}");
    }

    #[test]
    fn varying_history_balances_init_and_growth() {
        // bimodal: many small, few huge — init should NOT provision peak
        let mut history = vec![100.0; 90];
        history.extend(vec![4000.0; 10]);
        let s = solve(&history, None, AdjustParams::default());
        assert!(s.init_mb < 2000.0, "peak-provisioning wastes: {s:?}");
        assert!(s.step_mb >= 16.0);
        // coverage always holds by construction
        for &h in &history {
            assert!(s.init_mb + growths(s.init_mb, s.step_mb, h) * s.step_mb >= h - 1e-9);
        }
    }

    #[test]
    fn optimum_beats_naive_choices() {
        let mut history = vec![150.0; 70];
        history.extend(vec![1200.0; 30]);
        let p = AdjustParams::default();
        let s = solve(&history, None, p);
        let naive_peak = objective(1200.0, 64.0, &history, p.cost_factor);
        let naive_min = objective(150.0, 64.0, &history, p.cost_factor);
        assert!(s.cost <= naive_peak + 1e-9);
        assert!(s.cost <= naive_min + 1e-9);
    }

    #[test]
    fn growths_formula() {
        assert_eq!(growths(100.0, 50.0, 80.0), 0.0);
        assert_eq!(growths(100.0, 50.0, 100.0), 0.0);
        assert_eq!(growths(100.0, 50.0, 101.0), 1.0);
        assert_eq!(growths(100.0, 50.0, 250.0), 3.0);
    }

    #[test]
    fn waste_constraint_excludes_fat_inits() {
        // mostly tiny invocations: provisioning the rare peak violates
        // the waste bound, so init stays small.
        let mut history = vec![32.0; 95];
        history.extend(vec![2048.0; 5]);
        let s = solve(&history, None, AdjustParams { threshold: 0.2, ..Default::default() });
        assert!(s.init_mb < 512.0, "{s:?}");
    }

    /// Satellite-4 regression: pins the reconciled waste semantics on a
    /// non-uniform `exec_ms` — time-weighted over-allocation over
    /// time-weighted demand.
    #[test]
    fn waste_is_exec_time_weighted_fraction() {
        let history = [100.0, 300.0];
        let t = [3000.0, 1000.0];
        // over  = (200−100)·3000 + 0·1000          = 300 000
        // demand = 100·3000 + 300·1000             = 600 000
        let w = waste(200.0, &history, Some(&t));
        assert!((w - 0.5).abs() < 1e-12, "{w}");
        // uniform weights reduce to Σ(init−h)⁺ / Σh
        let u = waste(200.0, &history, None);
        assert!((u - 100.0 / 400.0).abs() < 1e-12, "{u}");
        // never negative, zero when init covers nothing
        assert_eq!(waste(50.0, &history, Some(&t)), 0.0);
    }

    #[test]
    fn exec_time_weighting_matters() {
        // over-allocation on long-running invocations is worse
        let history = vec![100.0, 1000.0];
        let long_small = vec![100.0, 1.0]; // the small invocation runs long
        let s1 = solve(&history, Some(&long_small), AdjustParams::default());
        let s2 = solve(&history, None, AdjustParams::default());
        assert!(s1.init_mb <= s2.init_mb + 1e-9);
    }

    #[test]
    fn batch_solves_all() {
        let histories: Vec<Vec<f64>> = (0..32)
            .map(|i| (0..20).map(|j| 100.0 + (i * j) as f64).collect())
            .collect();
        let out = solve_batch(&histories, AdjustParams::default());
        assert_eq!(out.len(), 32);
        assert!(out.iter().all(|s| s.init_mb > 0.0 && s.step_mb >= 16.0));
    }
}
