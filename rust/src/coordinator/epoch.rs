//! Sharded epoch-barrier event loop: deterministic parallel replay.
//!
//! The sequential driver loop ([`super::driver`]) replays 1M+
//! invocation traces one event at a time. Most of those events are
//! *rack-local*: a wave placed entirely inside one rack only ever
//! touches that rack's servers through its allocation timeline. This
//! module exploits that structure to replay rack-local timelines in
//! parallel — without giving up a single bit of the pinned digest:
//!
//! - **Shards are racks, not threads.** The trace is partitioned into
//!   one logical shard per rack plus a *global* residue (waves whose
//!   placement spans racks, which are never split). The partition —
//!   and therefore every intermediate float and the final digest — is
//!   a function of the workload alone, so `workers = n` is
//!   digest-identical to `workers = 1` for every `n` by construction;
//!   the thread count only decides how many shard batches run
//!   concurrently.
//! - **Bounded epochs.** The coordinator computes a *fence*: the
//!   `(time, seq)` of the next cross-shard item (arrival, global
//!   event) clipped to at most [`super::driver::DriverConfig::epoch_ms`]
//!   of simulated time. Every shard independently drains its local
//!   event heap strictly up to the fence (phase A), mutating only its
//!   own rack's servers — disjoint state, no locks on the hot path.
//! - **Deterministic barrier.** Shard workers snapshot every
//!   availability mutation as an [`AllocNote`] keyed by the event's
//!   global `(time, seq)`. At the barrier the coordinator k-way-merges
//!   the per-shard note runs in canonical `(time, seq)` order and
//!   replays them through
//!   [`crate::cluster::Cluster::replay_index_update`] — the placement
//!   index and the dirty-rack feed observe the *exact* mutation
//!   sequence the sequential loop would have produced, signed float
//!   deltas and all. Then the fence item itself (admission routing,
//!   wave completion, fault/repair, cross-rack timeline) runs on the
//!   coordinator with the full cluster hooks (phase C).
//! - **Serialized admission.** While a deferred queue is occupied the
//!   sequential loop probes admission after *every* event, so batching
//!   would reorder decisions. The loop detects this and falls back to
//!   exact one-event-at-a-time replay (still across the sharded
//!   heaps, still in global `(time, seq)` order) until the queues
//!   drain — legacy semantics by literal re-execution, not by
//!   argument.
//!
//! Worker threads are engaged per batch through a [`std::thread::scope`]
//! over a shrinking [`Mutex`]-guarded job queue, and only when at
//! least two shards have enough pending work to amortize the dispatch;
//! small batches run inline on the coordinator thread. In steady state
//! the shard contexts (heaps, slabs, note buffers) recycle their
//! capacity, so the parallel loop stays allocation-free per event just
//! like the sequential one (`rust/tests/alloc_free.rs` phase 5 pins
//! it); the only engaged-batch allocation is the job vector of `S`
//! fat pointers.
//!
//! Ordering argument, in one place: every event carries the globally
//! unique `seq` it would have carried in the sequential loop (the
//! routing only chooses *which heap* holds it). A wave's timeline
//! events all land on one shard (or all on the coordinator), so
//! per-server mutations replay in exactly the sequential `(time,
//! seq)` order; its `WaveDone` is always a global event whose `(time,
//! seq)` sorts after them, so slots are never freed with shard events
//! outstanding; and the barrier replays index updates in the same
//! total order before any coordinator-side decision reads the index.
//! Completions reach the [`super::driver::Aggregator`] in canonical
//! `WaveDone` order, so the per-app ordered sums — and the digest
//! folded from them — are bit-identical.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Mutex;

use crate::apps::Invocation;
use crate::cluster::clock::Millis;
use crate::cluster::server::Server;
use crate::cluster::{RackId, Resources, ServerId, StartupTier};
use crate::metrics::fairness::JainAccumulator;
use crate::metrics::streaming::{P2Quantile, StreamingMoments};

use super::admission::{AdmissionPolicy, DeferredQueues};
use super::driver::{
    crash_scan, prewarm_order, Aggregator, Arrival, BitMask, DriverReport, MultiTenantDriver,
    Schedule, Slab, TenantApp, TierTelemetry, PREWARM_TOP_K,
};
use super::exec::{apply_timeline_on, AllocSink, OngoingInvocation, TimelineEv};
use super::faults::{FaultKind, FaultPlan};
use super::workflow::{StageLaunch, WorkflowRuntime};
use super::{Platform, ZenixConfig};

/// Sentinel shard index for the global (cross-rack) slab.
const GLOBAL: usize = usize::MAX;

/// Minimum pending shard events before a batch engages the worker
/// pool; below it the dispatch overhead dwarfs the work and the batch
/// runs inline on the coordinator thread.
const PAR_THRESHOLD: usize = 64;

/// Which slab an in-flight invocation lives in: one of the per-shard
/// slabs (`shard < shards`) or the global slab (`shard == GLOBAL`).
/// Fixed at admission for the invocation's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SlabRef {
    shard: usize,
    idx: usize,
}

/// Coordinator-side event: cross-shard effects, wave completions and
/// the fault schedule. Ordered exactly like the sequential loop's
/// heap: earliest time first, then insertion sequence.
enum GKind {
    /// Timeline event of a cross-rack (or global-slab) wave, applied
    /// with the full cluster hooks at the fence.
    Timeline { slot: SlabRef, server: ServerId, ev: TimelineEv },
    /// The in-flight wave of `slot` completes (always coordinator-side:
    /// wave transitions route, spill and re-place across racks).
    WaveDone { slot: SlabRef },
    /// Scheduled fault/repair event `idx` of the run's [`FaultPlan`].
    Fault { idx: usize },
    /// A workflow downstream stage becomes launchable (always
    /// coordinator-side: stage admission routes, allocates and spawns
    /// across racks, exactly like the fence events above — so every
    /// worker count observes the identical launch order and the digest
    /// stays worker-count invariant).
    StageLaunch { run: u32, stage: u32 },
}

struct GEv {
    at: Millis,
    seq: u64,
    kind: GKind,
}

impl PartialEq for GEv {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for GEv {}
impl PartialOrd for GEv {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for GEv {
    /// Reversed (min-heap), mirroring the sequential loop's ordering.
    fn cmp(&self, other: &Self) -> Ordering {
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Shard-local event: one timeline step of a rack-resident wave. The
/// `seq` is the *global* sequence the event would have carried in the
/// sequential loop — sharding never renumbers.
struct SEv {
    at: Millis,
    seq: u64,
    idx: usize,
    server: ServerId,
    ev: TimelineEv,
}

impl PartialEq for SEv {
    fn eq(&self, other: &Self) -> bool {
        self.at.total_cmp(&other.at) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for SEv {}
impl PartialOrd for SEv {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for SEv {
    /// Reversed (min-heap), mirroring the sequential loop's ordering.
    fn cmp(&self, other: &Self) -> Ordering {
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One availability mutation, snapshotted by a shard worker right
/// after it landed on the server. Replayed through
/// [`crate::cluster::Cluster::replay_index_update`] at the barrier in
/// `(at, seq)` order — feeding the *snapshot* (not the server's final
/// state) keeps the index's signed float deltas accumulating in the
/// exact sequential hook order. At most one note per event (`Grow`
/// notes its alloc, `Finish` its free), so `(at, seq)` is unique.
#[derive(Debug, Clone, Copy)]
struct AllocNote {
    at: Millis,
    seq: u64,
    server: ServerId,
    avail: Resources,
    unmarked: Resources,
    marked: bool,
}

/// Per-shard worker state. Persists across epochs so heaps, slabs and
/// note buffers reuse their capacity — no steady-state allocation.
struct ShardCtx {
    heap: BinaryHeap<SEv>,
    slab: Slab,
    notes: Vec<AllocNote>,
    /// Latest event time this shard has applied (merged into the
    /// global clock at each barrier; max is order-insensitive).
    end_time: Millis,
    local_events: u64,
    batch_moments: StreamingMoments,
    batch_p95: P2Quantile,
}

impl ShardCtx {
    fn new() -> Self {
        Self {
            heap: BinaryHeap::with_capacity(64),
            slab: Slab::new(),
            notes: Vec::with_capacity(64),
            end_time: 0.0,
            local_events: 0,
            batch_moments: StreamingMoments::new(),
            batch_p95: P2Quantile::new(0.95),
        }
    }
}

/// A shard worker's window onto the cluster: direct mutable access to
/// its own rack's server slice, recording an [`AllocNote`] per
/// availability mutation in place of the sequential loop's immediate
/// index update. Indexing is `id - base`, so an event routed to the
/// wrong shard panics instead of corrupting a neighbor — the routing
/// invariant is load-bearing and this enforces it.
struct ShardView<'a> {
    servers: &'a mut [Server],
    base: usize,
    notes: &'a mut Vec<AllocNote>,
    at: Millis,
    seq: u64,
}

impl ShardView<'_> {
    /// Snapshot `id`'s availability after a mutation — exactly the
    /// fields [`crate::cluster::Cluster::replay_index_update`] consumes.
    fn note(&mut self, id: ServerId) {
        let s = &self.servers[id.0 - self.base];
        let (avail, unmarked, marked) =
            (s.available(), s.available_unmarked(), s.marked() != Resources::ZERO);
        self.notes.push(AllocNote {
            at: self.at,
            seq: self.seq,
            server: id,
            avail,
            unmarked,
            marked,
        });
    }
}

impl AllocSink for ShardView<'_> {
    fn try_alloc(&mut self, id: ServerId, amount: Resources, now: Millis) -> bool {
        if !self.servers[id.0 - self.base].try_alloc(amount, now) {
            return false;
        }
        self.note(id);
        true
    }
    fn add_used(&mut self, id: ServerId, delta: Resources, now: Millis) {
        // accounting only — the sequential hook has no index effect
        // either, so no note
        self.servers[id.0 - self.base].add_used(delta, now);
    }
    fn sub_used(&mut self, id: ServerId, delta: Resources, now: Millis) {
        self.servers[id.0 - self.base].sub_used(delta, now);
    }
    fn free(&mut self, id: ServerId, amount: Resources, now: Millis) {
        self.servers[id.0 - self.base].free(amount, now);
        self.note(id);
    }
}

/// A shard batch handed to the worker pool: disjoint `&mut` borrows of
/// one shard's context and its rack's server slice.
struct Job<'a> {
    ctx: &'a mut ShardCtx,
    servers: &'a mut [Server],
    base: usize,
}

/// `(at, seq) < fence` in the loop's canonical event order.
fn before(at: Millis, seq: u64, fence: (Millis, u64)) -> bool {
    match at.total_cmp(&fence.0) {
        Ordering::Less => true,
        Ordering::Equal => seq < fence.1,
        Ordering::Greater => false,
    }
}

/// Phase A for one shard: pop and apply every local event strictly
/// before the fence, in `(at, seq)` order, against the shard's own
/// rack slice. Runs on a worker thread (engaged batches) or inline.
fn run_shard_batch(ctx: &mut ShardCtx, servers: &mut [Server], base: usize, fence: (Millis, u64)) {
    let mut n = 0u64;
    while ctx.heap.peek().map_or(false, |t| before(t.at, t.seq, fence)) {
        let ev = ctx.heap.pop().expect("peeked above");
        ctx.end_time = ctx.end_time.max(ev.at);
        if let Some(st) = ctx.slab.state_mut(ev.idx) {
            let mut view = ShardView {
                servers: &mut *servers,
                base,
                notes: &mut ctx.notes,
                at: ev.at,
                seq: ev.seq,
            };
            apply_timeline_on(&mut view, st, ev.server, ev.ev, ev.at);
        }
        n += 1;
    }
    ctx.local_events += n;
    ctx.batch_moments.push(n as f64);
    ctx.batch_p95.push(n as f64);
}

/// The rack every pending event of the freshly started wave lands on,
/// if they all land on one (and it is a real rack). `None` for empty,
/// mixed-rack or out-of-range placements — those waves stay on the
/// coordinator so their per-server mutation order is trivially
/// sequential.
fn wave_home(
    pending: &[(Millis, u32, ServerId, TimelineEv)],
    spr: usize,
    shards: usize,
) -> Option<usize> {
    let mut home: Option<usize> = None;
    for (_, _, server, _) in pending {
        let r = server.0 / spr;
        if r >= shards {
            return None;
        }
        match home {
            None => home = Some(r),
            Some(h) if h == r => {}
            Some(_) => return None,
        }
    }
    home
}

fn slot_meta(ctxs: &[ShardCtx], gslab: &Slab, slot: SlabRef) -> Option<(usize, usize)> {
    if slot.shard == GLOBAL {
        gslab.meta(slot.idx)
    } else {
        ctxs[slot.shard].slab.meta(slot.idx)
    }
}

fn slot_state_mut<'s>(
    ctxs: &'s mut [ShardCtx],
    gslab: &'s mut Slab,
    slot: SlabRef,
) -> Option<&'s mut OngoingInvocation> {
    if slot.shard == GLOBAL {
        gslab.state_mut(slot.idx)
    } else {
        ctxs[slot.shard].slab.state_mut(slot.idx)
    }
}

fn slot_take(
    ctxs: &mut [ShardCtx],
    gslab: &mut Slab,
    slot: SlabRef,
) -> Option<(usize, usize, OngoingInvocation)> {
    if slot.shard == GLOBAL {
        gslab.take(slot.idx)
    } else {
        ctxs[slot.shard].slab.take(slot.idx)
    }
}

fn slot_set_wf(ctxs: &mut [ShardCtx], gslab: &mut Slab, slot: SlabRef, run: u32, stage: u32) {
    if slot.shard == GLOBAL {
        gslab.set_wf(slot.idx, run, stage);
    } else {
        ctxs[slot.shard].slab.set_wf(slot.idx, run, stage);
    }
}

fn slot_wf_meta(ctxs: &[ShardCtx], gslab: &Slab, slot: SlabRef) -> Option<(u32, u32)> {
    if slot.shard == GLOBAL {
        gslab.wf_meta(slot.idx)
    } else {
        ctxs[slot.shard].slab.wf_meta(slot.idx)
    }
}

/// The whole mutable state of one sharded replay. One instance per
/// [`run_platform_sharded`] call; methods are the loop's phases.
struct Engine<'a, 'b> {
    apps: &'a [TenantApp],
    schedule: &'b Schedule,
    platform: Platform,
    gheap: BinaryHeap<GEv>,
    seq: u64,
    gslab: Slab,
    ctxs: Vec<ShardCtx>,
    /// Phase-B merge cursors, one per shard (persist to avoid a
    /// per-barrier allocation).
    cursors: Vec<usize>,
    agg: Aggregator<'a>,
    completed_mask: BitMask,
    rejected_per_app: Vec<usize>,
    aborted_per_app: Vec<usize>,
    queues: DeferredQueues,
    queueing: bool,
    in_flight: usize,
    max_in_flight: usize,
    end_time: Millis,
    next_arrival: usize,
    fault_plan: FaultPlan,
    spr: usize,
    workers: usize,
    epoch_ms: f64,
    faulted_per_app: Vec<usize>,
    recovered_per_app: Vec<usize>,
    faulted_unrec_per_app: Vec<usize>,
    recovery_moments: StreamingMoments,
    recovery_p95: P2Quantile,
    tiers: TierTelemetry,
    epochs: u64,
    engaged_batches: u64,
    /// Workflow runtime — all bookkeeping happens at coordinator-side
    /// instants (`WaveDone`, `StageLaunch`), so the sharded replay
    /// observes the sequential loop's exact launch order.
    wfrt: WorkflowRuntime,
    workflow_affinity: bool,
    spawned_per_app: Vec<usize>,
    stage_buf: Vec<StageLaunch>,
}

impl<'a, 'b> Engine<'a, 'b> {
    /// Open and start one invocation, mirroring the sequential loop's
    /// `try_admit` exactly — same `begin_at`/`start_wave` call
    /// sequence, same sequence numbers — with the slab and event
    /// routing decided by the new wave's placement.
    fn try_admit_sharded(&mut self, arr: Arrival, sched_idx: usize, at: Millis) -> bool {
        let graph = &self.apps[arr.app].graph;
        let mut st = self.platform.begin_at(graph, Invocation::new(arr.scale), at, None);
        match self.platform.start_wave(graph, &mut st) {
            Ok(()) => {
                self.in_flight += 1;
                self.max_in_flight = self.max_in_flight.max(self.in_flight);
                self.tiers.record(
                    arr.app,
                    st.start_tier().unwrap_or(StartupTier::ColdBoot),
                    st.start_latency_ms(),
                );
                let home = wave_home(&st.pending, self.spr, self.ctxs.len());
                let mut pending = std::mem::take(&mut st.pending);
                let wave_done_at = st.wave_done_at();
                let slot = match home {
                    Some(r) => SlabRef {
                        shard: r,
                        idx: self.ctxs[r].slab.insert(arr.app, sched_idx, st),
                    },
                    None => {
                        SlabRef { shard: GLOBAL, idx: self.gslab.insert(arr.app, sched_idx, st) }
                    }
                };
                self.route_wave(slot, home, &mut pending);
                if let Some(st) = slot_state_mut(&mut self.ctxs, &mut self.gslab, slot) {
                    // hand the drained buffer back so the next wave
                    // reuses its capacity
                    st.pending = pending;
                }
                self.gheap.push(GEv {
                    at: wave_done_at,
                    seq: self.seq,
                    kind: GKind::WaveDone { slot },
                });
                self.seq += 1;
                if let Some(wf) = self.apps[arr.app].workflow.as_ref() {
                    let run = self.wfrt.on_root_admitted(arr.app, sched_idx, arr.scale, at, wf);
                    slot_set_wf(&mut self.ctxs, &mut self.gslab, slot, run, 0);
                }
                true
            }
            Err(_) => {
                self.platform.recycle_shell(st);
                false
            }
        }
    }

    /// Admit one workflow downstream stage on its pinned rack —
    /// [`try_admit_sharded`] with `begin_at_on` (no re-route) and the
    /// slab entry tagged with the stage's workflow metadata.
    #[allow(clippy::too_many_arguments)]
    fn try_admit_stage_sharded(
        &mut self,
        app: usize,
        sched_idx: usize,
        run: u32,
        stage: u32,
        scale: f64,
        rack: RackId,
        at: Millis,
    ) -> bool {
        let graph = &self.apps[app].graph;
        let mut st =
            self.platform.begin_at_on(graph, Invocation::new(scale), at, None, Some(rack));
        match self.platform.start_wave(graph, &mut st) {
            Ok(()) => {
                self.in_flight += 1;
                self.max_in_flight = self.max_in_flight.max(self.in_flight);
                self.tiers.record(
                    app,
                    st.start_tier().unwrap_or(StartupTier::ColdBoot),
                    st.start_latency_ms(),
                );
                let home = wave_home(&st.pending, self.spr, self.ctxs.len());
                let mut pending = std::mem::take(&mut st.pending);
                let wave_done_at = st.wave_done_at();
                let slot = match home {
                    Some(r) => {
                        SlabRef { shard: r, idx: self.ctxs[r].slab.insert(app, sched_idx, st) }
                    }
                    None => SlabRef { shard: GLOBAL, idx: self.gslab.insert(app, sched_idx, st) },
                };
                slot_set_wf(&mut self.ctxs, &mut self.gslab, slot, run, stage);
                self.route_wave(slot, home, &mut pending);
                if let Some(st) = slot_state_mut(&mut self.ctxs, &mut self.gslab, slot) {
                    st.pending = pending;
                }
                self.gheap.push(GEv {
                    at: wave_done_at,
                    seq: self.seq,
                    kind: GKind::WaveDone { slot },
                });
                self.seq += 1;
                true
            }
            Err(_) => {
                self.platform.recycle_shell(st);
                false
            }
        }
    }

    /// Route one started wave's pending timeline events, assigning the
    /// same global sequence numbers (push order) the sequential loop
    /// would: to the resident shard's heap when the wave is wholly on
    /// that shard's rack, to the coordinator heap otherwise. All-or-
    /// nothing per wave — a wave's per-server mutation order is only
    /// sequential if one executor owns all of it.
    fn route_wave(
        &mut self,
        slot: SlabRef,
        home: Option<usize>,
        pending: &mut Vec<(Millis, u32, ServerId, TimelineEv)>,
    ) {
        let local = slot.shard != GLOBAL && home == Some(slot.shard);
        for (at, _wave_seq, server, ev) in pending.drain(..) {
            if local {
                self.ctxs[slot.shard].heap.push(SEv {
                    at,
                    seq: self.seq,
                    idx: slot.idx,
                    server,
                    ev,
                });
            } else {
                self.gheap.push(GEv {
                    at,
                    seq: self.seq,
                    kind: GKind::Timeline { slot, server, ev },
                });
            }
            self.seq += 1;
        }
    }

    /// The sequential loop's deferred-queue service pass, verbatim,
    /// over the sharded admission path.
    fn drain_deferred_sharded(&mut self, now: Millis) {
        while self.queues.pop_expired(now).is_some() {}
        let fair = self.queues.policy().skips_blocked_tenant();
        let mut consecutive_failures = 0usize;
        while let Some(p) = self.queues.pop_next() {
            let arr = self.schedule.arrivals[p.sched];
            let admitted = self.try_admit_sharded(arr, p.sched, now);
            if admitted {
                self.queues.record_admitted(p.app, now - p.enqueued_at);
                consecutive_failures = 0;
            } else if fair {
                self.queues.unpop_skip_tenant(p);
                consecutive_failures += 1;
                if consecutive_failures >= self.queues.non_empty_tenants() {
                    break;
                }
            } else {
                self.queues.unpop(p);
                break;
            }
        }
    }

    /// Crash in-flight work on `server` across every slab. The scan
    /// order differs from the sequential loop's single-slab order, but
    /// every effect (set `fault_at` once, count once, pin the crash
    /// state) is idempotent per invocation and commutative across
    /// invocations, so the end state is identical.
    fn crash_scan_all(&mut self, server: ServerId, at: Millis) {
        crash_scan(&mut self.gslab, &mut self.faulted_per_app, server, at);
        for ctx in &mut self.ctxs {
            crash_scan(&mut ctx.slab, &mut self.faulted_per_app, server, at);
        }
    }

    /// Handle one coordinator-side event — the sequential loop's event
    /// arm, with slab access indirected through [`SlabRef`].
    fn handle_global(&mut self, kind: GKind, at: Millis) {
        match kind {
            GKind::Timeline { slot, server, ev } => {
                if let Some(st) = slot_state_mut(&mut self.ctxs, &mut self.gslab, slot) {
                    self.platform.apply_timeline(st, server, ev, at);
                }
            }
            GKind::Fault { idx } => {
                let kind = self.fault_plan.events[idx].kind;
                match kind {
                    FaultKind::ServerCrash(s) => {
                        if self.platform.cluster.fail_server(s, at) {
                            self.platform.evict_snapshots_on(s, at);
                            self.crash_scan_all(s, at);
                        }
                    }
                    FaultKind::RackOutage(r) => {
                        for i in r.0 * self.spr..(r.0 + 1) * self.spr {
                            let s = ServerId(i);
                            if self.platform.cluster.fail_server(s, at) {
                                self.platform.evict_snapshots_on(s, at);
                                self.crash_scan_all(s, at);
                            }
                        }
                    }
                    FaultKind::TransientCompute(s) => {
                        self.crash_scan_all(s, at);
                    }
                    FaultKind::ServerRepair(s) => {
                        self.platform.cluster.repair_server(s, at);
                    }
                    FaultKind::RackRepair(r) => {
                        for i in r.0 * self.spr..(r.0 + 1) * self.spr {
                            self.platform.cluster.repair_server(ServerId(i), at);
                        }
                    }
                }
            }
            GKind::WaveDone { slot } => {
                let (app_idx, _sched_idx) = match slot_meta(&self.ctxs, &self.gslab, slot) {
                    Some(m) => m,
                    None => return,
                };
                let graph = &self.apps[app_idx].graph;
                let finished = {
                    let st = slot_state_mut(&mut self.ctxs, &mut self.gslab, slot)
                        .expect("busy slot");
                    self.platform.wave_done(graph, st)
                };
                if finished {
                    let wf_meta = slot_wf_meta(&self.ctxs, &self.gslab, slot);
                    let (app_idx, sched_idx, st) =
                        slot_take(&mut self.ctxs, &mut self.gslab, slot).expect("busy slot");
                    self.in_flight -= 1;
                    let warm = st.first_wave_warm().unwrap_or(false);
                    let growths = st.growths();
                    let done_rack = st.rack_id;
                    if let Some(t_fault) = st.fault_at {
                        self.recovered_per_app[app_idx] += 1;
                        self.recovery_moments.push(at - t_fault);
                        self.recovery_p95.push(at - t_fault);
                    }
                    let (exec_ms, consumption) = self.platform.finish_invocation_attrib(graph, st);
                    self.completed_mask.set(sched_idx);
                    self.agg.record(app_idx, exec_ms, growths, warm, consumption);
                    if let Some((run, stage)) = wf_meta {
                        let wf = self.apps[app_idx]
                            .workflow
                            .as_ref()
                            .expect("workflow-tagged slot without a DAG");
                        let mut buf = std::mem::take(&mut self.stage_buf);
                        buf.clear();
                        self.wfrt.on_stage_done(
                            run,
                            stage,
                            done_rack,
                            at,
                            wf,
                            &graph.program,
                            &mut self.platform,
                            self.workflow_affinity,
                            &mut buf,
                        );
                        for l in buf.drain(..) {
                            self.gheap.push(GEv {
                                at: l.at,
                                seq: self.seq,
                                kind: GKind::StageLaunch { run: l.run, stage: l.stage },
                            });
                            self.seq += 1;
                        }
                        self.stage_buf = buf;
                    }
                } else {
                    let start = {
                        let st = slot_state_mut(&mut self.ctxs, &mut self.gslab, slot)
                            .expect("busy slot");
                        self.platform.start_wave(graph, st)
                    };
                    match start {
                        Ok(()) => {
                            let shards = self.ctxs.len();
                            let (mut pending, wave_done_at, home) = {
                                let st = slot_state_mut(&mut self.ctxs, &mut self.gslab, slot)
                                    .expect("busy slot");
                                let home = wave_home(&st.pending, self.spr, shards);
                                (std::mem::take(&mut st.pending), st.wave_done_at(), home)
                            };
                            // the continuation wave may live on a
                            // different rack than the slot: then its
                            // events run coordinator-side (the slab
                            // residence never migrates)
                            self.route_wave(slot, home, &mut pending);
                            if let Some(st) =
                                slot_state_mut(&mut self.ctxs, &mut self.gslab, slot)
                            {
                                st.pending = pending;
                            }
                            self.gheap.push(GEv {
                                at: wave_done_at,
                                seq: self.seq,
                                kind: GKind::WaveDone { slot },
                            });
                            self.seq += 1;
                        }
                        Err(_) => {
                            self.in_flight -= 1;
                            let wf_meta = slot_wf_meta(&self.ctxs, &self.gslab, slot);
                            if let Some((_, _, st)) =
                                slot_take(&mut self.ctxs, &mut self.gslab, slot)
                            {
                                if st.fault_at.is_some() {
                                    self.faulted_unrec_per_app[app_idx] += 1;
                                } else {
                                    self.aborted_per_app[app_idx] += 1;
                                }
                                self.platform.recycle_shell(st);
                            } else {
                                self.aborted_per_app[app_idx] += 1;
                            }
                            if let Some((run, _)) = wf_meta {
                                self.wfrt.on_stage_aborted(run, &mut self.platform, at);
                            }
                        }
                    }
                }
            }
            GKind::StageLaunch { run, stage } => {
                let app = self.wfrt.run_app(run);
                let wf = self.apps[app]
                    .workflow
                    .as_ref()
                    .expect("stage launch for a DAG-less tenant");
                if self.wfrt.begin_launch(run, stage, wf, &mut self.platform, at) {
                    self.spawned_per_app[app] += 1;
                    let sched_idx = self.wfrt.run_sched(run);
                    let scale = self.wfrt.stage_scale(run, stage, wf);
                    let rack = self.wfrt.pinned_rack(run, stage);
                    let admitted =
                        self.try_admit_stage_sharded(app, sched_idx, run, stage, scale, rack, at);
                    if admitted {
                        self.wfrt.on_stage_admitted(run);
                    } else {
                        self.rejected_per_app[app] += 1;
                        self.wfrt.on_stage_rejected(run, &mut self.platform, at);
                    }
                }
            }
        }
    }

    /// Process exactly the fence item: the next arrival or global
    /// event, whichever the sequential loop would take (arrival wins
    /// ties). Only called in batch mode, where the deferred queues are
    /// empty — so the sequential arrival branch's expire/drain/park
    /// preamble is vacuous and omitted.
    fn step_fence(&mut self) {
        let take_arrival =
            match (self.schedule.arrivals.get(self.next_arrival), self.gheap.peek()) {
                (Some(a), Some(h)) => a.at <= h.at,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => return,
            };
        if take_arrival {
            let i = self.next_arrival;
            self.next_arrival += 1;
            let arr = self.schedule.arrivals[i];
            self.end_time = self.end_time.max(arr.at);
            let admitted = self.try_admit_sharded(arr, i, arr.at);
            if !admitted && !self.queues.try_park(arr.app, i, arr.at) {
                self.rejected_per_app[arr.app] += 1;
            }
        } else {
            let GEv { at, kind, .. } = self.gheap.pop().expect("peeked above");
            self.end_time = self.end_time.max(at);
            self.handle_global(kind, at);
            // the sequential loop's post-event deferred drain is gated
            // on a non-empty queue — empty here by batch-mode invariant
        }
    }

    /// One exact sequential step while the deferred queues are
    /// occupied: the earliest item across the arrival cursor, the
    /// coordinator heap and every shard heap, with the sequential
    /// loop's full arrival preamble and post-event drain gates.
    fn serialize_step(&mut self) {
        let mut best: Option<(Millis, u64, Option<usize>)> =
            self.gheap.peek().map(|h| (h.at, h.seq, None));
        for (r, ctx) in self.ctxs.iter().enumerate() {
            if let Some(t) = ctx.heap.peek() {
                let better = match best {
                    None => true,
                    Some((at, s, _)) => match t.at.total_cmp(&at) {
                        Ordering::Less => true,
                        Ordering::Equal => t.seq < s,
                        Ordering::Greater => false,
                    },
                };
                if better {
                    best = Some((t.at, t.seq, Some(r)));
                }
            }
        }
        let take_arrival = match (self.schedule.arrivals.get(self.next_arrival), best) {
            (Some(a), Some((at, _, _))) => a.at <= at,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => {
                // trace exhausted, every heap drained, entries still
                // parked: one final full drain, then expire the rest
                let before_len = self.queues.len();
                let now = self.end_time;
                self.drain_deferred_sharded(now);
                if self.queues.len() == before_len {
                    self.queues.expire_all(now);
                }
                return;
            }
        };

        if take_arrival {
            let i = self.next_arrival;
            self.next_arrival += 1;
            let arr = self.schedule.arrivals[i];
            self.end_time = self.end_time.max(arr.at);
            while self.queues.pop_expired(arr.at).is_some() {}
            if !self.queues.is_empty() && self.platform.cluster.has_dirty_racks() {
                self.drain_deferred_sharded(arr.at);
            }
            if !self.queues.is_empty() {
                if !self.queues.try_park(arr.app, i, arr.at) {
                    self.rejected_per_app[arr.app] += 1;
                }
                return;
            }
            let admitted = self.try_admit_sharded(arr, i, arr.at);
            if !admitted && !self.queues.try_park(arr.app, i, arr.at) {
                self.rejected_per_app[arr.app] += 1;
            }
            return;
        }

        let (_, _, src) = best.expect("event branch");
        let at = match src {
            Some(r) => {
                let ev = self.ctxs[r].heap.pop().expect("peeked above");
                self.end_time = self.end_time.max(ev.at);
                // while serialized, every mutation goes through the
                // full cluster hooks — the drains below read the index
                // and the dirty-rack feed immediately
                if let Some(st) = self.ctxs[r].slab.state_mut(ev.idx) {
                    self.platform.apply_timeline(st, ev.server, ev.ev, ev.at);
                }
                ev.at
            }
            None => {
                let GEv { at, kind, .. } = self.gheap.pop().expect("peeked above");
                self.end_time = self.end_time.max(at);
                self.handle_global(kind, at);
                at
            }
        };
        if !self.queues.is_empty() && self.platform.cluster.has_dirty_racks() {
            self.drain_deferred_sharded(at);
        }
    }

    /// Phases A + B of one epoch: drain every shard up to the fence
    /// (threaded when engaged, inline otherwise), then replay the
    /// availability snapshots into the placement index in canonical
    /// `(time, seq)` order and merge the shard clocks.
    fn run_window(&mut self, fence: (Millis, u64), engage: bool) {
        let spr = self.spr;
        {
            let all = self.platform.cluster.servers_for_replay();
            if engage {
                self.engaged_batches += 1;
                let jobs: Vec<Job<'_>> = self
                    .ctxs
                    .iter_mut()
                    .zip(all.chunks_mut(spr))
                    .enumerate()
                    .map(|(r, (ctx, servers))| Job { ctx, servers, base: r * spr })
                    .collect();
                // The one allocation of an engaged batch: S fat
                // pointers. The engagement threshold keeps it off the
                // common path; the inline path allocates nothing.
                let queue = Mutex::new(jobs);
                std::thread::scope(|scope| {
                    for _ in 0..self.workers {
                        scope.spawn(|| loop {
                            let job = queue.lock().expect("worker queue poisoned").pop();
                            match job {
                                Some(j) => run_shard_batch(j.ctx, j.servers, j.base, fence),
                                None => break,
                            }
                        });
                    }
                });
            } else {
                for (r, (ctx, servers)) in self.ctxs.iter_mut().zip(all.chunks_mut(spr)).enumerate()
                {
                    run_shard_batch(ctx, servers, r * spr, fence);
                }
            }
        }

        // barrier: k-way merge of the per-shard note runs (each already
        // `(at, seq)`-sorted) replayed into the index in global order
        loop {
            let mut best: Option<(usize, Millis, u64)> = None;
            for (r, ctx) in self.ctxs.iter().enumerate() {
                if let Some(n) = ctx.notes.get(self.cursors[r]) {
                    let better = match best {
                        None => true,
                        Some((_, at, s)) => match n.at.total_cmp(&at) {
                            Ordering::Less => true,
                            Ordering::Equal => n.seq < s,
                            Ordering::Greater => false,
                        },
                    };
                    if better {
                        best = Some((r, n.at, n.seq));
                    }
                }
            }
            let Some((r, _, _)) = best else { break };
            let n = self.ctxs[r].notes[self.cursors[r]];
            self.cursors[r] += 1;
            self.platform.cluster.replay_index_update(n.server, n.avail, n.unmarked, n.marked);
        }
        for (r, ctx) in self.ctxs.iter_mut().enumerate() {
            ctx.notes.clear();
            self.cursors[r] = 0;
            self.end_time = self.end_time.max(ctx.end_time);
        }
    }

    fn run(mut self, label: &str) -> DriverReport {
        loop {
            // while a deferred queue is occupied, admission decisions
            // depend on every event — replay exactly, one at a time
            if self.queueing && !self.queues.is_empty() {
                self.serialize_step();
                continue;
            }

            // the natural fence: the next coordinator item in the
            // sequential order (arrival wins ties, as ever)
            let natural: Option<(Millis, u64)> =
                match (self.schedule.arrivals.get(self.next_arrival), self.gheap.peek()) {
                    (Some(a), Some(h)) => {
                        Some(if a.at <= h.at { (a.at, 0) } else { (h.at, h.seq) })
                    }
                    (Some(a), None) => Some((a.at, 0)),
                    (None, Some(h)) => Some((h.at, h.seq)),
                    (None, None) => None,
                };

            // earliest shard-local event + work census for engagement
            let mut min_local: Option<(Millis, u64)> = None;
            let mut busy_shards = 0usize;
            let mut local_items = 0usize;
            for ctx in &self.ctxs {
                if let Some(t) = ctx.heap.peek() {
                    busy_shards += 1;
                    local_items += ctx.heap.len();
                    let better = match min_local {
                        None => true,
                        Some((at, s)) => match t.at.total_cmp(&at) {
                            Ordering::Less => true,
                            Ordering::Equal => t.seq < s,
                            Ordering::Greater => false,
                        },
                    };
                    if better {
                        min_local = Some((t.at, t.seq));
                    }
                }
            }
            let have_local = match (min_local, natural) {
                (None, _) => false,
                (Some(_), None) => true,
                (Some((lat, lseq)), Some(f)) => before(lat, lseq, f),
            };

            if !have_local {
                if natural.is_none() {
                    break; // heaps drained, trace done, nothing parked
                }
                self.step_fence();
                continue;
            }

            // epoch window [first local event, +epoch_ms), clipped to
            // the natural fence; a capped window replays local work
            // only and comes back for the fence item — always
            // processing at least one event, so the loop advances
            let (lat, _) = min_local.expect("have_local");
            let cap = (lat + self.epoch_ms, 0u64);
            let (fence, capped) = match natural {
                Some(f) if !before(cap.0, cap.1, f) => (f, false),
                _ => (cap, true),
            };
            self.epochs += 1;
            let engage = self.workers > 1 && busy_shards >= 2 && local_items >= PAR_THRESHOLD;
            self.run_window(fence, engage);
            if !capped {
                self.step_fence();
            }
        }
        self.finish(label)
    }

    fn finish(mut self, label: &str) -> DriverReport {
        // Same teardown order as the sequential loop: resident snapshot
        // images return their rack-memory charge before the leak asserts.
        self.platform.drain_snapshot_caches(self.end_time);
        // Every workflow run retired with its handoff charges released.
        self.wfrt.assert_idle();
        #[cfg(debug_assertions)]
        {
            let high_water: usize = self.gslab.high_water()
                + self.ctxs.iter().map(|c| c.slab.high_water()).sum::<usize>();
            debug_assert!(
                high_water
                    <= self.schedule.arrivals.len()
                        + self.spawned_per_app.iter().sum::<usize>()
            );
            let live: usize =
                self.gslab.live() + self.ctxs.iter().map(|c| c.slab.live()).sum::<usize>();
            debug_assert_eq!(live, self.in_flight, "slab/in-flight accounting out of sync");
            debug_assert_eq!(self.in_flight, 0, "events drained with invocations still in flight");
            for s in self.platform.cluster.servers() {
                debug_assert!(
                    s.allocated().cpu < 1e-3 && s.allocated().mem_mb < 1e-3,
                    "server {:?} leaked allocations: {:?}",
                    s.id,
                    s.allocated()
                );
                debug_assert!(
                    s.marked().cpu < 1e-3 && s.marked().mem_mb < 1e-3,
                    "server {:?} leaked marks: {:?}",
                    s.id,
                    s.marked()
                );
            }
        }
        let fleet = self.platform.cluster.total_consumption(self.end_time);
        let adm = self.queues.finish(&self.rejected_per_app, &self.aborted_per_app);
        let route = self.platform.global.route_stats();

        // shard telemetry, reduced in ascending shard order — merged
        // accumulators feed digest-excluded fields only
        let mut batch_moments = StreamingMoments::new();
        let mut batch_p95 = P2Quantile::new(0.95);
        let mut shard_jain = JainAccumulator::new();
        let mut local_events = 0u64;
        for ctx in &self.ctxs {
            batch_moments.merge(&ctx.batch_moments);
            batch_p95.merge(&ctx.batch_p95);
            let mut one = JainAccumulator::new();
            one.push(ctx.local_events as f64);
            shard_jain.merge(&one);
            local_events += ctx.local_events;
        }

        let mut report = self.agg.finish(
            label,
            adm,
            fleet,
            self.end_time,
            self.max_in_flight,
            self.completed_mask,
        );
        report.route_fast_hits = route.fast_hits;
        report.route_scans = route.scans;
        report.faulted = self.faulted_per_app.iter().sum();
        report.recovered = self.recovered_per_app.iter().sum();
        report.faulted_unrecovered = self.faulted_unrec_per_app.iter().sum();
        if self.recovery_moments.count() > 0 {
            report.mean_recovery_ms = self.recovery_moments.mean();
            report.p95_recovery_ms = self.recovery_p95.value();
        }
        for (i, a) in report.apps.iter_mut().enumerate() {
            a.faulted = self.faulted_per_app[i];
            a.recovered = self.recovered_per_app[i];
            a.faulted_unrecovered = self.faulted_unrec_per_app[i];
        }
        report.workers = self.workers;
        report.epochs = self.epochs;
        report.parallel_batches = self.engaged_batches;
        report.parallel_local_events = local_events;
        report.epoch_batch_mean = batch_moments.mean();
        report.epoch_batch_p95 = batch_p95.value();
        report.epoch_shard_jain = shard_jain.value();
        self.tiers.apply_to(&mut report);
        let snap = self.platform.snapshot_stats();
        report.snap_hits = snap.hits;
        report.snap_misses = snap.misses;
        report.snap_evictions = snap.evictions;
        report.snap_prewarms = snap.prewarms;
        report.snap_bytes_hwm = snap.bytes_hwm;
        let wstats = &self.wfrt.stats;
        report.wf_runs = wstats.runs;
        report.wf_runs_completed = wstats.runs_completed;
        report.wf_stages_started = wstats.stages_started;
        report.wf_stages_completed = wstats.stages_completed;
        report.wf_spawned = wstats.spawned;
        report.wf_cross_rack_mb = wstats.cross_rack_mb;
        if wstats.e2e.count() > 0 {
            report.wf_e2e_mean_ms = wstats.e2e.mean();
            report.wf_e2e_p95_ms = wstats.e2e_p95.value();
            report.wf_e2e_p99_ms = wstats.e2e_p99.value();
        }
        report.wf_affinity_hits = route.affinity_hits;
        report.wf_affinity_spills = route.affinity_spills;
        for (i, a) in report.apps.iter_mut().enumerate() {
            a.spawned = self.spawned_per_app[i];
        }
        report
    }
}

/// The sharded epoch-barrier replay of one schedule. Entered from
/// [`MultiTenantDriver`]'s `run_platform` when
/// [`super::driver::DriverConfig::workers`] `> 1`; digest-identical to
/// the sequential loop for every worker count.
pub(crate) fn run_platform_sharded(
    driver: &MultiTenantDriver<'_>,
    schedule: &Schedule,
    config: ZenixConfig,
    label: &str,
) -> DriverReport {
    let apps = driver.apps;
    let cfg = &driver.cfg;
    let shards = cfg.cluster.racks.max(1);
    let spr = cfg.cluster.servers_per_rack;
    let workers = cfg.workers.min(shards).max(1);

    let mut sched_counts = vec![0usize; apps.len()];
    for arr in &schedule.arrivals {
        sched_counts[arr.app] += 1;
    }

    let mut queues = DeferredQueues::new(cfg.admission, apps.len());
    let queueing = queues.policy().queues();
    if queueing {
        if matches!(cfg.admission, AdmissionPolicy::WeightedFairShare { .. }) {
            let weights: Vec<f64> = apps.iter().map(|a| a.weight).collect();
            queues.set_weights(&weights);
        }
        if let AdmissionPolicy::Deadline { deadline_ms, .. } = cfg.admission {
            let slos: Vec<f64> =
                apps.iter().map(|a| a.deadline_ms.unwrap_or(deadline_ms)).collect();
            queues.set_deadlines(&slos);
        }
    }

    let mut gheap: BinaryHeap<GEv> = BinaryHeap::with_capacity(256);
    let mut seq = 0u64;
    let horizon = schedule.arrivals.last().map_or(0.0, |a| a.at);
    let fault_plan = FaultPlan::generate(&cfg.faults, cfg.seed, &cfg.cluster, horizon);
    for idx in 0..fault_plan.events.len() {
        gheap.push(GEv { at: fault_plan.events[idx].at, seq, kind: GKind::Fault { idx } });
        seq += 1;
    }

    let mut wfrt = WorkflowRuntime::new();
    wfrt.set_net(config.net);
    let mut platform = Platform::new(cfg.cluster, config);
    // Same gate as the sequential loop: a zero budget leaves the
    // snapshot layer off and the replay byte-identical to legacy.
    if cfg.snapshot_budget_bytes > 0 {
        platform.enable_snapshots(
            cfg.snapshot_budget_bytes,
            cfg.prewarm,
            prewarm_order(apps, &sched_counts),
            PREWARM_TOP_K,
        );
    }

    let engine = Engine {
        apps,
        schedule,
        platform,
        gheap,
        seq,
        gslab: Slab::new(),
        ctxs: (0..shards).map(|_| ShardCtx::new()).collect(),
        cursors: vec![0usize; shards],
        agg: Aggregator::new(apps, &sched_counts, cfg.exact_stats),
        completed_mask: BitMask::new(schedule.arrivals.len()),
        rejected_per_app: vec![0usize; apps.len()],
        aborted_per_app: vec![0usize; apps.len()],
        queues,
        queueing,
        in_flight: 0,
        max_in_flight: 0,
        end_time: 0.0,
        next_arrival: 0,
        fault_plan,
        spr,
        workers,
        epoch_ms: cfg.epoch_ms.max(1.0),
        faulted_per_app: vec![0usize; apps.len()],
        recovered_per_app: vec![0usize; apps.len()],
        faulted_unrec_per_app: vec![0usize; apps.len()],
        recovery_moments: StreamingMoments::new(),
        recovery_p95: P2Quantile::new(0.95),
        tiers: TierTelemetry::new(apps.len()),
        epochs: 0,
        engaged_batches: 0,
        wfrt,
        workflow_affinity: cfg.workflow_affinity,
        spawned_per_app: vec![0usize; apps.len()],
        stage_buf: Vec::new(),
    };
    engine.run(label)
}

#[cfg(test)]
mod tests {
    use super::super::admission::AdmissionPolicy;
    use super::super::driver::{standard_mix, DriverConfig, MultiTenantDriver};
    use super::super::faults::FaultConfig;
    use crate::trace::Archetype;

    fn zenix_digest(cfg: DriverConfig) -> (u64, usize, usize, usize) {
        let apps = standard_mix(6, Archetype::Average);
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        // the failure split partitions the invocations in every mode
        // (spawned widens the right-hand side for workflow mixes; this
        // DAG-less mix spawns nothing)
        assert_eq!(
            r.completed
                + r.rejected
                + r.aborted
                + r.timed_out
                + r.expired
                + r.faulted_unrecovered,
            schedule.arrivals.len() + usize::try_from(r.wf_spawned).expect("spawned fits usize"),
            "conservation identity (workers = {})",
            cfg.workers
        );
        (r.digest, r.completed, r.warm_hits, r.max_in_flight)
    }

    #[test]
    fn parallel_replay_digest_matches_sequential() {
        let base = DriverConfig {
            seed: 9,
            invocations: 240,
            mean_iat_ms: 120.0,
            ..DriverConfig::default()
        }
        .with_racks(4);
        let sequential = zenix_digest(base);
        for workers in [2usize, 4, 8] {
            let parallel = zenix_digest(DriverConfig { workers, ..base });
            assert_eq!(
                parallel, sequential,
                "workers = {workers} must reproduce the sequential outcome"
            );
        }
    }

    #[test]
    fn epoch_width_cannot_affect_the_digest() {
        let base = DriverConfig {
            seed: 5,
            invocations: 180,
            mean_iat_ms: 150.0,
            workers: 4,
            ..DriverConfig::default()
        }
        .with_racks(4);
        let wide = zenix_digest(DriverConfig { epoch_ms: 10_000.0, ..base });
        let narrow = zenix_digest(DriverConfig { epoch_ms: 5.0, ..base });
        assert_eq!(wide, narrow, "epoch width is a batching knob, not a semantic one");
    }

    #[test]
    fn parallel_replay_matches_under_queueing_policies() {
        for admission in [
            AdmissionPolicy::FifoQueue { max_wait_ms: 60_000.0, max_depth: 32 },
            AdmissionPolicy::FairShare { max_wait_ms: 60_000.0, max_depth: 32 },
        ] {
            let base = DriverConfig {
                seed: 11,
                invocations: 200,
                mean_iat_ms: 40.0, // saturating: queues must engage
                admission,
                ..DriverConfig::default()
            }
            .with_racks(2);
            let sequential = zenix_digest(base);
            for workers in [2usize, 4] {
                let parallel = zenix_digest(DriverConfig { workers, ..base });
                assert_eq!(
                    parallel, sequential,
                    "queueing replay must serialize exactly (workers = {workers})"
                );
            }
        }
    }

    #[test]
    fn parallel_replay_matches_under_fault_injection() {
        let base = DriverConfig {
            seed: 7,
            invocations: 200,
            mean_iat_ms: 150.0,
            faults: FaultConfig { rate_per_min: 10.0, repair_ms: 5_000.0, rack_outage: true },
            ..DriverConfig::default()
        }
        .with_racks(4);
        let sequential = zenix_digest(base);
        for workers in [2usize, 4] {
            let parallel = zenix_digest(DriverConfig { workers, ..base });
            assert_eq!(
                parallel, sequential,
                "chaos replay must stay digest-identical (workers = {workers})"
            );
        }
    }

    #[test]
    fn sharded_run_reports_parallel_telemetry() {
        let apps = standard_mix(6, Archetype::Average);
        let cfg = DriverConfig {
            seed: 9,
            invocations: 240,
            mean_iat_ms: 120.0,
            workers: 4,
            ..DriverConfig::default()
        }
        .with_racks(4);
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.workers, 4);
        assert!(r.epochs > 0, "a multi-rack run must execute epoch windows");
        assert!(
            r.parallel_local_events > 0,
            "single-rack waves must replay inside shard batches"
        );
        assert!(r.epoch_shard_jain > 0.0 && r.epoch_shard_jain <= 1.0 + 1e-12);
        // the sequential loop reports the idle defaults
        let seq = MultiTenantDriver::new(&apps, DriverConfig { workers: 1, ..cfg })
            .run_zenix(&schedule);
        assert_eq!(seq.workers, 1);
        assert_eq!(seq.epochs, 0);
        assert_eq!(seq.parallel_local_events, 0);
    }

    #[test]
    fn comparison_fanout_is_byte_identical() {
        let apps = standard_mix(5, Archetype::Average);
        let cfg = DriverConfig {
            seed: 13,
            invocations: 150,
            mean_iat_ms: 200.0,
            ..DriverConfig::default()
        }
        .with_racks(2);
        let a = MultiTenantDriver::new(&apps, cfg).run_comparison();
        let b = MultiTenantDriver::new(&apps, cfg).run_comparison_with_workers(3);
        assert_eq!(a.zenix.digest, b.zenix.digest);
        assert_eq!(a.peak.digest, b.peak.digest);
        assert_eq!(a.faas.digest, b.faas.digest);
        assert_eq!(a.faas_on_completed.digest, b.faas_on_completed.digest);
    }

    #[test]
    fn worker_count_clamps_to_the_rack_count() {
        let apps = standard_mix(4, Archetype::Average);
        let cfg = DriverConfig {
            seed: 3,
            invocations: 80,
            mean_iat_ms: 300.0,
            workers: 64,
            ..DriverConfig::default()
        }
        .with_racks(2);
        let driver = MultiTenantDriver::new(&apps, cfg);
        let schedule = driver.schedule();
        let r = driver.run_zenix(&schedule);
        assert_eq!(r.workers, 2, "workers clamp to the shard (rack) count");
    }
}
