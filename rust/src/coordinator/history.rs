//! History-based resource profiles (§4.2, §5.2.3).
//!
//! Zenix samples application runs and stores, per resource-graph node, a
//! histogram of observed usage with *decaying weights*: recent
//! invocations count more, so the profile tracks drift without
//! overreacting to one-off inputs. The exec engine reads quantiles for
//! initial sizing; the [`super::adjust`] solver consumes the weighted
//! observations directly.

use std::collections::HashMap;

use crate::util::cast;

/// One node's decaying-weight usage record.
///
/// Weights are *implicit*: observation `i` carries sequence number
/// `seq_i`, and its weight is `decay^(cur_seq - seq_i)`. Recording is
/// O(1) (no re-multiplication sweep — EXPERIMENTS.md §Perf change 2);
/// weights materialize lazily on query.
#[derive(Debug, Clone)]
pub struct Profile {
    /// (value, sequence-number) pairs, insertion order.
    obs: std::collections::VecDeque<(f64, u64)>,
    seq: u64,
    decay: f64,
    cap: usize,
    /// Incrementally-maintained decayed sums: Σ w_i and Σ w_i·v_i
    /// (weights decay by `decay` on each insert) — O(1) mean queries
    /// (§Perf change 3). Eviction error is ≤ decay^cap ≈ 5e-6.
    w_total: f64,
    wv_total: f64,
}

impl Default for Profile {
    fn default() -> Self {
        Self::new(0.95, 256)
    }
}

impl Profile {
    /// Profile with the given per-observation `decay` factor and
    /// retention window `cap` (oldest observations are dropped past
    /// it; their residual weight is ≤ `decay^cap`).
    pub fn new(decay: f64, cap: usize) -> Self {
        Self {
            obs: std::collections::VecDeque::new(),
            seq: 0,
            decay,
            cap,
            w_total: 0.0,
            wv_total: 0.0,
        }
    }

    /// Record one observation (most recent gets weight 1.0; older decay).
    pub fn record(&mut self, value: f64) {
        self.obs.push_back((value, self.seq));
        self.seq += 1;
        self.w_total = self.w_total * self.decay + 1.0;
        self.wv_total = self.wv_total * self.decay + value;
        if self.obs.len() > self.cap {
            // oldest entry has the lowest weight by construction; its
            // residual (≤ decay^cap) is left in the running sums.
            self.obs.pop_front();
        }
    }

    /// Materialized weight of one stored observation.
    #[inline]
    fn weight(&self, seq: u64) -> f64 {
        self.decay.powi(cast::i32_of(self.seq - 1 - seq))
    }

    /// Observations currently retained (saturates at the window cap).
    pub fn len(&self) -> usize {
        self.obs.len()
    }

    /// Total observations ever recorded (monotonic, not capped by the
    /// retention window). The §5.2.3 re-tune schedule counts against
    /// this — the windowed [`Self::len`] saturates at `cap`, which
    /// would silently stop periodic re-tuning after the window fills.
    pub fn total_recorded(&self) -> u64 {
        self.seq
    }

    /// True when nothing has been recorded (or everything aged out).
    pub fn is_empty(&self) -> bool {
        self.obs.is_empty()
    }

    /// Weighted quantile (q in [0,1]) of observed values.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.obs.is_empty() {
            return None;
        }
        let mut v: Vec<(f64, f64)> =
            self.obs.iter().map(|&(val, seq)| (val, self.weight(seq))).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let total: f64 = v.iter().map(|(_, w)| w).sum();
        let target = q.clamp(0.0, 1.0) * total;
        let mut acc = 0.0;
        for (val, w) in &v {
            acc += w;
            if acc + 1e-12 >= target {
                return Some(*val);
            }
        }
        v.last().map(|(val, _)| *val)
    }

    /// Weighted maximum == quantile(1.0) (peak provisioning).
    pub fn max(&self) -> Option<f64> {
        self.obs.iter().map(|(v, _)| *v).fold(None, |m, v| {
            Some(m.map_or(v, |m: f64| m.max(v)))
        })
    }

    /// Weighted mean (O(1): incrementally maintained).
    pub fn mean(&self) -> Option<f64> {
        if self.obs.is_empty() {
            return None;
        }
        Some(self.wv_total / self.w_total)
    }

    /// Raw values (for the adjust solver).
    pub fn values(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.values_into(&mut out);
        out
    }

    /// Allocation-free variant of [`Self::values`]: clears and refills
    /// `out` (the executor's periodic §5.2.3 re-tune reuses one scratch
    /// buffer so the steady-state hot path never allocates).
    pub fn values_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.obs.iter().map(|&(v, _)| v));
    }
}

/// Resource kinds tracked per node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Peak memory (MB) of a compute worker or data component.
    MemMb,
    /// vCPUs actually exercised.
    Cpu,
    /// CPU utilization of the allocated vCPUs (0..1).
    CpuUtil,
    /// Lifetime (ms).
    LifetimeMs,
}

/// Profiles for every (application, node, metric) triple.
///
/// Keyed app-first (`HashMap<String, …>`) so lookups borrow the `&str`
/// key directly — the executor's per-component sizing path queries this
/// on every invocation and must not allocate a `String` per lookup
/// (PR-2 hot-path fix; `benches/hotpath.rs history_profile_lookup_hit`).
#[derive(Debug, Default)]
pub struct ProfileStore {
    profiles: HashMap<String, HashMap<(usize, Metric), Profile>>,
}

impl ProfileStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation for `(app, node, metric)`, creating the
    /// profile on first sight. Allocates the owned app key only the
    /// first time an app is seen — steady-state recording is
    /// allocation-free.
    pub fn record(&mut self, app: &str, node: usize, metric: Metric, value: f64) {
        // allocate the owned app key only on first sight of the app
        if !self.profiles.contains_key(app) {
            self.profiles.insert(app.to_string(), HashMap::new());
        }
        self.profiles
            .get_mut(app)
            .expect("just inserted")
            .entry((node, metric))
            .or_default()
            .record(value);
    }

    /// The profile recorded for `(app, node, metric)`, if any. Borrows
    /// the `&str` key directly — no per-lookup allocation.
    pub fn profile(&self, app: &str, node: usize, metric: Metric) -> Option<&Profile> {
        self.profiles.get(app)?.get(&(node, metric))
    }

    /// Weighted quantile of one profile (`None` when nothing is
    /// recorded); see [`Profile::quantile`].
    pub fn quantile(&self, app: &str, node: usize, metric: Metric, q: f64) -> Option<f64> {
        self.profile(app, node, metric)?.quantile(q)
    }

    /// Number of recorded invocations for an app's node 0 (proxy for
    /// "K executions" in the §5.2.3 re-tuning schedule).
    pub fn executions(&self, app: &str, metric: Metric) -> usize {
        self.profile(app, 0, metric).map_or(0, |p| p.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_uniform_values() {
        let mut p = Profile::default();
        for v in 1..=100 {
            p.record(v as f64);
        }
        let q50 = p.quantile(0.5).unwrap();
        // decay biases toward recent (larger) values
        assert!(q50 >= 50.0, "{q50}");
        assert_eq!(p.max(), Some(100.0));
        assert!(p.quantile(0.0).unwrap() >= 1.0);
        assert_eq!(p.quantile(1.0), Some(100.0));
    }

    #[test]
    fn decay_prefers_recent() {
        let mut p = Profile::new(0.5, 64);
        for _ in 0..20 {
            p.record(100.0);
        }
        for _ in 0..3 {
            p.record(10.0);
        }
        // recent small values dominate the low quantiles quickly
        assert!(p.quantile(0.3).unwrap() <= 100.0);
        let mean = p.mean().unwrap();
        assert!(mean < 60.0, "decayed mean {mean}");
    }

    #[test]
    fn cap_bounds_memory() {
        let mut p = Profile::new(0.99, 16);
        for v in 0..100 {
            p.record(v as f64);
        }
        assert_eq!(p.len(), 16);
        // survivors are the most recent ones
        assert!(p.values().iter().all(|&v| v >= 84.0));
    }

    #[test]
    fn total_recorded_outlives_window_cap() {
        // The re-tune schedule must keep advancing after the retention
        // window fills (len saturates at cap; seq does not).
        let mut p = Profile::new(0.95, 16);
        for v in 0..40 {
            p.record(v as f64);
        }
        assert_eq!(p.len(), 16);
        assert_eq!(p.total_recorded(), 40);
    }

    #[test]
    fn store_roundtrip() {
        let mut s = ProfileStore::new();
        s.record("app", 3, Metric::MemMb, 512.0);
        s.record("app", 3, Metric::MemMb, 1024.0);
        assert_eq!(s.quantile("app", 3, Metric::MemMb, 1.0), Some(1024.0));
        assert_eq!(s.quantile("other", 3, Metric::MemMb, 1.0), None);
        assert_eq!(s.quantile("app", 3, Metric::Cpu, 0.5), None);
    }

    #[test]
    fn empty_profile_safe() {
        let p = Profile::default();
        assert_eq!(p.quantile(0.5), None);
        assert_eq!(p.max(), None);
        assert_eq!(p.mean(), None);
    }
}
