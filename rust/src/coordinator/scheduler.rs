//! Two-level scheduler (§5.3.1).
//!
//! One **global scheduler** per cluster tracks rough per-rack
//! availability, balances application requests across racks, and owns
//! the compilation database. One **rack scheduler** per rack holds the
//! exact per-server view and serves per-component allocation requests.
//! When a rack runs out, the request bounces back to the global
//! scheduler for another rack.
//!
//! Performance guarantee (the §6.2 scalability targets: 50k apps/s
//! global, 20k components/s rack): the per-request decision paths are
//! allocation-free. Rack-level placement is an indexed lookup through
//! [`crate::cluster::PlacementIndex`] (O(buckets + occupancy), no
//! per-call collections; the old linear scan survives only as the
//! differential-test reference). Global routing keeps an incremental
//! best-rack cache maintained by [`GlobalScheduler::update_rack`], so
//! the common case routes without rescanning every rack; the O(racks)
//! scan runs only when the cache is stale or the most-available rack
//! cannot fit the estimate. The executor feeds `update_rack` from the
//! cluster's dirty-rack deltas (`Cluster::for_each_dirty_rack`) — only
//! racks whose availability actually changed are refreshed per
//! admission, not all of them. See `rust/benches/scheduler.rs` for the
//! measured throughputs.

use std::collections::HashMap;

use crate::cluster::{Cluster, RackId, Resources, ServerId};

use super::placement;

/// Compilation database entry (§4.2: two pre-compiled versions; runtime
/// layouts compiled on demand and cached).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Compilation {
    /// All accessed data local — native memory instructions.
    AllLocal,
    /// All accessed data remote — BulkX data-access APIs.
    AllRemote,
    /// Mixed layout, keyed by a bitmask of which data is local.
    Mixed(u32),
}

/// The global scheduler.
#[derive(Debug, Default)]
pub struct GlobalScheduler {
    /// Rough per-rack availability (refreshed by rack schedulers).
    rack_avail: Vec<Resources>,
    /// Incremental best-rack cache: racks whose availability magnitude
    /// equals `best_mag`. `update_rack` maintains it in O(1) except
    /// when the sole best rack degrades (then `best_stale` defers an
    /// O(racks) rescan to the next `route`).
    best_racks: Vec<usize>,
    best_mag: f64,
    best_stale: bool,
    /// Compilation DB: (app, variant) -> compiled (cache hit at
    /// runtime). Keyed by the program's interned (`&'static`) name like
    /// the platform's sizing/warm-pool caches, so a lookup allocates
    /// nothing (the old `(String, _)` key built an owned string per
    /// query).
    compilations: HashMap<(&'static str, Compilation), bool>,
    /// Round-robin cursor for tie-breaking equally-loaded racks.
    cursor: usize,
    /// Routing decisions answered by the best-rack cache fast path /
    /// by the O(racks) fallback scan (multi-rack sharding telemetry;
    /// the driver surfaces both per run).
    fast_hits: u64,
    scans: u64,
    /// Affinity-routed decisions (workflow downstream stages): the
    /// preferred rack fit and was taken / could not fit and the
    /// decision fell back to the ordinary smallest-fit `route`.
    affinity_hits: u64,
    affinity_spills: u64,
}

/// How the global scheduler answered its routing decisions: via the
/// incremental best-rack cache (`fast_hits`, O(best set)) or the
/// O(racks) fallback scan (`scans` — stale cache, or no best-magnitude
/// rack fit the estimate). The multi-rack sharding sweep reads this to
/// show the cache holds up as rack count grows.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouteStats {
    /// Decisions served by the best-rack cache fast path.
    pub fast_hits: u64,
    /// Decisions that fell back to the full rack scan.
    pub scans: u64,
    /// Affinity routes where the preferred (data-resident) rack fit.
    pub affinity_hits: u64,
    /// Affinity routes that spilled to the ordinary smallest-fit path.
    pub affinity_spills: u64,
}

impl GlobalScheduler {
    /// Scheduler over `racks` racks (availability seeded by the first
    /// dirty-rack drain).
    pub fn new(racks: usize) -> Self {
        Self {
            rack_avail: vec![Resources::ZERO; racks],
            best_racks: Vec::with_capacity(racks),
            best_mag: 0.0,
            best_stale: true,
            compilations: HashMap::new(),
            cursor: 0,
            fast_hits: 0,
            scans: 0,
            affinity_hits: 0,
            affinity_spills: 0,
        }
    }

    /// Routing-path telemetry: fast-path vs full-scan decision counts
    /// (and affinity hit/spill counts) since construction.
    pub fn route_stats(&self) -> RouteStats {
        RouteStats {
            fast_hits: self.fast_hits,
            scans: self.scans,
            affinity_hits: self.affinity_hits,
            affinity_spills: self.affinity_spills,
        }
    }

    /// Refresh the rough view for one rack (rack schedulers push this).
    /// Maintains the best-rack cache incrementally.
    pub fn update_rack(&mut self, rack: RackId, avail: Resources) {
        self.rack_avail[rack.0] = avail;
        if self.best_stale {
            return;
        }
        let i = rack.0;
        let mag = avail.magnitude();
        let member = self.best_racks.iter().position(|&r| r == i);
        if mag > self.best_mag {
            self.best_mag = mag;
            self.best_racks.clear();
            self.best_racks.push(i);
        } else if mag == self.best_mag {
            if member.is_none() {
                self.best_racks.push(i);
            }
        } else if let Some(pos) = member {
            // the (former) best rack degraded
            self.best_racks.swap_remove(pos);
            if self.best_racks.is_empty() {
                self.best_stale = true;
            }
        }
    }

    fn rebuild_best(&mut self) {
        self.best_racks.clear();
        self.best_mag = f64::NEG_INFINITY;
        for (i, a) in self.rack_avail.iter().enumerate() {
            let mag = a.magnitude();
            if mag > self.best_mag {
                self.best_mag = mag;
                self.best_racks.clear();
                self.best_racks.push(i);
            } else if mag == self.best_mag {
                self.best_racks.push(i);
            }
        }
        self.best_stale = false;
    }

    /// Route an application request: the rack with the most available
    /// resources that fits `estimate` (load balancing), else the rack
    /// with the most available overall (it will queue/spill). Equally
    /// loaded racks round-robin via the cursor.
    pub fn route(&mut self, estimate: Resources) -> RackId {
        let n = self.rack_avail.len();
        if n == 0 {
            return RackId(0);
        }
        if self.best_stale {
            self.rebuild_best();
        }
        // Fast path: pick round-robin among the most-available racks
        // that fit. Correct because any fitting best-magnitude rack
        // dominates every other fitting rack by magnitude.
        let mut fast: Option<(usize, usize)> = None; // (modular distance, rack)
        for &r in &self.best_racks {
            if self.rack_avail[r].fits(estimate) {
                let dist = (r + n - self.cursor % n) % n;
                if fast.map_or(true, |(bd, _)| dist < bd) {
                    fast = Some((dist, r));
                }
            }
        }
        let chosen = if let Some((_, r)) = fast {
            self.fast_hits += 1;
            r
        } else {
            self.scans += 1;
            // Slow path: no best-magnitude rack fits (or none exists):
            // full scan, carrying the incumbent's fit in the fold state.
            let mut best: Option<(usize, f64, bool)> = None; // (rack, mag, fits)
            for off in 0..n {
                let i = (self.cursor + off) % n;
                let a = self.rack_avail[i];
                let mag = a.magnitude();
                let fits = a.fits(estimate);
                best = match best {
                    Some((bi, bm, bf)) => {
                        if (fits && !bf) || (fits == bf && mag > bm) {
                            Some((i, mag, fits))
                        } else {
                            Some((bi, bm, bf))
                        }
                    }
                    None => Some((i, mag, fits)),
                };
            }
            best.map(|(i, _, _)| i).unwrap_or(0)
        };
        self.cursor = (self.cursor + 1) % n;
        RackId(chosen)
    }

    /// Route a workflow downstream stage with rack affinity: take the
    /// preferred rack (where the stage's input handoff bytes are
    /// resident) when its rough availability fits `estimate`, otherwise
    /// fall back to the ordinary smallest-fit [`GlobalScheduler::route`]
    /// (§5.3.1's bounce semantics). Returns the chosen rack and whether
    /// the affinity candidate was taken. The hit/spill split is
    /// surfaced through [`GlobalScheduler::route_stats`].
    pub fn route_with_affinity(&mut self, estimate: Resources, prefer: RackId) -> (RackId, bool) {
        if prefer.0 < self.rack_avail.len() && self.rack_avail[prefer.0].fits(estimate) {
            self.affinity_hits += 1;
            return (prefer, true);
        }
        self.affinity_spills += 1;
        (self.route(estimate), false)
    }

    /// Look up / install a compilation (returns true on cache hit).
    /// Allocation-free: the key borrows the interned app name.
    pub fn compilation(&mut self, app: &'static str, variant: Compilation) -> bool {
        let key = (app, variant);
        if self.compilations.contains_key(&key) {
            true
        } else {
            self.compilations.insert(key, true);
            false
        }
    }
}

/// One rack's scheduler: exact server accounting within the rack.
#[derive(Debug)]
pub struct RackScheduler {
    /// The rack this scheduler owns.
    pub rack: RackId,
    servers: Vec<ServerId>,
}

/// Outcome of a component allocation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Allocation {
    /// Placed on a server; `colocated` = with its accessed data.
    Placed { server: ServerId, colocated: bool },
    /// Rack out of resources: bounce to the global scheduler (§5.3.1).
    Spill,
}

impl RackScheduler {
    /// Scheduler for one rack of `cluster`.
    pub fn new(cluster: &Cluster, rack: RackId) -> Self {
        Self { rack, servers: cluster.rack_servers(rack).collect() }
    }

    /// Server ids this rack owns.
    pub fn servers(&self) -> &[ServerId] {
        &self.servers
    }

    /// Try to fit the whole application on one server (§5.1.1 step 1).
    pub fn whole_app_fit(&self, cluster: &Cluster, demand: Resources) -> Option<ServerId> {
        placement::smallest_fit_in_rack(cluster, self.rack, demand)
    }

    /// Allocate one component; commits the allocation into the cluster
    /// (through the index-maintaining hook). Allocation-free: the
    /// co-location pass filters `data_servers` by rack inline and the
    /// rack-wide fallback is an indexed lookup.
    pub fn allocate(
        &self,
        cluster: &mut Cluster,
        demand: Resources,
        data_servers: &[ServerId],
        now: f64,
    ) -> Allocation {
        let rack = self.rack;
        let choice = placement::smallest_fit_among(
            cluster,
            demand,
            data_servers
                .iter()
                .copied()
                .filter(|&id| cluster.server(id).rack == rack),
        )
        .map(|id| (id, true))
        .or_else(|| {
            placement::smallest_fit_in_rack(cluster, rack, demand)
                .map(|id| (id, data_servers.contains(&id)))
        });
        match choice {
            Some((server, colocated)) => {
                let ok = cluster.try_alloc(server, demand, now);
                debug_assert!(ok, "placement said it fits");
                Allocation::Placed { server, colocated }
            }
            None => Allocation::Spill,
        }
    }

    /// Release a component's resources (index-maintaining hook).
    pub fn release(&self, cluster: &mut Cluster, server: ServerId, amount: Resources, now: f64) {
        cluster.free(server, amount, now);
    }

    /// Rough availability to push up to the global scheduler.
    pub fn availability(&self, cluster: &Cluster) -> Resources {
        cluster.rack_available(self.rack)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn cluster(racks: usize) -> Cluster {
        Cluster::new(ClusterSpec::multi_rack(racks, 4))
    }

    #[test]
    fn global_routes_to_most_available_fitting_rack() {
        let c = cluster(3);
        let mut g = GlobalScheduler::new(3);
        for r in c.racks() {
            g.update_rack(r, c.rack_available(r));
        }
        // rack 1 drained
        g.update_rack(RackId(1), Resources::ZERO);
        let got = g.route(Resources::new(8.0, 8192.0));
        assert_ne!(got, RackId(1));
    }

    #[test]
    fn global_round_robins_between_equal_racks() {
        let mut g = GlobalScheduler::new(2);
        g.update_rack(RackId(0), Resources::new(100.0, 100.0));
        g.update_rack(RackId(1), Resources::new(100.0, 100.0));
        let a = g.route(Resources::new(1.0, 1.0));
        let b = g.route(Resources::new(1.0, 1.0));
        assert_ne!(a, b, "equal racks should alternate");
    }

    #[test]
    fn round_robin_is_fair_across_equal_racks() {
        // Satellite regression test: repeated routing over equally-
        // loaded racks must spread requests evenly, with and without
        // interleaved (no-op) availability refreshes.
        let n = 4;
        let mut g = GlobalScheduler::new(n);
        for i in 0..n {
            g.update_rack(RackId(i), Resources::new(100.0, 100000.0));
        }
        let mut counts = vec![0usize; n];
        for round in 0..3 * n {
            if round % 2 == 0 {
                // refresh with unchanged values, like the executor does
                for i in 0..n {
                    g.update_rack(RackId(i), Resources::new(100.0, 100000.0));
                }
            }
            let got = g.route(Resources::new(1.0, 1.0));
            counts[got.0] += 1;
        }
        assert!(
            counts.iter().all(|&c| c == 3),
            "uneven round-robin: {counts:?}"
        );
    }

    #[test]
    fn best_rack_cache_survives_degrade_and_recover() {
        let mut g = GlobalScheduler::new(3);
        for i in 0..3 {
            g.update_rack(RackId(i), Resources::new(50.0, 50000.0));
        }
        let _ = g.route(Resources::new(1.0, 1.0)); // builds the cache
        // the whole best set degrades → stale → next route rebuilds
        for i in 0..3 {
            g.update_rack(RackId(i), Resources::new(10.0, 10000.0));
        }
        let got = g.route(Resources::new(1.0, 1.0));
        assert!(got.0 < 3);
        // one rack recovers and must win immediately
        g.update_rack(RackId(2), Resources::new(60.0, 60000.0));
        assert_eq!(g.route(Resources::new(1.0, 1.0)), RackId(2));
    }

    #[test]
    fn route_falls_back_when_best_rack_cannot_fit() {
        // Rack 0: CPU-rich but memory-poor (highest magnitude); rack 1
        // fits the estimate. The fast path must yield to the scan.
        let mut g = GlobalScheduler::new(2);
        g.update_rack(RackId(0), Resources::new(32.0, 1000.0));
        g.update_rack(RackId(1), Resources::new(8.0, 32000.0));
        let got = g.route(Resources::new(4.0, 16000.0));
        assert_eq!(got, RackId(1));
    }

    #[test]
    fn route_stats_split_fast_path_from_scans() {
        let mut g = GlobalScheduler::new(2);
        g.update_rack(RackId(0), Resources::new(100.0, 100000.0));
        g.update_rack(RackId(1), Resources::new(100.0, 100000.0));
        assert_eq!(g.route_stats(), RouteStats::default());
        // equal fitting racks ride the cache fast path (the lazy
        // rebuild of a stale cache does not count as a scan)
        let _ = g.route(Resources::new(1.0, 1.0));
        let _ = g.route(Resources::new(1.0, 1.0));
        let _ = g.route(Resources::new(1.0, 1.0));
        let s = g.route_stats();
        assert_eq!(s.fast_hits + s.scans, 3);
        assert!(s.fast_hits >= 2, "equal racks must ride the cache: {s:?}");
        // an unfittable estimate forces the fallback scan
        let _ = g.route(Resources::new(1e6, 1e9));
        assert_eq!(g.route_stats().scans, s.scans + 1);
    }

    #[test]
    fn affinity_route_prefers_resident_rack_then_spills() {
        let mut g = GlobalScheduler::new(2);
        g.update_rack(RackId(0), Resources::new(100.0, 100000.0));
        g.update_rack(RackId(1), Resources::new(4.0, 2048.0));
        // rack 1 fits a small stage: affinity wins even though rack 0
        // has far more available resources
        let (rack, hit) = g.route_with_affinity(Resources::new(1.0, 512.0), RackId(1));
        assert_eq!(rack, RackId(1));
        assert!(hit);
        // a stage too big for the preferred rack spills to smallest-fit
        let (rack, hit) = g.route_with_affinity(Resources::new(16.0, 32000.0), RackId(1));
        assert_eq!(rack, RackId(0));
        assert!(!hit);
        let s = g.route_stats();
        assert_eq!((s.affinity_hits, s.affinity_spills), (1, 1));
        // out-of-range preference never panics, it spills
        let (_, hit) = g.route_with_affinity(Resources::new(1.0, 1.0), RackId(9));
        assert!(!hit);
    }

    #[test]
    fn compilation_cache_hits_second_time() {
        let mut g = GlobalScheduler::new(1);
        assert!(!g.compilation("app", Compilation::AllLocal));
        assert!(g.compilation("app", Compilation::AllLocal));
        assert!(!g.compilation("app", Compilation::Mixed(0b101)));
        assert!(g.compilation("app", Compilation::Mixed(0b101)));
    }

    #[test]
    fn rack_allocates_and_spills() {
        let mut c = cluster(2);
        let rs = RackScheduler::new(&c, RackId(0));
        // fill rack 0 completely
        let per_server = Resources::new(32.0, 65536.0);
        for id in rs.servers().to_vec() {
            match rs.allocate(&mut c, per_server, &[], 0.0) {
                Allocation::Placed { .. } => {}
                Allocation::Spill => panic!("should fit on {id:?}"),
            }
        }
        assert_eq!(rs.allocate(&mut c, Resources::new(1.0, 1.0), &[], 1.0), Allocation::Spill);
        // rack 1 untouched
        let rs1 = RackScheduler::new(&c, RackId(1));
        assert!(matches!(
            rs1.allocate(&mut c, Resources::new(1.0, 1.0), &[], 2.0),
            Allocation::Placed { .. }
        ));
    }

    #[test]
    fn rack_prefers_colocated_data_server() {
        let mut c = cluster(1);
        let rs = RackScheduler::new(&c, RackId(0));
        let data_server = ServerId(2);
        c.server_mut(data_server).try_alloc(Resources::mem_only(1000.0), 0.0);
        match rs.allocate(&mut c, Resources::new(2.0, 2048.0), &[data_server], 1.0) {
            Allocation::Placed { server, colocated } => {
                assert_eq!(server, data_server);
                assert!(colocated);
            }
            Allocation::Spill => panic!("should place"),
        }
    }

    #[test]
    fn rack_ignores_foreign_data_servers() {
        let mut c = cluster(2);
        let rs = RackScheduler::new(&c, RackId(0));
        // data server is in rack 1: allocation stays in rack 0, not colocated
        match rs.allocate(&mut c, Resources::new(2.0, 2048.0), &[ServerId(7)], 0.0) {
            Allocation::Placed { server, colocated } => {
                assert!(rs.servers().contains(&server));
                assert!(!colocated);
            }
            Allocation::Spill => panic!("should place"),
        }
    }
}
