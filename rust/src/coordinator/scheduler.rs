//! Two-level scheduler (§5.3.1).
//!
//! One **global scheduler** per cluster tracks rough per-rack
//! availability, balances application requests across racks, and owns
//! the compilation database. One **rack scheduler** per rack holds the
//! exact per-server view and serves per-component allocation requests.
//! When a rack runs out, the request bounces back to the global
//! scheduler for another rack.
//!
//! The decision paths are allocation-free so the scalability targets
//! (§6.2: 50k apps/s global, 20k components/s rack) hold; see
//! `rust/benches/scheduler.rs`.

use std::collections::HashMap;

use crate::cluster::{Cluster, RackId, Resources, ServerId};

use super::placement;

/// Compilation database entry (§4.2: two pre-compiled versions; runtime
/// layouts compiled on demand and cached).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Compilation {
    /// All accessed data local — native memory instructions.
    AllLocal,
    /// All accessed data remote — BulkX data-access APIs.
    AllRemote,
    /// Mixed layout, keyed by a bitmask of which data is local.
    Mixed(u32),
}

/// The global scheduler.
#[derive(Debug, Default)]
pub struct GlobalScheduler {
    /// Rough per-rack availability (refreshed by rack schedulers).
    rack_avail: Vec<Resources>,
    /// Compilation DB: (app, variant) -> compiled (cache hit at runtime).
    compilations: HashMap<(String, Compilation), bool>,
    /// Round-robin cursor for tie-breaking equally-loaded racks.
    cursor: usize,
}

impl GlobalScheduler {
    pub fn new(racks: usize) -> Self {
        Self {
            rack_avail: vec![Resources::ZERO; racks],
            compilations: HashMap::new(),
            cursor: 0,
        }
    }

    /// Refresh the rough view for one rack (rack schedulers push this).
    pub fn update_rack(&mut self, rack: RackId, avail: Resources) {
        self.rack_avail[rack.0] = avail;
    }

    /// Route an application request: the rack with the most available
    /// resources that fits `estimate` (load balancing), else the rack
    /// with the most available overall (it will queue/spill).
    pub fn route(&mut self, estimate: Resources) -> RackId {
        let n = self.rack_avail.len();
        let mut best: Option<(usize, f64)> = None;
        for off in 0..n {
            let i = (self.cursor + off) % n;
            let a = self.rack_avail[i];
            let mag = a.magnitude();
            let fits = a.fits(estimate);
            match best {
                Some((_, bm)) if !fits && bm >= mag => {}
                Some((bi, bm)) => {
                    let best_fits = self.rack_avail[bi].fits(estimate);
                    if (fits && !best_fits) || (fits == best_fits && mag > bm) {
                        best = Some((i, mag));
                    }
                }
                None => best = Some((i, mag)),
            }
        }
        self.cursor = (self.cursor + 1) % n;
        RackId(best.map(|(i, _)| i).unwrap_or(0))
    }

    /// Look up / install a compilation (returns true on cache hit).
    pub fn compilation(&mut self, app: &str, variant: Compilation) -> bool {
        let key = (app.to_string(), variant);
        if self.compilations.contains_key(&key) {
            true
        } else {
            self.compilations.insert(key, true);
            false
        }
    }
}

/// One rack's scheduler: exact server accounting within the rack.
#[derive(Debug)]
pub struct RackScheduler {
    pub rack: RackId,
    servers: Vec<ServerId>,
}

/// Outcome of a component allocation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Allocation {
    /// Placed on a server; `colocated` = with its accessed data.
    Placed { server: ServerId, colocated: bool },
    /// Rack out of resources: bounce to the global scheduler (§5.3.1).
    Spill,
}

impl RackScheduler {
    pub fn new(cluster: &Cluster, rack: RackId) -> Self {
        Self { rack, servers: cluster.rack_servers(rack).collect() }
    }

    pub fn servers(&self) -> &[ServerId] {
        &self.servers
    }

    /// Try to fit the whole application on one server (§5.1.1 step 1).
    pub fn whole_app_fit(&self, cluster: &Cluster, demand: Resources) -> Option<ServerId> {
        placement::smallest_fit_among(cluster, demand, &mut self.servers.iter().copied())
    }

    /// Allocate one component; commits the allocation into the cluster.
    pub fn allocate(
        &self,
        cluster: &mut Cluster,
        demand: Resources,
        data_servers: &[ServerId],
        now: f64,
    ) -> Allocation {
        let rack_data: Vec<ServerId> = data_servers
            .iter()
            .copied()
            .filter(|id| self.servers.contains(id))
            .collect();
        // restrict placement to this rack
        let in_rack = |id: ServerId| self.servers.contains(&id);
        let choice = placement::smallest_fit_among(
            cluster,
            demand,
            &mut rack_data.iter().copied(),
        )
        .map(|id| (id, true))
        .or_else(|| {
            placement::smallest_fit_among(
                cluster,
                demand,
                &mut self.servers.iter().copied(),
            )
            .map(|id| (id, rack_data.contains(&id)))
        });
        match choice {
            Some((server, colocated)) if in_rack(server) => {
                let ok = cluster.server_mut(server).try_alloc(demand, now);
                debug_assert!(ok, "placement said it fits");
                Allocation::Placed { server, colocated }
            }
            _ => Allocation::Spill,
        }
    }

    /// Release a component's resources.
    pub fn release(&self, cluster: &mut Cluster, server: ServerId, amount: Resources, now: f64) {
        cluster.server_mut(server).free(amount, now);
    }

    /// Rough availability to push up to the global scheduler.
    pub fn availability(&self, cluster: &Cluster) -> Resources {
        cluster.rack_available(self.rack)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn cluster(racks: usize) -> Cluster {
        Cluster::new(ClusterSpec::multi_rack(racks, 4))
    }

    #[test]
    fn global_routes_to_most_available_fitting_rack() {
        let c = cluster(3);
        let mut g = GlobalScheduler::new(3);
        for r in c.racks() {
            g.update_rack(r, c.rack_available(r));
        }
        // rack 1 drained
        g.update_rack(RackId(1), Resources::ZERO);
        let got = g.route(Resources::new(8.0, 8192.0));
        assert_ne!(got, RackId(1));
    }

    #[test]
    fn global_round_robins_between_equal_racks() {
        let mut g = GlobalScheduler::new(2);
        g.update_rack(RackId(0), Resources::new(100.0, 100.0));
        g.update_rack(RackId(1), Resources::new(100.0, 100.0));
        let a = g.route(Resources::new(1.0, 1.0));
        let b = g.route(Resources::new(1.0, 1.0));
        assert_ne!(a, b, "equal racks should alternate");
    }

    #[test]
    fn compilation_cache_hits_second_time() {
        let mut g = GlobalScheduler::new(1);
        assert!(!g.compilation("app", Compilation::AllLocal));
        assert!(g.compilation("app", Compilation::AllLocal));
        assert!(!g.compilation("app", Compilation::Mixed(0b101)));
        assert!(g.compilation("app", Compilation::Mixed(0b101)));
    }

    #[test]
    fn rack_allocates_and_spills() {
        let mut c = cluster(2);
        let rs = RackScheduler::new(&c, RackId(0));
        // fill rack 0 completely
        let per_server = Resources::new(32.0, 65536.0);
        for id in rs.servers().to_vec() {
            match rs.allocate(&mut c, per_server, &[], 0.0) {
                Allocation::Placed { .. } => {}
                Allocation::Spill => panic!("should fit on {id:?}"),
            }
        }
        assert_eq!(rs.allocate(&mut c, Resources::new(1.0, 1.0), &[], 1.0), Allocation::Spill);
        // rack 1 untouched
        let rs1 = RackScheduler::new(&c, RackId(1));
        assert!(matches!(
            rs1.allocate(&mut c, Resources::new(1.0, 1.0), &[], 2.0),
            Allocation::Placed { .. }
        ));
    }

    #[test]
    fn rack_prefers_colocated_data_server() {
        let mut c = cluster(1);
        let rs = RackScheduler::new(&c, RackId(0));
        let data_server = ServerId(2);
        c.server_mut(data_server).try_alloc(Resources::mem_only(1000.0), 0.0);
        match rs.allocate(&mut c, Resources::new(2.0, 2048.0), &[data_server], 1.0) {
            Allocation::Placed { server, colocated } => {
                assert_eq!(server, data_server);
                assert!(colocated);
            }
            Allocation::Spill => panic!("should place"),
        }
    }

    #[test]
    fn rack_ignores_foreign_data_servers() {
        let mut c = cluster(2);
        let rs = RackScheduler::new(&c, RackId(0));
        // data server is in rack 1: allocation stays in rack 0, not colocated
        match rs.allocate(&mut c, Resources::new(2.0, 2048.0), &[ServerId(7)], 0.0) {
            Allocation::Placed { server, colocated } => {
                assert!(rs.servers().contains(&server));
                assert!(!colocated);
            }
            Allocation::Spill => panic!("should place"),
        }
    }
}
