//! Distributed synchronization primitives (§5.3.3 + §8.1).
//!
//! Zenix provides `@mutex` (distributed lock), `@barrier`, and
//! `@message` rather than a coherence protocol: compute components
//! sharing a data component coordinate explicitly. These are the
//! platform-side implementations, modeled with their messaging costs so
//! the simulator can charge them.

use std::collections::VecDeque;

/// A distributed lock: FIFO grant order, one holder at a time.
#[derive(Debug, Default)]
pub struct DistLock {
    holder: Option<u64>,
    waiters: VecDeque<u64>,
}

impl DistLock {
    /// Fresh unheld lock with an empty waiter queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request the lock for `owner`; true if granted immediately.
    pub fn acquire(&mut self, owner: u64) -> bool {
        if self.holder.is_none() {
            self.holder = Some(owner);
            true
        } else if self.holder == Some(owner) {
            true // re-entrant
        } else {
            if !self.waiters.contains(&owner) {
                self.waiters.push_back(owner);
            }
            false
        }
    }

    /// Release by `owner`; returns the next grantee if any.
    pub fn release(&mut self, owner: u64) -> Option<u64> {
        if self.holder != Some(owner) {
            return None;
        }
        self.holder = self.waiters.pop_front();
        self.holder
    }

    /// Current holder, if the lock is held.
    pub fn holder(&self) -> Option<u64> {
        self.holder
    }

    /// Number of queued waiters.
    pub fn queue_len(&self) -> usize {
        self.waiters.len()
    }
}

/// A counting barrier over `n` participants.
#[derive(Debug)]
pub struct Barrier {
    n: usize,
    arrived: Vec<u64>,
    generation: u64,
}

impl Barrier {
    /// Barrier over `n > 0` participants (panics on `n == 0`).
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { n, arrived: Vec::new(), generation: 0 }
    }

    /// Arrive at the barrier; returns `Some(generation)` when this
    /// arrival releases everyone (the barrier then resets).
    pub fn arrive(&mut self, who: u64) -> Option<u64> {
        if !self.arrived.contains(&who) {
            self.arrived.push(who);
        }
        if self.arrived.len() == self.n {
            self.arrived.clear();
            self.generation += 1;
            Some(self.generation)
        } else {
            None
        }
    }

    /// Distinct arrivals in the current generation.
    pub fn waiting(&self) -> usize {
        self.arrived.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_fifo_handoff() {
        let mut l = DistLock::new();
        assert!(l.acquire(1));
        assert!(!l.acquire(2));
        assert!(!l.acquire(3));
        assert_eq!(l.queue_len(), 2);
        assert_eq!(l.release(1), Some(2));
        assert_eq!(l.holder(), Some(2));
        assert_eq!(l.release(2), Some(3));
        assert_eq!(l.release(3), None);
        assert_eq!(l.holder(), None);
    }

    #[test]
    fn lock_reentrant_and_foreign_release_ignored() {
        let mut l = DistLock::new();
        assert!(l.acquire(7));
        assert!(l.acquire(7));
        assert_eq!(l.release(9), None); // not the holder
        assert_eq!(l.holder(), Some(7));
    }

    #[test]
    fn duplicate_waiters_not_queued_twice() {
        let mut l = DistLock::new();
        l.acquire(1);
        l.acquire(2);
        l.acquire(2);
        assert_eq!(l.queue_len(), 1);
    }

    #[test]
    fn barrier_releases_on_nth() {
        let mut b = Barrier::new(3);
        assert_eq!(b.arrive(1), None);
        assert_eq!(b.arrive(2), None);
        assert_eq!(b.arrive(2), None); // duplicate arrival ignored
        assert_eq!(b.arrive(3), Some(1));
        // reusable: next generation
        assert_eq!(b.arrive(1), None);
        assert_eq!(b.waiting(), 1);
    }
}
