//! The Zenix coordinator — the paper's system contribution.
//!
//! - [`graph`] — the *resource graph* IR (§4.2): compute/data component
//!   nodes with trigger/access edges, derived from program annotations.
//! - [`history`] — decaying-weight resource profiles per graph node
//!   (§4.2 sampling + §5.2.3 history-based adjustment inputs).
//! - [`adjust`] — the init/incremental sizing optimizer (§9.3).
//! - [`placement`] — locality-based greedy placement (§5.1.1).
//! - [`scheduler`] — two-level global/rack scheduler (§5.3.1).
//! - [`msglog`] — reliable message log (Kafka substitute, §5.3.2).
//! - [`failure`] — resource-graph-cut recovery (§5.3.2).
//! - [`faults`] — deterministic fault injection: the seeded chaos
//!   schedule (server crash / rack outage / transient compute crash)
//!   the driver replays to exercise [`failure`] at scale.
//! - [`sync`] — distributed lock/barrier primitives (§5.3.3).
//! - [`exec`] — the adaptive execution engine + [`exec::Platform`]:
//!   sizing, materialization, autoscaling, proactive startup (§5.1-5.2).
//! - [`driver`] — multi-tenant trace-driven workload driver: overlapping
//!   invocations from N apps interleaved on one shared platform over
//!   simulated time (the Fig 22/26/29 load scenario).
//! - [`epoch`] — the driver's sharded epoch-barrier event loop:
//!   per-rack shard workers replay rack-local timelines inside bounded
//!   epochs; cross-shard effects exchange at a deterministic barrier in
//!   canonical `(time, seq)` order, so every worker count produces the
//!   sequential loop's exact digest.
//! - [`admission`] — admission control for the driver: deferred-arrival
//!   queueing policies (FIFO, fair-share, weighted fair-share,
//!   SLO-deadline EDF), burst arrival models (MMPP / rate replay), and
//!   the rejected/aborted/timed-out accounting split.
//! - [`workflow`] — workflow-structured tenants: inter-invocation DAGs
//!   (pipelines, fan-out/fan-in) whose stage completions enqueue
//!   downstream invocations through either event loop, with handoff
//!   data retained on the producer's rack and rack-affinity placement
//!   for downstream stages.

// Modules below that have not yet had their rustdoc sweep are shielded
// from the crate-level `missing_docs` lint; drop the `allow` when
// sweeping one.
#[allow(missing_docs)]
pub mod adjust;
pub mod admission;
pub mod driver;
pub mod epoch;
pub mod exec;
pub mod failure;
pub mod faults;
pub mod graph;
pub mod history;
pub mod msglog;
pub mod placement;
pub mod scheduler;
pub mod sync;
pub mod workflow;

pub use admission::{AdmissionOutcome, AdmissionPolicy, ArrivalModel, DeferredQueues};
pub use faults::{FaultConfig, FaultPlan};
pub use driver::{DriverConfig, DriverReport, MultiTenantDriver, Schedule, TenantApp};
pub use scheduler::RouteStats;
pub use exec::{OngoingInvocation, Platform, ZenixConfig};
pub use graph::{NodeId, NodeKind, ResourceGraph};
pub use history::ProfileStore;
pub use workflow::{StageLaunch, Workflow, WorkflowEdge, WorkflowRuntime, WorkflowStats};
