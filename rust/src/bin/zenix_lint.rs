//! CLI for the dependency-free determinism & accounting lint.
//!
//! ```text
//! cargo run --release --bin zenix_lint            # human-readable
//! cargo run --release --bin zenix_lint -- --json  # machine-readable
//! ```
//!
//! Scans `rust/src/**/*.rs` (with `rust/tests/` as auxiliary context)
//! against the committed allowlist and exits nonzero on any violation —
//! the CI gate in `scripts/ci.sh`. Exit codes: 0 clean, 1 violations,
//! 2 usage/scan error.

use std::path::PathBuf;

const USAGE: &str = "usage: zenix_lint [--json] [--root <repo-root>]
  --json    emit the machine-readable JSON report instead of text
  --root    repo root to scan (default: this crate's manifest dir)";

fn main() {
    let mut json = false;
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("zenix_lint: --root needs a path\n{USAGE}");
                    std::process::exit(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return;
            }
            other => {
                eprintln!("zenix_lint: unknown argument {other:?}\n{USAGE}");
                std::process::exit(2);
            }
        }
    }
    match zenix::analysis::scan_repo(&root) {
        Ok(r) => {
            if json {
                println!("{}", r.render_json());
            } else {
                print!("{}", r.render_text());
            }
            std::process::exit(if r.clean() { 0 } else { 1 });
        }
        Err(e) => {
            eprintln!("zenix_lint: scan failed: {e:#}");
            std::process::exit(2);
        }
    }
}
