//! Plain-text allowlist for `zenix_lint` (`analysis/allowlist.toml`).
//!
//! A deliberately tiny TOML subset, hand-parsed so the lint stays
//! dependency-free: `[[allow]]` / `[[conservation]]` table headers
//! followed by `key = "value"` lines. Every `[[allow]]` entry carries a
//! **mandatory reason** — an allowlisted hazard without a justification
//! is a parse error, and an entry that matches nothing in the tree is a
//! *stale-entry* violation, so the list can only shrink as hazards are
//! fixed (the D5 contract).

use crate::Result;

/// One justified suppression.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    /// Rule id the entry applies to (`"D1"` … `"D6"`, `"C1"`).
    pub rule: String,
    /// File the hazard lives in — matched as a suffix of the scanned
    /// path, so `util/rng.rs` matches `rust/src/util/rng.rs`.
    pub file: String,
    /// The flagged token (hazard identifier, module name, …).
    pub token: String,
    /// Mandatory justification.
    pub reason: String,
}

/// One term of the D4 arrival-conservation inventory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConservationTerm {
    /// Field name summed by `AppStats::failed()`.
    pub term: String,
    /// What the counter means (documentation, also mandatory).
    pub meaning: String,
}

/// Parsed allowlist file.
#[derive(Debug, Default)]
pub struct Allowlist {
    /// Justified suppressions.
    pub allows: Vec<AllowEntry>,
    /// The checked failure-counter inventory (rule D4).
    pub conservation: Vec<ConservationTerm>,
}

impl Allowlist {
    /// Find an entry matching `(rule, file, token)`; returns its index
    /// so the engine can track per-entry use (stale detection).
    pub fn find(&self, rule: &str, file: &str, token: &str) -> Option<usize> {
        self.allows
            .iter()
            .position(|e| e.rule == rule && file.ends_with(&e.file) && e.token == token)
    }
}

/// Parse the allowlist text. Errors on unknown keys, missing mandatory
/// fields, or `key = value` lines outside an entry.
pub fn parse(text: &str) -> Result<Allowlist> {
    #[derive(PartialEq)]
    enum Section {
        None,
        Allow,
        Conservation,
    }
    let mut out = Allowlist::default();
    let mut section = Section::None;
    // pending key-value pairs of the current entry
    let mut kv: Vec<(String, String)> = Vec::new();

    let flush = |section: &Section, kv: &mut Vec<(String, String)>, out: &mut Allowlist| -> Result<()> {
        let take = |kv: &[(String, String)], key: &str| -> Option<String> {
            kv.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone())
        };
        match section {
            Section::None => {}
            Section::Allow => {
                let entry = AllowEntry {
                    rule: take(kv, "rule").unwrap_or_default(),
                    file: take(kv, "file").unwrap_or_default(),
                    token: take(kv, "token").unwrap_or_default(),
                    reason: take(kv, "reason").unwrap_or_default(),
                };
                if entry.rule.is_empty() || entry.file.is_empty() || entry.token.is_empty() {
                    anyhow::bail!("[[allow]] entry needs rule/file/token: {kv:?}");
                }
                if entry.reason.trim().is_empty() {
                    anyhow::bail!(
                        "[[allow]] {} {} {}: reason is mandatory",
                        entry.rule,
                        entry.file,
                        entry.token
                    );
                }
                out.allows.push(entry);
            }
            Section::Conservation => {
                let term = ConservationTerm {
                    term: take(kv, "term").unwrap_or_default(),
                    meaning: take(kv, "meaning").unwrap_or_default(),
                };
                if term.term.is_empty() || term.meaning.trim().is_empty() {
                    anyhow::bail!("[[conservation]] entry needs term + meaning: {kv:?}");
                }
                out.conservation.push(term);
            }
        }
        kv.clear();
        Ok(())
    };

    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            flush(&section, &mut kv, &mut out)?;
            section = Section::Allow;
            continue;
        }
        if line == "[[conservation]]" {
            flush(&section, &mut kv, &mut out)?;
            section = Section::Conservation;
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            if section == Section::None {
                anyhow::bail!("line {}: key outside an entry: {line}", ln + 1);
            }
            let key = k.trim().to_string();
            let val = v.trim();
            let val = val
                .strip_prefix('"')
                .and_then(|s| s.strip_suffix('"'))
                .ok_or_else(|| anyhow::anyhow!("line {}: value must be double-quoted: {line}", ln + 1))?;
            if !matches!(key.as_str(), "rule" | "file" | "token" | "reason" | "term" | "meaning") {
                anyhow::bail!("line {}: unknown key {key:?}", ln + 1);
            }
            kv.push((key, val.to_string()));
            continue;
        }
        anyhow::bail!("line {}: unparseable allowlist line: {line}", ln + 1);
    }
    flush(&section, &mut kv, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_entries_and_sections() {
        let a = parse(
            "# comment\n\n[[allow]]\nrule = \"D2\"\nfile = \"util/rng.rs\"\ntoken = \"SystemTime\"\nreason = \"opt-in\"\n\n[[conservation]]\nterm = \"rejected\"\nmeaning = \"admission-time rejections\"\n",
        )
        .unwrap();
        assert_eq!(a.allows.len(), 1);
        assert_eq!(a.conservation.len(), 1);
        assert_eq!(a.allows[0].token, "SystemTime");
        assert!(a.find("D2", "rust/src/util/rng.rs", "SystemTime").is_some());
        assert!(a.find("D2", "rust/src/util/other.rs", "SystemTime").is_none());
        assert!(a.find("D5", "rust/src/util/rng.rs", "SystemTime").is_none());
    }

    #[test]
    fn reason_is_mandatory() {
        let err = parse("[[allow]]\nrule = \"D2\"\nfile = \"a.rs\"\ntoken = \"Instant\"\nreason = \"  \"\n");
        assert!(err.is_err());
        let err = parse("[[allow]]\nrule = \"D2\"\nfile = \"a.rs\"\ntoken = \"Instant\"\n");
        assert!(err.is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_loose_lines() {
        assert!(parse("[[allow]]\nrule = \"D2\"\nbogus = \"x\"\n").is_err());
        assert!(parse("rule = \"D2\"\n").is_err());
        assert!(parse("[[allow]]\nrule = unquoted\n").is_err());
    }
}
