//! Minimal hand-rolled Rust tokenizer for the `zenix_lint` pass.
//!
//! Produces a flat token stream with line numbers — identifiers,
//! punctuation, literals, lifetimes and comments. Deliberately *not* a
//! parser: the rule engine ([`super::rules`]) pattern-matches token
//! sequences, which is exactly the granularity the determinism and
//! accounting rules need. Crucially, hazard names inside string
//! literals or comments lex as [`TokKind::Str`] / [`TokKind::Comment`]
//! tokens, so the lint can mention `"SystemTime"` in its own source
//! (and in fixture strings) without flagging itself.
//!
//! Handled: line comments, nested block comments, string literals with
//! escapes, raw strings (`r"…"`, `r#"…"#`, any hash depth), byte
//! strings, char literals vs lifetimes, numeric literals with
//! underscores and type suffixes (`0xcbf2_9ce4u64` is one token).

/// Token category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `HashMap`, `as`, …).
    Ident,
    /// Single punctuation character (`.`, `:`, `{`, `<`, …).
    Punct,
    /// String literal (normal, raw or byte), quotes included.
    Str,
    /// Char literal, quotes included.
    Char,
    /// Numeric literal, underscores and suffix included.
    Num,
    /// Lifetime (`'a`, `'static`), leading quote included.
    Lifetime,
    /// Line or block comment, delimiters included.
    Comment,
}

/// One lexed token.
#[derive(Debug, Clone)]
pub struct Token {
    /// Category.
    pub kind: TokKind,
    /// Verbatim source text of the token.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

impl Token {
    fn new(kind: TokKind, text: String, line: u32) -> Self {
        Token { kind, text, line }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a token stream. Never fails: unterminated constructs
/// simply run to end-of-file (the lint scans code that `cargo build`
/// already accepted, so this is a non-issue in practice).
pub fn lex(src: &str) -> Vec<Token> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            let start_line = line;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            toks.push(Token::new(TokKind::Comment, b[start..i].iter().collect(), start_line));
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Token::new(TokKind::Comment, b[start..i].iter().collect(), start_line));
            continue;
        }
        // raw / byte strings: r"…", r#"…"#, b"…", br#"…"#
        if c == 'r' || c == 'b' {
            if let Some((tok, next)) = try_raw_or_byte_string(&b, i, line) {
                line += u32::try_from(tok.text.matches('\n').count()).unwrap_or(0);
                toks.push(tok);
                i = next;
                continue;
            }
        }
        // normal string
        if c == '"' {
            let start = i;
            let start_line = line;
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    i += 2;
                } else if b[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Token::new(TokKind::Str, b[start..i].iter().collect(), start_line));
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let start = i;
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char literal: '\n', '\'', '\u{…}'
                i += 2;
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                toks.push(Token::new(TokKind::Char, b[start..i].iter().collect(), line));
            } else if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // plain one-char literal: 'x'
                i += 3;
                toks.push(Token::new(TokKind::Char, b[start..i].iter().collect(), line));
            } else {
                // lifetime: 'a, 'static, '_
                i += 1;
                while i < n && is_ident_continue(b[i]) {
                    i += 1;
                }
                toks.push(Token::new(TokKind::Lifetime, b[start..i].iter().collect(), line));
            }
            continue;
        }
        // number: digits, underscores, suffixes, hex/oct/bin, one '.'
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            let mut seen_dot = false;
            while i < n {
                if is_ident_continue(b[i]) {
                    i += 1;
                } else if b[i] == '.'
                    && !seen_dot
                    && i + 1 < n
                    && b[i + 1].is_ascii_digit()
                {
                    seen_dot = true;
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Token::new(TokKind::Num, b[start..i].iter().collect(), line));
            continue;
        }
        // identifier / keyword
        if is_ident_start(c) {
            let start = i;
            i += 1;
            while i < n && is_ident_continue(b[i]) {
                i += 1;
            }
            toks.push(Token::new(TokKind::Ident, b[start..i].iter().collect(), line));
            continue;
        }
        // single-char punctuation
        toks.push(Token::new(TokKind::Punct, c.to_string(), line));
        i += 1;
    }
    toks
}

/// Try to lex a raw or byte string starting at `i`; returns the token
/// and the index just past it, or `None` if this isn't one.
fn try_raw_or_byte_string(b: &[char], i: usize, line: u32) -> Option<(Token, usize)> {
    let n = b.len();
    let mut j = i;
    if j < n && b[j] == 'b' {
        j += 1;
    }
    let raw = j < n && b[j] == 'r';
    if raw {
        j += 1;
        let mut hashes = 0usize;
        while j < n && b[j] == '#' {
            hashes += 1;
            j += 1;
        }
        if j >= n || b[j] != '"' {
            return None; // `r` / `br` was just an identifier prefix
        }
        j += 1;
        // scan for closing `"` followed by `hashes` hashes
        loop {
            if j >= n {
                break;
            }
            if b[j] == '"' {
                let mut k = 0usize;
                while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                    k += 1;
                }
                if k == hashes {
                    j += 1 + hashes;
                    break;
                }
            }
            j += 1;
        }
        return Some((Token::new(TokKind::Str, b[i..j].iter().collect(), line), j));
    }
    // byte string b"…" (no raw)
    if j > i && j < n && b[j] == '"' {
        j += 1;
        while j < n {
            if b[j] == '\\' && j + 1 < n {
                j += 2;
            } else if b[j] == '"' {
                j += 1;
                break;
            } else {
                j += 1;
            }
        }
        return Some((Token::new(TokKind::Str, b[i..j].iter().collect(), line), j));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let t = kinds("let x = 0xcbf2_9ce4_8422_2325u64;");
        assert_eq!(
            t,
            vec![
                (TokKind::Ident, "let".to_string()),
                (TokKind::Ident, "x".to_string()),
                (TokKind::Punct, "=".to_string()),
                (TokKind::Num, "0xcbf2_9ce4_8422_2325u64".to_string()),
                (TokKind::Punct, ";".to_string()),
            ]
        );
    }

    #[test]
    fn hazards_in_strings_are_not_idents() {
        let t = lex(r#"let s = "SystemTime::now()";"#);
        assert!(t.iter().all(|t| !(t.kind == TokKind::Ident && t.text == "SystemTime")));
        assert!(t.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn comments_capture_text_and_nesting() {
        let t = lex("a /* outer /* inner */ still */ b // tail\nc");
        let comments: Vec<&str> =
            t.iter().filter(|t| t.kind == TokKind::Comment).map(|t| t.text.as_str()).collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].contains("inner"));
        assert!(comments[1].starts_with("// tail"));
        let idents: Vec<&str> =
            t.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
        assert_eq!(idents, vec!["a", "b", "c"]);
    }

    #[test]
    fn raw_strings_swallow_quotes() {
        let t = kinds(r##"x(r#"has "quotes" and // not a comment"#)"##);
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Str).count(), 1);
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Comment).count(), 0);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let t = kinds("fn f<'a>(x: &'a str) { let c = 'y'; let nl = '\\n'; }");
        let lifetimes = t.iter().filter(|(k, _)| *k == TokKind::Lifetime).count();
        let chars = t.iter().filter(|(k, _)| *k == TokKind::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn line_numbers_advance() {
        let t = lex("a\nb\n\nc");
        let lines: Vec<u32> = t.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn range_is_not_a_float() {
        let t = kinds("for i in 0..5 {}");
        assert!(t.contains(&(TokKind::Num, "0".to_string())));
        assert!(t.contains(&(TokKind::Num, "5".to_string())));
    }
}
