//! Diagnostics and human/machine-readable rendering for `zenix_lint`.

use std::fmt::Write as _;

/// One lint violation with a stable `file:line` anchor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Rule id (`"D1"` … `"D6"`, `"C1"`, or `"ALLOW"` for stale
    /// allowlist entries).
    pub rule: &'static str,
    /// Path relative to `rust/src/` (or `rust/tests/` for aux files).
    pub file: String,
    /// 1-based line of the offending token (0 when file-scoped).
    pub line: u32,
    /// Human-readable description of the violation.
    pub msg: String,
    /// The token an allowlist entry must name to suppress this
    /// diagnostic (hazard identifier, module name, …).
    pub allow_token: String,
}

impl Diagnostic {
    /// Construct a diagnostic.
    pub fn new(
        rule: &'static str,
        file: &str,
        line: u32,
        allow_token: &str,
        msg: String,
    ) -> Self {
        Diagnostic { rule, file: file.to_string(), line, msg, allow_token: allow_token.to_string() }
    }
}

/// Result of one full scan, after allowlist filtering.
#[derive(Debug)]
pub struct ScanResult {
    /// Violations that survived the allowlist (non-empty ⇒ exit 1).
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `rust/src/` files scanned.
    pub files_scanned: usize,
    /// Diagnostics suppressed by allowlist entries.
    pub suppressed: usize,
    /// Rules that ran (for the summary line).
    pub rules_run: Vec<&'static str>,
}

impl ScanResult {
    /// True when the tree is clean.
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Plain-text report: one `file:line: [rule] message` per finding
    /// plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            let _ = writeln!(out, "{}:{}: [{}] {}", d.file, d.line, d.rule, d.msg);
        }
        let _ = writeln!(
            out,
            "zenix_lint: {} file(s), rules {}, {} violation(s), {} allowlisted",
            self.files_scanned,
            self.rules_run.join("+"),
            self.diagnostics.len(),
            self.suppressed
        );
        out
    }

    /// Machine-readable JSON report (`--json`).
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\n  \"violations\": [");
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"token\": \"{}\", \"message\": \"{}\"}}",
                escape(d.rule),
                escape(&d.file),
                d.line,
                escape(&d.allow_token),
                escape(&d.msg)
            );
        }
        if !self.diagnostics.is_empty() {
            out.push('\n');
            out.push_str("  ");
        }
        let _ = write!(
            out,
            "],\n  \"files_scanned\": {},\n  \"suppressed\": {},\n  \"clean\": {}\n}}",
            self.files_scanned,
            self.suppressed,
            self.clean()
        );
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScanResult {
        ScanResult {
            diagnostics: vec![Diagnostic::new(
                "D2",
                "util/example.rs",
                7,
                "SystemTime",
                "wall-clock read: `SystemTime`".to_string(),
            )],
            files_scanned: 3,
            suppressed: 2,
            rules_run: vec!["D1", "D2"],
        }
    }

    #[test]
    fn text_has_file_line_rule() {
        let r = sample().render_text();
        assert!(r.contains("util/example.rs:7: [D2]"));
        assert!(r.contains("1 violation(s), 2 allowlisted"));
    }

    #[test]
    fn json_parses_with_the_vendored_parser() {
        let r = sample();
        let v = crate::util::json::parse(&r.render_json()).expect("valid json");
        let obj = v.as_object().unwrap();
        assert_eq!(obj["files_scanned"], crate::util::json::Value::Number(3.0));
        let viol = obj["violations"].as_array().unwrap();
        assert_eq!(viol.len(), 1);
        assert_eq!(
            viol[0].as_object().unwrap()["rule"],
            crate::util::json::Value::String("D2".to_string())
        );
    }

    #[test]
    fn json_escapes_quotes() {
        let mut r = sample();
        r.diagnostics[0].msg = "has \"quotes\" and \\slash".to_string();
        let v = crate::util::json::parse(&r.render_json()).expect("valid json");
        assert!(format!("{v:?}").contains("quotes"));
    }
}
