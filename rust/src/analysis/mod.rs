//! `zenix_lint`: a dependency-free static determinism & accounting pass.
//!
//! Everything this reproduction guarantees — byte-identical digests per
//! seed (`DRIVER_DIGEST.lock`), the arrival-conservation identity, the
//! allocation-free steady state — is otherwise enforced only
//! *dynamically*, by tests that must happen to execute the offending
//! path. This module rejects the known hazard classes *statically*, at
//! CI time, before the planned sharded-event-loop (parallel replay)
//! refactor would turn any latent one into a silent digest-breaker.
//!
//! Layout:
//!
//! - [`lexer`] — a minimal hand-rolled Rust tokenizer (no parser, no
//!   dependencies; hazard names in strings/comments don't lex as
//!   identifiers, so the lint never flags its own rule tables).
//! - [`rules`] — the D1–D6 + C1 rule engine over token streams.
//! - [`allowlist`] — the tiny hand-parsed TOML-subset allowlist with
//!   mandatory reason strings and stale-entry detection.
//! - [`report`] — `file:line` diagnostics, text and `--json` rendering.
//!
//! The committed allowlist lives at `rust/src/analysis/allowlist.toml`;
//! the CLI entry point is the `zenix_lint` bin target. See
//! `docs/ANALYSIS.md` for the full rule contract.

pub mod allowlist;
pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use crate::Result;
use report::{Diagnostic, ScanResult};
use rules::{Ctx, LexedFile, ALL_RULES};

/// Repo-relative location of the committed allowlist.
pub const ALLOWLIST_PATH: &str = "rust/src/analysis/allowlist.toml";

/// Recursively collect `.rs` paths under `dir`, sorted for a
/// deterministic scan (and report) order.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in fs::read_dir(dir)? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Load and lex every `.rs` file under `dir`; `prefix` is prepended to
/// the dir-relative path (`""` for `rust/src/`, `"tests/"` for aux).
fn load_dir(dir: &Path, prefix: &str) -> Result<Vec<LexedFile>> {
    let mut paths = Vec::new();
    collect_rs(dir, &mut paths)?;
    let mut files = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(dir)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(&p)?;
        files.push(LexedFile::from_source(&format!("{prefix}{rel}"), &text));
    }
    Ok(files)
}

/// Run every rule over pre-lexed sources and filter through the
/// allowlist. Pure (no filesystem) — the unit/fixture seam.
pub fn scan_sources(
    files: &[LexedFile],
    aux: &[LexedFile],
    allow: &allowlist::Allowlist,
) -> ScanResult {
    let ctx = Ctx { files, aux };
    let inventory: Vec<String> = allow.conservation.iter().map(|c| c.term.clone()).collect();
    let raw = rules::run_all(&ctx, &inventory);

    let mut hits = vec![0usize; allow.allows.len()];
    let mut suppressed = 0usize;
    let mut diagnostics = Vec::new();
    for d in raw {
        if let Some(i) = allow.find(d.rule, &d.file, &d.allow_token) {
            hits[i] += 1;
            suppressed += 1;
        } else {
            diagnostics.push(d);
        }
    }
    // an entry that suppresses nothing is itself a violation: the
    // allowlist may only shrink as hazards are fixed
    for (i, e) in allow.allows.iter().enumerate() {
        if hits[i] == 0 {
            diagnostics.push(Diagnostic::new(
                "ALLOW",
                &e.file,
                0,
                &e.token,
                format!(
                    "stale allowlist entry [{} {} {:?}]: it suppresses nothing — remove it (the allowlist only shrinks)",
                    e.rule, e.file, e.token
                ),
            ));
        }
    }
    diagnostics.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    ScanResult {
        diagnostics,
        files_scanned: files.len(),
        suppressed,
        rules_run: ALL_RULES.to_vec(),
    }
}

/// Scan a repo checkout rooted at `root` (the directory holding
/// `Cargo.toml`): lints `rust/src/**/*.rs` with `rust/tests/` as
/// auxiliary context, against the committed allowlist.
pub fn scan_repo(root: &Path) -> Result<ScanResult> {
    let src = root.join("rust").join("src");
    let tests = root.join("rust").join("tests");
    let allow_path = root.join(ALLOWLIST_PATH);
    let allow_text = fs::read_to_string(&allow_path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", allow_path.display()))?;
    let allow = allowlist::parse(&allow_text)?;
    let files = load_dir(&src, "")?;
    let aux = if tests.is_dir() { load_dir(&tests, "tests/")? } else { Vec::new() };
    Ok(scan_sources(&files, &aux, &allow))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stale_allowlist_entries_are_violations() {
        let allow = allowlist::parse(
            "[[allow]]\nrule = \"D2\"\nfile = \"nowhere.rs\"\ntoken = \"Instant\"\nreason = \"left over\"\n",
        )
        .unwrap();
        let files =
            vec![LexedFile::from_source("util/clean.rs", "pub fn f() -> u32 { 7 }\n")];
        let r = scan_sources(&files, &[], &allow);
        assert_eq!(r.diagnostics.len(), 1, "{:?}", r.diagnostics);
        assert_eq!(r.diagnostics[0].rule, "ALLOW");
        assert!(!r.clean());
    }

    #[test]
    fn allowlisted_hazards_are_suppressed_and_counted() {
        let allow = allowlist::parse(
            "[[allow]]\nrule = \"D2\"\nfile = \"util/timed.rs\"\ntoken = \"Instant\"\nreason = \"bench harness, non-sim\"\n",
        )
        .unwrap();
        let files = vec![LexedFile::from_source(
            "util/timed.rs",
            "use std::time::Instant;\npub fn f() { let _ = Instant::now(); }\n",
        )];
        let r = scan_sources(&files, &[], &allow);
        assert!(r.clean(), "{:?}", r.diagnostics);
        assert_eq!(r.suppressed, 2); // the use + the call site
    }

    #[test]
    fn diagnostics_sort_by_file_then_line() {
        let allow = allowlist::Allowlist::default();
        let files = vec![
            LexedFile::from_source("util/b.rs", "pub fn f() { let _ = Instant::now(); }\n"),
            LexedFile::from_source(
                "util/a.rs",
                "pub fn g() { let _ = Instant::now(); }\npub fn h() { let _ = SystemTime::now(); }\n",
            ),
        ];
        let r = scan_sources(&files, &[], &allow);
        let keys: Vec<(&str, u32)> =
            r.diagnostics.iter().map(|d| (d.file.as_str(), d.line)).collect();
        assert_eq!(keys, vec![("util/a.rs", 1), ("util/a.rs", 2), ("util/b.rs", 1)]);
    }
}
