//! The `zenix_lint` rule engine: D1–D6 + C1 over lexed token streams.
//!
//! Each rule is a standalone function from a [`Ctx`] to diagnostics;
//! [`run_all`] composes them. Rules are *syntactic* — a hand-rolled
//! tokenizer cannot do type inference — so they track identifiers bound
//! to hazardous types within a file and pattern-match token sequences.
//! The residual false-positive/negative band is covered by the
//! allowlist (with mandatory reasons) and by code review; the point is
//! that every *known* hazard class is mechanically enumerated and can
//! only shrink.
//!
//! Rule inventory (see `docs/ANALYSIS.md` for the full contract):
//!
//! - **D1** — no iteration-order-dependent traversal of `HashMap` /
//!   `HashSet` in the digest-affecting layers (`coordinator/`,
//!   `cluster/`, `metrics/`). Keyed lookups stay legal.
//! - **D2** — no wall-clock or ambient-entropy APIs anywhere in `src/`.
//! - **D3** — every `DriverReport` field is either folded into the
//!   run digest or carries `// digest: excluded(reason)`.
//! - **D4** — the failure counters summed by `AppStats::failed()`
//!   match the committed conservation inventory exactly, and each term
//!   is exercised by the conservation property tests.
//! - **D5** — shared-mutable-state audit of `coordinator/` against a
//!   shrink-only allowlist (what the sharded event loop must confront).
//! - **D6** — the `#[allow(missing_docs)]` remainder matches the
//!   committed docs-sweep allowlist exactly.
//! - **C1** — no unchecked narrowing `as` casts on the hot path
//!   (`coordinator/`, `metrics/`) without a `// cast: safe(reason)`
//!   annotation; use the `util::cast` checked helpers instead.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, TokKind, Token};
use super::report::Diagnostic;

/// Hash containers whose iteration order is seed-dependent.
const HASH_TYPES: [&str; 2] = ["HashMap", "HashSet"];
/// Methods whose results depend on hash iteration order.
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];
/// Wall-clock / ambient-entropy identifiers banned by D2.
const D2_HAZARDS: [&str; 5] =
    ["SystemTime", "Instant", "thread_rng", "from_entropy", "RandomState"];
/// Shared-mutable-state type identifiers audited by D5.
const D5_HAZARDS: [&str; 7] =
    ["Rc", "RefCell", "Cell", "UnsafeCell", "Mutex", "RwLock", "OnceLock"];
/// Integer destination types of a narrowing-suspect `as` cast (C1).
const INT_TYPES: [&str; 12] = [
    "usize", "isize", "u8", "u16", "u32", "u64", "u128", "i8", "i16", "i32", "i64", "i128",
];
/// Layers whose event/accounting order feeds the run digest (D1 scope).
const DIGEST_LAYERS: [&str; 3] = ["coordinator/", "cluster/", "metrics/"];
/// Hot-path layers swept for unchecked casts (C1 scope).
const CAST_LAYERS: [&str; 2] = ["coordinator/", "metrics/"];

/// A lexed source file, rule-ready.
#[derive(Debug)]
pub struct LexedFile {
    /// Path relative to the scan root (`coordinator/driver.rs`, …).
    pub rel: String,
    /// Full token stream, comments included.
    pub toks: Vec<Token>,
    /// Indices into `toks` of the non-comment tokens.
    pub code: Vec<usize>,
    /// Concatenated comment text per line (annotation lookups).
    pub comments: BTreeMap<u32, String>,
}

impl LexedFile {
    /// Lex `text` as the file `rel`.
    pub fn from_source(rel: &str, text: &str) -> Self {
        let toks = lex(text);
        let mut code = Vec::with_capacity(toks.len());
        let mut comments: BTreeMap<u32, String> = BTreeMap::new();
        for (i, t) in toks.iter().enumerate() {
            if t.kind == TokKind::Comment {
                let slot = comments.entry(t.line).or_default();
                slot.push(' ');
                slot.push_str(&t.text);
            } else {
                code.push(i);
            }
        }
        LexedFile { rel: rel.to_string(), toks, code, comments }
    }

    /// Number of code (non-comment) tokens.
    fn clen(&self) -> usize {
        self.code.len()
    }

    /// The `k`-th code token.
    fn ctok(&self, k: usize) -> &Token {
        &self.toks[self.code[k]]
    }

    /// Text of the `k`-th code token ("" out of range).
    fn ctext(&self, k: usize) -> &str {
        if k < self.code.len() {
            &self.ctok(k).text
        } else {
            ""
        }
    }

    /// Is code token `k` the identifier `s`?
    fn is_ident(&self, k: usize, s: &str) -> bool {
        k < self.code.len() && self.ctok(k).kind == TokKind::Ident && self.ctok(k).text == s
    }

    /// Is code token `k` the punctuation `c`?
    fn is_punct(&self, k: usize, c: char) -> bool {
        k < self.code.len()
            && self.ctok(k).kind == TokKind::Punct
            && self.ctok(k).text.chars().next() == Some(c)
    }

    /// True when a `marker(reason)` annotation with a non-empty reason
    /// sits in a comment on `line` or the line directly above.
    pub fn has_annotation(&self, line: u32, marker: &str) -> bool {
        for l in [line, line.saturating_sub(1)] {
            if let Some(text) = self.comments.get(&l) {
                if let Some(pos) = text.find(marker) {
                    let rest = &text[pos + marker.len()..];
                    if let Some(body) = rest.strip_prefix('(') {
                        if let Some(end) = body.find(')') {
                            if !body[..end].trim().is_empty() {
                                return true;
                            }
                        }
                    }
                }
            }
        }
        false
    }

    /// Line ranges of `#[cfg(test)] mod … { … }` blocks.
    pub fn test_spans(&self) -> Vec<(u32, u32)> {
        let mut spans = Vec::new();
        let n = self.clen();
        for k in 0..n {
            if self.is_punct(k, '#')
                && self.is_punct(k + 1, '[')
                && self.is_ident(k + 2, "cfg")
                && self.is_punct(k + 3, '(')
                && self.is_ident(k + 4, "test")
                && self.is_punct(k + 5, ')')
                && self.is_punct(k + 6, ']')
            {
                // require a `mod` between the attribute and the brace
                let mut j = k + 7;
                let mut saw_mod = false;
                while j < n && j < k + 16 && !self.is_punct(j, '{') {
                    if self.is_ident(j, "mod") {
                        saw_mod = true;
                    }
                    if self.is_punct(j, ';') {
                        break;
                    }
                    j += 1;
                }
                if saw_mod && self.is_punct(j, '{') {
                    if let Some(close) = self.match_brace(j) {
                        spans.push((self.ctok(j).line, self.ctok(close).line));
                    }
                }
            }
        }
        spans
    }

    /// Index of the `}` matching the `{` at code index `open`.
    fn match_brace(&self, open: usize) -> Option<usize> {
        let mut depth = 0usize;
        for k in open..self.clen() {
            if self.is_punct(k, '{') {
                depth += 1;
            } else if self.is_punct(k, '}') {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
        }
        None
    }
}

fn in_spans(spans: &[(u32, u32)], line: u32) -> bool {
    spans.iter().any(|&(lo, hi)| line >= lo && line <= hi)
}

/// Rule input: the scanned tree plus auxiliary files (`rust/tests/`,
/// readable by cross-file rules like D4 but not themselves scanned).
pub struct Ctx<'a> {
    /// Files under `rust/src/`.
    pub files: &'a [LexedFile],
    /// Files under `rust/tests/`.
    pub aux: &'a [LexedFile],
}

/// All rule ids, in report order.
pub const ALL_RULES: [&str; 7] = ["D1", "D2", "D3", "D4", "D5", "D6", "C1"];

/// Run every rule and return the raw (pre-allowlist) diagnostics.
/// `inventory` is the `[[conservation]]` term list from the allowlist
/// (rule D4 checks it against the code).
pub fn run_all(ctx: &Ctx, inventory: &[String]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    out.extend(d1_hash_iteration(ctx));
    out.extend(d2_wall_clock_entropy(ctx));
    out.extend(d3_digest_fold(ctx));
    out.extend(d4_conservation_terms(ctx, inventory));
    out.extend(d5_shared_state(ctx));
    out.extend(d6_missing_docs(ctx));
    out.extend(c1_narrowing_casts(ctx));
    out
}

// ---- D1: hash-iteration in digest-affecting layers ----------------------

/// Identifiers bound to a `HashMap`/`HashSet` within one file, found by
/// type ascription (`name: …HashMap<…>`, fields, params, struct-literal
/// initializers) or `let name = …HashMap::new()`.
fn collect_hash_idents(f: &LexedFile) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let n = f.clen();
    let scan_for_hash = |from: usize, terminators: &[char]| -> bool {
        let mut depth = 0i32;
        for j in from..n.min(from + 96) {
            let t = f.ctok(j);
            if t.kind == TokKind::Ident && HASH_TYPES.contains(&t.text.as_str()) {
                return true;
            }
            if t.kind == TokKind::Punct {
                let c = t.text.chars().next().unwrap_or(' ');
                match c {
                    '<' | '(' | '[' => depth += 1,
                    '>' | ']' => depth -= 1,
                    ')' => {
                        if depth == 0 {
                            return false;
                        }
                        depth -= 1;
                    }
                    c if depth <= 0 && terminators.contains(&c) => return false,
                    _ => {}
                }
            }
        }
        false
    };
    for k in 0..n {
        // `name : Type…` (not a `::` path segment on either side)
        if f.ctok(k).kind == TokKind::Ident
            && f.is_punct(k + 1, ':')
            && !f.is_punct(k + 2, ':')
            && (k == 0 || !f.is_punct(k - 1, ':'))
            && scan_for_hash(k + 2, &[',', ';', '=', '{', '}'])
        {
            out.insert(f.ctext(k).to_string());
        }
        // `let [mut] name = … HashMap/HashSet …;`
        if f.is_ident(k, "let") {
            let mut j = k + 1;
            if f.is_ident(j, "mut") {
                j += 1;
            }
            if f.ctok(j.min(n - 1)).kind == TokKind::Ident
                && f.is_punct(j + 1, '=')
                && scan_for_hash(j + 2, &[';'])
            {
                out.insert(f.ctext(j).to_string());
            }
        }
    }
    out
}

fn d1_hash_iteration(ctx: &Ctx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in ctx.files {
        if !DIGEST_LAYERS.iter().any(|p| f.rel.starts_with(p)) {
            continue;
        }
        let maps = collect_hash_idents(f);
        if maps.is_empty() {
            continue;
        }
        let spans = f.test_spans();
        let n = f.clen();
        for k in 0..n {
            let t = f.ctok(k);
            if t.kind != TokKind::Ident || in_spans(&spans, t.line) {
                continue;
            }
            // `map.iter()` and friends
            if maps.contains(&t.text)
                && f.is_punct(k + 1, '.')
                && k + 2 < n
                && ITER_METHODS.contains(&f.ctext(k + 2))
                && f.is_punct(k + 3, '(')
            {
                out.push(Diagnostic::new(
                    "D1",
                    &f.rel,
                    t.line,
                    &t.text,
                    format!(
                        "iteration-order-dependent traversal `{}.{}()` of a hash container in a digest-affecting layer; use BTreeMap, a dense Vec table, or sort the keys first",
                        t.text,
                        f.ctext(k + 2)
                    ),
                ));
            }
            // `for … in [&][mut][self.]map {`
            if t.text == "for" {
                if let Some(d) = d1_check_for_loop(f, k, &maps) {
                    out.push(d);
                }
            }
        }
    }
    out
}

/// Check one `for` loop for direct iteration over a tracked container.
fn d1_check_for_loop(f: &LexedFile, k: usize, maps: &BTreeSet<String>) -> Option<Diagnostic> {
    let n = f.clen();
    // find `in` at pattern depth 0
    let mut depth = 0i32;
    let mut j = k + 1;
    let mut found_in = None;
    while j < n && j < k + 32 {
        if f.is_punct(j, '(') || f.is_punct(j, '[') {
            depth += 1;
        } else if f.is_punct(j, ')') || f.is_punct(j, ']') {
            depth -= 1;
        } else if depth == 0 && f.is_ident(j, "in") {
            found_in = Some(j);
            break;
        }
        j += 1;
    }
    let start = found_in? + 1;
    // collect the iterated expression up to the loop body brace
    let mut idents: Vec<(String, u32)> = Vec::new();
    let mut has_call = false;
    let mut m = start;
    while m < n && m < start + 32 && !f.is_punct(m, '{') {
        let t = f.ctok(m);
        match t.kind {
            TokKind::Ident if t.text != "self" && t.text != "mut" => {
                idents.push((t.text.clone(), t.line));
            }
            TokKind::Punct if t.text == "(" => has_call = true,
            _ => {}
        }
        m += 1;
    }
    if has_call || idents.len() != 1 {
        return None;
    }
    let (name, line) = &idents[0];
    if !maps.contains(name) {
        return None;
    }
    Some(Diagnostic::new(
        "D1",
        &f.rel,
        *line,
        name,
        format!(
            "iteration-order-dependent `for … in {name}` over a hash container in a digest-affecting layer; use BTreeMap, a dense Vec table, or sort the keys first"
        ),
    ))
}

// ---- D2: wall clock / ambient entropy -----------------------------------

fn d2_wall_clock_entropy(ctx: &Ctx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in ctx.files {
        for &i in &f.code {
            let t = &f.toks[i];
            if t.kind == TokKind::Ident && D2_HAZARDS.contains(&t.text.as_str()) {
                out.push(Diagnostic::new(
                    "D2",
                    &f.rel,
                    t.line,
                    &t.text,
                    format!(
                        "wall-clock/entropy API `{}`: nondeterministic input; simulated time comes from cluster::Clock, randomness from seeded util::rng::Rng",
                        t.text
                    ),
                ));
            }
        }
    }
    out
}

// ---- struct-field extraction shared by D3/D4 ----------------------------

/// `(name, line)` of each field of `struct name { … }` in `f`.
fn struct_fields(f: &LexedFile, name: &str) -> Vec<(String, u32)> {
    let n = f.clen();
    let mut fields = Vec::new();
    for k in 0..n {
        if !(f.is_ident(k, "struct") && f.is_ident(k + 1, name) && f.is_punct(k + 2, '{')) {
            continue;
        }
        let open = k + 2;
        let close = match f.match_brace(open) {
            Some(c) => c,
            None => return fields,
        };
        let mut j = open + 1;
        while j < close {
            // skip attributes
            if f.is_punct(j, '#') && f.is_punct(j + 1, '[') {
                let mut depth = 0i32;
                j += 1;
                while j < close {
                    if f.is_punct(j, '[') {
                        depth += 1;
                    } else if f.is_punct(j, ']') {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    j += 1;
                }
                continue;
            }
            if f.is_ident(j, "pub") {
                j += 1;
                continue;
            }
            if f.ctok(j).kind == TokKind::Ident && f.is_punct(j + 1, ':') && !f.is_punct(j + 2, ':')
            {
                fields.push((f.ctext(j).to_string(), f.ctok(j).line));
                // skip the type up to the field-separating comma
                let mut depth = 0i32;
                j += 2;
                while j < close {
                    if f.is_punct(j, '<') || f.is_punct(j, '(') || f.is_punct(j, '[')
                        || f.is_punct(j, '{')
                    {
                        depth += 1;
                    } else if f.is_punct(j, '>') || f.is_punct(j, ')') || f.is_punct(j, ']')
                        || f.is_punct(j, '}')
                    {
                        depth -= 1;
                    } else if depth == 0 && f.is_punct(j, ',') {
                        j += 1;
                        break;
                    }
                    j += 1;
                }
                continue;
            }
            j += 1;
        }
        break;
    }
    fields
}

// ---- D3: digest-fold completeness ---------------------------------------

/// FNV-1a offset basis used by the driver's digest fold — the anchor
/// for the digest-region token scan.
const FNV_OFFSET_PREFIX: &str = "0xcbf29ce484222325";

fn d3_digest_fold(ctx: &Ctx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let f = match ctx.files.iter().find(|f| f.rel.ends_with("coordinator/driver.rs")) {
        Some(f) => f,
        None => return out,
    };
    let fields = struct_fields(f, "DriverReport");
    if fields.is_empty() {
        out.push(Diagnostic::new(
            "D3",
            &f.rel,
            0,
            "DriverReport",
            "struct DriverReport not found — the D3 digest-fold contract has no anchor".to_string(),
        ));
        return out;
    }
    // the digest region: from the FNV offset-basis literal to the
    // `DriverReport {` construction that stores the fold
    let n = f.clen();
    let start = (0..n).find(|&k| {
        let t = f.ctok(k);
        t.kind == TokKind::Num
            && t.text.to_lowercase().replace('_', "").starts_with(FNV_OFFSET_PREFIX)
    });
    let region: BTreeSet<String> = match start {
        Some(s) => {
            let end = (s..n)
                .find(|&k| f.is_ident(k, "DriverReport") && f.is_punct(k + 1, '{'))
                .unwrap_or(n);
            (s..end)
                .filter(|&k| f.ctok(k).kind == TokKind::Ident)
                .map(|k| f.ctext(k).to_string())
                .collect()
        }
        None => {
            out.push(Diagnostic::new(
                "D3",
                &f.rel,
                0,
                "digest",
                "digest fold site (FNV offset-basis literal) not found in driver.rs".to_string(),
            ));
            return out;
        }
    };
    // struct brace line: comments between fields bound the annotations
    let struct_open_line = (0..n)
        .find(|&k| f.is_ident(k, "struct") && f.is_ident(k + 1, "DriverReport"))
        .map(|k| f.ctok(k).line)
        .unwrap_or(0);
    let mut prev_line = struct_open_line;
    for (name, line) in &fields {
        let mut text = String::new();
        for (_, c) in f.comments.range(prev_line..=*line) {
            text.push_str(c);
            text.push(' ');
        }
        prev_line = *line;
        let folded = text.contains("digest: folded");
        let excluded = text
            .find("digest: excluded(")
            .map(|p| {
                let body = &text[p + "digest: excluded(".len()..];
                body.find(')').map(|e| !body[..e].trim().is_empty()).unwrap_or(false)
            })
            .unwrap_or(false);
        match (folded, excluded) {
            (true, true) => out.push(Diagnostic::new(
                "D3",
                &f.rel,
                *line,
                name,
                format!("DriverReport.{name}: carries both `digest: folded` and `digest: excluded(…)`"),
            )),
            (false, false) => out.push(Diagnostic::new(
                "D3",
                &f.rel,
                *line,
                name,
                format!(
                    "DriverReport.{name}: new report fields must declare digest intent — annotate `// digest: folded` or `// digest: excluded(reason)`"
                ),
            )),
            (true, false) => {
                if !region.contains(name) {
                    out.push(Diagnostic::new(
                        "D3",
                        &f.rel,
                        *line,
                        name,
                        format!(
                            "DriverReport.{name}: annotated `digest: folded` but never referenced in the digest fold region"
                        ),
                    ));
                }
            }
            (false, true) => {}
        }
    }
    out
}

// ---- D4: conservation-term completeness ---------------------------------

/// Check the committed `[[conservation]]` inventory against the
/// counters actually summed by `AppStats::failed()` (exact set
/// equality), the `AppStats` field list, and the property tests.
pub fn d4_conservation_terms(ctx: &Ctx, inventory: &[String]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let f = match ctx.files.iter().find(|f| f.rel.ends_with("coordinator/driver.rs")) {
        Some(f) => f,
        None => return out,
    };
    // terms summed by AppStats::failed(): idents behind `self.` in the body
    let n = f.clen();
    let fail_at = (0..n).find(|&k| f.is_ident(k, "fn") && f.is_ident(k + 1, "failed"));
    let mut summed: BTreeSet<String> = BTreeSet::new();
    let mut fail_line = 0u32;
    if let Some(k) = fail_at {
        fail_line = f.ctok(k).line;
        if let Some(open) = (k..n.min(k + 32)).find(|&j| f.is_punct(j, '{')) {
            if let Some(close) = f.match_brace(open) {
                for j in open..close {
                    if f.is_ident(j, "self") && f.is_punct(j + 1, '.') && j + 2 < close {
                        summed.insert(f.ctext(j + 2).to_string());
                    }
                }
            }
        }
    } else {
        out.push(Diagnostic::new(
            "D4",
            &f.rel,
            0,
            "failed",
            "AppStats::failed() not found — the conservation inventory has no anchor".to_string(),
        ));
        return out;
    }
    let inv: BTreeSet<String> = inventory.iter().cloned().collect();
    for t in summed.difference(&inv) {
        out.push(Diagnostic::new(
            "D4",
            &f.rel,
            fail_line,
            t,
            format!(
                "failure counter `{t}` is summed by AppStats::failed() but missing from the [[conservation]] inventory — add it with its meaning (and extend the conservation tests)"
            ),
        ));
    }
    for t in inv.difference(&summed) {
        out.push(Diagnostic::new(
            "D4",
            &f.rel,
            fail_line,
            t,
            format!(
                "[[conservation]] term `{t}` is no longer summed by AppStats::failed() — stale inventory entry"
            ),
        ));
    }
    // every inventory term must be an AppStats field…
    let app_fields: BTreeSet<String> =
        struct_fields(f, "AppStats").into_iter().map(|(n, _)| n).collect();
    for t in &inv {
        if !app_fields.contains(t) {
            out.push(Diagnostic::new(
                "D4",
                &f.rel,
                fail_line,
                t,
                format!("[[conservation]] term `{t}` is not a field of AppStats"),
            ));
        }
    }
    // …and must be exercised by the conservation property tests
    if let Some(pt) = ctx.aux.iter().find(|f| f.rel.ends_with("proptests.rs")) {
        for t in &inv {
            let used = pt
                .code
                .iter()
                .any(|&i| pt.toks[i].kind == TokKind::Ident && pt.toks[i].text == *t);
            if !used {
                out.push(Diagnostic::new(
                    "D4",
                    &pt.rel,
                    0,
                    t,
                    format!(
                        "[[conservation]] term `{t}` never appears in the conservation property tests (proptests.rs)"
                    ),
                ));
            }
        }
    } else if !inv.is_empty() {
        out.push(Diagnostic::new(
            "D4",
            "rust/tests/proptests.rs",
            0,
            "proptests",
            "conservation property-test file proptests.rs not found".to_string(),
        ));
    }
    out
}

// ---- D5: shared-mutable-state audit -------------------------------------

fn d5_shared_state(ctx: &Ctx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in ctx.files {
        if !f.rel.starts_with("coordinator/") {
            continue;
        }
        let spans = f.test_spans();
        let mut seen: BTreeSet<(u32, String)> = BTreeSet::new();
        let n = f.clen();
        for k in 0..n {
            let t = f.ctok(k);
            if t.kind != TokKind::Ident || in_spans(&spans, t.line) {
                continue;
            }
            let token = if D5_HAZARDS.contains(&t.text.as_str()) {
                Some(t.text.clone())
            } else if t.text == "static" && f.is_ident(k + 1, "mut") {
                Some("static mut".to_string())
            } else if t.text == "thread_local" && f.is_punct(k + 1, '!') {
                Some("thread_local!".to_string())
            } else {
                None
            };
            if let Some(token) = token {
                if seen.insert((t.line, token.clone())) {
                    out.push(Diagnostic::new(
                        "D5",
                        &f.rel,
                        t.line,
                        &token,
                        format!(
                            "shared-mutable-state construct `{token}` in the coordinator — the sharded event loop must confront this; inventory it in the allowlist with a migration note"
                        ),
                    ));
                }
            }
        }
    }
    out
}

// ---- D6: #[allow(missing_docs)] inventory -------------------------------

fn d6_missing_docs(ctx: &Ctx) -> Vec<Diagnostic> {
    const ITEM_KEYWORDS: [&str; 12] = [
        "pub", "mod", "fn", "struct", "enum", "trait", "type", "const", "static", "crate",
        "unsafe", "impl",
    ];
    let mut out = Vec::new();
    for f in ctx.files {
        let n = f.clen();
        for k in 0..n {
            if f.is_punct(k, '#')
                && f.is_punct(k + 1, '[')
                && f.is_ident(k + 2, "allow")
                && f.is_punct(k + 3, '(')
                && f.is_ident(k + 4, "missing_docs")
                && f.is_punct(k + 5, ')')
                && f.is_punct(k + 6, ']')
            {
                let mut name = String::from("?");
                for j in k + 7..n.min(k + 16) {
                    let t = f.ctok(j);
                    if t.kind == TokKind::Ident && !ITEM_KEYWORDS.contains(&t.text.as_str()) {
                        name = t.text.clone();
                        break;
                    }
                }
                out.push(Diagnostic::new(
                    "D6",
                    &f.rel,
                    f.ctok(k).line,
                    &name,
                    format!(
                        "#[allow(missing_docs)] on `{name}`: the docs-sweep remainder must match the committed allowlist (drop the allow when sweeping)"
                    ),
                ));
            }
        }
    }
    out
}

// ---- C1: unchecked narrowing casts --------------------------------------

fn c1_narrowing_casts(ctx: &Ctx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in ctx.files {
        if !CAST_LAYERS.iter().any(|p| f.rel.starts_with(p)) {
            continue;
        }
        let spans = f.test_spans();
        let n = f.clen();
        for k in 0..n {
            if !f.is_ident(k, "as") {
                continue;
            }
            let ty = f.ctext(k + 1).to_string();
            if !INT_TYPES.contains(&ty.as_str()) {
                continue;
            }
            let line = f.ctok(k).line;
            if in_spans(&spans, line) {
                continue;
            }
            if f.has_annotation(line, "cast: safe") {
                continue;
            }
            out.push(Diagnostic::new(
                "C1",
                &f.rel,
                line,
                &format!("as {ty}"),
                format!(
                    "unchecked `as {ty}` cast on the hot path: use a util::cast checked helper or annotate `// cast: safe(reason)`"
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, src: &str) -> LexedFile {
        LexedFile::from_source(rel, src)
    }

    fn run<F: Fn(&Ctx) -> Vec<Diagnostic>>(rule: F, files: Vec<LexedFile>) -> Vec<Diagnostic> {
        let ctx = Ctx { files: &files, aux: &[] };
        rule(&ctx)
    }

    // ---- D1 ----

    #[test]
    fn d1_flags_hash_iteration_in_digest_layers() {
        let src = "use std::collections::HashMap;\nfn f() {\n  let mut m: HashMap<u32, u32> = HashMap::new();\n  for (k, v) in &m { drop((k, v)); }\n  let s: Vec<u32> = m.keys().copied().collect();\n}\n";
        let d = run(d1_hash_iteration, vec![file("coordinator/x.rs", src)]);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|d| d.rule == "D1"));
        assert_eq!(d[0].line, 4); // for … in &m (token order)
        assert_eq!(d[1].line, 5); // m.keys()
    }

    #[test]
    fn d1_keyed_lookups_and_out_of_scope_files_are_clean() {
        let keyed = "use std::collections::HashMap;\nfn f(m: &mut HashMap<u32, u32>) {\n  m.insert(1, 2);\n  let _ = m.get(&1);\n  let _ = m.contains_key(&1);\n  m.entry(3).or_insert(4);\n}\n";
        assert!(run(d1_hash_iteration, vec![file("coordinator/x.rs", keyed)]).is_empty());
        let iterating = "use std::collections::HashMap;\nfn f(m: &HashMap<u32,u32>) { for x in m.values() { drop(x); } }\n";
        assert!(run(d1_hash_iteration, vec![file("util/x.rs", iterating)]).is_empty());
        // Vec iteration in scope is fine
        let vecs = "fn f(v: &Vec<u32>) { for x in v { drop(x); } for y in v.iter() { drop(y); } }\n";
        assert!(run(d1_hash_iteration, vec![file("coordinator/x.rs", vecs)]).is_empty());
    }

    #[test]
    fn d1_tracks_struct_fields_and_set_drain() {
        let src = "use std::collections::HashSet;\nstruct S { warm: HashSet<u32> }\nimpl S { fn f(&mut self) { for x in self.warm.drain() { drop(x); } } }\n";
        let d = run(d1_hash_iteration, vec![file("metrics/x.rs", src)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].allow_token, "warm");
    }

    // ---- D2 ----

    #[test]
    fn d2_flags_wall_clock_and_entropy_idents() {
        let src = "fn f() { let t = std::time::SystemTime::now(); let i = Instant::now(); }\n";
        let d = run(d2_wall_clock_entropy, vec![file("net/x.rs", src)]);
        assert_eq!(d.len(), 2, "{d:?}");
        assert_eq!(d[0].allow_token, "SystemTime");
        assert_eq!(d[1].allow_token, "Instant");
    }

    #[test]
    fn d2_ignores_strings_and_comments() {
        let src = "// SystemTime is banned\nfn f() { let s = \"Instant::now\"; drop(s); }\n";
        assert!(run(d2_wall_clock_entropy, vec![file("net/x.rs", src)]).is_empty());
    }

    // ---- D3 ----

    const D3_TAIL: &str = "fn fold(completed: u64) -> u64 {\n  let mut h = 0xcbf2_9ce4_8422_2325u64;\n  h = h ^ completed;\n  let r = DriverReport { completed: 0, digest: h };\n  r.digest\n}\n";

    #[test]
    fn d3_requires_annotation_on_every_field() {
        let src = format!(
            "pub struct DriverReport {{\n  // digest: folded\n  pub completed: usize,\n  pub digest: u64,\n}}\n{D3_TAIL}"
        );
        let d = run(d3_digest_fold, vec![file("coordinator/driver.rs", &src)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("declare digest intent"), "{}", d[0].msg);
        assert_eq!(d[0].allow_token, "digest");
    }

    #[test]
    fn d3_clean_when_folded_and_excluded_cover_all() {
        let src = format!(
            "pub struct DriverReport {{\n  // digest: folded\n  pub completed: usize,\n  // digest: excluded(the digest itself)\n  pub digest: u64,\n}}\n{D3_TAIL}"
        );
        assert!(run(d3_digest_fold, vec![file("coordinator/driver.rs", &src)]).is_empty());
    }

    #[test]
    fn d3_folded_field_must_appear_in_fold_region() {
        let src = format!(
            "pub struct DriverReport {{\n  // digest: folded\n  pub queued: usize,\n  // digest: excluded(self)\n  pub digest: u64,\n}}\n{D3_TAIL}"
        );
        let d = run(d3_digest_fold, vec![file("coordinator/driver.rs", &src)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("never referenced"), "{}", d[0].msg);
    }

    // ---- D4 ----

    const D4_SRC: &str = "pub struct AppStats { pub rejected: usize, pub aborted: usize }\nimpl AppStats {\n  pub fn failed(&self) -> usize { self.rejected + self.aborted }\n}\n";

    #[test]
    fn d4_flags_missing_and_stale_inventory_terms() {
        let files = vec![file("coordinator/driver.rs", D4_SRC)];
        let aux = vec![file("proptests.rs", "fn t(r: R) { assert_eq!(r.rejected + r.aborted, 0); }\n")];
        let ctx = Ctx { files: &files, aux: &aux };
        // missing: aborted not in inventory
        let d = d4_conservation_terms(&ctx, &["rejected".to_string()]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("missing from the [[conservation]]"), "{}", d[0].msg);
        // stale: timed_out not summed
        let d = d4_conservation_terms(
            &ctx,
            &["rejected".to_string(), "aborted".to_string(), "timed_out".to_string()],
        );
        assert!(d.iter().any(|d| d.msg.contains("stale inventory")), "{d:?}");
        // clean when the inventory matches and the tests use both terms
        let d = d4_conservation_terms(&ctx, &["rejected".to_string(), "aborted".to_string()]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn d4_requires_terms_in_the_property_tests() {
        let files = vec![file("coordinator/driver.rs", D4_SRC)];
        let aux = vec![file("proptests.rs", "fn t(r: R) { assert_eq!(r.rejected, 0); }\n")];
        let ctx = Ctx { files: &files, aux: &aux };
        let d = d4_conservation_terms(&ctx, &["rejected".to_string(), "aborted".to_string()]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("never appears in the conservation property tests"));
    }

    // ---- D5 ----

    #[test]
    fn d5_inventories_shared_state_outside_tests() {
        let src = "use std::cell::RefCell;\nstruct S { c: RefCell<u32> }\nstatic mut G: u32 = 0;\n#[cfg(test)]\nmod tests {\n  use std::sync::Mutex;\n  static M: Mutex<u32> = Mutex::new(0);\n}\n";
        let d = run(d5_shared_state, vec![file("coordinator/x.rs", src)]);
        let tokens: Vec<&str> = d.iter().map(|d| d.allow_token.as_str()).collect();
        assert_eq!(tokens, vec!["RefCell", "RefCell", "static mut"], "{d:?}");
    }

    #[test]
    fn d5_ignores_other_layers() {
        let src = "use std::sync::Mutex;\nstatic M: Mutex<u32> = Mutex::new(0);\n";
        assert!(run(d5_shared_state, vec![file("runtime/x.rs", src)]).is_empty());
    }

    // ---- D6 ----

    #[test]
    fn d6_reports_module_names() {
        let src = "#[allow(missing_docs)]\npub mod foo;\npub mod bar;\n";
        let d = run(d6_missing_docs, vec![file("lib.rs", src)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].allow_token, "foo");
    }

    #[test]
    fn d6_other_allows_are_not_flagged() {
        let src = "#[allow(dead_code)]\npub mod foo;\n";
        assert!(run(d6_missing_docs, vec![file("lib.rs", src)]).is_empty());
    }

    // ---- C1 ----

    #[test]
    fn c1_flags_unannotated_integer_casts() {
        let src = "fn f(x: f64) -> usize { x as usize }\n";
        let d = run(c1_narrowing_casts, vec![file("coordinator/x.rs", src)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].allow_token, "as usize");
    }

    #[test]
    fn c1_accepts_annotations_and_skips_tests_and_float_casts() {
        let annotated = "fn f(x: f64) -> usize {\n  // cast: safe(x is a small non-negative count)\n  x as usize\n}\nfn g(x: u32) -> u64 { x as u64 // cast: safe(widening)\n}\n";
        assert!(run(c1_narrowing_casts, vec![file("coordinator/x.rs", annotated)]).is_empty());
        let test_only = "#[cfg(test)]\nmod tests {\n  fn f(x: f64) -> usize { x as usize }\n}\n";
        assert!(run(c1_narrowing_casts, vec![file("coordinator/x.rs", test_only)]).is_empty());
        let float = "fn f(x: usize) -> f64 { x as f64 }\n";
        assert!(run(c1_narrowing_casts, vec![file("coordinator/x.rs", float)]).is_empty());
        let out_of_scope = "fn f(x: f64) -> usize { x as usize }\n";
        assert!(run(c1_narrowing_casts, vec![file("util/x.rs", out_of_scope)]).is_empty());
    }

    #[test]
    fn c1_annotation_requires_a_reason() {
        let src = "fn f(x: f64) -> usize {\n  // cast: safe()\n  x as usize\n}\n";
        let d = run(c1_narrowing_casts, vec![file("coordinator/x.rs", src)]);
        assert_eq!(d.len(), 1, "{d:?}");
    }
}
