//! Memory controller substrate: data components as sets of physical
//! memory regions, local mmap vs remote regions, growth, and the
//! user-space swap system of §9.2.

pub mod controller;
pub mod swap;

pub use controller::{DataComponentState, MemoryController, RegionId};
pub use swap::{AccessPattern, SwapConfig, SwapSim};
