//! User-space swap simulator (§9.2).
//!
//! The paper's swap system runs on `userfaultfd` with an NRU policy: a
//! background thread handles faults, swaps pages in from the remote
//! memory component, and evicts not-recently-used pages under pressure.
//! This module simulates that mechanism at page granularity to reproduce
//! the Fig 25 microbenchmark (sequential/random array reads under
//! different local-cache sizes: +1%..+26% overhead).

use crate::cluster::clock::Millis;
use crate::net::{NetKind, NetModel};
use crate::util::rng::Rng;

/// 4 KiB pages, like the paper's Linux setup.
pub const PAGE_KB: f64 = 4.0;

/// Access pattern of the microbenchmark (Fig 25).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    Sequential,
    Random,
}

/// Swap-system configuration.
#[derive(Debug, Clone, Copy)]
pub struct SwapConfig {
    /// Local cache size (MB) — the compute component's resident budget.
    pub local_mb: f64,
    /// Remote transport for page-in/page-out.
    pub net: NetKind,
    /// Per-fault fixed handler cost (userfaultfd wakeup + syscall), ms.
    pub fault_handler_ms: Millis,
    /// Local access cost per page (cache/DRAM), ms — the no-swap
    /// baseline speed.
    pub local_access_ms: Millis,
}

impl Default for SwapConfig {
    fn default() -> Self {
        Self {
            local_mb: 400.0,
            net: NetKind::Rdma,
            fault_handler_ms: 0.004,
            local_access_ms: 0.0002,
        }
    }
}

/// Result of one simulated pass over the array.
#[derive(Debug, Clone, Copy)]
pub struct SwapRun {
    pub accesses: u64,
    pub faults: u64,
    pub total_ms: Millis,
    pub baseline_ms: Millis,
}

impl SwapRun {
    /// Overhead relative to all-local execution (0.26 == +26%).
    pub fn overhead(&self) -> f64 {
        if self.baseline_ms <= 0.0 {
            0.0
        } else {
            self.total_ms / self.baseline_ms - 1.0
        }
    }
}

/// Page-granularity NRU swap simulator.
///
/// NRU approximation per §9.2: the user-space handler cannot read page
/// tables, so it evicts a page that has "not recently been swapped in" —
/// we model this as a FIFO-with-second-chance over swap-in order, which
/// is what the described policy degenerates to.
#[derive(Debug)]
pub struct SwapSim {
    cfg: SwapConfig,
    net: NetModel,
    /// resident[i] = true if page i is local.
    resident: Vec<bool>,
    /// Recently-swapped-in bit (second chance).
    recent: Vec<bool>,
    /// Swap-in order queue (indices into the page array).
    queue: std::collections::VecDeque<u32>,
    capacity_pages: usize,
    resident_count: usize,
    pub faults: u64,
    pub accesses: u64,
}

impl SwapSim {
    pub fn new(array_mb: f64, cfg: SwapConfig, net: NetModel) -> Self {
        let pages = ((array_mb * 1024.0 / PAGE_KB).ceil() as usize).max(1);
        let capacity_pages = ((cfg.local_mb * 1024.0 / PAGE_KB) as usize).max(1);
        let mut sim = Self {
            cfg,
            net,
            resident: vec![false; pages],
            recent: vec![false; pages],
            queue: std::collections::VecDeque::new(),
            capacity_pages,
            resident_count: 0,
            faults: 0,
            accesses: 0,
        };
        // Initially the first `capacity` pages are resident (the warm
        // working set after allocation).
        for i in 0..pages.min(capacity_pages) {
            sim.resident[i] = true;
            sim.queue.push_back(i as u32);
            sim.resident_count += 1;
        }
        sim
    }

    pub fn pages(&self) -> usize {
        self.resident.len()
    }

    /// Access one page; returns the access cost in ms.
    pub fn access(&mut self, page: usize) -> Millis {
        self.accesses += 1;
        if self.resident[page] {
            self.recent[page] = true;
            return self.cfg.local_access_ms;
        }
        // Fault: evict if at capacity (NRU second-chance), then page in.
        self.faults += 1;
        while self.resident_count >= self.capacity_pages {
            let victim = self.queue.pop_front().expect("resident pages tracked");
            if self.recent[victim as usize] {
                // Second chance: clear bit, requeue.
                self.recent[victim as usize] = false;
                self.queue.push_back(victim);
            } else {
                self.resident[victim as usize] = false;
                self.resident_count -= 1;
            }
        }
        self.resident[page] = true;
        self.recent[page] = true;
        self.queue.push_back(page as u32);
        self.resident_count += 1;
        self.cfg.fault_handler_ms
            + self.net.transfer(self.cfg.net, PAGE_KB / 1024.0, false)
            + self.cfg.local_access_ms
    }

    /// Run one full pass over the array with the given pattern.
    ///
    /// The returned [`SwapRun`] reports *this pass only*: `self.faults`
    /// / `self.accesses` keep the simulator-lifetime totals, and the
    /// run carries the per-pass deltas — a second pass on the same sim
    /// must not inherit the first pass's faults (that made fault rates
    /// exceed 1.0 and corrupted [`SwapRun::overhead`]).
    pub fn run_pass(&mut self, pattern: AccessPattern, rng: &mut Rng) -> SwapRun {
        let pages = self.pages();
        let faults_before = self.faults;
        let accesses_before = self.accesses;
        let mut total = 0.0;
        match pattern {
            AccessPattern::Sequential => {
                for p in 0..pages {
                    total += self.access(p);
                }
            }
            AccessPattern::Random => {
                for _ in 0..pages {
                    let p = rng.range(0, pages);
                    total += self.access(p);
                }
            }
        }
        SwapRun {
            accesses: self.accesses - accesses_before,
            faults: self.faults - faults_before,
            total_ms: total,
            baseline_ms: pages as f64 * self.cfg.local_access_ms,
        }
    }
}

/// Convenience: overhead of reading `array_mb` once with `cfg`.
pub fn pass_overhead(
    array_mb: f64,
    pattern: AccessPattern,
    cfg: SwapConfig,
    seed: u64,
) -> SwapRun {
    let mut sim = SwapSim::new(array_mb, cfg, NetModel::default());
    let mut rng = Rng::new(seed);
    sim.run_pass(pattern, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_swap_when_array_fits() {
        let cfg = SwapConfig { local_mb: 400.0, ..Default::default() };
        let run = pass_overhead(200.0, AccessPattern::Sequential, cfg, 1);
        assert_eq!(run.faults, 0);
        assert!(run.overhead().abs() < 1e-9);
    }

    #[test]
    fn sequential_overhead_grows_with_array() {
        let cfg = SwapConfig { local_mb: 200.0, ..Default::default() };
        let small = pass_overhead(300.0, AccessPattern::Sequential, cfg, 1);
        let large = pass_overhead(1200.0, AccessPattern::Sequential, cfg, 1);
        assert!(small.faults > 0);
        assert!(large.overhead() > small.overhead());
    }

    #[test]
    fn bigger_cache_fewer_random_faults() {
        let a = pass_overhead(
            800.0,
            AccessPattern::Random,
            SwapConfig { local_mb: 200.0, ..Default::default() },
            7,
        );
        let b = pass_overhead(
            800.0,
            AccessPattern::Random,
            SwapConfig { local_mb: 400.0, ..Default::default() },
            7,
        );
        assert!(b.faults < a.faults, "{} vs {}", b.faults, a.faults);
        assert!(b.total_ms < a.total_ms);
    }

    #[test]
    fn random_fault_rate_tracks_cache_ratio() {
        // With cache = half the array, random access faults ~half the time
        // (steady state), within tolerance.
        let run = pass_overhead(
            400.0,
            AccessPattern::Random,
            SwapConfig { local_mb: 200.0, ..Default::default() },
            3,
        );
        let rate = run.faults as f64 / run.accesses as f64;
        assert!((0.3..0.7).contains(&rate), "{rate}");
    }

    #[test]
    fn resident_never_exceeds_capacity() {
        let cfg = SwapConfig { local_mb: 1.0, ..Default::default() }; // 256 pages
        let mut sim = SwapSim::new(4.0, cfg, NetModel::default());
        let mut rng = Rng::new(5);
        for _ in 0..5000 {
            let p = rng.range(0, sim.pages());
            sim.access(p);
            assert!(sim.resident_count <= sim.capacity_pages + 1);
        }
    }

    /// Satellite-1 regression: a second pass over the same sim must
    /// report per-pass deltas, not the cumulative lifetime counters
    /// (which made `faults > accesses`, i.e. fault rates > 1).
    #[test]
    fn second_pass_reports_per_pass_deltas() {
        let cfg = SwapConfig { local_mb: 200.0, ..Default::default() };
        let mut sim = SwapSim::new(800.0, cfg, NetModel::default());
        let mut rng = Rng::new(13);
        let first = sim.run_pass(AccessPattern::Sequential, &mut rng);
        let second = sim.run_pass(AccessPattern::Sequential, &mut rng);
        assert!(first.faults > 0, "800 MB over a 200 MB cache must fault");
        assert!(first.faults <= first.accesses);
        assert!(
            second.faults <= second.accesses,
            "per-pass faults must not accumulate: {} faults for {} accesses",
            second.faults,
            second.accesses
        );
        // lifetime counters still track the whole sim
        assert_eq!(sim.faults, first.faults + second.faults);
        assert_eq!(sim.accesses, first.accesses + second.accesses);
        // and the per-pass overhead stays consistent with its own time
        assert!(second.overhead() >= 0.0);
        assert!(second.total_ms <= first.total_ms * 1.5 + 1.0, "steady state");
    }

    #[test]
    fn rdma_swap_cheaper_than_tcp() {
        let rdma = pass_overhead(
            600.0,
            AccessPattern::Sequential,
            SwapConfig { local_mb: 200.0, net: NetKind::Rdma, ..Default::default() },
            1,
        );
        let tcp = pass_overhead(
            600.0,
            AccessPattern::Sequential,
            SwapConfig { local_mb: 200.0, net: NetKind::Tcp, ..Default::default() },
            1,
        );
        assert!(rdma.total_ms < tcp.total_ms);
    }
}
