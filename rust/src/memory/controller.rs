//! Per-server memory controller state for data components (§5.1.2).
//!
//! A *data component* is one resource-graph node; at runtime it
//! materializes as one or more *physical memory regions*, each on some
//! server. Co-located regions are mmap-ed into the accessing container;
//! remote regions are reached over RDMA MRs or the TCP controller
//! process (§9.1). Growth allocates additional regions, local-first
//! (§5.1.1 scaling policy).
//!
//! Component ids are dense per invocation (resource-graph data
//! indices), so the controller keeps a `Vec`-indexed table instead of a
//! hash map, and recycles released [`DataComponentState`] shells so the
//! steady-state launch/grow/release cycle performs no heap allocation
//! (mirroring the platform's pooled invocation shells).

use crate::cluster::clock::Millis;
use crate::cluster::{Cluster, Resources, ServerId};
use crate::Result;

/// Identifier of one physical memory region within a data component.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RegionId(pub usize);

/// One physical region of a data component.
#[derive(Debug, Clone)]
pub struct Region {
    pub id: RegionId,
    pub server: ServerId,
    pub mb: f64,
    /// RDMA memory-region + protection-domain identity (§9.1 isolation:
    /// one MR + PD per physical component). Modeled as a tag checked by
    /// access validation.
    pub mr_tag: u64,
}

/// Runtime state of one data component: its regions and accessors.
#[derive(Debug, Clone, Default)]
pub struct DataComponentState {
    pub regions: Vec<Region>,
    /// Live accessor compute components (by opaque id). The component
    /// ends when the last accessor releases it (§5.1.2).
    pub accessors: Vec<u64>,
    next_region: usize,
    next_mr_tag: u64,
}

impl DataComponentState {
    pub fn total_mb(&self) -> f64 {
        self.regions.iter().map(|r| r.mb).sum()
    }

    /// MB resident on `server`.
    pub fn local_mb(&self, server: ServerId) -> f64 {
        self.regions.iter().filter(|r| r.server == server).map(|r| r.mb).sum()
    }

    /// Fraction of this component remote to `server` (for slowdown
    /// models). 0.0 when empty.
    pub fn remote_fraction(&self, server: ServerId) -> f64 {
        let total = self.total_mb();
        if total <= 0.0 {
            0.0
        } else {
            1.0 - self.local_mb(server) / total
        }
    }
}

/// The memory controller: allocates/grows/releases data-component
/// regions against cluster capacity.
///
/// Dense storage: slot `id` of `components` holds the live state of
/// data component `id` (ids are per-invocation resource-graph indices).
/// Released states go to `spare` with their buffers intact, so a later
/// launch reuses capacity instead of allocating.
#[derive(Debug, Default)]
pub struct MemoryController {
    components: Vec<Option<DataComponentState>>,
    /// Recycled state shells (empty, capacity preserved).
    spare: Vec<DataComponentState>,
}

impl MemoryController {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, id: u64) -> Option<&DataComponentState> {
        self.components.get(id as usize).and_then(|s| s.as_ref())
    }

    /// Recycle `state` after its regions were drained.
    fn recycle(&mut self, mut state: DataComponentState) {
        state.regions.clear();
        state.accessors.clear();
        state.next_region = 0;
        state.next_mr_tag = 0;
        self.spare.push(state);
    }

    /// Drop every live component back to the spare pool *without*
    /// touching the cluster (pooled-shell reset; normally a no-op since
    /// a finished invocation has released everything).
    pub fn reset(&mut self) {
        for i in 0..self.components.len() {
            if let Some(state) = self.components[i].take() {
                self.recycle(state);
            }
        }
    }

    /// Start a data component with an initial region on `server`
    /// (invoked when its first accessor starts, §5.1.2).
    pub fn launch(
        &mut self,
        cluster: &mut Cluster,
        id: u64,
        server: ServerId,
        mb: f64,
        now: Millis,
    ) -> Result<RegionId> {
        let idx = id as usize;
        if idx >= self.components.len() {
            self.components.resize_with(idx + 1, || None);
        }
        if self.components[idx].is_some() {
            anyhow::bail!("data component {id} already launched");
        }
        // The Cluster hooks keep the placement index in sync (the
        // executor launches data components inside its wave loop).
        if !cluster.try_alloc(server, Resources::mem_only(mb), now) {
            anyhow::bail!("server {server:?} cannot fit {mb} MB");
        }
        cluster.add_used(server, Resources::mem_only(mb), now);
        let mut state = self.spare.pop().unwrap_or_default();
        let rid = RegionId(0);
        state.regions.push(Region { id: rid, server, mb, mr_tag: 0 });
        state.next_region = 1;
        state.next_mr_tag = 1;
        self.components[idx] = Some(state);
        Ok(rid)
    }

    /// Grow a component by `mb`, preferring its existing servers, then
    /// any of `candidates` in order (§5.1.1: same server, then servers
    /// running accessors, then smallest-available).
    pub fn grow(
        &mut self,
        cluster: &mut Cluster,
        id: u64,
        mb: f64,
        candidates: &[ServerId],
        now: Millis,
    ) -> Result<RegionId> {
        let state = self
            .components
            .get_mut(id as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("unknown data component {id}"))?;
        // Probe existing region servers first, then the candidates, and
        // commit on the first fit — no candidate list is materialized.
        let mut placed = None;
        for server in state.regions.iter().map(|r| r.server).chain(candidates.iter().copied())
        {
            if cluster.try_alloc(server, Resources::mem_only(mb), now) {
                placed = Some(server);
                break;
            }
        }
        match placed {
            Some(server) => {
                cluster.add_used(server, Resources::mem_only(mb), now);
                let rid = RegionId(state.next_region);
                state.next_region += 1;
                let mr_tag = state.next_mr_tag;
                state.next_mr_tag += 1;
                state.regions.push(Region { id: rid, server, mb, mr_tag });
                Ok(rid)
            }
            None => {
                anyhow::bail!("no candidate server can fit {mb} MB for component {id}")
            }
        }
    }

    /// Register/unregister an accessor; the component is released when
    /// the last accessor unregisters (returns freed MB).
    pub fn attach(&mut self, id: u64, accessor: u64) -> Result<()> {
        let state = self
            .components
            .get_mut(id as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("unknown data component {id}"))?;
        state.accessors.push(accessor);
        Ok(())
    }

    pub fn detach(
        &mut self,
        cluster: &mut Cluster,
        id: u64,
        accessor: u64,
        now: Millis,
    ) -> Result<bool> {
        let state = self
            .components
            .get_mut(id as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("unknown data component {id}"))?;
        if let Some(pos) = state.accessors.iter().position(|&a| a == accessor) {
            state.accessors.swap_remove(pos);
        }
        if state.accessors.is_empty() {
            self.release(cluster, id, now)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Release all regions of a component (end of life or failure
    /// discard, §5.3.2). The emptied state shell is recycled.
    pub fn release(&mut self, cluster: &mut Cluster, id: u64, now: Millis) -> Result<f64> {
        let mut state = self
            .components
            .get_mut(id as usize)
            .and_then(|s| s.take())
            .ok_or_else(|| anyhow::anyhow!("unknown data component {id}"))?;
        let mut freed = 0.0;
        for r in state.regions.drain(..) {
            cluster.sub_used(r.server, Resources::mem_only(r.mb), now);
            cluster.free(r.server, Resources::mem_only(r.mb), now);
            freed += r.mb;
        }
        self.recycle(state);
        Ok(freed)
    }

    /// Release every live component (error-path cleanup); returns the
    /// total MB freed. Index order — deterministic.
    pub fn release_all(&mut self, cluster: &mut Cluster, now: Millis) -> f64 {
        let mut freed = 0.0;
        for id in 0..self.components.len() {
            if self.components[id].is_some() {
                if let Ok(mb) = self.release(cluster, id as u64, now) {
                    freed += mb;
                }
            }
        }
        freed
    }

    /// Servers currently holding regions of `id` (QP-reuse check, §9.4).
    pub fn region_servers(&self, id: u64) -> Vec<ServerId> {
        self.region_server_iter(id).collect()
    }

    /// Allocation-free variant of [`Self::region_servers`] for the
    /// executor's connection-setup loop.
    pub fn region_server_iter(&self, id: u64) -> impl Iterator<Item = ServerId> + '_ {
        self.get(id).into_iter().flat_map(|s| s.regions.iter().map(|r| r.server))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, RackId};

    fn small_cluster() -> Cluster {
        // 2 servers × 1024 MB so growth must spill.
        Cluster::new(ClusterSpec {
            racks: 1,
            servers_per_rack: 2,
            server_capacity: Resources::new(8.0, 1024.0),
        })
    }

    #[test]
    fn launch_grow_release_conserves_memory() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        mc.launch(&mut cluster, 1, ServerId(0), 512.0, 0.0).unwrap();
        assert_eq!(cluster.server(ServerId(0)).available().mem_mb, 512.0);
        // grows locally first
        mc.grow(&mut cluster, 1, 256.0, &[ServerId(1)], 1.0).unwrap();
        assert_eq!(cluster.server(ServerId(0)).available().mem_mb, 256.0);
        // then spills to the candidate when local is full
        mc.grow(&mut cluster, 1, 512.0, &[ServerId(1)], 2.0).unwrap();
        assert_eq!(cluster.server(ServerId(1)).available().mem_mb, 512.0);
        assert_eq!(mc.get(1).unwrap().total_mb(), 1280.0);
        let freed = mc.release(&mut cluster, 1, 3.0).unwrap();
        assert_eq!(freed, 1280.0);
        assert_eq!(cluster.server(ServerId(0)).available().mem_mb, 1024.0);
        assert_eq!(cluster.server(ServerId(1)).available().mem_mb, 1024.0);
    }

    #[test]
    fn remote_fraction_reflects_region_split() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        mc.launch(&mut cluster, 7, ServerId(0), 300.0, 0.0).unwrap();
        assert_eq!(mc.get(7).unwrap().remote_fraction(ServerId(0)), 0.0);
        // force the growth remote by filling server 0
        cluster.server_mut(ServerId(0)).try_alloc(Resources::mem_only(724.0), 0.0);
        mc.grow(&mut cluster, 7, 100.0, &[ServerId(1)], 1.0).unwrap();
        let f = mc.get(7).unwrap().remote_fraction(ServerId(0));
        assert!((f - 0.25).abs() < 1e-9, "{f}");
        assert_eq!(mc.region_servers(7), vec![ServerId(0), ServerId(1)]);
    }

    #[test]
    fn detach_releases_on_last_accessor() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        mc.launch(&mut cluster, 3, ServerId(0), 100.0, 0.0).unwrap();
        mc.attach(3, 11).unwrap();
        mc.attach(3, 12).unwrap();
        assert!(!mc.detach(&mut cluster, 3, 11, 1.0).unwrap());
        assert!(mc.get(3).is_some());
        assert!(mc.detach(&mut cluster, 3, 12, 2.0).unwrap());
        assert!(mc.get(3).is_none());
        assert_eq!(cluster.server(ServerId(0)).available().mem_mb, 1024.0);
    }

    #[test]
    fn launch_rejects_oversize_and_duplicates() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        assert!(mc.launch(&mut cluster, 1, ServerId(0), 4096.0, 0.0).is_err());
        mc.launch(&mut cluster, 1, ServerId(0), 10.0, 0.0).unwrap();
        assert!(mc.launch(&mut cluster, 1, ServerId(1), 10.0, 0.0).is_err());
    }

    #[test]
    fn grow_fails_when_cluster_full() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        mc.launch(&mut cluster, 1, ServerId(0), 1024.0, 0.0).unwrap();
        mc.grow(&mut cluster, 1, 1024.0, &[ServerId(1)], 1.0).unwrap();
        let err = mc.grow(&mut cluster, 1, 1.0, &[ServerId(1)], 2.0);
        assert!(err.is_err());
    }

    #[test]
    fn released_state_shells_recycle_with_fresh_tags() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        mc.launch(&mut cluster, 0, ServerId(0), 64.0, 0.0).unwrap();
        mc.grow(&mut cluster, 0, 32.0, &[], 1.0).unwrap();
        mc.release(&mut cluster, 0, 2.0).unwrap();
        assert!(mc.get(0).is_none());
        // relaunch under the same id: recycled shell, tag space restarts
        mc.launch(&mut cluster, 0, ServerId(0), 32.0, 3.0).unwrap();
        assert_eq!(mc.get(0).unwrap().regions[0].mr_tag, 0);
        mc.grow(&mut cluster, 0, 16.0, &[], 4.0).unwrap();
        assert_eq!(mc.get(0).unwrap().regions[1].mr_tag, 1);
        let freed = mc.release(&mut cluster, 0, 5.0).unwrap();
        assert_eq!(freed, 48.0);
        assert_eq!(cluster.server(ServerId(0)).available().mem_mb, 1024.0);
        mc.reset(); // no live components: pure no-op
        assert!(mc.get(0).is_none());
    }

    #[test]
    fn mr_tags_unique_per_region() {
        let mut cluster = small_cluster();
        let mut mc = MemoryController::new();
        mc.launch(&mut cluster, 1, ServerId(0), 10.0, 0.0).unwrap();
        mc.grow(&mut cluster, 1, 10.0, &[], 1.0).unwrap();
        mc.grow(&mut cluster, 1, 10.0, &[], 2.0).unwrap();
        let tags: Vec<u64> = mc.get(1).unwrap().regions.iter().map(|r| r.mr_tag).collect();
        let mut dedup = tags.clone();
        dedup.dedup();
        assert_eq!(tags.len(), dedup.len());
        let _ = RackId(0); // silence unused import in some cfgs
    }
}
