//! Cluster topology: racks of servers, lookup helpers, aggregate
//! consumption readouts, and the availability index that backs the
//! placement hot path.
//!
//! Mutation discipline: the scheduler/executor hot path mutates servers
//! through the [`Cluster`] hooks ([`Cluster::try_alloc`],
//! [`Cluster::free`], [`Cluster::mark`], [`Cluster::unmark`]), which
//! keep the [`PlacementIndex`] synchronized incrementally. Raw
//! [`Cluster::server_mut`] access stays available for cold paths and
//! tests; it bumps a mutation epoch and the next index query pays one
//! O(servers) rebuild (dirty-epoch invalidation).

use std::cell::{Cell, RefCell};

use super::clock::Millis;
use super::index::PlacementIndex;
use super::server::{Consumption, Server, ServerId};
use super::Resources;

/// Dense rack identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RackId(pub usize);

/// Construction parameters for a cluster.
///
/// Default mirrors the paper's testbed: 1 rack × 8 servers, each with
/// 2×16-core Xeons (32 vCPU) and 64 GB (§6 Environment).
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    /// Number of racks.
    pub racks: usize,
    /// Servers per rack (uniform).
    pub servers_per_rack: usize,
    /// Per-server capacity (uniform).
    pub server_capacity: Resources,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            racks: 1,
            servers_per_rack: 8,
            server_capacity: Resources::new(32.0, 65536.0),
        }
    }
}

impl ClusterSpec {
    /// The paper's 8-server local rack.
    pub fn paper_testbed() -> Self {
        Self::default()
    }

    /// A multi-rack cluster for scheduler-scalability experiments.
    pub fn multi_rack(racks: usize, servers_per_rack: usize) -> Self {
        Self { racks, servers_per_rack, ..Self::default() }
    }

    /// The same fleet resharded into `racks` racks at *fixed total
    /// capacity*: the total server count and per-server resources are
    /// unchanged, only the rack fan-out moves. The axis of the driver's
    /// multi-rack sharding sweeps (`racks` must divide the current
    /// total server count).
    pub fn resharded(self, racks: usize) -> Self {
        let total = self.racks * self.servers_per_rack;
        assert!(racks > 0, "a cluster needs at least one rack");
        assert_eq!(
            total % racks,
            0,
            "resharding must preserve total capacity: {total} servers across {racks} racks"
        );
        Self { racks, servers_per_rack: total / racks, ..self }
    }

    /// Total servers across all racks.
    pub fn total_servers(&self) -> usize {
        self.racks * self.servers_per_rack
    }
}

/// Racks of servers with aggregate accounting.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// The construction parameters (rack fan-out and server shape).
    pub spec: ClusterSpec,
    servers: Vec<Server>,
    /// Mutation epoch: bumped by raw mutable access (`server_mut`,
    /// `servers_mut`); the index lazily rebuilds when it lags.
    epoch: Cell<u64>,
    /// Availability index (interior mutability so `&self` queries can
    /// perform the lazy rebuild).
    index: RefCell<PlacementIndex>,
    /// Racks whose availability changed since the last
    /// [`Self::for_each_dirty_rack`] drain (the global scheduler's
    /// incremental refresh feed — replaces the executor's O(racks)
    /// sweep per invocation). Push order, deduplicated via `rack_dirty`.
    dirty_racks: Vec<usize>,
    rack_dirty: Vec<bool>,
}

impl Cluster {
    /// Build the fleet `spec` describes, every server up and empty.
    pub fn new(spec: ClusterSpec) -> Self {
        let mut servers = Vec::with_capacity(spec.racks * spec.servers_per_rack);
        for r in 0..spec.racks {
            for s in 0..spec.servers_per_rack {
                let id = ServerId(r * spec.servers_per_rack + s);
                servers.push(Server::new(id, RackId(r), spec.server_capacity));
            }
        }
        let mut index = PlacementIndex::new(
            spec.racks,
            servers.len(),
            spec.server_capacity.magnitude(),
        );
        index.rebuild(&servers, 0);
        Self {
            spec,
            servers,
            epoch: Cell::new(0),
            index: RefCell::new(index),
            // every rack starts dirty so the first drain seeds the
            // global scheduler with the full picture
            dirty_racks: (0..spec.racks).collect(),
            rack_dirty: vec![true; spec.racks],
        }
    }

    /// Shared access to one server.
    pub fn server(&self, id: ServerId) -> &Server {
        &self.servers[id.0]
    }

    /// Raw mutable server access (cold paths/tests). Invalidates the
    /// availability index; prefer the typed hooks on the hot path.
    pub fn server_mut(&mut self, id: ServerId) -> &mut Server {
        self.epoch.set(self.epoch.get() + 1);
        self.mark_all_racks_dirty();
        &mut self.servers[id.0]
    }

    /// All servers, rack-major (server `i` lives in rack
    /// `i / servers_per_rack`).
    pub fn servers(&self) -> &[Server] {
        &self.servers
    }

    /// Raw mutable access to all servers; invalidates the index.
    pub fn servers_mut(&mut self) -> &mut [Server] {
        self.epoch.set(self.epoch.get() + 1);
        self.mark_all_racks_dirty();
        &mut self.servers
    }

    fn mark_rack_dirty(&mut self, rack: usize) {
        if !self.rack_dirty[rack] {
            self.rack_dirty[rack] = true;
            self.dirty_racks.push(rack);
        }
    }

    fn mark_all_racks_dirty(&mut self) {
        for r in 0..self.spec.racks {
            self.mark_rack_dirty(r);
        }
    }

    /// True when some rack's availability changed since the last
    /// [`Self::for_each_dirty_rack`] drain. The multi-tenant driver
    /// uses this as its admission-retry trigger: an empty feed means no
    /// capacity was freed (or claimed) since the previous attempt, so
    /// re-probing a deferred-queue head cannot succeed and is skipped.
    pub fn has_dirty_racks(&self) -> bool {
        !self.dirty_racks.is_empty()
    }

    /// Visit every rack whose availability changed since the last
    /// drain, handing `(rack, current availability)` to `f` (in
    /// first-dirtied order — deterministic under a deterministic
    /// mutation sequence). Allocation-free in steady state: the drain
    /// list's capacity is reused. The executor drains this into
    /// `GlobalScheduler::update_rack` on each admission instead of
    /// sweeping all racks.
    pub fn for_each_dirty_rack(&mut self, mut f: impl FnMut(RackId, Resources)) {
        if self.dirty_racks.is_empty() {
            return;
        }
        let mut dirty = std::mem::take(&mut self.dirty_racks);
        for &r in &dirty {
            self.rack_dirty[r] = false;
        }
        for &r in &dirty {
            f(RackId(r), self.rack_available(RackId(r)));
        }
        dirty.clear();
        // restore the drained list so its capacity is reused (`f`
        // cannot re-dirty racks — `self` is exclusively borrowed for
        // the duration of this call, so the live list is still empty)
        debug_assert!(self.dirty_racks.is_empty());
        self.dirty_racks = dirty;
    }

    // ---- index-maintaining mutation hooks (the placement hot path) ----

    /// Allocate on one server, keeping the availability index in sync.
    pub fn try_alloc(&mut self, id: ServerId, amount: Resources, now: Millis) -> bool {
        let ok = self.servers[id.0].try_alloc(amount, now);
        if ok {
            self.index.get_mut().update(&self.servers[id.0]);
            let rack = self.servers[id.0].rack.0;
            self.mark_rack_dirty(rack);
        }
        ok
    }

    /// Release resources on one server, keeping the index in sync.
    pub fn free(&mut self, id: ServerId, amount: Resources, now: Millis) {
        self.servers[id.0].free(amount, now);
        self.index.get_mut().update(&self.servers[id.0]);
        let rack = self.servers[id.0].rack.0;
        self.mark_rack_dirty(rack);
    }

    /// Place a low-priority mark (§5.1.1), keeping the index in sync.
    pub fn mark(&mut self, id: ServerId, amount: Resources) {
        self.servers[id.0].mark(amount);
        self.index.get_mut().update(&self.servers[id.0]);
        let rack = self.servers[id.0].rack.0;
        self.mark_rack_dirty(rack);
    }

    /// Remove a low-priority mark, keeping the index in sync.
    pub fn unmark(&mut self, id: ServerId, amount: Resources) {
        self.servers[id.0].unmark(amount);
        self.index.get_mut().update(&self.servers[id.0]);
        let rack = self.servers[id.0].rack.0;
        self.mark_rack_dirty(rack);
    }

    /// Report used share (consumption accounting only — usage does not
    /// affect availability, so the index needs no update).
    pub fn set_used(&mut self, id: ServerId, used: Resources, now: Millis) {
        self.servers[id.0].set_used(used, now);
    }

    /// Adjust used share upward; accounting only, index untouched.
    pub fn add_used(&mut self, id: ServerId, delta: Resources, now: Millis) {
        self.servers[id.0].add_used(delta, now);
    }

    /// Adjust used share downward; accounting only, index untouched.
    pub fn sub_used(&mut self, id: ServerId, delta: Resources, now: Millis) {
        self.servers[id.0].sub_used(delta, now);
    }

    // ---- sharded-replay raw access + note replay -----------------------

    /// Raw mutable server access for the sharded replay's phase-A
    /// workers, *without* invalidating the index or dirtying racks.
    ///
    /// Contract (enforced by `coordinator/epoch.rs`, the only caller):
    /// every index-relevant mutation performed through this slice is
    /// snapshotted as a note at mutation time and replayed through
    /// [`Self::replay_index_update`] before the next index query or
    /// dirty-rack drain, in canonical `(time, seq)` order. The pair of
    /// calls is therefore observationally identical to the same
    /// mutation sequence through the [`Self::try_alloc`] /
    /// [`Self::free`] hooks — which is why it must not bump the
    /// mutation epoch the way [`Self::servers_mut`] does (an epoch bump
    /// would force a rebuild and discard the carefully ordered
    /// incremental float deltas the digest depends on).
    pub(crate) fn servers_for_replay(&mut self) -> &mut [Server] {
        &mut self.servers
    }

    /// Replay one snapshotted availability mutation into the index and
    /// the dirty-rack feed: exactly the tail of [`Self::try_alloc`] /
    /// [`Self::free`] after the server mutation itself, fed from the
    /// snapshot a shard worker recorded. See
    /// [`PlacementIndex::update_snapshot`] for why the snapshot (and
    /// not the server's final state) is replayed.
    pub(crate) fn replay_index_update(
        &mut self,
        id: ServerId,
        avail: Resources,
        unmarked: Resources,
        marked: bool,
    ) {
        let rack = self.servers[id.0].rack;
        self.index.get_mut().update_snapshot(id, rack, avail, unmarked, marked);
        self.mark_rack_dirty(rack.0);
    }

    // ---- churn (fault injection / repair) ------------------------------

    /// Take one server down at `now` (fault injection). The index sees
    /// zero availability for it after the rebuild this triggers, and
    /// every rack is marked dirty so the admission-retry feed and the
    /// global scheduler observe the capacity loss. Returns false if the
    /// server was already down (repeat faults are idempotent).
    pub fn fail_server(&mut self, id: ServerId, now: Millis) -> bool {
        if !self.servers[id.0].is_up() {
            return false;
        }
        self.servers[id.0].fail(now);
        // Availability collapsed to zero: rebuild lazily via the epoch
        // (churn is rare; O(servers) on the next query is fine) and
        // ping the dirty-rack feed so deferred admissions re-probe.
        self.epoch.set(self.epoch.get() + 1);
        self.mark_all_racks_dirty();
        true
    }

    /// Bring one server back up at `now` (repair after the configured
    /// delay). Returns false if the server was already up.
    pub fn repair_server(&mut self, id: ServerId, now: Millis) -> bool {
        if self.servers[id.0].is_up() {
            return false;
        }
        self.servers[id.0].repair(now);
        self.epoch.set(self.epoch.get() + 1);
        self.mark_all_racks_dirty();
        true
    }

    /// Run `f` against the availability index, rebuilding it first if a
    /// raw mutation made it stale.
    pub fn with_index<R>(&self, f: impl FnOnce(&PlacementIndex) -> R) -> R {
        {
            let mut ix = self.index.borrow_mut();
            if ix.synced_epoch() != self.epoch.get() {
                ix.rebuild(&self.servers, self.epoch.get());
            }
        }
        f(&self.index.borrow())
    }

    // ---- lookups -------------------------------------------------------

    /// Server ids in one rack.
    pub fn rack_servers(&self, rack: RackId) -> impl Iterator<Item = ServerId> + '_ {
        self.servers
            .iter()
            .filter(move |s| s.rack == rack)
            .map(|s| s.id)
    }

    /// All rack ids, in order.
    pub fn racks(&self) -> impl Iterator<Item = RackId> {
        (0..self.spec.racks).map(RackId)
    }

    /// Same-rack test for the locality policy.
    pub fn same_rack(&self, a: ServerId, b: ServerId) -> bool {
        self.server(a).rack == self.server(b).rack
    }

    /// Aggregate free resources in a rack (the global scheduler's
    /// "rough amount of available resources" view, §5.3.1). O(1) from
    /// the index's maintained per-rack sums.
    pub fn rack_available(&self, rack: RackId) -> Resources {
        self.with_index(|ix| ix.rack_available(rack))
    }

    /// Total capacity across the cluster.
    pub fn total_capacity(&self) -> Resources {
        self.servers
            .iter()
            .fold(Resources::ZERO, |acc, s| acc.plus(s.capacity))
    }

    /// Aggregate consumption up to `now` across all servers. (Advances
    /// consumption integrals only; availability — and therefore the
    /// index — is untouched.)
    pub fn total_consumption(&mut self, now: Millis) -> Consumption {
        let mut total = Consumption::default();
        for s in &mut self.servers {
            total = total.plus(&s.consumption(now));
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_paper_testbed() {
        let c = Cluster::new(ClusterSpec::paper_testbed());
        assert_eq!(c.servers().len(), 8);
        assert_eq!(c.total_capacity(), Resources::new(256.0, 524288.0));
        assert_eq!(c.racks().count(), 1);
    }

    #[test]
    fn resharding_preserves_total_capacity() {
        let base = ClusterSpec::multi_rack(1, 8);
        for racks in [1, 2, 4, 8] {
            let spec = base.resharded(racks);
            assert_eq!(spec.racks, racks);
            assert_eq!(spec.total_servers(), 8);
            let c = Cluster::new(spec);
            assert_eq!(c.total_capacity(), Cluster::new(base).total_capacity());
        }
    }

    #[test]
    #[should_panic(expected = "preserve total capacity")]
    fn resharding_rejects_non_divisor_rack_counts() {
        let _ = ClusterSpec::multi_rack(1, 8).resharded(3);
    }

    #[test]
    fn multi_rack_lookup() {
        let c = Cluster::new(ClusterSpec::multi_rack(3, 4));
        assert_eq!(c.servers().len(), 12);
        assert_eq!(c.rack_servers(RackId(1)).count(), 4);
        assert!(c.same_rack(ServerId(4), ServerId(7)));
        assert!(!c.same_rack(ServerId(3), ServerId(4)));
    }

    #[test]
    fn rack_available_tracks_allocations() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(2, 2));
        let id = ServerId(0);
        assert!(c.server_mut(id).try_alloc(Resources::new(10.0, 1000.0), 0.0));
        let avail = c.rack_available(RackId(0));
        assert_eq!(avail, Resources::new(54.0, 130072.0));
        // rack 1 untouched
        assert_eq!(c.rack_available(RackId(1)), Resources::new(64.0, 131072.0));
    }

    #[test]
    fn rack_available_tracks_hook_allocations() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(2, 2));
        assert!(c.try_alloc(ServerId(0), Resources::new(10.0, 1000.0), 0.0));
        assert_eq!(c.rack_available(RackId(0)), Resources::new(54.0, 130072.0));
        c.free(ServerId(0), Resources::new(10.0, 1000.0), 1.0);
        assert_eq!(c.rack_available(RackId(0)), Resources::new(64.0, 131072.0));
    }

    #[test]
    fn hooks_and_raw_access_interleave() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(1, 2));
        assert!(c.try_alloc(ServerId(0), Resources::new(4.0, 4096.0), 0.0));
        // raw mutation invalidates; following hook + query still coherent
        c.server_mut(ServerId(1)).try_alloc(Resources::new(8.0, 8192.0), 1.0);
        assert!(c.try_alloc(ServerId(1), Resources::new(1.0, 1024.0), 2.0));
        let total = c.rack_available(RackId(0));
        assert_eq!(total, Resources::new(64.0 - 13.0, 131072.0 - 13312.0));
    }

    #[test]
    fn dirty_rack_drain_tracks_changes() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(3, 2));
        let mut seen: Vec<usize> = Vec::new();
        c.for_each_dirty_rack(|r, _| seen.push(r.0));
        assert_eq!(seen, vec![0, 1, 2], "all racks dirty at construction");
        seen.clear();
        c.for_each_dirty_rack(|r, _| seen.push(r.0));
        assert!(seen.is_empty(), "drain clears dirtiness");
        // hook mutations dirty exactly the touched rack (deduplicated)
        c.try_alloc(ServerId(2), Resources::new(1.0, 1.0), 0.0);
        c.free(ServerId(2), Resources::new(1.0, 1.0), 1.0);
        c.for_each_dirty_rack(|r, avail| {
            seen.push(r.0);
            assert_eq!(avail, Resources::new(64.0, 131072.0));
        });
        assert_eq!(seen, vec![1]);
        // raw access conservatively dirties every rack
        seen.clear();
        let _ = c.server_mut(ServerId(0));
        c.for_each_dirty_rack(|r, _| seen.push(r.0));
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn failed_server_disappears_from_index_until_repair() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(2, 2));
        // drain construction dirtiness so churn dirtiness is observable
        c.for_each_dirty_rack(|_, _| {});
        assert!(c.fail_server(ServerId(0), 0.0));
        assert!(!c.fail_server(ServerId(0), 1.0), "repeat fault is a no-op");
        assert_eq!(c.rack_available(RackId(0)), Resources::new(32.0, 65536.0));
        assert_eq!(c.rack_available(RackId(1)), Resources::new(64.0, 131072.0));
        let mut seen: Vec<usize> = Vec::new();
        c.for_each_dirty_rack(|r, _| seen.push(r.0));
        assert_eq!(seen, vec![0, 1], "churn pings the admission-retry feed");
        assert!(c.repair_server(ServerId(0), 10.0));
        assert!(!c.repair_server(ServerId(0), 11.0), "repeat repair is a no-op");
        assert_eq!(c.rack_available(RackId(0)), Resources::new(64.0, 131072.0));
    }

    #[test]
    fn replay_path_matches_hook_path_bit_for_bit() {
        // Same mutation sequence through (a) the index-maintaining
        // hooks and (b) raw server access + snapshot replay — the
        // sharded replay's contract. Availability sums must be
        // *bit*-identical (the float deltas accumulate in the same
        // order), and the dirty feed must drain the same racks in the
        // same order.
        let spec = ClusterSpec::multi_rack(2, 2);
        let mut hooked = Cluster::new(spec);
        let mut replayed = Cluster::new(spec);
        hooked.for_each_dirty_rack(|_, _| {});
        replayed.for_each_dirty_rack(|_, _| {});

        let seq: [(usize, f64, f64, bool); 4] = [
            (0, 10.0, 10000.0, true),
            (2, 4.0, 512.0, true),
            (0, 10.0, 10000.0, false),
            (3, 1.0, 64.0, true),
        ];
        for &(id, cpu, mem, alloc) in &seq {
            let amt = Resources::new(cpu, mem);
            if alloc {
                assert!(hooked.try_alloc(ServerId(id), amt, 1.0));
            } else {
                hooked.free(ServerId(id), amt, 1.0);
            }
            let (avail, unmarked, marked) = {
                let s = &mut replayed.servers_for_replay()[id];
                if alloc {
                    assert!(s.try_alloc(amt, 1.0));
                } else {
                    s.free(amt, 1.0);
                }
                (s.available(), s.available_unmarked(), s.marked() != Resources::ZERO)
            };
            replayed.replay_index_update(ServerId(id), avail, unmarked, marked);
        }

        for r in 0..spec.racks {
            let a = hooked.rack_available(RackId(r));
            let b = replayed.rack_available(RackId(r));
            assert!(a.cpu.to_bits() == b.cpu.to_bits(), "rack {r} cpu sums diverge");
            assert!(a.mem_mb.to_bits() == b.mem_mb.to_bits(), "rack {r} mem sums diverge");
        }
        let mut da = Vec::new();
        let mut db = Vec::new();
        hooked.for_each_dirty_rack(|r, _| da.push(r.0));
        replayed.for_each_dirty_rack(|r, _| db.push(r.0));
        assert_eq!(da, db, "dirty-rack drain order diverges");
        for demand in [Resources::new(8.0, 8192.0), Resources::new(30.0, 62000.0)] {
            assert_eq!(
                hooked.with_index(|ix| ix.smallest_fit(demand)),
                replayed.with_index(|ix| ix.smallest_fit(demand)),
            );
        }
    }

    #[test]
    fn total_consumption_aggregates() {
        let mut c = Cluster::new(ClusterSpec::multi_rack(1, 2));
        c.server_mut(ServerId(0)).try_alloc(Resources::new(1.0, 1024.0), 0.0);
        c.server_mut(ServerId(1)).try_alloc(Resources::new(2.0, 2048.0), 0.0);
        let total = c.total_consumption(1000.0);
        assert!((total.alloc_cpu_s - 3.0).abs() < 1e-9);
        assert!((total.alloc_mem_mb_s - 3072.0).abs() < 1e-9);
    }
}
