//! Availability index for the placement hot path (§6.2 scalability).
//!
//! Every placement decision used to be an O(servers) linear scan that
//! also heap-allocated a candidate vector. This module replaces the
//! scan with a bucketed index so `smallest_fit` and the rack
//! scheduler's allocation path are O(log-ish buckets + bucket
//! occupancy) with **zero allocations per query**.
//!
//! # Bucket scheme
//!
//! Servers are bucketed by their *quantized available-resource
//! magnitude*: `bucket = floor(available().magnitude() / capacity
//! magnitude * BUCKETS)`, clamped to `BUCKETS - 1`. Because
//! `Resources::fits` implies `available.magnitude() >=
//! demand.magnitude()`, a query for `demand` only needs to scan buckets
//! from `bucket(demand.magnitude())` upward — lower buckets cannot hold
//! a fitting server. (The scan actually starts one epsilon earlier to
//! honor the float tolerance inside `fits`.)
//!
//! Each bucket is split into an **unmarked** and a **marked** list,
//! mirroring the §5.1.1 low-priority marks: the first placement pass
//! prefers servers whose *unmarked* availability fits, the second pass
//! falls back to raw availability. Unmarked servers need no separate
//! `available_unmarked()` evaluation, so the common pass-1 probe stays
//! a single 2-D compare per candidate.
//!
//! # Invariants
//!
//! - Every live server appears in exactly one (bucket, list) slot of the
//!   global bucket set and exactly one slot of its rack's bucket set;
//!   `slots` records both positions for O(1) removal (swap-remove with
//!   back-pointer fixup).
//! - Cached `avail`/`unmarked`/`mag` per entry are bit-identical to what
//!   a fresh `Server::available()` / `available_unmarked()` /
//!   `magnitude()` evaluation would return — queries never touch the
//!   `Server` table, and decisions are identical to the retained
//!   linear-scan reference (`placement::smallest_fit_linear`,
//!   differential-tested in `rust/tests/proptests.rs`).
//! - `rack_avail` carries per-rack availability sums, maintained
//!   incrementally (signed deltas) and recomputed exactly on rebuild.
//! - `synced_epoch` tracks the owning [`Cluster`]'s mutation epoch; raw
//!   `server_mut` access bumps the epoch, and the next query lazily
//!   rebuilds the whole index (dirty-epoch invalidation). The scheduler
//!   hot path mutates through the `Cluster` hooks (`try_alloc`, `free`,
//!   `mark`, `unmark`) which update the index in place, so rebuilds
//!   only happen after cold-path raw access.
//!
//! # Complexity
//!
//! - update (hook path): O(bucket occupancy) worst case for the
//!   swap-remove, O(1) expected.
//! - `smallest_fit` / `smallest_fit_in_rack`: O(buckets scanned +
//!   occupancy of the first bucket holding a fitting server); no
//!   allocation.
//! - rebuild after raw access: O(servers), amortized over however many
//!   raw mutations preceded it.
//!
//! [`Cluster`]: super::topology::Cluster

use super::server::{Server, ServerId};
use super::topology::RackId;
use super::Resources;

/// Number of quantization buckets per bucket set. 64 keeps expected
/// occupancy ≈ servers/64 per bucket at rack scale while the start-
/// bucket pruning still skips the bulk of loaded servers.
pub const BUCKETS: usize = 64;

/// Safety margin subtracted from the demand magnitude before choosing
/// the start bucket, covering the float tolerance inside
/// [`Resources::fits`] so a server that "fits within epsilon" is never
/// hidden in a lower bucket.
const START_EPS: f64 = 1e-9;

/// Cached availability snapshot of one server.
#[derive(Debug, Clone, Copy)]
struct Entry {
    id: ServerId,
    avail: Resources,
    unmarked: Resources,
    /// `avail.magnitude()`, cached for the best-fit comparisons.
    mag: f64,
}

impl Entry {
    fn of(s: &Server) -> Self {
        let avail = s.available();
        Entry { id: s.id, avail, unmarked: s.available_unmarked(), mag: avail.magnitude() }
    }
}

/// One quantization bucket: unmarked/marked split (§5.1.1).
#[derive(Debug, Clone, Default)]
struct Level {
    clean: Vec<Entry>,
    marked: Vec<Entry>,
}

/// A full bucket array (one global, one per rack).
#[derive(Debug, Clone)]
struct Buckets {
    levels: Vec<Level>,
}

impl Buckets {
    fn new() -> Self {
        Self { levels: (0..BUCKETS).map(|_| Level::default()).collect() }
    }

    fn clear(&mut self) {
        for level in &mut self.levels {
            level.clean.clear();
            level.marked.clear();
        }
    }
}

/// Position of a server's entry inside one bucket set.
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    level: usize,
    marked: bool,
    pos: usize,
}

/// The availability index. Owned by [`Cluster`]; see module docs.
///
/// [`Cluster`]: super::topology::Cluster
#[derive(Debug, Clone)]
pub struct PlacementIndex {
    /// Quantization range: the (uniform) server capacity magnitude.
    scale: f64,
    global: Buckets,
    racks: Vec<Buckets>,
    /// Incremental per-rack availability sums as raw (cpu, mem_mb);
    /// signed so deltas cancel exactly on alloc/free round trips.
    rack_avail: Vec<(f64, f64)>,
    /// Per server: (slot in `global`, slot in its rack's bucket set).
    slots: Vec<(Slot, Slot)>,
    synced_epoch: u64,
}

impl PlacementIndex {
    /// Empty index for `racks` racks and `n_servers` servers with the
    /// given capacity magnitude; callers must `rebuild` before queries.
    pub fn new(racks: usize, n_servers: usize, scale: f64) -> Self {
        Self {
            scale,
            global: Buckets::new(),
            racks: (0..racks).map(|_| Buckets::new()).collect(),
            rack_avail: vec![(0.0, 0.0); racks],
            slots: vec![(Slot::default(), Slot::default()); n_servers],
            synced_epoch: 0,
        }
    }

    /// Epoch this index was last synchronized at.
    pub fn synced_epoch(&self) -> u64 {
        self.synced_epoch
    }

    fn bucket_of(&self, mag: f64) -> usize {
        if self.scale <= 0.0 {
            return 0;
        }
        ((mag / self.scale * BUCKETS as f64) as usize).min(BUCKETS - 1)
    }

    fn remove_from(
        buckets: &mut Buckets,
        slots: &mut [(Slot, Slot)],
        which: usize,
        id: ServerId,
    ) -> Entry {
        let slot = if which == 0 { slots[id.0].0 } else { slots[id.0].1 };
        let level = &mut buckets.levels[slot.level];
        let list = if slot.marked { &mut level.marked } else { &mut level.clean };
        let entry = list.swap_remove(slot.pos);
        debug_assert_eq!(entry.id, id, "slot table out of sync");
        if let Some(moved) = list.get(slot.pos) {
            let moved_slot =
                if which == 0 { &mut slots[moved.id.0].0 } else { &mut slots[moved.id.0].1 };
            moved_slot.pos = slot.pos;
        }
        entry
    }

    fn insert_into(
        buckets: &mut Buckets,
        slots: &mut [(Slot, Slot)],
        which: usize,
        e: Entry,
        level: usize,
        marked: bool,
    ) {
        let lvl = &mut buckets.levels[level];
        let list = if marked { &mut lvl.marked } else { &mut lvl.clean };
        list.push(e);
        let slot = Slot { level, marked, pos: list.len() - 1 };
        if which == 0 {
            slots[e.id.0].0 = slot;
        } else {
            slots[e.id.0].1 = slot;
        }
    }

    /// Re-index one server after an availability-changing mutation
    /// (the `Cluster` alloc/free/mark/unmark hooks call this).
    pub fn update(&mut self, s: &Server) {
        self.update_snapshot(
            s.id,
            s.rack,
            s.available(),
            s.available_unmarked(),
            s.marked() != Resources::ZERO,
        );
    }

    /// Re-index one server from an availability *snapshot* taken at
    /// mutation time, rather than from the live `Server`.
    ///
    /// This is [`Self::update`]'s whole body (`update` delegates here);
    /// the split exists for the sharded replay's epoch barrier: shard
    /// workers mutate rack-local servers directly and snapshot
    /// `available()` / `available_unmarked()` / `marked()` immediately
    /// after each mutation, and the coordinator replays those snapshots
    /// through this method in canonical `(time, seq)` order. Feeding
    /// the *snapshot* (not the server's final state) keeps the signed
    /// `rack_avail` float deltas accumulating in exactly the sequential
    /// hook order — bit-identical sums, and therefore bit-identical
    /// routing decisions and digests.
    pub(crate) fn update_snapshot(
        &mut self,
        id: ServerId,
        rack: RackId,
        avail: Resources,
        unmarked: Resources,
        marked: bool,
    ) {
        let rack = rack.0;
        let old = Self::remove_from(&mut self.global, &mut self.slots, 0, id);
        Self::remove_from(&mut self.racks[rack], &mut self.slots, 1, id);
        let e = Entry { id, avail, unmarked, mag: avail.magnitude() };
        self.rack_avail[rack].0 += e.avail.cpu - old.avail.cpu;
        self.rack_avail[rack].1 += e.avail.mem_mb - old.avail.mem_mb;
        let level = self.bucket_of(e.mag);
        Self::insert_into(&mut self.global, &mut self.slots, 0, e, level, marked);
        Self::insert_into(&mut self.racks[rack], &mut self.slots, 1, e, level, marked);
    }

    /// Rebuild from scratch (dirty-epoch invalidation path). Entries are
    /// inserted and rack sums accumulated in server-id order so the
    /// sums are bit-identical to a linear fold over the server table.
    pub fn rebuild(&mut self, servers: &[Server], epoch: u64) {
        self.global.clear();
        for rb in &mut self.racks {
            rb.clear();
        }
        for sum in &mut self.rack_avail {
            *sum = (0.0, 0.0);
        }
        for s in servers {
            let e = Entry::of(s);
            let rack = s.rack.0;
            self.rack_avail[rack].0 += e.avail.cpu;
            self.rack_avail[rack].1 += e.avail.mem_mb;
            let level = self.bucket_of(e.mag);
            let marked = s.marked() != Resources::ZERO;
            Self::insert_into(&mut self.global, &mut self.slots, 0, e, level, marked);
            Self::insert_into(&mut self.racks[rack], &mut self.slots, 1, e, level, marked);
        }
        self.synced_epoch = epoch;
    }

    /// Scan one bucket set from `start` upward; smallest `(mag, id)`
    /// among entries whose (pass-dependent) availability fits wins —
    /// exactly the linear scan's `min_by` + first-wins tie-break.
    fn query(
        buckets: &Buckets,
        demand: Resources,
        respect_marks: bool,
        start: usize,
    ) -> Option<ServerId> {
        for level in &buckets.levels[start..] {
            let mut best: Option<(f64, usize)> = None;
            let mut consider = |mag: f64, id: usize| match best {
                Some((bm, bid)) if bm < mag || (bm == mag && bid < id) => {}
                _ => best = Some((mag, id)),
            };
            for e in &level.clean {
                // unmarked == avail for clean entries: one compare serves
                // both passes.
                if e.avail.fits(demand) {
                    consider(e.mag, e.id.0);
                }
            }
            for e in &level.marked {
                let a = if respect_marks { e.unmarked } else { e.avail };
                if a.fits(demand) {
                    consider(e.mag, e.id.0);
                }
            }
            if let Some((_, id)) = best {
                return Some(ServerId(id));
            }
        }
        None
    }

    fn start_bucket(&self, demand: Resources) -> usize {
        self.bucket_of((demand.magnitude() - START_EPS).max(0.0))
    }

    /// Cluster-wide smallest fit: unmarked-first, then any availability.
    /// Decision-identical to `placement::smallest_fit_linear`.
    pub fn smallest_fit(&self, demand: Resources) -> Option<ServerId> {
        let start = self.start_bucket(demand);
        Self::query(&self.global, demand, true, start)
            .or_else(|| Self::query(&self.global, demand, false, start))
    }

    /// Smallest fit restricted to one rack.
    pub fn smallest_fit_in_rack(&self, rack: RackId, demand: Resources) -> Option<ServerId> {
        let start = self.start_bucket(demand);
        let buckets = &self.racks[rack.0];
        Self::query(buckets, demand, true, start)
            .or_else(|| Self::query(buckets, demand, false, start))
    }

    /// Aggregate rack availability (the global scheduler's rough view),
    /// O(1) from the maintained sums.
    pub fn rack_available(&self, rack: RackId) -> Resources {
        let (cpu, mem) = self.rack_avail[rack.0];
        Resources::new(cpu.max(0.0), mem.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec};

    fn cluster(racks: usize, servers: usize) -> Cluster {
        Cluster::new(ClusterSpec {
            racks,
            servers_per_rack: servers,
            server_capacity: Resources::new(32.0, 65536.0),
        })
    }

    #[test]
    fn hook_updates_match_fresh_rebuild() {
        let mut c = cluster(2, 4);
        assert!(c.try_alloc(ServerId(1), Resources::new(8.0, 8192.0), 0.0));
        c.mark(ServerId(2), Resources::new(16.0, 32768.0));
        c.free(ServerId(1), Resources::new(4.0, 4096.0), 1.0);
        c.unmark(ServerId(2), Resources::new(8.0, 16384.0));
        // Indexed answers equal a linear scan for a spread of demands.
        for demand in [
            Resources::new(1.0, 1024.0),
            Resources::new(28.0, 60000.0),
            Resources::new(30.0, 62000.0),
            Resources::ZERO,
        ] {
            let indexed = c.with_index(|ix| ix.smallest_fit(demand));
            let linear =
                crate::coordinator::placement::smallest_fit_linear(&c, demand);
            assert_eq!(indexed, linear, "demand {demand:?}");
        }
    }

    #[test]
    fn raw_access_invalidates_and_rebuilds() {
        let mut c = cluster(1, 4);
        // Raw mutation bypasses the hooks…
        c.server_mut(ServerId(0)).try_alloc(Resources::new(32.0, 65536.0), 0.0);
        // …but the next query rebuilds and sees it.
        let got = c.with_index(|ix| ix.smallest_fit(Resources::new(4.0, 4096.0)));
        assert_ne!(got, Some(ServerId(0)));
        assert_eq!(
            got,
            crate::coordinator::placement::smallest_fit_linear(
                &c,
                Resources::new(4.0, 4096.0)
            )
        );
    }

    #[test]
    fn rack_sums_track_hooks_and_rebuilds() {
        let mut c = cluster(2, 2);
        assert!(c.try_alloc(ServerId(0), Resources::new(10.0, 1000.0), 0.0));
        assert_eq!(c.rack_available(RackId(0)), Resources::new(54.0, 130072.0));
        assert_eq!(c.rack_available(RackId(1)), Resources::new(64.0, 131072.0));
        c.free(ServerId(0), Resources::new(10.0, 1000.0), 1.0);
        assert_eq!(c.rack_available(RackId(0)), Resources::new(64.0, 131072.0));
    }

    #[test]
    fn marks_demote_in_pass_one_only() {
        let mut c = cluster(1, 3);
        // Server 0 lightly loaded but unmarked; 1 and 2 empty but marked.
        assert!(c.try_alloc(ServerId(0), Resources::new(16.0, 30000.0), 0.0));
        c.mark(ServerId(1), Resources::new(32.0, 65536.0));
        c.mark(ServerId(2), Resources::new(32.0, 65536.0));
        let small = Resources::new(8.0, 8192.0);
        assert_eq!(c.with_index(|ix| ix.smallest_fit(small)), Some(ServerId(0)));
        // A demand only the marked servers can hold still places (pass 2),
        // tie between 1 and 2 broken by id like the linear scan.
        let big = Resources::new(30.0, 60000.0);
        assert_eq!(c.with_index(|ix| ix.smallest_fit(big)), Some(ServerId(1)));
    }

    #[test]
    fn in_rack_query_stays_in_rack() {
        let mut c = cluster(2, 2);
        // Rack 0 nearly full; rack 1 empty.
        assert!(c.try_alloc(ServerId(0), Resources::new(32.0, 65536.0), 0.0));
        assert!(c.try_alloc(ServerId(1), Resources::new(30.0, 60000.0), 0.0));
        let d = Resources::new(8.0, 8192.0);
        assert_eq!(c.with_index(|ix| ix.smallest_fit_in_rack(RackId(0), d)), None);
        let got = c.with_index(|ix| ix.smallest_fit_in_rack(RackId(1), d)).unwrap();
        assert!(got == ServerId(2) || got == ServerId(3));
    }

    #[test]
    fn boundary_demand_not_hidden_by_quantization() {
        // Demand magnitude exactly on a bucket boundary (0.5 → bucket 32)
        // must still find a server whose availability equals it.
        let mut c = cluster(1, 2);
        assert!(c.try_alloc(ServerId(0), Resources::new(16.0, 32768.0), 0.0));
        let demand = Resources::new(16.0, 32768.0); // exactly what's left
        assert_eq!(
            c.with_index(|ix| ix.smallest_fit(demand)),
            crate::coordinator::placement::smallest_fit_linear(&c, demand)
        );
    }
}
