//! Environment start-up cost model.
//!
//! The paper measures these latencies on its testbed (Fig 7, Fig 23 and
//! the appendix cold/warm-start table); we parameterize the simulator
//! with the same numbers so startup-bound effects — pre-launching,
//! pre-warming, asynchronous connection setup — reproduce (DESIGN.md §1).

use super::clock::Millis;

/// Start-up latency constants (milliseconds), decomposed so the Fig 23
/// ablation can add/remove individual pieces.
#[derive(Debug, Clone, Copy)]
pub struct StartupModel {
    /// Docker container create+start on OpenWhisk's path.
    pub container_cold_ow: Millis,
    /// Zenix executor's leaner container launch path.
    pub container_cold_zenix: Millis,
    /// Language runtime + library load inside the container.
    pub runtime_load: Millis,
    /// User-code load (overlappable with connection setup, §5.2.2).
    pub user_code_load: Millis,
    /// Overlay-network attach (the costly prior-work path the paper
    /// measured at ~40% of startup).
    pub overlay_setup: Millis,
    /// Zenix network-virtualization module init (replaces the overlay).
    pub netvirt_setup: Millis,
    /// RDMA QP establishment via scheduler-assisted exchange (§9.4).
    pub qp_setup: Millis,
    /// TCP connection establishment (3-way handshake + registration).
    pub tcp_setup: Millis,
    /// Warm-start dispatch on OpenWhisk (environment reuse).
    pub warm_ow: Millis,
    /// Warm-start dispatch on AWS Lambda / Step Functions.
    pub warm_aws: Millis,
    /// Warm-start dispatch on Zenix.
    pub warm_zenix: Millis,
    /// AWS Lambda cold invoke (public-cloud baseline).
    pub cold_lambda: Millis,
    /// AWS Step Functions cold invoke (public-cloud baseline).
    pub cold_step_functions: Millis,
    /// Fixed cost of restoring an environment from a local snapshot
    /// image: page-table setup, device reattach and dispatch. Sized so
    /// restores land between a warm hit and a pre-warmed cold start.
    pub snapshot_restore_base: Millis,
    /// Restore cost per GiB of snapshot image (lazy page-in over the
    /// rack-local RDMA fabric, so far cheaper than a container boot).
    pub snapshot_restore_per_gb: Millis,
}

impl Default for StartupModel {
    fn default() -> Self {
        // Decomposition chosen so the composed paths reproduce the
        // appendix table:
        //   OW cold            = 600 + 173              = 773 ms
        //   OW cold + overlay  = 773 + 415              = 1188 ms
        //   Zenix + overlay    = 414 + 173 + 415        = 1002 ms
        //   Zenix no overlay   = 414 + 173 + 8          = 595 ms
        //   Full Zenix prewarm = 284 ms (env ready; user code + hidden QP)
        Self {
            container_cold_ow: 600.0,
            container_cold_zenix: 414.0,
            runtime_load: 173.0,
            user_code_load: 250.0,
            overlay_setup: 415.0,
            netvirt_setup: 8.0,
            qp_setup: 34.0,
            tcp_setup: 1.5,
            warm_ow: 35.0,
            warm_aws: 114.0,
            warm_zenix: 10.0,
            cold_lambda: 140.0,
            cold_step_functions: 215.0,
            snapshot_restore_base: 18.0,
            snapshot_restore_per_gb: 12.0,
        }
    }
}

/// Which start-latency tier an invocation's first environment resolved
/// to (the hierarchy production stacks expose, from cheapest to most
/// expensive path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartupTier {
    /// Nothing reusable: pay the full cold path for the platform.
    ColdBoot,
    /// A snapshot image of the app was resident in the rack's snapshot
    /// cache; restore cost scales with image size.
    SnapshotRestore,
    /// A live warm environment was reused (warm-pool hit).
    WarmHit,
}

/// Which platform's startup path to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartupPath {
    /// Stock OpenWhisk container + runtime bring-up.
    OpenWhisk,
    /// OpenWhisk with the overlay-network attach the paper measured.
    OpenWhiskOverlay,
    /// Zenix's leaner container launch, still paying the overlay attach.
    ZenixOverlay,
    /// Full Zenix cold path: lean launch + network-virtualization init.
    Zenix,
    /// Zenix with a pre-warmed environment (§5.2.1): container + runtime
    /// already up; only user code loads, with connection setup hidden
    /// behind it.
    ZenixPrewarmed,
    /// AWS Lambda cold invoke (public-cloud baseline).
    Lambda,
    /// AWS Step Functions cold invoke (public-cloud baseline).
    StepFunctions,
}

impl StartupModel {
    /// Cold-start latency of one environment on `path`.
    pub fn cold(&self, path: StartupPath) -> Millis {
        match path {
            StartupPath::OpenWhisk => self.container_cold_ow + self.runtime_load,
            StartupPath::OpenWhiskOverlay => {
                self.container_cold_ow + self.runtime_load + self.overlay_setup
            }
            StartupPath::ZenixOverlay => {
                self.container_cold_zenix + self.runtime_load + self.overlay_setup
            }
            StartupPath::Zenix => {
                self.container_cold_zenix + self.runtime_load + self.netvirt_setup
            }
            StartupPath::ZenixPrewarmed => {
                // Environment pre-launched; QP setup (34 ms) runs while
                // user code loads (250 ms) → max() + dispatch.
                self.warm_zenix + self.user_code_load.max(self.qp_setup)
            }
            StartupPath::Lambda => self.cold_lambda,
            StartupPath::StepFunctions => self.cold_step_functions,
        }
    }

    /// Warm-start latency (environment reuse).
    pub fn warm(&self, path: StartupPath) -> Millis {
        match path {
            StartupPath::OpenWhisk | StartupPath::OpenWhiskOverlay => self.warm_ow,
            StartupPath::Lambda | StartupPath::StepFunctions => self.warm_aws,
            _ => self.warm_zenix,
        }
    }

    /// Latency of restoring one environment from a snapshot image of
    /// `image_bytes` bytes ([`StartupTier::SnapshotRestore`]): fixed
    /// restore overhead plus size-proportional page-in.
    pub fn restore(&self, image_bytes: u64) -> Millis {
        const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
        // cast: safe(u64 -> f64 may round above 2^53; image sizes are
        // clamped to single-digit GiB by the snapshot layer)
        self.snapshot_restore_base + self.snapshot_restore_per_gb * (image_bytes as f64 / GIB)
    }

    /// Connection setup cost on the data path between two components
    /// (§5.2.2): synchronous unless hidden behind user-code load.
    pub fn conn_setup(&self, rdma: bool, asynchronous: bool) -> Millis {
        let raw = if rdma { self.qp_setup } else { self.tcp_setup };
        if asynchronous {
            // Hidden behind user-code load; residual only if it outlasts
            // the load (it doesn't with the paper's constants).
            (raw - self.user_code_load).max(0.0)
        } else {
            raw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_startup_table() {
        let m = StartupModel::default();
        assert_eq!(m.cold(StartupPath::OpenWhisk), 773.0);
        assert_eq!(m.cold(StartupPath::OpenWhiskOverlay), 1188.0);
        assert_eq!(m.cold(StartupPath::ZenixOverlay), 1002.0);
        assert_eq!(m.cold(StartupPath::Zenix), 595.0);
        assert_eq!(m.cold(StartupPath::ZenixPrewarmed), 260.0);
        assert_eq!(m.cold(StartupPath::Lambda), 140.0);
        assert_eq!(m.cold(StartupPath::StepFunctions), 215.0);
        assert_eq!(m.warm(StartupPath::OpenWhisk), 35.0);
        assert_eq!(m.warm(StartupPath::Lambda), 114.0);
        assert_eq!(m.warm(StartupPath::Zenix), 10.0);
    }

    #[test]
    fn zenix_ordering_matches_paper() {
        // Fig 23 ordering: OW < OW+overlay is false (overlay adds);
        // Zenix beats OW; prewarmed beats all cold paths.
        let m = StartupModel::default();
        assert!(m.cold(StartupPath::Zenix) < m.cold(StartupPath::OpenWhisk));
        assert!(m.cold(StartupPath::ZenixOverlay) < m.cold(StartupPath::OpenWhiskOverlay));
        assert!(m.cold(StartupPath::ZenixPrewarmed) < m.cold(StartupPath::Zenix));
        assert!(m.warm(StartupPath::Zenix) < m.warm(StartupPath::OpenWhisk));
    }

    #[test]
    fn restore_tier_sits_between_warm_and_prewarmed_cold() {
        // The tier hierarchy the driver exposes: warm hit < snapshot
        // restore (any plausible image size) < pre-warmed cold < cold.
        let m = StartupModel::default();
        const MIB: u64 = 1024 * 1024;
        let small = m.restore(64 * MIB);
        let large = m.restore(1024 * MIB);
        assert!(m.warm(StartupPath::Zenix) < small);
        assert!(small < large, "restore cost scales with image size");
        assert!(large < m.cold(StartupPath::ZenixPrewarmed));
        assert!(m.cold(StartupPath::ZenixPrewarmed) < m.cold(StartupPath::Zenix));
        assert_eq!(m.restore(0), m.snapshot_restore_base);
    }

    #[test]
    fn async_conn_setup_fully_hidden() {
        let m = StartupModel::default();
        assert_eq!(m.conn_setup(true, false), 34.0);
        assert_eq!(m.conn_setup(true, true), 0.0);
        assert!(m.conn_setup(false, false) < m.conn_setup(true, false));
    }
}
