//! Cluster substrate: servers, racks, containers, virtual clock and
//! resource accounting.
//!
//! The paper evaluates on a private 8-server RDMA rack; this module is
//! the discrete-event substitute (DESIGN.md §1): capacities, allocations
//! and start-up latencies are modeled explicitly so that the paper's
//! *allocation-shape* claims (GB·s, vCPU·s, makespan, utilization)
//! reproduce on commodity hardware.

pub mod clock;
pub mod index;
pub mod server;
pub mod snapshot;
pub mod startup;
pub mod topology;

pub use clock::Clock;
pub use index::PlacementIndex;
pub use server::{Server, ServerId};
pub use snapshot::{SnapshotCache, SnapshotStats};
pub use startup::{StartupModel, StartupTier};
pub use topology::{Cluster, ClusterSpec, RackId};

/// CPU (vCPUs) + memory (MB) bundle used for every allocation decision.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    /// vCPUs.
    pub cpu: f64,
    /// Memory in MB.
    pub mem_mb: f64,
}

impl Resources {
    /// The empty bundle.
    pub const ZERO: Resources = Resources { cpu: 0.0, mem_mb: 0.0 };

    /// Bundle of `cpu` vCPUs and `mem_mb` MB.
    pub fn new(cpu: f64, mem_mb: f64) -> Self {
        Self { cpu, mem_mb }
    }

    /// CPU-only bundle.
    pub fn cpu_only(cpu: f64) -> Self {
        Self { cpu, mem_mb: 0.0 }
    }

    /// Memory-only bundle.
    pub fn mem_only(mem_mb: f64) -> Self {
        Self { cpu: 0.0, mem_mb }
    }

    /// Component-wise `self + other`.
    pub fn plus(&self, other: Resources) -> Resources {
        Resources { cpu: self.cpu + other.cpu, mem_mb: self.mem_mb + other.mem_mb }
    }

    /// Component-wise saturating `self - other` (never negative).
    pub fn minus(&self, other: Resources) -> Resources {
        Resources {
            cpu: (self.cpu - other.cpu).max(0.0),
            mem_mb: (self.mem_mb - other.mem_mb).max(0.0),
        }
    }

    /// True iff `other` fits inside `self` (with float tolerance).
    pub fn fits(&self, other: Resources) -> bool {
        const EPS: f64 = 1e-9;
        other.cpu <= self.cpu + EPS && other.mem_mb <= self.mem_mb + EPS
    }

    /// Component-wise `self * k`.
    pub fn scale(&self, k: f64) -> Resources {
        Resources { cpu: self.cpu * k, mem_mb: self.mem_mb * k }
    }

    /// Scalar "size" used by best-fit comparisons: normalize CPU and
    /// memory to a common scale (paper server shape: 32 cores / 64 GB)
    /// and take the max so neither dimension dominates.
    pub fn magnitude(&self) -> f64 {
        (self.cpu / 32.0).max(self.mem_mb / 65536.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Resources::new(4.0, 1024.0);
        let b = Resources::new(1.0, 512.0);
        assert_eq!(a.plus(b), Resources::new(5.0, 1536.0));
        assert_eq!(a.minus(b), Resources::new(3.0, 512.0));
        assert_eq!(b.minus(a), Resources::ZERO);
        assert_eq!(a.scale(2.0), Resources::new(8.0, 2048.0));
    }

    #[test]
    fn fits_with_tolerance() {
        let cap = Resources::new(4.0, 1000.0);
        assert!(cap.fits(Resources::new(4.0, 1000.0)));
        assert!(cap.fits(Resources::new(3.9999999999, 1000.0)));
        assert!(!cap.fits(Resources::new(4.1, 10.0)));
        assert!(!cap.fits(Resources::new(1.0, 1001.0)));
    }

    #[test]
    fn magnitude_orders_servers() {
        // a mem-heavy remainder is "bigger" than a CPU-heavy small one
        let m1 = Resources::new(16.0, 8192.0).magnitude();
        let m2 = Resources::new(8.0, 32768.0).magnitude();
        assert!(m1 > m2 * 0.9); // both well-defined, comparable scale
        assert!(Resources::new(32.0, 65536.0).magnitude() > m1);
    }
}
