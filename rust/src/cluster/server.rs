//! A single server: capacity, allocations, low-priority marks, and
//! time-integrated consumption counters.
//!
//! Availability-changing mutations (`try_alloc`, `free`, `mark`,
//! `unmark`) are mirrored into the cluster's [`PlacementIndex`] when
//! they go through the `Cluster` hooks of the same names — the hot
//! path must use those so placement queries stay incremental; direct
//! `&mut Server` access instead invalidates the index wholesale.
//!
//! [`PlacementIndex`]: super::index::PlacementIndex

use super::clock::Millis;
use super::{RackId, Resources};

/// Dense server identifier (index into the cluster's server table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServerId(pub usize);

/// Time-integrated resource consumption, split into allocated vs used.
///
/// `alloc_*` integrates what was *reserved* (what a provider bills);
/// `used_*` integrates what the application actually exercised. The gap
/// is the paper's "unused/wasted" bar in Figs 12-16.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Consumption {
    /// vCPU·seconds allocated.
    pub alloc_cpu_s: f64,
    /// MB·seconds allocated.
    pub alloc_mem_mb_s: f64,
    /// vCPU·seconds actually used.
    pub used_cpu_s: f64,
    /// MB·seconds actually used.
    pub used_mem_mb_s: f64,
}

impl Consumption {
    /// Component-wise sum (fleet aggregation).
    pub fn plus(&self, o: &Consumption) -> Consumption {
        Consumption {
            alloc_cpu_s: self.alloc_cpu_s + o.alloc_cpu_s,
            alloc_mem_mb_s: self.alloc_mem_mb_s + o.alloc_mem_mb_s,
            used_cpu_s: self.used_cpu_s + o.used_cpu_s,
            used_mem_mb_s: self.used_mem_mb_s + o.used_mem_mb_s,
        }
    }

    /// Allocated GB·s of memory (the headline unit in the paper's plots).
    pub fn alloc_gb_s(&self) -> f64 {
        self.alloc_mem_mb_s / 1024.0
    }

    /// Used GB·s of memory (the paper's "actually exercised" bar).
    pub fn used_gb_s(&self) -> f64 {
        self.used_mem_mb_s / 1024.0
    }

    /// Memory utilization: used / allocated (1.0 when nothing allocated).
    pub fn mem_utilization(&self) -> f64 {
        if self.alloc_mem_mb_s <= 0.0 {
            1.0
        } else {
            (self.used_mem_mb_s / self.alloc_mem_mb_s).min(1.0)
        }
    }

    /// CPU utilization: used / allocated (1.0 when nothing allocated).
    pub fn cpu_utilization(&self) -> f64 {
        if self.alloc_cpu_s <= 0.0 {
            1.0
        } else {
            (self.used_cpu_s / self.alloc_cpu_s).min(1.0)
        }
    }
}

/// A server with explicit allocation bookkeeping.
#[derive(Debug, Clone)]
pub struct Server {
    /// Dense id (index into the cluster's server table).
    pub id: ServerId,
    /// The rack this server lives in.
    pub rack: RackId,
    /// Total resources the server offers.
    pub capacity: Resources,
    allocated: Resources,
    used: Resources,
    /// Low-priority reservation (§5.1.1): the scheduler marks a server
    /// with an application's *potential* whole-app demand. Marks do not
    /// block allocations, they only demote the server in placement
    /// decisions for other applications.
    marked: Resources,
    /// Liveness flag for churn experiments: a downed server reports
    /// zero availability (so placement never lands on it) while its
    /// allocation bookkeeping stays intact for the recovery unwind.
    up: bool,
    last_change: Millis,
    consumption: Consumption,
}

impl Server {
    /// Fresh, empty, up server with the given identity and capacity.
    pub fn new(id: ServerId, rack: RackId, capacity: Resources) -> Self {
        Self {
            id,
            rack,
            capacity,
            allocated: Resources::ZERO,
            used: Resources::ZERO,
            marked: Resources::ZERO,
            up: true,
            last_change: 0.0,
            consumption: Consumption::default(),
        }
    }

    /// Free resources (capacity - allocated). Zero while the server is
    /// down: a crashed server never attracts placement.
    pub fn available(&self) -> Resources {
        if !self.up {
            return Resources::ZERO;
        }
        self.capacity.minus(self.allocated)
    }

    /// Free resources after honoring low-priority marks from other apps.
    pub fn available_unmarked(&self) -> Resources {
        if !self.up {
            return Resources::ZERO;
        }
        self.capacity.minus(self.allocated).minus(self.marked)
    }

    /// Liveness readout for the churn path.
    pub fn is_up(&self) -> bool {
        self.up
    }

    /// Take the server down at `now` (fault injection). Integrates
    /// consumption up to the crash instant first so billing integrals
    /// stay exact; allocations are NOT force-freed — the recovery path
    /// unwinds in-flight invocations through their normal abort/crash
    /// machinery so every mark, region, and used-integral is returned
    /// through the same bookkeeping that created it.
    pub fn fail(&mut self, now: Millis) {
        self.integrate(now);
        self.up = false;
    }

    /// Bring the server back up at `now` (repair). Capacity becomes
    /// placeable again on the next index rebuild.
    pub fn repair(&mut self, now: Millis) {
        self.integrate(now);
        self.up = true;
    }

    /// Currently reserved resources.
    pub fn allocated(&self) -> Resources {
        self.allocated
    }

    /// Currently exercised share of the allocation.
    pub fn used(&self) -> Resources {
        self.used
    }

    /// Currently outstanding low-priority marks.
    pub fn marked(&self) -> Resources {
        self.marked
    }

    fn integrate(&mut self, now: Millis) {
        debug_assert!(now + 1e-9 >= self.last_change, "time went backwards");
        let dt_s = (now - self.last_change).max(0.0) / 1000.0;
        self.consumption.alloc_cpu_s += self.allocated.cpu * dt_s;
        self.consumption.alloc_mem_mb_s += self.allocated.mem_mb * dt_s;
        self.consumption.used_cpu_s += self.used.cpu * dt_s;
        self.consumption.used_mem_mb_s += self.used.mem_mb * dt_s;
        self.last_change = now;
    }

    /// Try to allocate `amount` at time `now`; false if it doesn't fit.
    pub fn try_alloc(&mut self, amount: Resources, now: Millis) -> bool {
        if !self.available().fits(amount) {
            return false;
        }
        self.integrate(now);
        self.allocated = self.allocated.plus(amount);
        true
    }

    /// Release `amount` at time `now` (saturating).
    pub fn free(&mut self, amount: Resources, now: Millis) {
        self.integrate(now);
        self.allocated = self.allocated.minus(amount);
        // Used can never exceed allocated.
        self.used = Resources {
            cpu: self.used.cpu.min(self.allocated.cpu),
            mem_mb: self.used.mem_mb.min(self.allocated.mem_mb),
        };
    }

    /// Report the actually-used share of the allocation at `now`.
    pub fn set_used(&mut self, used: Resources, now: Millis) {
        self.integrate(now);
        self.used = Resources {
            cpu: used.cpu.min(self.allocated.cpu),
            mem_mb: used.mem_mb.min(self.allocated.mem_mb),
        };
    }

    /// Adjust the used share by a delta (saturating at 0/allocated).
    pub fn add_used(&mut self, delta: Resources, now: Millis) {
        let u = self.used.plus(delta);
        self.set_used(u, now);
    }

    /// Adjust the used share downward by a delta (saturating at zero).
    pub fn sub_used(&mut self, delta: Resources, now: Millis) {
        let u = self.used.minus(delta);
        self.set_used(u, now);
    }

    /// Place a low-priority mark (future-need hint).
    pub fn mark(&mut self, amount: Resources) {
        self.marked = self.marked.plus(amount);
    }

    /// Remove a low-priority mark (saturating).
    pub fn unmark(&mut self, amount: Resources) {
        self.marked = self.marked.minus(amount);
    }

    /// Finalize integrals up to `now` and read consumption counters.
    pub fn consumption(&mut self, now: Millis) -> Consumption {
        self.integrate(now);
        self.consumption
    }

    /// Read consumption without advancing (test/diagnostic use).
    pub fn consumption_raw(&self) -> Consumption {
        self.consumption
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> Server {
        Server::new(ServerId(0), RackId(0), Resources::new(32.0, 65536.0))
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut s = server();
        assert!(s.try_alloc(Resources::new(4.0, 1024.0), 0.0));
        assert_eq!(s.available(), Resources::new(28.0, 64512.0));
        s.free(Resources::new(4.0, 1024.0), 10.0);
        assert_eq!(s.available(), s.capacity);
    }

    #[test]
    fn rejects_overcommit() {
        let mut s = server();
        assert!(s.try_alloc(Resources::new(32.0, 0.0), 0.0));
        assert!(!s.try_alloc(Resources::new(0.1, 0.0), 1.0));
        // memory axis independent
        assert!(s.try_alloc(Resources::new(0.0, 65536.0), 2.0));
        assert!(!s.try_alloc(Resources::new(0.0, 1.0), 3.0));
    }

    #[test]
    fn consumption_integrates_alloc_and_used() {
        let mut s = server();
        s.try_alloc(Resources::new(10.0, 10240.0), 0.0);
        s.set_used(Resources::new(5.0, 2048.0), 0.0);
        // 2 seconds at alloc(10 cpu, 10 GB) used(5 cpu, 2 GB)
        let c = s.consumption(2000.0);
        assert!((c.alloc_cpu_s - 20.0).abs() < 1e-9);
        assert!((c.alloc_mem_mb_s - 20480.0).abs() < 1e-9);
        assert!((c.used_cpu_s - 10.0).abs() < 1e-9);
        assert!((c.used_mem_mb_s - 4096.0).abs() < 1e-9);
        assert!((c.mem_utilization() - 0.2).abs() < 1e-9);
        assert!((c.cpu_utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn used_capped_by_allocated() {
        let mut s = server();
        s.try_alloc(Resources::new(2.0, 100.0), 0.0);
        s.set_used(Resources::new(50.0, 5000.0), 0.0);
        assert_eq!(s.used(), Resources::new(2.0, 100.0));
        s.free(Resources::new(1.0, 50.0), 1.0);
        assert_eq!(s.used(), Resources::new(1.0, 50.0));
    }

    #[test]
    fn downed_server_reports_zero_availability_and_keeps_integrals() {
        let mut s = server();
        assert!(s.try_alloc(Resources::new(8.0, 8192.0), 0.0));
        s.fail(1000.0);
        assert!(!s.is_up());
        assert_eq!(s.available(), Resources::ZERO);
        assert_eq!(s.available_unmarked(), Resources::ZERO);
        assert!(!s.try_alloc(Resources::new(1.0, 1.0), 1500.0));
        // the allocation survives the crash until the recovery unwind
        assert_eq!(s.allocated(), Resources::new(8.0, 8192.0));
        s.free(Resources::new(8.0, 8192.0), 2000.0);
        s.repair(3000.0);
        assert!(s.is_up());
        assert_eq!(s.available(), s.capacity);
        // integrals cover the downtime: 2 s at 8 cpu / 8 GB allocated
        let c = s.consumption(3000.0);
        assert!((c.alloc_cpu_s - 16.0).abs() < 1e-9);
        assert!((c.alloc_mem_mb_s - 16384.0).abs() < 1e-9);
    }

    #[test]
    fn marks_do_not_block_allocation() {
        let mut s = server();
        s.mark(Resources::new(30.0, 60000.0));
        assert!(s.available_unmarked().cpu < 3.0);
        // but a real allocation still succeeds
        assert!(s.try_alloc(Resources::new(30.0, 60000.0), 0.0));
        s.unmark(Resources::new(30.0, 60000.0));
        assert_eq!(s.marked(), Resources::ZERO);
    }
}
