//! Virtual clock + discrete-event queue.
//!
//! All platform latencies (startup, network, compute durations) advance
//! this clock rather than wall time, so an 8-server, multi-minute paper
//! experiment replays in microseconds and the benches can sweep hundreds
//! of configurations.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Milliseconds of simulated time.
pub type Millis = f64;

/// A discrete-event queue over an opaque event payload.
///
/// Events fire in (time, insertion-order) order, so simultaneous events
/// are FIFO — deterministic replays for tests and benches.
#[derive(Debug)]
pub struct Clock<E> {
    now: Millis,
    seq: u64,
    heap: BinaryHeap<Entry<E>>,
}

#[derive(Debug)]
struct Entry<E> {
    at: Millis,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E> Default for Clock<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Clock<E> {
    /// Empty queue at simulated time 0.
    pub fn new() -> Self {
        Self { now: 0.0, seq: 0, heap: BinaryHeap::new() }
    }

    /// Current simulated time (ms).
    pub fn now(&self) -> Millis {
        self.now
    }

    /// Schedule `event` to fire `delay` ms from now (clamped to >= 0).
    pub fn schedule(&mut self, delay: Millis, event: E) {
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Schedule `event` at absolute time `at` (clamped to >= now).
    pub fn schedule_at(&mut self, at: Millis, event: E) {
        let at = at.max(self.now);
        self.heap.push(Entry { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock to its fire time.
    pub fn next(&mut self) -> Option<(Millis, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now, "time went backwards");
        self.now = e.at;
        Some((e.at, e.event))
    }

    /// Peek at the next fire time without advancing.
    pub fn peek_time(&self) -> Option<Millis> {
        self.heap.peek().map(|e| e.at)
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut c = Clock::new();
        c.schedule(30.0, "c");
        c.schedule(10.0, "a");
        c.schedule(20.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| c.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(c.now(), 30.0);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut c = Clock::new();
        for i in 0..10 {
            c.schedule(5.0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| c.next()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn negative_delay_clamps_to_now() {
        let mut c = Clock::new();
        c.schedule(10.0, "x");
        c.next();
        c.schedule(-5.0, "y");
        let (t, _) = c.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = Clock::new();
        c.schedule(10.0, 1);
        c.schedule(5.0, 0);
        let (t0, _) = c.next().unwrap();
        c.schedule(1.0, 2); // scheduled at 6.0, before pending 10.0
        let (t1, _) = c.next().unwrap();
        let (t2, _) = c.next().unwrap();
        assert!(t0 <= t1 && t1 <= t2);
        assert_eq!((t0, t1, t2), (5.0, 6.0, 10.0));
    }
}
