//! Per-rack bounded snapshot cache.
//!
//! Production serverless stacks keep checkpoint/restore images of hot
//! applications near the compute so a start can skip the container boot
//! path (the reuse survey in PAPERS.md identifies snapshot restore as
//! the dominant cold-start mitigation after environment reuse). This
//! module models that layer: a byte-budgeted LRU cache of per-app
//! snapshot images, one per rack, whose resident bytes are charged
//! against rack memory by the coordinator so cached images *compete
//! with invocations for capacity*.
//!
//! Determinism contract: the cache is a `Vec` slot arena threaded by
//! intrusive doubly-linked lists (recency chain + free list) — no hash
//! maps anywhere, so lookup, hit/miss accounting and eviction order are
//! pure functions of the operation sequence (D1-clean). Slots are
//! recycled through the free list, so steady-state operation allocates
//! nothing after the first few insertions.

use super::server::ServerId;

/// Sentinel for "no slot" in the intrusive lists.
const NIL: usize = usize::MAX;

/// Hit/miss/eviction telemetry for one cache (merged fleet-wide by the
/// driver; digest-excluded — counters never feed the replay digest).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SnapshotStats {
    /// Lookups that found the app's image resident.
    pub hits: u64,
    /// Lookups that missed (the start pays the cold path).
    pub misses: u64,
    /// Images evicted to make room (capacity pressure or server loss).
    pub evictions: u64,
    /// Images installed by the predictive pre-warm pass (vs on demand).
    pub prewarms: u64,
    /// High-water mark of resident bytes.
    pub bytes_hwm: u64,
}

impl SnapshotStats {
    /// Fold `other` into `self`: counters sum, the high-water mark is
    /// the per-cache maximum (each cache has its own budget).
    pub fn absorb(&mut self, other: &SnapshotStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.prewarms += other.prewarms;
        self.bytes_hwm = self.bytes_hwm.max(other.bytes_hwm);
    }
}

/// One resident image: interned app name, image size, and the server
/// whose memory the image is charged against.
#[derive(Debug, Clone, Copy)]
struct Slot {
    app: &'static str,
    bytes: u64,
    home: ServerId,
    /// Toward the MRU end (NIL at the head).
    prev: usize,
    /// Toward the LRU end (NIL at the tail); doubles as the free-list
    /// link while the slot is unused.
    next: usize,
}

/// Byte-budgeted LRU cache of per-app snapshot images for one rack.
///
/// The cache itself never talks to the cluster: the coordinator charges
/// and releases the backing memory through the [`Cluster`] hooks and
/// records the charged server as the image's `home` so a server crash
/// can wipe exactly the images it held.
///
/// [`Cluster`]: super::topology::Cluster
#[derive(Debug)]
pub struct SnapshotCache {
    budget: u64,
    bytes: u64,
    slots: Vec<Slot>,
    /// Most-recently-used end of the recency chain.
    head: usize,
    /// Least-recently-used end of the recency chain (eviction victim).
    tail: usize,
    free_head: usize,
    len: usize,
    /// Telemetry for this cache (public so the coordinator can count
    /// pre-warm installs at the install site).
    pub stats: SnapshotStats,
}

impl SnapshotCache {
    /// Empty cache holding at most `budget_bytes` of images.
    pub fn new(budget_bytes: u64) -> Self {
        Self {
            budget: budget_bytes,
            bytes: 0,
            slots: Vec::new(),
            head: NIL,
            tail: NIL,
            free_head: NIL,
            len: 0,
            stats: SnapshotStats::default(),
        }
    }

    /// The byte budget this cache was built with.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Bytes currently resident.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Number of resident images.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no image is resident.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Peak slot count ever live — the arena never shrinks, so this is
    /// also its length. The allocation-free harness asserts it stays
    /// bounded while images churn (slots recycle through the free
    /// list).
    pub fn slot_high_water(&self) -> usize {
        self.slots.len()
    }

    /// True when an image of `bytes` would fit in the remaining budget.
    pub fn fits(&self, bytes: u64) -> bool {
        bytes <= self.budget.saturating_sub(self.bytes)
    }

    /// Whether `app`'s image is resident. No recency or telemetry
    /// effect (the pre-warm pass probes with this).
    pub fn contains(&self, app: &'static str) -> bool {
        self.find(app) != NIL
    }

    /// Start-path lookup: on a hit the image moves to the MRU position
    /// and `hits` increments; on a miss `misses` increments.
    pub fn touch(&mut self, app: &'static str) -> bool {
        let i = self.find(app);
        if i == NIL {
            self.stats.misses += 1;
            return false;
        }
        self.stats.hits += 1;
        if self.head != i {
            self.detach(i);
            self.push_front(i);
        }
        true
    }

    /// Install `app`'s image (charged against `home`'s memory by the
    /// caller) at the MRU position. Returns false — and installs
    /// nothing — if the image is already resident or does not fit the
    /// remaining budget; the caller decides whether to evict first.
    pub fn insert(&mut self, app: &'static str, bytes: u64, home: ServerId) -> bool {
        if !self.fits(bytes) || self.contains(app) {
            return false;
        }
        let i = self.alloc_slot(app, bytes, home);
        self.push_front(i);
        self.bytes += bytes;
        self.len += 1;
        self.stats.bytes_hwm = self.stats.bytes_hwm.max(self.bytes);
        true
    }

    /// Evict the least-recently-used image, returning it so the caller
    /// can release the backing memory. Counts toward `evictions`.
    pub fn evict_lru(&mut self) -> Option<(&'static str, u64, ServerId)> {
        if self.tail == NIL {
            return None;
        }
        let i = self.tail;
        self.stats.evictions += 1;
        Some(self.remove_slot(i))
    }

    /// Wipe every image homed on `server` (the server crashed and its
    /// memory — snapshot images included — is gone), handing each
    /// `(app, bytes)` to `f` so the caller can release the charge.
    /// Counts toward `evictions`. Walks MRU→LRU, so the wipe order is a
    /// pure function of the recency state.
    pub fn evict_homed_on(&mut self, server: ServerId, mut f: impl FnMut(&'static str, u64)) {
        let mut i = self.head;
        while i != NIL {
            let next = self.slots[i].next;
            if self.slots[i].home == server {
                let (app, bytes, _) = self.remove_slot(i);
                self.stats.evictions += 1;
                f(app, bytes);
            }
            i = next;
        }
    }

    /// Tear the cache down at end of run, handing each resident
    /// `(app, bytes, home)` to `f` so the caller can release the
    /// charges. Not counted as evictions (no capacity pressure).
    pub fn drain(&mut self, mut f: impl FnMut(&'static str, u64, ServerId)) {
        while self.head != NIL {
            let (app, bytes, home) = self.remove_slot(self.head);
            f(app, bytes, home);
        }
    }

    // ---- intrusive-list plumbing --------------------------------------

    /// Linear scan of the recency chain (racks cache a handful of
    /// images; a map would buy nothing and cost determinism review).
    fn find(&self, app: &'static str) -> usize {
        let mut i = self.head;
        while i != NIL {
            if self.slots[i].app == app {
                return i;
            }
            i = self.slots[i].next;
        }
        NIL
    }

    fn alloc_slot(&mut self, app: &'static str, bytes: u64, home: ServerId) -> usize {
        let slot = Slot { app, bytes, home, prev: NIL, next: NIL };
        if self.free_head != NIL {
            let i = self.free_head;
            self.free_head = self.slots[i].next;
            self.slots[i] = slot;
            i
        } else {
            self.slots.push(slot);
            self.slots.len() - 1
        }
    }

    fn remove_slot(&mut self, i: usize) -> (&'static str, u64, ServerId) {
        let Slot { app, bytes, home, .. } = self.slots[i];
        self.detach(i);
        self.slots[i].next = self.free_head;
        self.free_head = i;
        self.bytes -= bytes;
        self.len -= 1;
        (app, bytes, home)
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 1024 * 1024;

    fn sid(i: usize) -> ServerId {
        ServerId(i)
    }

    #[test]
    fn lru_eviction_order_is_recency_order() {
        let mut c = SnapshotCache::new(10 * MIB);
        assert!(c.insert("a", 3 * MIB, sid(0)));
        assert!(c.insert("b", 3 * MIB, sid(0)));
        assert!(c.insert("c", 3 * MIB, sid(1)));
        // touch "a" so "b" becomes the LRU victim
        assert!(c.touch("a"));
        assert_eq!(c.evict_lru().map(|(app, ..)| app), Some("b"));
        assert_eq!(c.evict_lru().map(|(app, ..)| app), Some("c"));
        assert_eq!(c.evict_lru().map(|(app, ..)| app), Some("a"));
        assert_eq!(c.evict_lru(), None);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.evictions, 3);
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn budget_is_enforced_at_insert() {
        let mut c = SnapshotCache::new(5 * MIB);
        assert!(c.insert("a", 4 * MIB, sid(0)));
        assert!(!c.insert("b", 2 * MIB, sid(0)), "over budget must refuse");
        assert!(c.fits(MIB));
        assert!(!c.fits(2 * MIB));
        assert!(!c.insert("a", MIB, sid(0)), "duplicate insert refused");
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 4 * MIB);
        assert_eq!(c.stats.bytes_hwm, 4 * MIB);
    }

    #[test]
    fn touch_counts_hits_and_misses() {
        let mut c = SnapshotCache::new(4 * MIB);
        assert!(!c.touch("a"));
        assert!(c.insert("a", MIB, sid(0)));
        assert!(c.touch("a"));
        assert!(!c.touch("b"));
        assert_eq!((c.stats.hits, c.stats.misses), (1, 2));
    }

    #[test]
    fn server_crash_wipes_exactly_its_images() {
        let mut c = SnapshotCache::new(100 * MIB);
        assert!(c.insert("a", MIB, sid(0)));
        assert!(c.insert("b", MIB, sid(1)));
        assert!(c.insert("c", MIB, sid(0)));
        let mut wiped = Vec::new();
        c.evict_homed_on(sid(0), |app, _| wiped.push(app));
        // MRU→LRU walk: "c" (most recent) before "a"
        assert_eq!(wiped, vec!["c", "a"]);
        assert!(c.contains("b"));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats.evictions, 2);
    }

    #[test]
    fn slots_recycle_through_the_free_list() {
        let mut c = SnapshotCache::new(2 * MIB);
        for round in 0..100 {
            let name: &'static str = if round % 2 == 0 { "even" } else { "odd" };
            while !c.fits(2 * MIB) {
                assert!(c.evict_lru().is_some());
            }
            assert!(c.insert(name, 2 * MIB, sid(round % 3)));
        }
        assert!(
            c.slot_high_water() <= 2,
            "churn must recycle slots, not grow the arena (hwm {})",
            c.slot_high_water()
        );
    }

    #[test]
    fn drain_releases_everything_without_counting_evictions() {
        let mut c = SnapshotCache::new(10 * MIB);
        assert!(c.insert("a", 2 * MIB, sid(0)));
        assert!(c.insert("b", 3 * MIB, sid(1)));
        let mut freed = 0;
        c.drain(|_, bytes, _| freed += bytes);
        assert_eq!(freed, 5 * MIB);
        assert!(c.is_empty());
        assert_eq!(c.stats.evictions, 0);
    }

    #[test]
    fn stats_absorb_sums_counters_and_maxes_hwm() {
        let mut a = SnapshotStats { hits: 1, misses: 2, evictions: 3, prewarms: 4, bytes_hwm: 10 };
        let b = SnapshotStats { hits: 10, misses: 20, evictions: 30, prewarms: 40, bytes_hwm: 7 };
        a.absorb(&b);
        assert_eq!(a, SnapshotStats { hits: 11, misses: 22, evictions: 33, prewarms: 44, bytes_hwm: 10 });
    }
}
