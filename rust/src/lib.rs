//! # Zenix — resource-centric serverless for bulky applications
//!
//! Reproduction of the paper's platform (see the top-level `README.md`
//! and `docs/ARCHITECTURE.md` for the layer map, determinism contract
//! and offline-toolchain story). The crate is organised in the layers
//! the paper describes:
//!
//! - [`cluster`] — the cluster substrate: servers, racks, containers, a
//!   discrete-event virtual clock and resource accounting.
//! - [`net`] — network cost models: TCP vs RDMA data paths and the
//!   control-path variants of §5.2.2 / §9.4 (overlay, network
//!   virtualization, scheduler-assisted async exchange).
//! - [`memory`] — the memory controller: data components, local mmap vs
//!   remote regions, growth, and the user-space NRU swap of §9.2.
//! - [`apps`] — annotated-program model (`@compute` / `@data` /
//!   `@app_limit`) and the paper's workloads (TPC-DS Q1/16/95, the
//!   ExCamera video pipeline, Cirrus LR, SeBS small functions).
//! - [`coordinator`] — the paper's contribution: resource-graph IR,
//!   two-level scheduler, locality placement, adaptive materialization,
//!   autoscaling, history-based sizing, proactive startup, failure
//!   recovery, multi-tenant driving and admission control.
//! - [`baselines`] — every system the paper compares against.
//! - [`runtime`] — PJRT execution of the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text; python never on request path).
//! - [`metrics`] — GB·s / vCPU·s accounting and figure-row printers.
//! - [`trace`] — Azure-archetype invocation/usage trace generators.
//! - [`analysis`] — `zenix_lint`, the dependency-free static
//!   determinism & accounting pass gating CI (see `docs/ANALYSIS.md`).
//!
//! Public items in the documented core modules must carry rustdoc
//! (`missing_docs` warns at the crate level and `scripts/ci.sh` denies
//! rustdoc warnings); modules still awaiting their sweep carry a local
//! `#[allow(missing_docs)]` at their declaration.
#![warn(missing_docs)]

pub mod analysis;
pub mod apps;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
#[allow(missing_docs)]
pub mod figures;
#[allow(missing_docs)]
pub mod memory;
pub mod metrics;
pub mod net;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod trace;
pub mod util;

/// Convenient result alias used across the crate.
pub type Result<T> = anyhow::Result<T>;
