//! Invocation/usage trace generators.
//!
//! The paper's per-input sizing experiments (Fig 22, Fig 26/29) replay
//! real Azure serverless memory-usage distributions. Those traces are
//! not redistributable; `azure` generates synthetic traces matching the
//! archetypes the paper characterizes (DESIGN.md §1 substitution table).

pub mod azure;

pub use azure::{Archetype, UsageTrace};
