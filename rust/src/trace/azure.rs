//! Synthetic Azure-style memory-usage traces (Fig 22 / 26 / 29).
//!
//! The paper picks four application archetypes out of the Azure dataset
//! [64]:
//!
//! - **Small**   — most invocations use little memory (well under the
//!   256 MB default initial allocation);
//! - **Large**   — most invocations use a lot of memory;
//! - **Varying** — usage differs wildly across invocations;
//! - **Stable**  — near-identical usage on every invocation;
//!
//! plus the dataset-wide **Average** mixture (heavy-tailed lognormal,
//! per the published characterization). Each generator returns per-
//! invocation peak memory (MB) and execution time (ms).

use crate::util::rng::Rng;

/// Application archetype from the paper's Fig 26.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Archetype {
    Small,
    Large,
    Varying,
    Stable,
    /// Dataset-wide mixture (heavy-tailed).
    Average,
}

impl Archetype {
    pub const ALL: [Archetype; 5] = [
        Archetype::Small,
        Archetype::Large,
        Archetype::Varying,
        Archetype::Stable,
        Archetype::Average,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Archetype::Small => "small",
            Archetype::Large => "large",
            Archetype::Varying => "varying",
            Archetype::Stable => "stable",
            Archetype::Average => "average",
        }
    }
}

/// One invocation's observed usage.
#[derive(Debug, Clone, Copy)]
pub struct Usage {
    pub peak_mem_mb: f64,
    pub exec_ms: f64,
}

/// A sequence of invocations of one application.
#[derive(Debug, Clone)]
pub struct UsageTrace {
    pub archetype: Archetype,
    pub invocations: Vec<Usage>,
}

impl UsageTrace {
    /// Generate `n` invocations of `archetype` with a deterministic seed.
    pub fn generate(archetype: Archetype, n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xA2_0E);
        let invocations = (0..n)
            .map(|_| {
                let peak_mem_mb = match archetype {
                    // mostly < 128 MB, occasionally a bit more
                    Archetype::Small => rng.lognormal(3.8, 0.5).clamp(8.0, 512.0),
                    // mostly 1.5-6 GB
                    Archetype::Large => rng.lognormal(7.9, 0.35).clamp(512.0, 16384.0),
                    // anywhere from tens of MB to GBs
                    Archetype::Varying => rng.lognormal(5.8, 1.4).clamp(16.0, 16384.0),
                    // tight around 400 MB
                    Archetype::Stable => rng.normal_with(400.0, 12.0).clamp(300.0, 500.0),
                    // Azure-wide: heavy-tailed, median ~170 MB
                    Archetype::Average => rng.lognormal(5.1, 1.1).clamp(8.0, 32768.0),
                };
                // Duration loosely correlates with memory (bulkier work
                // runs longer), plus noise — consistent with [64].
                let exec_ms = (peak_mem_mb.powf(0.6) * 40.0
                    * rng.lognormal(0.0, 0.4))
                .clamp(50.0, 600_000.0);
                Usage { peak_mem_mb, exec_ms }
            })
            .collect();
        Self { archetype, invocations }
    }

    pub fn peaks(&self) -> Vec<f64> {
        self.invocations.iter().map(|u| u.peak_mem_mb).collect()
    }

    pub fn mean_peak(&self) -> f64 {
        crate::util::stats::mean(&self.peaks())
    }

    pub fn max_peak(&self) -> f64 {
        self.peaks().iter().cloned().fold(0.0, f64::max)
    }

    /// Coefficient of variation of peaks (Varying ≫ Stable).
    pub fn peak_cv(&self) -> f64 {
        let peaks = self.peaks();
        let m = crate::util::stats::mean(&peaks);
        if m <= 0.0 {
            0.0
        } else {
            crate::util::stats::stddev(&peaks) / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(a: Archetype) -> UsageTrace {
        UsageTrace::generate(a, 2000, 42)
    }

    #[test]
    fn archetype_means_ordered() {
        assert!(trace(Archetype::Small).mean_peak() < 200.0);
        assert!(trace(Archetype::Large).mean_peak() > 1500.0);
        assert!(trace(Archetype::Small).mean_peak() < trace(Archetype::Average).mean_peak());
        assert!(trace(Archetype::Average).mean_peak() < trace(Archetype::Large).mean_peak());
    }

    #[test]
    fn varying_has_high_cv_stable_low() {
        assert!(trace(Archetype::Varying).peak_cv() > 1.0);
        assert!(trace(Archetype::Stable).peak_cv() < 0.1);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = UsageTrace::generate(Archetype::Average, 100, 9);
        let b = UsageTrace::generate(Archetype::Average, 100, 9);
        assert_eq!(a.peaks(), b.peaks());
        let c = UsageTrace::generate(Archetype::Average, 100, 10);
        assert_ne!(a.peaks(), c.peaks());
    }

    #[test]
    fn durations_positive_and_bounded() {
        for u in &trace(Archetype::Average).invocations {
            assert!(u.exec_ms >= 50.0 && u.exec_ms <= 600_000.0);
            assert!(u.peak_mem_mb > 0.0);
        }
    }

    #[test]
    fn average_is_heavy_tailed() {
        let t = trace(Archetype::Average);
        let peaks = t.peaks();
        let mean = crate::util::stats::mean(&peaks);
        let p50 = crate::util::stats::percentile(&peaks, 50.0);
        assert!(mean > 1.3 * p50, "mean {mean} vs median {p50}");
    }
}
