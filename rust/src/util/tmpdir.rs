//! Scoped temp directories for tests (std-only `tempfile` replacement).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp root, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh uniquely-named directory under the system temp
    /// root (prefix + pid + counter + timestamp).
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let id = COUNTER.fetch_add(1, Ordering::Relaxed);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .as_nanos();
        let path = std::env::temp_dir().join(format!(
            "zenix-{prefix}-{}-{id}-{nanos}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory's path (valid until drop).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept;
        {
            let t = TempDir::new("x").unwrap();
            kept = t.path().to_path_buf();
            std::fs::write(t.path().join("f"), "hi").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
