//! Checked integer-narrowing helpers for the hot path.
//!
//! The C1 lint rule (`zenix_lint`, see `docs/ANALYSIS.md`) bans bare
//! narrowing `as` casts in `coordinator/` and `metrics/`: a silently
//! wrapping cast is an accounting bug waiting for a bigger workload.
//! These helpers make the intended conversion explicit and
//! `debug_assert` that no value is ever truncated — zero release-mode
//! cost on the allocation-free loop, loud failure under `cargo test`.
//!
//! This module is the one place allowed to perform the raw casts
//! (`util/` is outside the C1 scope by construction).

/// Widen a `usize` count to the `u64` accounting domain (digest folds,
/// counters). Lossless on every supported target.
#[inline]
pub fn u64_of(v: usize) -> u64 {
    v as u64
}

/// Narrow a `u64` counter back to a `usize` index/count.
#[inline]
pub fn usize_of(v: u64) -> usize {
    debug_assert!(
        v <= usize::MAX as u64,
        "usize_of: {v} exceeds the platform usize range"
    );
    v as usize
}

/// Narrow a `usize` count to `u32` (compact per-wave counters).
#[inline]
pub fn u32_of(v: usize) -> u32 {
    debug_assert!(v <= u32::MAX as usize, "u32_of: {v} exceeds u32::MAX");
    v as u32
}

/// Narrow a `u64` sequence distance to `i32` (decay exponents).
#[inline]
pub fn i32_of(v: u64) -> i32 {
    debug_assert!(v <= i32::MAX as u64, "i32_of: {v} exceeds i32::MAX");
    v as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_preserve_values() {
        assert_eq!(u64_of(7usize), 7u64);
        assert_eq!(usize_of(7u64), 7usize);
        assert_eq!(u32_of(40_000usize), 40_000u32);
        assert_eq!(i32_of(12u64), 12i32);
        assert_eq!(usize_of(u64_of(usize::MAX)), usize::MAX);
    }

    #[test]
    #[should_panic(expected = "u32_of")]
    #[cfg(debug_assertions)]
    fn truncation_panics_in_debug() {
        let _ = u32_of(usize::MAX);
    }
}
