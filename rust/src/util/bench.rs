//! Minimal measurement harness for the `harness = false` benches
//! (std-only `criterion` replacement).
//!
//! Auto-tunes iteration counts to a target measurement time, reports
//! mean / p50 / p95 / throughput, and supports `--filter <substr>`,
//! `--quick` and `--json <path>` CLI args (as passed by
//! `cargo bench -- <args>`).
//!
//! Machine-readable output: `--json <path>` (or the `ZENIX_BENCH_JSON`
//! env var naming a directory) makes [`Bencher::write_json`] emit a
//! `{"bench": ..., "reports": [{name, mean_ns, p50_ns, p95_ns, iters,
//! throughput}]}` document — the perf-trajectory record checked in as
//! `BENCH_<bench>.json`.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::stats;

/// One benchmark's report.
#[derive(Debug, Clone)]
pub struct Report {
    /// Benchmark name as passed to [`Bencher::bench`].
    pub name: String,
    /// Total iterations measured (samples × per-sample batch).
    pub iters: u64,
    /// Mean wall time per iteration (ns).
    pub mean_ns: f64,
    /// Median wall time per iteration (ns).
    pub p50_ns: f64,
    /// 95th-percentile wall time per iteration (ns).
    pub p95_ns: f64,
}

impl Report {
    /// Mean time per iteration as a [`Duration`].
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// items/second given `items` work items per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns / 1e9)
    }
}

/// Bench runner with criterion-like ergonomics.
pub struct Bencher {
    filter: Option<String>,
    target: Duration,
    /// `--quick` was passed (shrinks both the micro-bench target time
    /// and [`Self::bench_macro`]'s sample count).
    quick: bool,
    /// Explicit `--json <path>` destination (wins over the env var).
    json_path: Option<PathBuf>,
    /// Every report collected so far, in run order (the rows
    /// [`Self::write_json`] emits).
    pub reports: Vec<Report>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::from_args(std::env::args().skip(1))
    }
}

impl Bencher {
    /// Parse `--filter <substr>` / `--quick` / `--json <path>` args.
    pub fn from_args(args: impl Iterator<Item = String>) -> Self {
        let mut filter = None;
        let mut target = Duration::from_millis(800);
        let mut quick = false;
        let mut json_path = None;
        let mut args = args.peekable();
        while let Some(a) = args.next() {
            match a.as_str() {
                "--filter" => filter = args.next(),
                "--quick" => {
                    quick = true;
                    target = Duration::from_millis(100);
                }
                "--json" => json_path = args.next().map(PathBuf::from),
                "--bench" => {} // cargo bench passes this through
                other if !other.starts_with('-') && filter.is_none() => {
                    filter = Some(other.to_string());
                }
                _ => {}
            }
        }
        Self { filter, target, quick, json_path, reports: Vec::new() }
    }

    fn selected(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }

    /// Measure `f`, auto-scaling iterations to the target time.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Option<Report> {
        if !self.selected(name) {
            return None;
        }
        // Warm-up + calibration: time one call, derive batch size.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = (self.target.as_nanos() / 20 / once.as_nanos()).max(1) as u64;
        let samples = 20;
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                f();
            }
            times.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
        }
        let report = Report {
            name: name.to_string(),
            iters: per_sample * samples as u64,
            mean_ns: stats::mean(&times),
            p50_ns: stats::percentile(&times, 50.0),
            p95_ns: stats::percentile(&times, 95.0),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12}  ({} iters)",
            report.name,
            fmt_ns(report.mean_ns),
            fmt_ns(report.p50_ns),
            fmt_ns(report.p95_ns),
            report.iters,
        );
        self.reports.push(report.clone());
        Some(report)
    }

    /// Measure a *macro*-benchmark: `f` is seconds-scale, so the
    /// auto-calibrating [`Self::bench`] (20 samples × tuned batches)
    /// would blow the wall-clock budget. Runs exactly `samples`
    /// single-iteration samples and reports the same statistics/JSON
    /// row. `--quick` halves the sample count (min 2).
    pub fn bench_macro(&mut self, name: &str, samples: usize, mut f: impl FnMut()) -> Option<Report> {
        if !self.selected(name) {
            return None;
        }
        let samples = if self.quick { (samples / 2).max(2) } else { samples.max(2) };
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_nanos() as f64);
        }
        let report = Report {
            name: name.to_string(),
            iters: samples as u64,
            mean_ns: stats::mean(&times),
            p50_ns: stats::percentile(&times, 50.0),
            p95_ns: stats::percentile(&times, 95.0),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12}  ({} iters)",
            report.name,
            fmt_ns(report.mean_ns),
            fmt_ns(report.p50_ns),
            fmt_ns(report.p95_ns),
            report.iters,
        );
        self.reports.push(report.clone());
        Some(report)
    }

    /// Print the column header once before a group of benches.
    pub fn header(&self, group: &str) {
        if self.filter.as_deref().map_or(true, |f| group.contains(f)) || true {
            println!("\n== {group}");
            println!("{:<48} {:>12} {:>12} {:>12}", "benchmark", "mean", "p50", "p95");
        }
    }

    /// Destination for machine-readable output, if any: `--json <path>`
    /// wins, else `$ZENIX_BENCH_JSON` is a directory to hold
    /// `default_name`.
    fn json_destination(&self, default_name: &str) -> Option<PathBuf> {
        if let Some(p) = &self.json_path {
            return Some(p.clone());
        }
        std::env::var_os("ZENIX_BENCH_JSON")
            .map(|dir| PathBuf::from(dir).join(default_name))
    }

    /// Write all collected reports as JSON (name, mean_ns, p50_ns,
    /// p95_ns, iters, throughput in items/s at 1 item/iteration) when a
    /// destination is configured; silently a no-op otherwise. Errors are
    /// reported to stderr but never fail the bench run.
    pub fn write_json(&self, default_name: &str) {
        let path = match self.json_destination(default_name) {
            Some(p) => p,
            None => return,
        };
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": {:?},\n", default_name));
        out.push_str("  \"reports\": [\n");
        for (i, r) in self.reports.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": {:?}, \"mean_ns\": {:.3}, \"p50_ns\": {:.3}, \
                 \"p95_ns\": {:.3}, \"iters\": {}, \"throughput\": {:.3}}}{}\n",
                r.name,
                r.mean_ns,
                r.p50_ns,
                r.p95_ns,
                r.iters,
                r.throughput(1.0),
                if i + 1 == self.reports.len() { "" } else { "," },
            ));
        }
        out.push_str("  ]\n}\n");
        match std::fs::write(&path, out) {
            Ok(()) => println!("(bench json written to {})", path.display()),
            Err(e) => eprintln!("(bench json write to {} failed: {e})", path.display()),
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_selects() {
        let b = Bencher::from_args(["--filter".to_string(), "foo".to_string()].into_iter());
        assert!(b.selected("foo_bar"));
        assert!(!b.selected("baz"));
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::from_args(["--quick".to_string()].into_iter());
        let r = b.bench("spin", || { std::hint::black_box(1 + 1); }).unwrap();
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert_eq!(b.reports.len(), 1);
    }

    #[test]
    fn bench_macro_runs_fixed_single_iteration_samples() {
        let mut b = Bencher::from_args(std::iter::empty());
        let r = b
            .bench_macro("macro_spin", 3, || {
                std::hint::black_box(1 + 1);
            })
            .unwrap();
        assert_eq!(r.iters, 3);
        assert!(r.mean_ns > 0.0);
        // --quick halves the sample count (min 2)
        let mut bq = Bencher::from_args(["--quick".to_string()].into_iter());
        let rq = bq.bench_macro("macro_spin_q", 3, || {}).unwrap();
        assert_eq!(rq.iters, 2);
    }

    #[test]
    fn positional_arg_is_filter() {
        let b = Bencher::from_args(["fig08".to_string()].into_iter());
        assert!(b.selected("fig08_tpcds_memory"));
        assert!(!b.selected("fig09_tpcds_time"));
    }

    #[test]
    fn json_mode_writes_parseable_reports() {
        use crate::util::tmpdir::TempDir;
        let tmp = TempDir::new("benchjson").unwrap();
        let path = tmp.path().join("BENCH_test.json");
        let mut b = Bencher::from_args(
            [
                "--quick".to_string(),
                "--json".to_string(),
                path.display().to_string(),
            ]
            .into_iter(),
        );
        b.bench("spin_a", || {
            std::hint::black_box(1 + 1);
        });
        b.bench("spin_b", || {
            std::hint::black_box(2 + 2);
        });
        b.write_json("BENCH_test.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        let reports = v.get("reports").unwrap().as_array().unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].get("name").unwrap().as_str().unwrap(), "spin_a");
        assert!(reports[0].get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(reports[1].get("throughput").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn no_json_destination_is_a_noop() {
        let b = Bencher::from_args(["--quick".to_string()].into_iter());
        // must not panic or create files
        b.write_json("BENCH_never.json");
        assert!(!std::path::Path::new("BENCH_never.json").exists());
    }
}
