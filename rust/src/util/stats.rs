//! Summary-statistics helpers shared by metrics, traces and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by nearest-rank on a copy (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive lower edge of the binned range.
    pub lo: f64,
    /// Exclusive upper edge of the binned range.
    pub hi: f64,
    /// Per-bin counts, lowest bin first.
    pub bins: Vec<u64>,
}

impl Histogram {
    /// `nbins` equal-width bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins] }
    }

    /// Count one observation (out-of-range values clamp to edge bins).
    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = (t.max(0.0) as usize).min(n - 1);
        self.bins[idx] += 1;
    }

    /// Total observations counted.
    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Fraction of mass at or below bin containing `x`.
    pub fn cdf_at(&self, x: f64) -> f64 {
        let n = self.bins.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = (t.max(0.0) as usize).min(n - 1);
        let below: u64 = self.bins[..=idx].iter().sum();
        below as f64 / self.total().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert!((stddev(&xs) - 1.4142).abs() < 1e-3);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn empty_inputs_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.bins.iter().all(|&b| b == 1));
        h.add(-5.0); // clamps low
        h.add(50.0); // clamps high
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 12);
        assert!((h.cdf_at(9.5) - 1.0).abs() < 1e-9);
    }
}
