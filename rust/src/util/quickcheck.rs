//! Randomized property-test driver (std-only `proptest` replacement).
//!
//! `forall(n, gen, prop)` runs `prop` over `n` inputs drawn by `gen` from
//! deterministic per-case seeds. On failure it panics with the case seed,
//! so a failing case reproduces with `forall_seeded(seed, gen, prop)`.

use super::rng::Rng;

/// Base seed; per-case seeds derive from it so runs are reproducible.
pub const BASE_SEED: u64 = 0x5EED_2E17;

/// Run `prop` over `cases` generated inputs; panic with the seed on the
/// first failure (either a `false` return or a propagated panic).
pub fn forall<T: std::fmt::Debug>(
    cases: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = BASE_SEED ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed on case {case} (seed {seed:#x}):\n  input = {input:#?}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn forall_seeded<T: std::fmt::Debug>(
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    assert!(prop(&input), "property failed (seed {seed:#x}): {input:#?}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(50, |r| r.range(0, 100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_seed_in_message() {
        forall(50, |r| r.range(0, 100), |&x| x < 5);
    }

    #[test]
    fn deterministic_inputs_per_case() {
        let mut first = Vec::new();
        forall(10, |r| r.next_u64(), |&x| {
            first.push(x);
            true
        });
        let mut second = Vec::new();
        forall(10, |r| r.next_u64(), |&x| {
            second.push(x);
            true
        });
        assert_eq!(first, second);
    }
}
