//! Deterministic PRNG + distributions (std-only `rand` replacement).
//!
//! xoshiro256++ seeded via splitmix64. Deterministic seeding keeps the
//! simulator runs and randomized property tests reproducible — every
//! failure report prints the seed that triggered it.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction (deterministic).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Seed from the wall clock (for exploratory runs only; tests pass
    /// explicit seeds).
    pub fn from_time() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .subsec_nanos() as u64;
        Self::new(nanos ^ 0xD1B54A32D192ED03)
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/stddev.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)). Heavy-tailed sizes/durations — the
    /// shape Azure's serverless characterization reports.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Pareto (heavy tail): xm * U^(-1/alpha).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm * self.f64().max(1e-300).powf(-1.0 / alpha)
    }

    /// Pick one element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u64> = (0..4).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..4).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let c: Vec<u64> = (0..4).map({ let mut r = Rng::new(8); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_center() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(10.0, 20.0)).sum::<f64>() / n as f64;
        assert!((mean - 15.0).abs() < 0.1, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn pareto_tail_heavier_than_exponential() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let p_big = (0..n).filter(|_| r.pareto(1.0, 1.2) > 50.0).count();
        assert!(p_big > 100, "pareto tail too light: {p_big}");
    }
}
