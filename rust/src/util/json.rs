//! Minimal recursive-descent JSON parser.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to parse `artifacts/manifest.json`
//! and any config files, without the (unvendored) serde_json dependency.

use std::collections::BTreeMap;
use std::fmt;

use crate::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`).
    Number(f64),
    /// A string, with escapes resolved.
    String(String),
    /// An array of values.
    Array(Vec<Value>),
    /// An object; keys sorted (BTreeMap), so display order is stable.
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// The underlying map, or an error naming the actual type.
    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Ok(m),
            other => anyhow::bail!("expected object, got {other:?}"),
        }
    }

    /// The underlying array, or an error naming the actual type.
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(v) => Ok(v),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    /// The underlying string, or an error naming the actual type.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::String(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    /// The numeric value, or an error naming the actual type.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Number(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    /// The value as a non-negative integer index/count; errors on
    /// negative or fractional numbers.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    /// Member lookup that names the missing key in its error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Number(n) => write!(f, "{n}"),
            Value::String(s) => write!(f, "{:?}", s),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Object(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::String(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Object(map)),
                c => anyhow::bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Array(out)),
                c => anyhow::bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => anyhow::bail!("raw control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| anyhow::anyhow!("invalid utf-8 in string"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| anyhow::anyhow!("invalid number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let v = parse(
            r#"{"lr": {"file": "lr.hlo.txt",
                       "inputs": [{"shape": [1024, 256], "dtype": "float32"}],
                       "outputs": []}}"#,
        )
        .unwrap();
        let e = v.get("lr").unwrap();
        assert_eq!(e.get("file").unwrap().as_str().unwrap(), "lr.hlo.txt");
        let shape = e.get("inputs").unwrap().as_array().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![1024, 256]);
    }

    #[test]
    fn parses_scalars_and_arrays() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Number(-1250.0));
        assert_eq!(
            parse(r#"[1, "a", [null]]"#).unwrap(),
            Value::Array(vec![
                Value::Number(1.0),
                Value::String("a".into()),
                Value::Array(vec![Value::Null]),
            ])
        );
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\ A""#).unwrap(),
            Value::String("a\n\t\"\\ A".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"b":[1,2],"a":{"x":true}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse(r#""héllo — ok""#).unwrap(), Value::String("héllo — ok".into()));
    }
}
