//! Std-only utility layer.
//!
//! The build environment is offline with only the `xla` crate closure
//! vendored, so the usual ecosystem crates are replaced by small,
//! purpose-built modules:
//!
//! - [`json`] — minimal JSON parser (for `artifacts/manifest.json`).
//! - [`rng`] — splitmix64/xoshiro256++ PRNG + distributions (replaces
//!   `rand`/`rand_distr` for trace generation and randomized tests).
//! - [`bench`] — measurement harness used by the `harness = false`
//!   benches (replaces `criterion`).
//! - [`quickcheck`] — randomized property-test driver (replaces
//!   `proptest`) used by `rust/tests/proptests.rs`.
//! - [`stats`] — mean/percentile/histogram helpers shared by metrics,
//!   profiling and the benches.
//! - [`cast`] — checked integer-narrowing helpers backing the C1 lint
//!   rule on the coordinator/metrics hot path.

pub mod bench;
pub mod cast;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod tmpdir;
