//! Network cost models: data path (TCP vs RDMA) and control path
//! (connection establishment variants of §5.2.2 / §9.4-9.5).
//!
//! The paper runs on 100 Gbps ConnectX-5; we model transfers as
//! `latency + bytes / bandwidth` with per-stack constants, plus the
//! data-path optimizations Zenix applies (request batching, local
//! caching of fetched data, zero-copy RDMA).

pub mod control;
pub mod datapath;

pub use control::{ControlPath, ControlPlane};
pub use datapath::{NetKind, NetModel};
