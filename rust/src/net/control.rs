//! Control-path model: how two components establish a connection.
//!
//! The paper's Fig 23 compares five variants; §9.4 details the
//! scheduler-assisted metadata exchange that replaces overlay networks:
//! both endpoints already hold a connection to their rack scheduler, the
//! scheduler knows both placements, so it routes the QP metadata and the
//! endpoints connect directly — and the exchange starts while user code
//! is still loading, hiding it entirely.

use crate::cluster::clock::Millis;
use crate::cluster::startup::StartupModel;

use super::datapath::NetKind;

/// Connection-establishment strategy (Fig 23 bars).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlPath {
    /// No direct channel: all traffic relayed through the platform
    /// (vanilla OpenWhisk bar 1).
    Relay,
    /// Overlay network for direct component-to-component channels
    /// (bar 2; ~40% of startup in the paper's measurement).
    Overlay,
    /// Zenix network-virtualization module, synchronous setup (bar 4).
    NetVirt,
    /// NetVirt + asynchronous exchange hidden behind user-code load
    /// (bar 5, the full Zenix path).
    NetVirtAsync,
}

/// Computes control-plane setup latency and per-connection state.
#[derive(Debug, Clone, Copy)]
pub struct ControlPlane {
    /// Startup-latency constants (overlay/netvirt attach, QP/TCP
    /// handshakes, user-code load) the setup costs draw from.
    pub startup: StartupModel,
    /// Scheduler message RTT for the metadata exchange (executor ->
    /// scheduler -> peer executor, §9.4).
    pub sched_msg_ms: Millis,
}

impl Default for ControlPlane {
    fn default() -> Self {
        Self { startup: StartupModel::default(), sched_msg_ms: 0.15 }
    }
}

impl ControlPlane {
    /// One-time environment cost of the chosen control path (charged at
    /// container start, e.g. overlay attach).
    pub fn env_setup(&self, path: ControlPath) -> Millis {
        match path {
            ControlPath::Relay => 0.0,
            ControlPath::Overlay => self.startup.overlay_setup,
            ControlPath::NetVirt | ControlPath::NetVirtAsync => self.startup.netvirt_setup,
        }
    }

    /// Per-connection establishment cost on the critical path.
    ///
    /// QP reuse (§9.4): a second physical memory component on a server we
    /// already talk to shares the existing QP — pass `reuse = true`.
    pub fn conn_setup(&self, path: ControlPath, kind: NetKind, reuse: bool) -> Millis {
        if reuse {
            return 0.0;
        }
        let raw = match kind {
            NetKind::Rdma => self.startup.qp_setup,
            NetKind::Tcp => self.startup.tcp_setup,
        };
        match path {
            // Relay: no direct channel is ever built; each access pays the
            // relay penalty on the data path instead (see data-path
            // callers); setup itself is free.
            ControlPath::Relay => 0.0,
            // Overlay must first discover the peer through the overlay
            // fabric, then connect.
            ControlPath::Overlay => 2.0 * self.sched_msg_ms + raw,
            // NetVirt: scheduler pushes the peer location at init; only
            // the exchange + handshake remain.
            ControlPath::NetVirt => 2.0 * self.sched_msg_ms + raw,
            // Async: exchange + handshake run during user-code load.
            ControlPath::NetVirtAsync => {
                let total = 2.0 * self.sched_msg_ms + raw;
                (total - self.startup.user_code_load).max(0.0)
            }
        }
    }

    /// Data-path relay multiplier: Relay sends every message through the
    /// platform (2 hops + copy); direct paths don't.
    pub fn relay_factor(&self, path: ControlPath) -> f64 {
        match path {
            ControlPath::Relay => 2.6,
            _ => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig23_ordering() {
        // Total first-communication latency per variant (env + conn),
        // matching Fig 23's qualitative ordering:
        //   overlay worst; netvirt better; async best (hidden).
        let cp = ControlPlane::default();
        let overlay =
            cp.env_setup(ControlPath::Overlay) + cp.conn_setup(ControlPath::Overlay, NetKind::Tcp, false);
        let netvirt =
            cp.env_setup(ControlPath::NetVirt) + cp.conn_setup(ControlPath::NetVirt, NetKind::Rdma, false);
        let asynchronous = cp.env_setup(ControlPath::NetVirtAsync)
            + cp.conn_setup(ControlPath::NetVirtAsync, NetKind::Rdma, false);
        assert!(netvirt < overlay);
        assert!(asynchronous < netvirt);
        assert_eq!(asynchronous, cp.startup.netvirt_setup); // conn fully hidden
    }

    #[test]
    fn qp_reuse_is_free() {
        let cp = ControlPlane::default();
        assert_eq!(cp.conn_setup(ControlPath::NetVirt, NetKind::Rdma, true), 0.0);
    }

    #[test]
    fn relay_penalizes_datapath_not_setup() {
        let cp = ControlPlane::default();
        assert_eq!(cp.conn_setup(ControlPath::Relay, NetKind::Tcp, false), 0.0);
        assert!(cp.relay_factor(ControlPath::Relay) > 2.0);
        assert_eq!(cp.relay_factor(ControlPath::NetVirt), 1.0);
    }
}
